//! Quickstart: decompose a small synthetic sparse tensor with CP-ALS.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Walks the minimal public-API path: generate a tensor, configure ALS,
//! run with the host backend, inspect fit and factors.

use ptmc::cpd::{cp_als, AlsConfig, NativeBackend};
use ptmc::tensor::synth::low_rank;

fn main() {
    // 1. A small tensor with genuine rank-4 structure plus noise, so the
    //    decomposition has something to recover.
    let mut tensor = low_rank(&[40, 32, 25], 4, 0.05, 7);
    println!(
        "tensor: dims {:?}, nnz {}, density {:.2e}",
        tensor.dims(),
        tensor.nnz(),
        tensor.density()
    );

    // 2. CP-ALS, rank 4 (matching the planted structure).
    let cfg = AlsConfig {
        rank: 4,
        max_iters: 12,
        tol: 1e-6,
        ..Default::default()
    };
    let model = cp_als(&mut tensor, &cfg, &mut NativeBackend);
    assert!(
        model.final_fit() > 0.9,
        "rank-4 structure should be recovered, got fit {}",
        model.final_fit()
    );

    // 3. Inspect the result.
    println!("ran {} iterations", model.iters);
    for (i, fit) in model.fit_history.iter().enumerate() {
        println!("  iter {:>2}: fit {fit:.5}", i + 1);
    }
    println!("lambda: {:?}", &model.lambda);
    println!(
        "factor shapes: {:?}",
        model
            .factors
            .iter()
            .map(|f| (f.rows(), f.cols()))
            .collect::<Vec<_>>()
    );

    // 4. Point predictions from the factorization.
    let coords = tensor.coords_of(0);
    println!(
        "X{coords:?} = {} ~ {}",
        tensor.values()[0],
        model.predict(&coords)
    );
}
