//! Memory-controller anatomy demo (paper §4/§5): drive each of the
//! paper's access patterns through each transfer type and show why the
//! pattern/engine pairing matters — streams through DMA, random factor
//! rows through the cache, and what goes wrong when they are mismatched.
//!
//! ```bash
//! cargo run --release --offline --example controller_sim
//! ```

use ptmc::bench::{fmt_cycles, Table};
use ptmc::controller::{Access, ControllerConfig, MemoryController};
use ptmc::testkit::Rng;

const TOTAL_BYTES: usize = 4 << 20; // 4 MiB of traffic per pattern
const ROW_BYTES: usize = 64; // one rank-16 factor row

fn fresh() -> MemoryController {
    MemoryController::new(ControllerConfig::default_for(16))
}

/// Sequential tensor stream addresses.
fn stream_trace(via_cache: bool) -> Vec<Access> {
    (0..TOTAL_BYTES / 4096)
        .map(|i| {
            let addr = (i * 4096) as u64;
            if via_cache {
                Access::Cached { addr, bytes: 4096 }
            } else {
                Access::Stream { addr, bytes: 4096 }
            }
        })
        .collect()
}

/// Zipf-random factor-row addresses over a 64 MiB matrix region.
fn random_rows_trace(kind: &str) -> Vec<Access> {
    let mut rng = Rng::new(3);
    (0..TOTAL_BYTES / ROW_BYTES)
        .map(|_| {
            let row = rng.zipf(1 << 20, 1.2);
            let addr = (8u64 << 30) + row * ROW_BYTES as u64;
            match kind {
                "cached" => Access::Cached {
                    addr,
                    bytes: ROW_BYTES,
                },
                "element" => Access::Element {
                    addr,
                    bytes: ROW_BYTES,
                },
                _ => Access::Stream {
                    addr,
                    bytes: ROW_BYTES,
                },
            }
        })
        .collect()
}

fn main() {
    let mut table = Table::new(&["access pattern", "served by", "cycles", "bytes/cycle"]);
    let mut run = |pattern: &str, served: &str, trace: Vec<Access>| {
        let mut ctl = fresh();
        let cycles = ctl.replay(&trace);
        let bytes: usize = trace.iter().map(|a| a.bytes()).sum();
        table.row(&[
            pattern.to_string(),
            served.to_string(),
            fmt_cycles(cycles),
            format!("{:.2}", bytes as f64 / cycles as f64),
        ]);
        ctl
    };

    // §4 pattern 1: tensor elements — streaming.
    run("tensor stream", "DMA stream (paper)", stream_trace(false));
    run("tensor stream", "cache (mismatched)", stream_trace(true));

    // §4 pattern 3: factor rows — random with locality.
    let ctl = run("factor rows (zipf)", "cache (paper)", random_rows_trace("cached"));
    let hits = ctl.cache_stats().hit_rate();
    run(
        "factor rows (zipf)",
        "DMA element (mismatched)",
        random_rows_trace("element"),
    );

    table.emit("transfer-type / access-pattern pairing (paper §4)", None);
    println!("cache hit rate on zipf rows: {:.1}%", 100.0 * hits);
    println!(
        "\nReading: bulk streams want the DMA engine; random-but-skewed\n\
         factor rows want the cache. Mismatching either direction costs\n\
         multiples of the right pairing — the §5 controller exists to\n\
         route each pattern to the right engine."
    );
}
