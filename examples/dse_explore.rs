//! Design-space exploration across application domains (paper §5.3):
//! measure one tensor profile per FROSTT-like domain, run the
//! module-by-module search per domain, and show that different domains
//! prefer different memory-controller configurations — the paper's
//! motivation for a *programmable* controller.
//!
//! ```bash
//! cargo run --release --offline --example dse_explore
//! ```

use ptmc::bench::Table;
use ptmc::controller::ControllerConfig;
use ptmc::dse::{explore, Evaluator, Grids};
use ptmc::fpga::Device;
use ptmc::pms::TensorProfile;
use ptmc::tensor::synth::{frostt_suite, generate};

fn main() {
    let dev = Device::alveo_u250();
    let mut table = Table::new(&[
        "domain", "modes", "nnz", "cache", "assoc", "dma", "pointers", "est-cycles", "bram", "uram",
    ]);

    for (name, cfg) in frostt_suite(11) {
        let tensor = generate(&cfg);
        let profile = TensorProfile::measure(&tensor);
        let base = ControllerConfig::default_for(tensor.record_bytes());
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let ex = explore(&base, &Grids::default(), &dev, &eval);
        let b = &ex.best;
        table.row(&[
            name.to_string(),
            tensor.n_modes().to_string(),
            tensor.nnz().to_string(),
            format!(
                "{}x{}B",
                b.cfg.cache.num_lines, b.cfg.cache.line_bytes
            ),
            b.cfg.cache.assoc.to_string(),
            format!(
                "{}x{}x{}B",
                b.cfg.dma.num_dmas, b.cfg.dma.buffers_per_dma, b.cfg.dma.buffer_bytes
            ),
            b.cfg.remapper.max_pointers.to_string(),
            format!("{:.3e}", b.cycles),
            b.bram36.to_string(),
            b.uram.to_string(),
        ]);
        println!(
            "{name}: {} feasible / {} rejected configs",
            ex.visited.len(),
            ex.rejected
        );
    }

    table.emit(
        "best memory-controller configuration per domain (PMS, U250)",
        None,
    );
    println!(
        "The paper's point: no single configuration is optimal across\n\
         domains — the controller must be programmable per synthesis."
    );
}
