//! End-to-end driver (experiment E8): full CP-ALS on a FROSTT-scale-like
//! synthetic tensor with the MTTKRP hot path running through **all three
//! layers** — Rust coordinator -> AOT-compiled JAX graph -> Pallas block
//! kernel — via PJRT, plus the same decomposition through the
//! memory-controller cycle simulator for the paper's FPGA-time view.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example cpd_decompose
//! ```
//!
//! Output (fit curve, coordinator metrics, simulated cycles) is recorded
//! in EXPERIMENTS.md §E8.

use ptmc::controller::{ControllerConfig, MemLayout, MemoryController};
use ptmc::coordinator::PjrtCoordinator;
use ptmc::cpd::{cp_als, AlsConfig, MttkrpBackend, NativeBackend, SimBackend};
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

fn main() {
    // A scaled NELL-like workload (Table 2 ranges / ~1000).
    let make_tensor = || {
        generate(&SynthConfig {
            dims: vec![3_900, 2_000, 1_200],
            nnz: 144_000,
            profile: Profile::Zipf { alpha_milli: 1300 },
            seed: 2022,
        })
    };
    let cfg = AlsConfig {
        rank: 16,
        max_iters: 10,
        tol: 1e-6,
        ..Default::default()
    };

    // ---- Path 1: PJRT (Rust coordinator -> JAX/Pallas artifact) -------
    println!("=== PJRT three-layer path ===");
    let mut t = make_tensor();
    println!(
        "tensor: dims {:?}, nnz {}, {} bytes",
        t.dims(),
        t.nnz(),
        t.bytes()
    );
    let mut pjrt = match PjrtCoordinator::open_default() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("cannot open artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let t0 = std::time::Instant::now();
    let model = cp_als(&mut t, &cfg, &mut pjrt);
    let wall = t0.elapsed();
    for (i, fit) in model.fit_history.iter().enumerate() {
        println!("  iter {:>2}: fit {fit:.6}", i + 1);
    }
    println!("final fit: {:.6} after {} iters", model.final_fit(), model.iters);
    println!("coordinator: {}", pjrt.metrics().summary());
    println!("wall time (pjrt): {wall:?}");

    // ---- Path 2: host-native reference (same seeds => same numbers) ---
    println!("\n=== native reference ===");
    let mut t2 = make_tensor();
    let t1 = std::time::Instant::now();
    let native = cp_als(&mut t2, &cfg, &mut NativeBackend);
    println!(
        "final fit: {:.6} (delta vs pjrt: {:.2e}) wall {:?}",
        native.final_fit(),
        (native.final_fit() - model.final_fit()).abs(),
        t1.elapsed()
    );

    // ---- Path 3: memory-controller cycle simulation (FPGA view) -------
    println!("\n=== simulated programmable memory controller ===");
    let mut t3 = make_tensor();
    let ctl_cfg = ControllerConfig::default_for(t3.record_bytes());
    let layout = MemLayout::plan(t3.dims(), t3.nnz(), t3.record_bytes(), cfg.rank);
    let mut sim = SimBackend::new(MemoryController::new(ctl_cfg), layout);
    let sim_model = cp_als(&mut t3, &cfg, &mut sim);
    println!(
        "final fit: {:.6}, simulated memory cycles: {}",
        sim_model.final_fit(),
        sim.cycles()
    );
    let cs = sim.ctl.cache_stats();
    println!(
        "cache hit rate {:.1}%, dram row-hit rate {:.1}%",
        100.0 * cs.hit_rate(),
        100.0 * sim.ctl.dram_stats().hit_rate()
    );
    // At 300 MHz controller clock:
    let secs = sim.cycles() as f64 / 300.0e6;
    println!("≈ {secs:.3} s on a 300 MHz FPGA memory controller");

    assert!(
        (native.final_fit() - model.final_fit()).abs() < 1e-3,
        "three-layer path must agree with the host reference"
    );
    println!("\nE8 OK: all layers compose and agree");
}
