"""Pure-jnp correctness oracles for the blocked spMTTKRP kernels.

These are the ground truth the Pallas kernels (L1) and the assembled JAX
graphs (L2) are tested against.  They mirror the paper's Algorithm 2
(COO spMTTKRP) and its blocked formulation used by the Rust coordinator:
the coordinator (playing the paper's memory-controller role) gathers the
factor-matrix rows for a block of non-zeros and hands the kernel dense,
fixed-shape operands.
"""

from __future__ import annotations

import jax.numpy as jnp


def mttkrp_block_ref(seg_ids, vals, *factor_rows, num_segments):
    """Blocked spMTTKRP partial-output oracle, via explicit segment sum.

    Args:
      seg_ids: int32[BLK] — output-row slot (0..num_segments-1) of each nnz.
        Slots are block-local: the Rust coordinator maps output-mode
        coordinates to slots after the tensor remap groups equal
        coordinates together (paper Alg. 5).
      vals: f32[BLK] — non-zero values.
      *factor_rows: (N-1) arrays f32[BLK, R] — gathered input factor rows
        (B[j,:], C[k,:], ... in paper Alg. 2 line 6).
      num_segments: S — number of output-row slots in the block.

    Returns:
      f32[S, R] — partial rows of the output factor matrix.
    """
    prod = vals[:, None]
    for rows in factor_rows:
        prod = prod * rows
    out = jnp.zeros((num_segments, prod.shape[1]), dtype=prod.dtype)
    return out.at[seg_ids].add(prod)


def onehot_from_segments(seg_ids, num_segments, dtype=jnp.float32):
    """One-hot scatter matrix Seg[S, BLK]: Seg[s, z] = 1 iff seg_ids[z]==s.

    This is the TPU adaptation of the paper's FPGA scatter-accumulate: the
    segment reduction becomes a matmul on the MXU (DESIGN.md §3).
    """
    blk = seg_ids.shape[0]
    return (
        (seg_ids[None, :] == jnp.arange(num_segments)[:, None])
        .astype(dtype)
        .reshape(num_segments, blk)
    )


def mttkrp_block_onehot_ref(seg_onehot, vals, *factor_rows):
    """Same as :func:`mttkrp_block_ref` but in the one-hot-matmul form the
    Pallas kernel implements: out = Seg @ (vals[:,None] * prod(rows))."""
    prod = vals[:, None]
    for rows in factor_rows:
        prod = prod * rows
    return seg_onehot @ prod


def mttkrp_coo_ref(indices, vals, factors, mode):
    """Full-tensor COO spMTTKRP oracle (paper Algorithm 2, any mode).

    Args:
      indices: int32[nnz, N] coordinate list.
      vals: f32[nnz].
      factors: list of N dense factor matrices, factors[m]: f32[I_m, R].
      mode: output mode (the paper's Alg. 2 is mode 0).

    Returns:
      f32[I_mode, R] — the un-normalized MTTKRP output \\tilde{A}.
    """
    nnz, n_modes = indices.shape
    r = factors[0].shape[1]
    prod = vals[:, None] * jnp.ones((nnz, r), dtype=vals.dtype)
    for m in range(n_modes):
        if m == mode:
            continue
        prod = prod * factors[m][indices[:, m]]
    out = jnp.zeros((factors[mode].shape[0], r), dtype=vals.dtype)
    return out.at[indices[:, mode]].add(prod)


def als_row_solve_ref(m_tile, hinv):
    """Oracle for the ALS row-solve tile: rows of the MTTKRP output times
    the (pre-inverted) Hadamard-of-Grams matrix, M @ Hinv (R x R)."""
    return m_tile @ hinv
