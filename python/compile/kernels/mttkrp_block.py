"""L1 — Pallas kernels for the blocked spMTTKRP hot spot.

Hardware adaptation (DESIGN.md §3).  The paper's FPGA compute unit is an
element-wise MAC pipeline fed dense operands by the memory controller; its
scatter-accumulate into the output factor matrix relies on the tensor
remap placing equal output coordinates consecutively.  On TPU we keep the
same contract — the (Rust) coordinator gathers factor rows and assigns
block-local output slots — and re-think the scatter as a **one-hot segment
matmul on the MXU**:

    out[S, R] = Seg[S, BLK] @ (vals[:, None] * Brows * Crows [* Drows])

The kernel tiles the BLK (non-zero) dimension through VMEM with a grid,
accumulating into a single (S, R) output tile that stays resident — the
VMEM analogue of the paper's on-chip output row buffer.  All Pallas calls
use ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is the correctness path and real-TPU
numbers are estimated analytically (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile along the non-zero (block) dimension.  8 sublanes x f32 is
# the TPU-native tiling; 128 keeps the Seg tile (S x TB) MXU-shaped.
DEFAULT_TB = 128


def _mttkrp_kernel(seg_ref, vals_ref, *rest):
    """Grid step: multiply-accumulate one TB-slice of non-zeros.

    seg_ref:  (S, TB) one-hot scatter tile
    vals_ref: (TB,)   non-zero values
    rest:     (N-1) refs of (TB, R) gathered factor rows, then o_ref (S, R)
    """
    *row_refs, o_ref = rest

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = vals_ref[...][:, None]
    for ref in row_refs:
        prod = prod * ref[...]
    # MXU-shaped scatter: Seg (S, TB) @ prod (TB, R) -> (S, R).
    o_ref[...] += jnp.dot(seg_ref[...], prod, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def mttkrp_block(seg_onehot, vals, *factor_rows, tb=DEFAULT_TB, interpret=True):
    """Blocked spMTTKRP partial product via the Pallas kernel.

    Args:
      seg_onehot: f32[S, BLK] one-hot output-slot matrix
        (:func:`ref.onehot_from_segments`).
      vals: f32[BLK] non-zero values.
      *factor_rows: (N-1) arrays f32[BLK, R] of gathered input factor rows.
      tb: tile size along BLK; must divide BLK.
      interpret: keep True off-TPU (see module docstring).

    Returns:
      f32[S, R] partial output-factor rows for this block.
    """
    s, blk = seg_onehot.shape
    r = factor_rows[0].shape[1]
    if blk % tb != 0:
        raise ValueError(f"BLK={blk} not divisible by tile tb={tb}")
    n_in = len(factor_rows)

    grid = (blk // tb,)
    in_specs = [
        # Seg: walk the BLK axis, keep all S rows resident.
        pl.BlockSpec((s, tb), lambda i: (0, i)),
        # vals: walk the BLK axis.
        pl.BlockSpec((tb,), lambda i: (i,)),
    ] + [
        # factor rows: walk the BLK axis, full rank width.
        pl.BlockSpec((tb, r), lambda i: (i, 0))
        for _ in range(n_in)
    ]
    out_spec = pl.BlockSpec((s, r), lambda i: (0, 0))

    return pl.pallas_call(
        _mttkrp_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((s, r), jnp.float32),
        interpret=interpret,
    )(seg_onehot, vals, *factor_rows)


def _row_solve_kernel(m_ref, hinv_ref, o_ref):
    """One tile of the ALS row-solve: O = M @ Hinv (Hinv is R x R)."""
    o_ref[...] = jnp.dot(m_ref[...], hinv_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tm", "interpret"))
def als_row_solve(m_block, hinv, tm=DEFAULT_TB, interpret=True):
    """ALS factor update tile: rows of the MTTKRP output times the inverted
    Hadamard-of-Grams matrix (CP-ALS line 4-6 right-multiplication).

    Args:
      m_block: f32[TILE, R] MTTKRP output rows.
      hinv: f32[R, R] pre-inverted Hadamard product of Gram matrices.
      tm: tile size along TILE; must divide TILE.

    Returns:
      f32[TILE, R] updated factor rows (un-normalized).
    """
    tile, r = m_block.shape
    if tile % tm != 0:
        raise ValueError(f"TILE={tile} not divisible by tile tm={tm}")
    grid = (tile // tm,)
    return pl.pallas_call(
        _row_solve_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, r), lambda i: (i, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tile, r), jnp.float32),
        interpret=interpret,
    )(m_block, hinv)


def vmem_bytes(s, blk, r, n_in, tb=DEFAULT_TB):
    """Estimated VMEM residency of one grid step (DESIGN.md §8): the Seg
    tile, vals tile, (N-1) factor-row tiles, and the resident output."""
    f32 = 4
    return f32 * (s * tb + tb + n_in * tb * r + s * r)


def mxu_macs(s, blk, r, n_in):
    """MAC count per block: element-wise products + the scatter matmul."""
    return blk * r * n_in + s * blk * r
