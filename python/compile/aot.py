"""L2->artifact AOT pipeline: lower the JAX graphs to HLO **text**.

HLO text (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per model variant plus ``manifest.txt`` with
``key=value`` lines the Rust runtime parses to pick an executable.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (blk, s, r) variants for the 3-mode one-hot block kernel.  blk must be a
# multiple of the kernel tile (128); s is the output-slot budget the Rust
# coordinator packs blocks against.
MTTKRP3_ONEHOT = [(256, 64, 8), (256, 64, 16), (256, 64, 32), (512, 128, 16)]
MTTKRP3_SEGIDS = [(256, 64, 16), (512, 128, 16)]
# D2 ablation: jnp segment-sum form (also the fastest on CPU backends).
MTTKRP3_REFSEG = [(256, 64, 16), (512, 128, 16)]
# One-hot matmul without Pallas: isolates interpret-mode overhead.
MTTKRP3_ONEHOT_JNP = [(256, 64, 16)]
MTTKRP4_ONEHOT = [(256, 64, 16)]
SOLVE_TILES = [(256, 8), (256, 16), (256, 32)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _emit(out_dir, name, fn, args, manifest, **meta):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    fields = " ".join(f"{k}={v}" for k, v in meta.items())
    manifest.append(f"name={name} file={name}.hlo.txt {fields}")
    print(f"  {name}: {len(text)} chars")


def build_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: list[str] = []

    for blk, s, r in MTTKRP3_ONEHOT:
        _emit(
            out_dir,
            f"mttkrp3_onehot_b{blk}_s{s}_r{r}",
            model.block_mttkrp_fn(2),
            model.example_args(2, blk, s, r),
            manifest,
            kind="mttkrp",
            modes=3,
            seg="onehot",
            blk=blk,
            s=s,
            r=r,
        )
    for blk, s, r in MTTKRP3_SEGIDS:
        _emit(
            out_dir,
            f"mttkrp3_segids_b{blk}_s{s}_r{r}",
            model.block_mttkrp_from_segments_fn(2, s),
            model.example_args(2, blk, s, r, from_segments=True),
            manifest,
            kind="mttkrp",
            modes=3,
            seg="segids",
            blk=blk,
            s=s,
            r=r,
        )
    for blk, s, r in MTTKRP3_REFSEG:
        _emit(
            out_dir,
            f"mttkrp3_refseg_b{blk}_s{s}_r{r}",
            model.block_mttkrp_ref_fn(2, s),
            model.example_args(2, blk, s, r, from_segments=True),
            manifest,
            kind="mttkrp",
            modes=3,
            seg="refseg",
            blk=blk,
            s=s,
            r=r,
        )
    for blk, s, r in MTTKRP3_ONEHOT_JNP:
        _emit(
            out_dir,
            f"mttkrp3_onehotjnp_b{blk}_s{s}_r{r}",
            model.block_mttkrp_onehot_jnp_fn(2),
            model.example_args(2, blk, s, r),
            manifest,
            kind="mttkrp",
            modes=3,
            seg="onehot_jnp",
            blk=blk,
            s=s,
            r=r,
        )
    for blk, s, r in MTTKRP4_ONEHOT:
        _emit(
            out_dir,
            f"mttkrp4_onehot_b{blk}_s{s}_r{r}",
            model.block_mttkrp_fn(3),
            model.example_args(3, blk, s, r),
            manifest,
            kind="mttkrp",
            modes=4,
            seg="onehot",
            blk=blk,
            s=s,
            r=r,
        )
    for tile, r in SOLVE_TILES:
        _emit(
            out_dir,
            f"als_rowsolve_t{tile}_r{r}",
            model.als_row_solve_fn(),
            model.example_args_solve(tile, r),
            manifest,
            kind="rowsolve",
            tile=tile,
            r=r,
        )

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts + manifest to {out_dir}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None, help="compat: ignored if --out-dir set")
    args = p.parse_args()
    out_dir = args.out_dir
    if args.out and not args.out_dir:
        out_dir = os.path.dirname(args.out)
    build_all(out_dir)


if __name__ == "__main__":
    main()
