"""L2 — JAX compute graphs that the Rust coordinator executes via PJRT.

Each graph is a fixed-shape function over the dense operands the
coordinator (the paper's memory-controller analogue) has already gathered:

  * ``block_mttkrp_fn``  — one spMTTKRP block: one-hot scatter matmul over
    the element-wise product of gathered factor rows (calls the L1 Pallas
    kernel so it lowers into the same HLO).
  * ``block_mttkrp_from_segments_fn`` — same, but takes raw int32 segment
    ids and builds the one-hot inside the graph (saves S*BLK*4 bytes of
    host->device traffic per block; benched as D2 in DESIGN.md §7).
  * ``als_row_solve_fn`` — a tile of the CP-ALS factor update
    M @ Hinv.

These are lowered once by ``aot.py`` to HLO *text* artifacts; Python never
runs on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import mttkrp_block as kernels
from .kernels import ref


def block_mttkrp_fn(n_inputs):
    """Returns fn(seg_onehot[S,BLK], vals[BLK], rows_0..rows_{n-1}[BLK,R])
    -> (out[S,R],) for a tensor with ``n_inputs``+1 modes."""

    def fn(seg_onehot, vals, *rows):
        assert len(rows) == n_inputs
        return (kernels.mttkrp_block(seg_onehot, vals, *rows),)

    return fn


def block_mttkrp_from_segments_fn(n_inputs, num_segments):
    """Like :func:`block_mttkrp_fn` but takes int32 seg ids; the one-hot is
    materialized inside the graph (XLA fuses it into the matmul operand)."""

    def fn(seg_ids, vals, *rows):
        assert len(rows) == n_inputs
        onehot = ref.onehot_from_segments(seg_ids, num_segments, dtype=vals.dtype)
        return (kernels.mttkrp_block(onehot, vals, *rows),)

    return fn


def als_row_solve_fn():
    """Returns fn(m_tile[TILE,R], hinv[R,R]) -> (out[TILE,R],)."""

    def fn(m_tile, hinv):
        return (kernels.als_row_solve(m_tile, hinv),)

    return fn


def block_mttkrp_onehot_jnp_fn(n_inputs):
    """One-hot matmul form *without* the Pallas kernel (pure jnp): same
    math and shapes as :func:`block_mttkrp_fn`.  Used to isolate the
    interpret-mode Pallas overhead on CPU backends (§Perf L1)."""

    def fn(seg_onehot, vals, *rows):
        assert len(rows) == n_inputs
        return (ref.mttkrp_block_onehot_ref(seg_onehot, vals, *rows),)

    return fn


def block_mttkrp_ref_fn(n_inputs, num_segments):
    """Pure-jnp segment-sum variant (no Pallas, no one-hot matmul) — the D2
    ablation baseline; also lowered to an artifact so the Rust bench can
    compare both forms end-to-end."""

    def fn(seg_ids, vals, *rows):
        assert len(rows) == n_inputs
        return (
            ref.mttkrp_block_ref(seg_ids, vals, *rows, num_segments=num_segments),
        )

    return fn


def example_args(n_inputs, blk, s, r, from_segments=False):
    """ShapeDtypeStructs for lowering a block-MTTKRP variant."""
    import jax

    if from_segments:
        seg = jax.ShapeDtypeStruct((blk,), jnp.int32)
    else:
        seg = jax.ShapeDtypeStruct((s, blk), jnp.float32)
    vals = jax.ShapeDtypeStruct((blk,), jnp.float32)
    rows = [jax.ShapeDtypeStruct((blk, r), jnp.float32) for _ in range(n_inputs)]
    return (seg, vals, *rows)


def example_args_solve(tile, r):
    import jax

    return (
        jax.ShapeDtypeStruct((tile, r), jnp.float32),
        jax.ShapeDtypeStruct((r, r), jnp.float32),
    )
