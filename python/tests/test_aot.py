"""AOT pipeline sanity: every variant lowers to parseable HLO text with
the right parameter/result shapes, and the manifest indexes them all."""

import os
import re

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_all(out)
    return out


def test_manifest_lists_every_artifact(artifacts):
    with open(os.path.join(artifacts, "manifest.txt")) as f:
        lines = [l for l in f.read().splitlines() if l.strip()]
    n_expected = (
        len(aot.MTTKRP3_ONEHOT)
        + len(aot.MTTKRP3_SEGIDS)
        + len(aot.MTTKRP3_REFSEG)
        + len(aot.MTTKRP3_ONEHOT_JNP)
        + len(aot.MTTKRP4_ONEHOT)
        + len(aot.SOLVE_TILES)
    )
    assert len(lines) == n_expected
    for line in lines:
        fields = dict(kv.split("=", 1) for kv in line.split())
        assert {"name", "file", "kind"} <= set(fields)
        path = os.path.join(artifacts, fields["file"])
        assert os.path.exists(path), f"missing artifact {path}"


def test_hlo_text_is_hlo_not_proto(artifacts):
    for fn in os.listdir(artifacts):
        if not fn.endswith(".hlo.txt"):
            continue
        with open(os.path.join(artifacts, fn)) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), f"{fn} is not HLO text: {head[:40]!r}"


def test_mttkrp_artifact_has_expected_shapes(artifacts):
    blk, s, r = aot.MTTKRP3_ONEHOT[1]  # (256, 64, 16)
    name = f"mttkrp3_onehot_b{blk}_s{s}_r{r}.hlo.txt"
    with open(os.path.join(artifacts, name)) as f:
        text = f.read()
    params = [l for l in text.splitlines() if re.search(r"= f32.* parameter\(", l)]
    assert any(f"f32[{s},{blk}]" in l for l in params)  # one-hot
    assert any(f"f32[{blk}]{{0}}" in l for l in params)  # vals
    assert sum(f"f32[{blk},{r}]" in l for l in params) >= 2  # gathered rows
    assert f"f32[{s},{r}]" in text  # result


def test_rowsolve_artifact_has_expected_shapes(artifacts):
    tile, r = aot.SOLVE_TILES[1]
    name = f"als_rowsolve_t{tile}_r{r}.hlo.txt"
    with open(os.path.join(artifacts, name)) as f:
        text = f.read()
    params = [l for l in text.splitlines() if re.search(r"= f32.* parameter\(", l)]
    assert any(f"f32[{tile},{r}]" in l for l in params)
    assert any(f"f32[{r},{r}]" in l for l in params)


def test_lowering_is_deterministic():
    """Same variant lowered twice gives identical text (Make caching and
    the Rust runtime's content-addressed executable cache rely on this)."""
    fn = model.block_mttkrp_fn(2)
    args = model.example_args(2, 256, 64, 16)
    import jax

    a = aot.to_hlo_text(jax.jit(fn).lower(*args))
    b = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert a == b
