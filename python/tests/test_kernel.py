"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes/contents; assert_allclose against ref.
This is the CORE correctness signal for the compute layer — everything
the Rust coordinator executes via PJRT is lowered from these kernels.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import mttkrp_block as kernels
from compile.kernels import ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def _block_case(rng, blk, s, r, n_in):
    seg_ids = jnp.asarray(rng.integers(0, s, size=blk), dtype=jnp.int32)
    vals = _rand(rng, blk)
    rows = [_rand(rng, blk, r) for _ in range(n_in)]
    return seg_ids, vals, rows


class TestMttkrpBlockKernel:
    @given(
        blk=st.sampled_from([128, 256, 512]),
        s=st.sampled_from([8, 32, 64, 128]),
        r=st.sampled_from([4, 8, 16, 32]),
        n_in=st.sampled_from([1, 2, 3, 4]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_segment_sum_oracle(self, blk, s, r, n_in, seed):
        rng = np.random.default_rng(seed)
        seg_ids, vals, rows = _block_case(rng, blk, s, r, n_in)
        onehot = ref.onehot_from_segments(seg_ids, s)
        got = kernels.mttkrp_block(onehot, vals, *rows)
        want = ref.mttkrp_block_ref(seg_ids, vals, *rows, num_segments=s)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @given(
        tb=st.sampled_from([64, 128, 256]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_tile_size_invariance(self, tb, seed):
        """Result must not depend on the VMEM tile split (tb)."""
        rng = np.random.default_rng(seed)
        seg_ids, vals, rows = _block_case(rng, 512, 64, 16, 2)
        onehot = ref.onehot_from_segments(seg_ids, 64)
        got = kernels.mttkrp_block(onehot, vals, *rows, tb=tb)
        want = ref.mttkrp_block_onehot_ref(onehot, vals, *rows)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_indivisible_tile(self):
        rng = np.random.default_rng(0)
        seg_ids, vals, rows = _block_case(rng, 192, 8, 4, 2)
        onehot = ref.onehot_from_segments(seg_ids, 8)
        with pytest.raises(ValueError, match="not divisible"):
            kernels.mttkrp_block(onehot, vals, *rows, tb=128)

    def test_zero_vals_give_zero_output(self):
        rng = np.random.default_rng(1)
        seg_ids, _, rows = _block_case(rng, 128, 16, 8, 2)
        onehot = ref.onehot_from_segments(seg_ids, 16)
        got = kernels.mttkrp_block(onehot, jnp.zeros(128), *rows)
        np.testing.assert_array_equal(np.asarray(got), 0.0)

    def test_single_segment_sums_everything(self):
        """All nnz mapped to slot 0 == plain weighted row-product sum."""
        rng = np.random.default_rng(2)
        blk, r = 128, 8
        vals = _rand(rng, blk)
        b, c = _rand(rng, blk, r), _rand(rng, blk, r)
        onehot = jnp.ones((1, blk), jnp.float32)
        got = kernels.mttkrp_block(onehot, vals, b, c)
        want = jnp.sum(vals[:, None] * b * c, axis=0, keepdims=True)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_padding_slots_stay_zero(self):
        """Slots with no nnz (coordinator pads short blocks) must be 0."""
        rng = np.random.default_rng(3)
        blk, s, r = 128, 32, 8
        # Only use slots 0..7.
        seg_ids = jnp.asarray(rng.integers(0, 8, size=blk), dtype=jnp.int32)
        vals = _rand(rng, blk)
        b, c = _rand(rng, blk, r), _rand(rng, blk, r)
        onehot = ref.onehot_from_segments(seg_ids, s)
        got = np.asarray(kernels.mttkrp_block(onehot, vals, b, c))
        np.testing.assert_array_equal(got[8:], 0.0)

    @given(seed=st.integers(0, 2**31 - 1))
    def test_linearity_in_vals(self, seed):
        """MTTKRP is linear in the tensor values (alg. 2 line 6)."""
        rng = np.random.default_rng(seed)
        seg_ids, vals, rows = _block_case(rng, 128, 16, 8, 2)
        onehot = ref.onehot_from_segments(seg_ids, 16)
        a = kernels.mttkrp_block(onehot, vals, *rows)
        b = kernels.mttkrp_block(onehot, 2.0 * vals, *rows)
        np.testing.assert_allclose(2.0 * np.asarray(a), b, rtol=1e-5, atol=1e-5)


class TestAlsRowSolveKernel:
    @given(
        tile=st.sampled_from([128, 256]),
        r=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_matmul_oracle(self, tile, r, seed):
        rng = np.random.default_rng(seed)
        m = _rand(rng, tile, r)
        hinv = _rand(rng, r, r)
        got = kernels.als_row_solve(m, hinv)
        np.testing.assert_allclose(
            got, ref.als_row_solve_ref(m, hinv), rtol=1e-5, atol=1e-5
        )

    def test_identity_hinv_is_noop(self):
        rng = np.random.default_rng(4)
        m = _rand(rng, 128, 16)
        got = kernels.als_row_solve(m, jnp.eye(16))
        np.testing.assert_allclose(got, m, rtol=1e-6, atol=1e-6)


class TestResourceEstimates:
    def test_vmem_fits_default_variants(self):
        """Every AOT variant must fit the 16 MiB TPU VMEM budget."""
        from compile import aot

        budget = 16 * 1024 * 1024
        for blk, s, r in aot.MTTKRP3_ONEHOT + aot.MTTKRP4_ONEHOT:
            n_in = 2 if (blk, s, r) in aot.MTTKRP3_ONEHOT else 3
            assert kernels.vmem_bytes(s, blk, r, n_in) < budget

    def test_mxu_macs_formula(self):
        # 2 inputs: blk*r elementwise MACs per input + s*blk*r scatter MACs
        assert kernels.mxu_macs(64, 256, 16, 2) == 256 * 16 * 2 + 64 * 256 * 16
