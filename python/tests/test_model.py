"""L2 correctness: assembled block graphs == full-COO oracle; the blocked
decomposition the Rust coordinator performs is replayed here in Python to
prove the contract (gather rows -> block kernel -> accumulate into output
rows) reconstructs the exact Algorithm-2 result."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from compile import model
from compile.kernels import ref


def _random_coo(rng, dims, nnz):
    idx = np.stack([rng.integers(0, d, size=nnz) for d in dims], axis=1)
    vals = rng.standard_normal(nnz).astype(np.float32)
    return jnp.asarray(idx, jnp.int32), jnp.asarray(vals)


def _random_factors(rng, dims, r):
    return [jnp.asarray(rng.standard_normal((d, r)), jnp.float32) for d in dims]


def _blocked_mttkrp(idx, vals, factors, mode, blk, s):
    """Replay the Rust coordinator's blocking: sort by output coordinate
    (the paper's remap), split into blocks of <= blk nnz with <= s distinct
    output coords, run the block graph, scatter partials into the output."""
    n_modes = idx.shape[1]
    r = factors[0].shape[1]
    order = np.argsort(np.asarray(idx[:, mode]), kind="stable")
    idx_s, vals_s = np.asarray(idx)[order], np.asarray(vals)[order]

    out = np.zeros((factors[mode].shape[0], r), np.float32)
    fn = model.block_mttkrp_fn(n_modes - 1)

    start = 0
    nnz = idx_s.shape[0]
    while start < nnz:
        # Greedy block: cap at blk nnz AND s distinct output coordinates.
        end, seen = start, []
        while end < nnz and end - start < blk:
            c = idx_s[end, mode]
            if (not seen or seen[-1] != c) and len(seen) >= s:
                break
            if not seen or seen[-1] != c:
                seen.append(c)
            end += 1
        n = end - start
        seg_ids = np.searchsorted(np.asarray(seen), idx_s[start:end, mode])
        # Pad to the fixed artifact shape with zero vals / slot 0.
        pad = blk - n
        seg_p = np.concatenate([seg_ids, np.zeros(pad, np.int32)]).astype(np.int32)
        vals_p = np.concatenate([vals_s[start:end], np.zeros(pad, np.float32)])
        rows = []
        for m in range(n_modes):
            if m == mode:
                continue
            g = np.asarray(factors[m])[idx_s[start:end, m]]
            rows.append(
                jnp.asarray(np.concatenate([g, np.zeros((pad, r), np.float32)]))
            )
        onehot = ref.onehot_from_segments(jnp.asarray(seg_p), s)
        (partial,) = fn(onehot, jnp.asarray(vals_p), *rows)
        out[np.asarray(seen)] += np.asarray(partial)[: len(seen)]
        start = end
    return out


class TestBlockedAssembly:
    @given(
        mode=st.integers(0, 2),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(deadline=None, max_examples=10, derandomize=True)
    def test_blocked_equals_full_coo_3mode(self, mode, seed):
        rng = np.random.default_rng(seed)
        dims = (37, 23, 41)
        idx, vals = _random_coo(rng, dims, 700)
        factors = _random_factors(rng, dims, 8)
        got = _blocked_mttkrp(idx, vals, factors, mode, blk=128, s=32)
        want = np.asarray(ref.mttkrp_coo_ref(idx, vals, factors, mode))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_blocked_equals_full_coo_4mode(self):
        rng = np.random.default_rng(7)
        dims = (19, 13, 17, 11)
        idx, vals = _random_coo(rng, dims, 500)
        factors = _random_factors(rng, dims, 8)
        got = _blocked_mttkrp(idx, vals, factors, 1, blk=128, s=32)
        want = np.asarray(ref.mttkrp_coo_ref(idx, vals, factors, 1))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_segids_variant_matches_onehot_variant(self):
        rng = np.random.default_rng(11)
        blk, s, r = 256, 64, 16
        seg_ids = jnp.asarray(rng.integers(0, s, blk), jnp.int32)
        vals = jnp.asarray(rng.standard_normal(blk), jnp.float32)
        rows = [
            jnp.asarray(rng.standard_normal((blk, r)), jnp.float32) for _ in range(2)
        ]
        onehot = ref.onehot_from_segments(seg_ids, s)
        (a,) = model.block_mttkrp_fn(2)(onehot, vals, *rows)
        (b,) = model.block_mttkrp_from_segments_fn(2, s)(seg_ids, vals, *rows)
        (c,) = model.block_mttkrp_ref_fn(2, s)(seg_ids, vals, *rows)
        (d,) = model.block_mttkrp_onehot_jnp_fn(2)(onehot, vals, *rows)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(a, d, rtol=1e-5, atol=1e-5)


class TestCpAlsInJax:
    """A small pure-JAX CP-ALS using the block kernels end-to-end: the fit
    must increase monotonically-ish on a synthetic low-rank tensor.  This
    pins the algorithmic contract the Rust cpd/ module implements."""

    def test_als_recovers_low_rank_tensor(self):
        rng = np.random.default_rng(3)
        dims, r_true, r_fit = (20, 18, 16), 3, 4
        gt = [rng.standard_normal((d, r_true)).astype(np.float32) for d in dims]
        dense = np.einsum("ir,jr,kr->ijk", *gt)
        idx = np.argwhere(np.abs(dense) > 0.8).astype(np.int32)  # sparsify
        vals = dense[idx[:, 0], idx[:, 1], idx[:, 2]].astype(np.float32)
        assert idx.shape[0] > 200

        idx_j, vals_j = jnp.asarray(idx), jnp.asarray(vals)
        factors = [
            jnp.asarray(rng.standard_normal((d, r_fit)), jnp.float32) for d in dims
        ]
        norm_x = float(np.linalg.norm(vals))

        def fit(factors):
            # ||X - X_hat||^2 over the nnz support (cheap proxy).
            est = np.ones((idx.shape[0], r_fit), np.float32)
            for m in range(3):
                est = est * np.asarray(factors[m])[idx[:, m]]
            resid = vals - est.sum(axis=1)
            return 1.0 - float(np.linalg.norm(resid)) / norm_x

        fits = [fit(factors)]
        for _ in range(6):
            for mode in range(3):
                m = ref.mttkrp_coo_ref(idx_j, vals_j, factors, mode)
                h = jnp.ones((r_fit, r_fit), jnp.float32)
                for other in range(3):
                    if other == mode:
                        continue
                    h = h * (factors[other].T @ factors[other])
                factors[mode] = m @ jnp.linalg.pinv(h)
            fits.append(fit(factors))
        assert fits[-1] > fits[0] + 0.1, f"fit did not improve: {fits}"
        assert fits[-1] > 0.5, f"final fit too low: {fits[-1]}"
