//! Physical address mapping: byte address -> (channel, bank, row, column).
//!
//! Layout (low to high bits): burst offset | channel | bank | column
//! bursts | row.  Channel bits lowest so sequential streams stripe across
//! channels; bank bits below the row so sequential streams also rotate
//! banks within a row-sized window — both standard interleavings for
//! bandwidth-bound accelerators.

use super::DramConfig;

/// Decomposed address.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mapped {
    pub channel: usize,
    pub bank: usize,
    pub row: u64,
    /// Column *burst* index within the row.
    pub col: u64,
}

/// Bit-slicing address mapper derived from a [`DramConfig`].
#[derive(Debug, Clone)]
pub struct AddressMap {
    burst_shift: u32,
    channel_bits: u32,
    bank_bits: u32,
    col_bits: u32,
}

fn log2_exact(x: usize, what: &str) -> u32 {
    assert!(x.is_power_of_two(), "{what} ({x}) must be a power of two");
    x.trailing_zeros()
}

impl AddressMap {
    pub fn new(cfg: &DramConfig) -> Self {
        let burst_shift = log2_exact(cfg.burst_bytes, "burst_bytes");
        let channel_bits = log2_exact(cfg.channels, "channels");
        let bank_bits = log2_exact(cfg.banks, "banks");
        let bursts_per_row = cfg.row_bytes / cfg.burst_bytes;
        let col_bits = log2_exact(bursts_per_row, "row_bytes/burst_bytes");
        AddressMap {
            burst_shift,
            channel_bits,
            bank_bits,
            col_bits,
        }
    }

    /// Map a byte address.
    pub fn map(&self, addr: u64) -> Mapped {
        let mut a = addr >> self.burst_shift;
        let channel = (a & ((1 << self.channel_bits) - 1)) as usize;
        a >>= self.channel_bits;
        let bank = (a & ((1 << self.bank_bits) - 1)) as usize;
        a >>= self.bank_bits;
        let col = a & ((1 << self.col_bits) - 1);
        let row = a >> self.col_bits;
        Mapped {
            channel,
            bank,
            row,
            col,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DramConfig {
        DramConfig {
            channels: 2,
            banks: 4,
            row_bytes: 1024,
            burst_bytes: 64,
            t_rcd: 1,
            t_rp: 1,
            t_cl: 1,
            t_burst: 1,
            row_policy: crate::dram::RowPolicy::Open,
        }
    }

    #[test]
    fn sequential_bursts_rotate_channels_then_banks() {
        let m = AddressMap::new(&cfg());
        let a = m.map(0);
        let b = m.map(64);
        let c = m.map(128);
        assert_eq!(a.channel, 0);
        assert_eq!(b.channel, 1);
        assert_eq!(c.channel, 0);
        assert_eq!(a.bank, 0);
        assert_eq!(c.bank, 1, "after channels wrap, bank advances");
    }

    #[test]
    fn row_changes_after_row_bytes_per_bank_set() {
        let m = AddressMap::new(&cfg());
        // bits: 6 burst | 1 ch | 2 bank | 4 col | row
        // row increments every 64B * 2ch * 4bank * 16col = 8192 bytes.
        assert_eq!(m.map(0).row, 0);
        assert_eq!(m.map(8191).row, 0);
        assert_eq!(m.map(8192).row, 1);
    }

    #[test]
    fn mapping_is_injective_over_a_window() {
        let m = AddressMap::new(&cfg());
        let mut seen = std::collections::HashSet::new();
        for burst in 0..4096u64 {
            let mp = m.map(burst * 64);
            assert!(
                seen.insert((mp.channel, mp.bank, mp.row, mp.col)),
                "duplicate mapping for burst {burst}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two_banks() {
        let mut c = cfg();
        c.banks = 3;
        AddressMap::new(&c);
    }

    #[test]
    fn single_channel_has_zero_channel_bits() {
        let mut c = cfg();
        c.channels = 1;
        let m = AddressMap::new(&c);
        assert_eq!(m.map(64).channel, 0);
        assert_eq!(m.map(64).bank, 1);
    }
}
