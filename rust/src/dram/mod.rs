//! DRAM timing model (S2) — the hardware substitution for the FPGA
//! board's external memory (DESIGN.md §2).
//!
//! The paper's whole argument rests on DRAM access-time asymmetry:
//! streaming bulk transfers amortize row activations while random
//! element accesses pay activate/precharge on nearly every request
//! (§4: "Accessing the data in bulk can reduce the total memory access
//! time. It is due to the characteristics of the DRAM").  This module
//! reproduces exactly that asymmetry with a bank/row-buffer state model
//! driven by request traces: per-bank open row, tRCD / tRP / tCL / tBURST
//! timing classes, multi-channel parallelism, and an open- vs
//! closed-page row policy.
//!
//! Device state is kept in flat structure-of-arrays form — one
//! row-state vector and one ready-clock vector over all (channel, bank)
//! pairs plus one bus clock per channel — so the vectorized
//! multi-candidate timing core ([`crate::engine::timing`]) can hold an
//! array of per-candidate devices without nested allocations.
//!
//! Times are in *memory-controller cycles*; [`DramConfig::default_ddr4`]
//! maps to DDR4-2400-class timings at the controller clock.

pub mod address;

pub use address::{AddressMap, Mapped};

use std::fmt;
use std::str::FromStr;

/// Row-buffer management policy (one of the paper's §2 memory-controller
/// parameters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RowPolicy {
    /// Open page: rows stay open after an access; subsequent same-row
    /// bursts hit (tCL only), different-row bursts pay a precharge
    /// conflict (tRP + tRCD + tCL).  Wins on streaming locality.
    #[default]
    Open,
    /// Closed page (auto-precharge): every burst re-activates its row
    /// (tRCD + tCL) but never pays a precharge on the critical path.
    /// Wins on locality-free random access.
    Closed,
}

impl FromStr for RowPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "open" => Ok(RowPolicy::Open),
            "closed" => Ok(RowPolicy::Closed),
            other => Err(format!("unknown row policy {other:?} (open|closed)")),
        }
    }
}

impl fmt::Display for RowPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            RowPolicy::Open => "open",
            RowPolicy::Closed => "closed",
        })
    }
}

/// DRAM timing / geometry parameters.  `Hash` so configuration tuples
/// can key memoization tables (the event engine's remap-pass memo,
/// [`crate::shard::ShardedSweep`]).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DramConfig {
    /// Independent channels (separate data buses, e.g. one per SLR DDR).
    pub channels: usize,
    /// Banks per channel.
    pub banks: usize,
    /// Row-buffer (page) size in bytes.
    pub row_bytes: usize,
    /// Bytes moved per burst (bus width x burst length).
    pub burst_bytes: usize,
    /// ACT-to-READ/WRITE delay (cycles).
    pub t_rcd: u64,
    /// Precharge delay (cycles).
    pub t_rp: u64,
    /// CAS latency (cycles).
    pub t_cl: u64,
    /// Data transfer time of one burst (cycles).
    pub t_burst: u64,
    /// Row-buffer management policy (open vs closed page).
    pub row_policy: RowPolicy,
}

impl DramConfig {
    /// DDR4-2400-like single-DIMM config at a 300 MHz controller clock:
    /// 16 banks, 8 KiB rows, 64 B bursts, tRCD=tRP=tCL≈5 controller
    /// cycles, burst occupies the bus for 2 cycles, open-page policy.
    pub fn default_ddr4() -> Self {
        DramConfig {
            channels: 1,
            banks: 16,
            row_bytes: 8192,
            burst_bytes: 64,
            t_rcd: 5,
            t_rp: 5,
            t_cl: 5,
            t_burst: 2,
            row_policy: RowPolicy::Open,
        }
    }

    /// Four-channel config (Alveo U250-like: one DDR4 DIMM per SLR).
    pub fn u250_quad() -> Self {
        DramConfig {
            channels: 4,
            ..Self::default_ddr4()
        }
    }

    /// Peak bandwidth in bytes/cycle (all channels streaming).
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        self.channels as f64 * self.burst_bytes as f64 / self.t_burst as f64
    }
}

/// Outcome class of one burst access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowOutcome {
    /// Row already open: tCL + tBURST.
    Hit,
    /// Bank idle (no open row): tRCD + tCL + tBURST.  Under the closed
    /// policy every burst lands here after the auto-precharge.
    Miss,
    /// Different row open: tRP + tRCD + tCL + tBURST.
    Conflict,
}

/// Aggregate DRAM statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DramStats {
    pub bursts: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub bytes: u64,
}

impl DramStats {
    /// Row activations issued: every non-hit burst opens a row
    /// (misses activate an idle bank, conflicts precharge + activate).
    pub fn activations(&self) -> u64 {
        self.row_misses + self.row_conflicts
    }

    /// Row-buffer hit rate over all bursts.
    pub fn hit_rate(&self) -> f64 {
        if self.bursts == 0 {
            0.0
        } else {
            self.row_hits as f64 / self.bursts as f64
        }
    }

    /// Accumulate another device's counters (per-shard aggregation,
    /// [`crate::shard`]).
    pub fn merge(&mut self, other: &DramStats) {
        self.bursts += other.bursts;
        self.row_hits += other.row_hits;
        self.row_misses += other.row_misses;
        self.row_conflicts += other.row_conflicts;
        self.bytes += other.bytes;
    }
}

/// Sentinel row value marking a precharged (no open row) bank in the
/// flat row-state vector.  Real row indices are addresses shifted right
/// by at least the burst bits, so they can never reach `u64::MAX`.
const NO_OPEN_ROW: u64 = u64::MAX;

/// The DRAM device model.  Drive it with [`Dram::access`] calls carrying
/// absolute byte addresses and lengths; it splits them into bursts,
/// updates bank state, and advances per-channel time.  `now` lets the
/// caller model idle gaps; the device never goes back in time.
///
/// State lives in flat vectors (see module docs): `open_rows` /
/// `bank_ready` are indexed by `channel * banks + bank`, `bus_free` by
/// channel.
#[derive(Debug, Clone)]
pub struct Dram {
    cfg: DramConfig,
    map: AddressMap,
    /// Open row per (channel, bank), `NO_OPEN_ROW` when precharged.
    open_rows: Vec<u64>,
    /// Cycle at which each (channel, bank) can issue its next command.
    bank_ready: Vec<u64>,
    /// Cycle at which each channel's data bus is next free.
    bus_free: Vec<u64>,
    stats: DramStats,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Self {
        let map = AddressMap::new(&cfg);
        let slots = cfg.channels * cfg.banks;
        Dram {
            open_rows: vec![NO_OPEN_ROW; slots],
            bank_ready: vec![0; slots],
            bus_free: vec![0; cfg.channels],
            cfg,
            map,
            stats: DramStats::default(),
        }
    }

    pub fn config(&self) -> &DramConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &DramStats {
        &self.stats
    }

    /// Reset bank/bus state and statistics (fresh epoch).
    pub fn reset(&mut self) {
        self.open_rows.iter_mut().for_each(|r| *r = NO_OPEN_ROW);
        self.bank_ready.iter_mut().for_each(|t| *t = 0);
        self.bus_free.iter_mut().for_each(|t| *t = 0);
        self.stats = DramStats::default();
    }

    /// Access `len` bytes at `addr` starting no earlier than `start`;
    /// returns the completion cycle.  Splits into burst-aligned accesses;
    /// consecutive bursts in the same open row pipeline on the bus.
    pub fn access(&mut self, addr: u64, len: usize, start: u64) -> u64 {
        assert!(len > 0, "zero-length DRAM access");
        let bb = self.cfg.burst_bytes as u64;
        let first = addr / bb;
        let last = (addr + len as u64 - 1) / bb;
        let mut done = start;
        for burst in first..=last {
            done = done.max(self.access_burst(burst * bb, start));
        }
        done
    }

    /// One burst access; returns completion cycle.
    fn access_burst(&mut self, addr: u64, start: u64) -> u64 {
        let m = self.map.map(addr);
        let slot = m.channel * self.cfg.banks + m.bank;

        let open = self.open_rows[slot];
        let outcome = if open == m.row {
            RowOutcome::Hit
        } else if open == NO_OPEN_ROW {
            RowOutcome::Miss
        } else {
            RowOutcome::Conflict
        };
        let (lat_pre, class) = match outcome {
            RowOutcome::Hit => (self.cfg.t_cl, &mut self.stats.row_hits),
            RowOutcome::Miss => (self.cfg.t_rcd + self.cfg.t_cl, &mut self.stats.row_misses),
            RowOutcome::Conflict => (
                self.cfg.t_rp + self.cfg.t_rcd + self.cfg.t_cl,
                &mut self.stats.row_conflicts,
            ),
        };
        *class += 1;
        self.stats.bursts += 1;
        self.stats.bytes += self.cfg.burst_bytes as u64;

        // Command issues when both the bank and the caller are ready;
        // data needs the bus after the access latency.
        let issue = start.max(self.bank_ready[slot]);
        let data_start = (issue + lat_pre).max(self.bus_free[m.channel]);
        let done = data_start + self.cfg.t_burst;
        match self.cfg.row_policy {
            RowPolicy::Open => {
                // Row stays open; the next access to this bank can
                // overlap its CAS with this burst's data phase.
                self.open_rows[slot] = m.row;
                self.bank_ready[slot] = data_start;
            }
            RowPolicy::Closed => {
                // Auto-precharge: the bank closes behind the burst and
                // can re-activate once the data phase completes.
                self.open_rows[slot] = NO_OPEN_ROW;
                self.bank_ready[slot] = done;
            }
        }
        self.bus_free[m.channel] = done;
        done
    }

    /// Current makespan: max completion across channels.
    pub fn makespan(&self) -> u64 {
        self.bus_free.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_bank_cfg() -> DramConfig {
        DramConfig {
            channels: 1,
            banks: 1,
            row_bytes: 1024,
            burst_bytes: 64,
            t_rcd: 5,
            t_rp: 5,
            t_cl: 5,
            t_burst: 2,
            row_policy: RowPolicy::Open,
        }
    }

    #[test]
    fn first_access_is_a_row_miss() {
        let mut d = Dram::new(one_bank_cfg());
        let done = d.access(0, 64, 0);
        // miss: tRCD + tCL + tBURST = 5 + 5 + 2
        assert_eq!(done, 12);
        assert_eq!(d.stats().row_misses, 1);
    }

    #[test]
    fn same_row_second_access_is_a_hit() {
        let mut d = Dram::new(one_bank_cfg());
        let t1 = d.access(0, 64, 0);
        let t2 = d.access(64, 64, t1);
        assert_eq!(d.stats().row_hits, 1);
        assert!(t2 - t1 < t1, "hit should be cheaper than cold miss");
    }

    #[test]
    fn different_row_is_a_conflict_and_slowest() {
        let mut d = Dram::new(one_bank_cfg());
        let t1 = d.access(0, 64, 0);
        let t2 = d.access(4096, 64, t1); // beyond row_bytes => other row
        assert_eq!(d.stats().row_conflicts, 1);
        // conflict latency = tRP+tRCD+tCL+tBURST = 17
        assert_eq!(t2 - t1, 17);
    }

    #[test]
    fn multi_burst_access_splits_correctly() {
        let mut d = Dram::new(one_bank_cfg());
        d.access(0, 256, 0); // 4 bursts
        assert_eq!(d.stats().bursts, 4);
        assert_eq!(d.stats().bytes, 256);
        assert_eq!(d.stats().row_misses, 1);
        assert_eq!(d.stats().row_hits, 3);
    }

    #[test]
    fn unaligned_access_touches_both_bursts() {
        let mut d = Dram::new(one_bank_cfg());
        d.access(60, 8, 0); // straddles burst boundary at 64
        assert_eq!(d.stats().bursts, 2);
    }

    #[test]
    fn streaming_is_much_faster_than_random_per_byte() {
        let cfg = DramConfig::default_ddr4();
        let total = 1 << 20; // 1 MiB
        let mut stream = Dram::new(cfg.clone());
        let mut t = 0;
        for off in (0..total).step_by(cfg.burst_bytes) {
            t = stream.access(off as u64, cfg.burst_bytes, t);
        }
        let stream_cycles = stream.makespan();

        let mut random = Dram::new(cfg.clone());
        let mut rng = crate::testkit::Rng::new(1);
        let mut t = 0;
        for _ in 0..total / cfg.burst_bytes {
            let addr = rng.below((256u64) << 20) / 64 * 64;
            t = random.access(addr, cfg.burst_bytes, t);
        }
        let random_cycles = random.makespan();
        assert!(
            random_cycles > 2 * stream_cycles,
            "random {random_cycles} should be >2x stream {stream_cycles}"
        );
        assert!(stream.stats().hit_rate() > 0.95);
    }

    #[test]
    fn channels_parallelize_independent_streams() {
        let mut cfg = DramConfig::default_ddr4();
        cfg.channels = 4;
        let mut d = Dram::new(cfg.clone());
        // One pass of sequential bursts round-robins channels (low bits);
        // makespan should be ~1/4 of the single channel case.
        let total = 1 << 20;
        for off in (0..total).step_by(cfg.burst_bytes) {
            d.access(off as u64, cfg.burst_bytes, 0);
        }
        let quad = d.makespan();

        let mut cfg1 = cfg.clone();
        cfg1.channels = 1;
        let mut d1 = Dram::new(cfg1);
        for off in (0..total).step_by(cfg.burst_bytes) {
            d1.access(off as u64, cfg.burst_bytes, 0);
        }
        let single = d1.makespan();
        let ratio = single as f64 / quad as f64;
        assert!(ratio > 3.0, "expected ~4x channel speedup, got {ratio}");
    }

    #[test]
    fn reset_clears_state_and_stats() {
        let mut d = Dram::new(one_bank_cfg());
        d.access(0, 64, 0);
        d.reset();
        assert_eq!(d.stats(), &DramStats::default());
        assert_eq!(d.makespan(), 0);
        // After reset the same access is a miss again.
        d.access(0, 64, 0);
        assert_eq!(d.stats().row_misses, 1);
    }

    #[test]
    fn peak_bandwidth_formula() {
        let cfg = DramConfig::default_ddr4();
        assert!((cfg.peak_bytes_per_cycle() - 32.0).abs() < 1e-12);
    }

    #[test]
    fn row_policy_parses_and_displays() {
        assert_eq!("open".parse::<RowPolicy>().unwrap(), RowPolicy::Open);
        assert_eq!("closed".parse::<RowPolicy>().unwrap(), RowPolicy::Closed);
        assert!("adaptive".parse::<RowPolicy>().is_err());
        assert_eq!(RowPolicy::Open.to_string(), "open");
        assert_eq!(RowPolicy::Closed.to_string(), "closed");
        assert_eq!(RowPolicy::default(), RowPolicy::Open);
    }

    #[test]
    fn closed_policy_never_hits_or_conflicts() {
        let mut cfg = one_bank_cfg();
        cfg.row_policy = RowPolicy::Closed;
        let mut d = Dram::new(cfg);
        let mut t = 0;
        for i in 0..8u64 {
            // Alternate rows: under open page these would conflict.
            t = d.access((i % 2) * 4096, 64, t);
        }
        assert_eq!(d.stats().row_hits, 0);
        assert_eq!(d.stats().row_conflicts, 0);
        assert_eq!(d.stats().row_misses, 8);
    }

    #[test]
    fn closed_policy_beats_open_on_row_conflicts() {
        // Ping-pong between two rows of one bank: open page pays tRP on
        // every access, closed page pre-charges for free in the shadow
        // of the burst.
        let run = |policy: RowPolicy| {
            let mut cfg = one_bank_cfg();
            cfg.row_policy = policy;
            let mut d = Dram::new(cfg);
            let mut t = 0;
            for i in 0..64u64 {
                t = d.access((i % 2) * 4096, 64, t);
            }
            t
        };
        let open = run(RowPolicy::Open);
        let closed = run(RowPolicy::Closed);
        assert!(
            closed < open,
            "closed {closed} must beat open {open} on conflict-heavy access"
        );
    }

    #[test]
    fn open_policy_beats_closed_on_streaming() {
        // Sequential bursts within one row: open page hits after the
        // first activate, closed page re-activates every burst.
        let run = |policy: RowPolicy| {
            let mut cfg = one_bank_cfg();
            cfg.row_policy = policy;
            let mut d = Dram::new(cfg);
            let mut t = 0;
            for i in 0..16u64 {
                t = d.access(i * 64, 64, t);
            }
            t
        };
        let open = run(RowPolicy::Open);
        let closed = run(RowPolicy::Closed);
        assert!(
            open < closed,
            "open {open} must beat closed {closed} on streaming"
        );
    }
}
