//! CP-ALS tensor decomposition (S9, paper Algorithm 1) built on the
//! spMTTKRP engines: each iteration updates every factor matrix via
//! MTTKRP + a Hadamard-of-Grams solve, normalizes, and tracks the fit.
//!
//! The MTTKRP itself is pluggable ([`MttkrpBackend`]): the numeric oracle
//! (host compute), the memory-controller-simulated Approach-1-with-remap
//! engine, or the PJRT-offloaded coordinator ([`crate::coordinator`]).

pub mod linalg;

use linalg::{spd_inverse, Mat};

use crate::controller::{MemLayout, MemoryController};
use crate::mttkrp::{oracle, remap_exec};
use crate::tensor::SparseTensor;

/// Where a CP-ALS run gets its MTTKRP results from.
pub trait MttkrpBackend {
    /// Compute the mode-`mode` MTTKRP.  May re-order `t` (remap).
    fn mttkrp(&mut self, t: &mut SparseTensor, factors: &[Mat], mode: usize) -> Mat;

    /// Simulated memory-access cycles consumed so far (0 for host paths).
    fn cycles(&self) -> u64 {
        0
    }

    /// Backend label for logs.
    fn name(&self) -> &'static str;
}

/// Host-compute backend: sequential Algorithm 2.
pub struct NativeBackend;

impl MttkrpBackend for NativeBackend {
    fn mttkrp(&mut self, t: &mut SparseTensor, factors: &[Mat], mode: usize) -> Mat {
        oracle::mttkrp(t, factors, mode)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Memory-controller-simulated backend: Approach 1 with remapping,
/// replayed through the programmable controller (advancing its clock).
pub struct SimBackend {
    pub ctl: MemoryController,
    pub layout: MemLayout,
    /// Ping-pong slot currently holding the tensor.
    src: usize,
}

impl SimBackend {
    pub fn new(ctl: MemoryController, layout: MemLayout) -> Self {
        SimBackend { ctl, layout, src: 0 }
    }
}

impl MttkrpBackend for SimBackend {
    fn mttkrp(&mut self, t: &mut SparseTensor, factors: &[Mat], mode: usize) -> Mat {
        let run = remap_exec::run(t, factors, mode, &self.layout, &mut self.ctl, self.src);
        if run.remap_report.is_some() {
            self.src = 1 - self.src;
        }
        run.engine.output
    }

    fn cycles(&self) -> u64 {
        self.ctl.now()
    }

    fn name(&self) -> &'static str {
        "sim"
    }
}

/// CP-ALS hyper-parameters.
#[derive(Debug, Clone)]
pub struct AlsConfig {
    pub rank: usize,
    pub max_iters: usize,
    /// Stop when fit improves by less than this between iterations.
    pub tol: f64,
    /// Ridge for the Hadamard-of-Grams inverse.
    pub ridge: f32,
    pub seed: u64,
}

impl Default for AlsConfig {
    fn default() -> Self {
        AlsConfig {
            rank: 16,
            max_iters: 20,
            tol: 1e-5,
            ridge: 1e-6,
            seed: 0,
        }
    }
}

/// Result of a CP-ALS run.
#[derive(Debug, Clone)]
pub struct CpModel {
    /// Factor matrices, columns unit-normalized.
    pub factors: Vec<Mat>,
    /// Component weights.
    pub lambda: Vec<f32>,
    /// Fit after each iteration (1 - relative residual norm).
    pub fit_history: Vec<f64>,
    /// Iterations actually executed.
    pub iters: usize,
    /// Simulated memory cycles (backend-dependent; 0 for native).
    pub cycles: u64,
}

impl CpModel {
    pub fn final_fit(&self) -> f64 {
        self.fit_history.last().copied().unwrap_or(0.0)
    }

    /// Reconstruct the value at `coords` from the model.
    pub fn predict(&self, coords: &[u32]) -> f32 {
        let r = self.lambda.len();
        let mut acc = 0.0f32;
        for rr in 0..r {
            let mut p = self.lambda[rr];
            for (m, &c) in coords.iter().enumerate() {
                p *= self.factors[m].get(c as usize, rr);
            }
            acc += p;
        }
        acc
    }
}

/// Run CP-ALS (paper Algorithm 1) on `t` with the given backend.
pub fn cp_als(t: &mut SparseTensor, cfg: &AlsConfig, backend: &mut dyn MttkrpBackend) -> CpModel {
    let n = t.n_modes();
    let r = cfg.rank;
    let norm_x: f64 = t
        .values()
        .iter()
        .map(|&v| (v as f64) * (v as f64))
        .sum::<f64>()
        .sqrt();

    // Random init, columns normalized so early Grams are well-scaled.
    let mut factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| {
            let mut f = Mat::randn(d, r, cfg.seed.wrapping_add(m as u64 * 7919));
            f.normalize_columns();
            f
        })
        .collect();
    let mut lambda = vec![1.0f32; r];

    let mut grams: Vec<Mat> = factors.iter().map(|f| f.gram()).collect();
    let mut fit_history = Vec::new();
    let mut iters = 0;

    for _iter in 0..cfg.max_iters {
        iters += 1;
        let mut last_m: Option<Mat> = None;
        for mode in 0..n {
            // H = hadamard of the other modes' Gram matrices.
            let mut h = Mat::from_fn(r, r, |_, _| 1.0);
            for (m, g) in grams.iter().enumerate() {
                if m != mode {
                    h.hadamard_assign(g);
                }
            }
            let m_mat = backend.mttkrp(t, &factors, mode);
            let updated = m_mat.matmul(&spd_inverse(&h, cfg.ridge));
            factors[mode] = updated;
            // Normalize and fold norms into lambda.
            lambda = factors[mode].normalize_columns();
            // Guard against dead components (zero columns): keep unit
            // lambda floor so H stays invertible.
            for l in &mut lambda {
                if *l == 0.0 {
                    *l = f32::MIN_POSITIVE;
                }
            }
            grams[mode] = factors[mode].gram();
            if mode == n - 1 {
                last_m = Some(m_mat);
            }
        }

        // Fit via the standard Gram identity (no dense reconstruction):
        //   ||Xhat||^2 = lambda^T (G_0 ∘ ... ∘ G_{N-1}) lambda
        //   <X, Xhat>  = sum_{i,r} M[i,r] * lambda_r * A_last[i,r]
        let mut h_all = Mat::from_fn(r, r, |_, _| 1.0);
        for g in &grams {
            h_all.hadamard_assign(g);
        }
        let mut model_norm2 = 0.0f64;
        for a in 0..r {
            for b in 0..r {
                model_norm2 +=
                    lambda[a] as f64 * lambda[b] as f64 * h_all.get(a, b) as f64;
            }
        }
        let m_mat = last_m.expect("n >= 1 modes");
        let mut inner = 0.0f64;
        let a_last = &factors[n - 1];
        for i in 0..a_last.rows() {
            let (mr, ar) = (m_mat.row(i), a_last.row(i));
            for rr in 0..r {
                inner += mr[rr] as f64 * lambda[rr] as f64 * ar[rr] as f64;
            }
        }
        let resid2 = (norm_x * norm_x + model_norm2 - 2.0 * inner).max(0.0);
        let fit = 1.0 - resid2.sqrt() / norm_x;
        let prev = fit_history.last().copied().unwrap_or(f64::NEG_INFINITY);
        fit_history.push(fit);
        if (fit - prev).abs() < cfg.tol {
            break;
        }
    }

    CpModel {
        factors,
        lambda,
        fit_history,
        iters,
        cycles: backend.cycles(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ControllerConfig;
    use crate::tensor::Coord;
    use crate::testkit::Rng;

    /// Build a tensor that IS exactly low-rank, stored sparsely: all
    /// cells of a rank-`rank` CP model are enumerated (small dims), so
    /// the COO zeros-are-zero semantics cannot break the rank structure.
    /// `_nnz` is ignored (kept for call-site readability of target size).
    fn low_rank_tensor(dims: &[usize], rank: usize, _nnz: usize, seed: u64) -> SparseTensor {
        let gt: Vec<Mat> = dims
            .iter()
            .enumerate()
            .map(|(m, &d)| Mat::randn(d, rank, seed + m as u64))
            .collect();
        let mut entries = Vec::new();
        let total: usize = dims.iter().product();
        for lin in 0..total {
            let mut rem = lin;
            let mut coords = vec![0 as Coord; dims.len()];
            for m in (0..dims.len()).rev() {
                coords[m] = (rem % dims[m]) as Coord;
                rem /= dims[m];
            }
            let mut v = 0.0f32;
            for rr in 0..rank {
                let mut p = 1.0f32;
                for (m, &c) in coords.iter().enumerate() {
                    p *= gt[m].get(c as usize, rr);
                }
                v += p;
            }
            entries.push((coords, v));
        }
        // Shuffle so engines cannot rely on construction order.
        let mut rng = Rng::new(seed ^ 0xabcd);
        rng.shuffle(&mut entries);
        SparseTensor::new(dims.to_vec(), &entries)
    }

    #[test]
    fn als_fits_low_rank_tensor_native() {
        let mut t = low_rank_tensor(&[25, 20, 15], 3, 1500, 71);
        let cfg = AlsConfig {
            rank: 4,
            max_iters: 30,
            tol: 1e-7,
            ..Default::default()
        };
        let model = cp_als(&mut t, &cfg, &mut NativeBackend);
        assert!(
            model.final_fit() > 0.85,
            "fit {} history {:?}",
            model.final_fit(),
            model.fit_history
        );
    }

    #[test]
    fn fit_is_nondecreasing_mostly() {
        let mut t = low_rank_tensor(&[20, 18, 14], 3, 1000, 72);
        let cfg = AlsConfig {
            rank: 3,
            max_iters: 15,
            tol: 0.0,
            ..Default::default()
        };
        let model = cp_als(&mut t, &cfg, &mut NativeBackend);
        // ALS fit is monotone in exact arithmetic; allow tiny fp wiggle.
        for w in model.fit_history.windows(2) {
            assert!(w[1] >= w[0] - 1e-4, "fit dropped: {:?}", model.fit_history);
        }
    }

    #[test]
    fn sim_backend_matches_native_numerically() {
        let mut t1 = low_rank_tensor(&[22, 16, 12], 2, 800, 73);
        let mut t2 = t1.clone();
        let cfg = AlsConfig {
            rank: 3,
            max_iters: 5,
            tol: 0.0,
            ..Default::default()
        };
        let native = cp_als(&mut t1, &cfg, &mut NativeBackend);
        let layout = MemLayout::plan(t2.dims(), t2.nnz(), t2.record_bytes(), cfg.rank);
        let ctl = MemoryController::new(ControllerConfig::default_for(t2.record_bytes()));
        let mut sim = SimBackend::new(ctl, layout);
        let simed = cp_als(&mut t2, &cfg, &mut sim);
        // Same arithmetic, different nnz iteration order within fibers →
        // identical up to fp reduction order.
        assert!((native.final_fit() - simed.final_fit()).abs() < 1e-3);
        assert!(simed.cycles > 0, "sim backend must advance the clock");
    }

    #[test]
    fn predict_reconstructs_training_entries_roughly() {
        let mut t = low_rank_tensor(&[20, 15, 10], 2, 800, 74);
        let cfg = AlsConfig {
            rank: 3,
            max_iters: 25,
            tol: 1e-8,
            ..Default::default()
        };
        let t_orig = t.clone();
        let model = cp_als(&mut t, &cfg, &mut NativeBackend);
        let mut err = 0.0f64;
        let mut norm = 0.0f64;
        for z in 0..t_orig.nnz() {
            let want = t_orig.values()[z];
            let got = model.predict(&t_orig.coords_of(z));
            err += ((want - got) as f64).powi(2);
            norm += (want as f64).powi(2);
        }
        let rel = (err / norm).sqrt();
        assert!(rel < 0.35, "relative reconstruction error {rel}");
    }

    #[test]
    fn early_stop_on_tolerance() {
        let mut t = low_rank_tensor(&[15, 12, 10], 2, 500, 75);
        let cfg = AlsConfig {
            rank: 3,
            max_iters: 100,
            tol: 1e-3,
            ..Default::default()
        };
        let model = cp_als(&mut t, &cfg, &mut NativeBackend);
        assert!(model.iters < 100, "should stop early, ran {}", model.iters);
    }
}
