//! From-scratch dense linear algebra (S9 substrate): row-major f32
//! matrices with exactly the operations CP-ALS needs — Gram matrices,
//! Hadamard products, Cholesky-based SPD inverse, column normalization.
//!
//! No external crates are available in the offline build; R is small
//! (8–64) so naive O(R^3) routines are ample.

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    pub fn from_rows(rows: &[&[f32]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |r| r.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Random N(0,1) entries (deterministic per seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = crate::testkit::Rng::new(seed);
        Mat::from_fn(rows, cols, |_, _| rng.normal() as f32)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Gram matrix `A^T A` (cols x cols, symmetric PSD).
    pub fn gram(&self) -> Mat {
        let c = self.cols;
        let mut g = Mat::zeros(c, c);
        for i in 0..self.rows {
            let row = self.row(i);
            for a in 0..c {
                let ra = row[a];
                if ra == 0.0 {
                    continue;
                }
                for b in a..c {
                    g.data[a * c + b] += ra * row[b];
                }
            }
        }
        for a in 0..c {
            for b in 0..a {
                g.data[a * c + b] = g.data[b * c + a];
            }
        }
        g
    }

    /// Element-wise (Hadamard) product, in place.
    pub fn hadamard_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
    }

    /// Dense matmul `self (m x k) * other (k x n)`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[p * n..(p + 1) * n];
                let dst = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    dst[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Sum of element-wise products `<self, other>_F`.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum()
    }

    /// Normalize each column to unit 2-norm; returns the norms (the CP
    /// lambda vector).  Zero columns get lambda 0 and are left as-is.
    pub fn normalize_columns(&mut self) -> Vec<f32> {
        let mut norms = vec![0.0f64; self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                let v = self.data[i * self.cols + j] as f64;
                norms[j] += v * v;
            }
        }
        let norms: Vec<f32> = norms.iter().map(|&n| n.sqrt() as f32).collect();
        for i in 0..self.rows {
            for j in 0..self.cols {
                if norms[j] > 0.0 {
                    self.data[i * self.cols + j] /= norms[j];
                }
            }
        }
        norms
    }

    /// Scale column `j` by `s`.
    pub fn scale_column(&mut self, j: usize, s: f32) {
        for i in 0..self.rows {
            self.data[i * self.cols + j] *= s;
        }
    }
}

/// Cholesky factorization of an SPD matrix (lower triangular L with
/// `A = L L^T`).  Returns None if not positive definite.
pub fn cholesky(a: &Mat) -> Option<Mat> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j) as f64;
            for k in 0..j {
                sum -= l.get(i, k) as f64 * l.get(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l.set(i, j, sum.sqrt() as f32);
            } else {
                l.set(i, j, (sum / l.get(j, j) as f64) as f32);
            }
        }
    }
    Some(l)
}

/// Inverse of an SPD matrix via Cholesky; adds `ridge * mean(diag)` to
/// the diagonal on failure and retries (ALS Gram-Hadamard matrices can be
/// near-singular when factors are collinear).
pub fn spd_inverse(a: &Mat, ridge: f32) -> Mat {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut work = a.clone();
    let mean_diag: f32 = (0..n).map(|i| a.get(i, i)).sum::<f32>() / n as f32;
    let mut bump = 0.0f32;
    let l = loop {
        if let Some(l) = cholesky(&work) {
            break l;
        }
        bump = if bump == 0.0 {
            ridge * mean_diag.max(1e-12)
        } else {
            bump * 10.0
        };
        work = a.clone();
        for i in 0..n {
            work.set(i, i, work.get(i, i) + bump);
        }
        assert!(
            bump.is_finite() && bump < 1e12,
            "spd_inverse: could not regularize"
        );
    };
    // Solve L L^T X = I column by column.
    let mut inv = Mat::zeros(n, n);
    for col in 0..n {
        // Forward: L y = e_col
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l.get(i, k) as f64 * y[k];
            }
            y[i] = s / l.get(i, i) as f64;
        }
        // Backward: L^T x = y
        let mut x = vec![0.0f64; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= l.get(k, i) as f64 * x[k];
            }
            x[i] = s / l.get(i, i) as f64;
        }
        for i in 0..n {
            inv.set(i, col, x[i] as f32);
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{assert_allclose, forall};

    #[test]
    fn gram_of_identity_is_identity() {
        let g = Mat::eye(4).gram();
        assert_eq!(g, Mat::eye(4));
    }

    #[test]
    fn gram_matches_explicit_transpose_matmul() {
        forall("gram_vs_matmul", 16, |rng| {
            let (m, n) = (rng.range(1, 20), rng.range(1, 8));
            let a = Mat::randn(m, n, rng.next_u64());
            let at = Mat::from_fn(n, m, |i, j| a.get(j, i));
            let want = at.matmul(&a);
            let got = a.gram();
            assert_allclose(got.data(), want.data(), 1e-4, 1e-4);
        });
    }

    #[test]
    fn matmul_identity_is_noop() {
        let a = Mat::randn(5, 5, 1);
        let got = a.matmul(&Mat::eye(5));
        assert_allclose(got.data(), a.data(), 1e-6, 0.0);
    }

    #[test]
    fn matmul_known_case() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn hadamard_known_case() {
        let mut a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        a.hadamard_assign(&Mat::from_rows(&[&[2.0, 0.5], &[1.0, -1.0]]));
        assert_eq!(a.data(), &[2.0, 1.0, 3.0, -4.0]);
    }

    #[test]
    fn cholesky_roundtrip() {
        forall("cholesky_roundtrip", 16, |rng| {
            let n = rng.range(1, 8);
            let b = Mat::randn(n + 2, n, rng.next_u64());
            let mut a = b.gram(); // SPD (a.s.)
            for i in 0..n {
                a.set(i, i, a.get(i, i) + 0.1); // ensure PD
            }
            let l = cholesky(&a).expect("PD");
            let lt = Mat::from_fn(n, n, |i, j| l.get(j, i));
            let back = l.matmul(&lt);
            assert_allclose(back.data(), a.data(), 1e-3, 1e-3);
        });
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eig -1, 3
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn spd_inverse_times_matrix_is_identity() {
        forall("spd_inverse", 16, |rng| {
            let n = rng.range(1, 10);
            let b = Mat::randn(n + 3, n, rng.next_u64());
            let mut a = b.gram();
            for i in 0..n {
                a.set(i, i, a.get(i, i) + 0.5);
            }
            let inv = spd_inverse(&a, 1e-6);
            let prod = a.matmul(&inv);
            assert_allclose(prod.data(), Mat::eye(n).data(), 5e-2, 5e-2);
        });
    }

    #[test]
    fn spd_inverse_regularizes_singular_input() {
        // Rank-1 Gram: singular; ridge path must still return something
        // finite with A*inv ~ I on the non-null space.
        let v = Mat::from_rows(&[&[1.0, 2.0]]);
        let g = v.gram(); // 2x2 rank 1
        let inv = spd_inverse(&g, 1e-6);
        assert!(inv.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn normalize_columns_returns_norms_and_unit_columns() {
        let mut a = Mat::from_rows(&[&[3.0, 0.0], &[4.0, 0.0]]);
        let norms = a.normalize_columns();
        assert_allclose(&norms, &[5.0, 0.0], 1e-6, 1e-6);
        assert_allclose(a.data(), &[0.6, 0.0, 0.8, 0.0], 1e-6, 1e-6);
    }

    #[test]
    fn fro_norm_and_dot() {
        let a = Mat::from_rows(&[&[3.0, 4.0]]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-9);
        let b = Mat::from_rows(&[&[1.0, 2.0]]);
        assert!((a.dot(&b) - 11.0).abs() < 1e-9);
    }
}
