//! Indexed scoped-thread fan-out.
//!
//! One helper replaces the hand-rolled `thread::scope` blocks that used
//! to live in `dse::score_batch`, the shard worker launch, the
//! concurrent shard replays, and the per-shard grid classification
//! ([`crate::shard`], [`crate::dse`]): run an indexed closure over
//! `0..n` on up to `available_parallelism` scoped host threads and
//! return the results in index order, so callers are deterministic
//! regardless of thread timing.

use std::thread;

/// Run `f(i)` for `i in 0..n` on up to `available_parallelism` scoped
/// host threads (contiguous chunks); results come back in index order.
/// `n <= 1` (or a single-core host) runs inline with no threads spawned.
pub fn parallel_indexed<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    let chunks: Vec<Vec<T>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .step_by(chunk)
            .map(|start| {
                let end = (start + chunk).min(n);
                scope.spawn(move || (start..end).map(f).collect::<Vec<T>>())
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel_indexed worker panicked"))
            .collect()
    });
    chunks.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_singleton() {
        assert_eq!(parallel_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_indexed(1, |i| i * 7), vec![0]);
    }

    #[test]
    fn results_come_back_in_index_order() {
        // Odd count over many threads: chunk boundaries must not
        // scramble or drop indices.
        let got = parallel_indexed(1_003, |i| i * 2);
        assert_eq!(got.len(), 1_003);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn closure_sees_every_index_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let got = parallel_indexed(64, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 64);
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }
}
