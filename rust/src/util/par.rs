//! Indexed scoped-thread fan-out and a queued fixed worker pool.
//!
//! Two layers share one process-wide parallelism budget:
//!
//! - [`parallel_indexed`] replaces the hand-rolled `thread::scope`
//!   blocks that used to live in `dse::score_batch`, the shard worker
//!   launch, the concurrent shard replays, and the per-shard grid
//!   classification ([`crate::shard`], [`crate::dse`]): run an indexed
//!   closure over `0..n` on scoped host threads and return the results
//!   in index order, so callers are deterministic regardless of thread
//!   timing.
//! - [`Pool`] is a long-lived queued executor for the DSE server
//!   ([`crate::serve`]): a fixed set of worker threads draining a FIFO
//!   job queue, so N concurrent queries are *scheduled* rather than
//!   each spawning its own unbounded thread scope.
//!
//! When a [`Pool`] runs J jobs concurrently, every nested
//! `parallel_indexed` fan-out inside those jobs (shard workers, batch
//! scoring, concurrent replays) would oversubscribe the host J-fold.
//! [`set_parallelism_cap`] installs a process-wide per-fan-out thread
//! cap that `parallel_indexed` honors, so the pool owner divides the
//! host between its workers once instead of every call site guessing.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Process-wide cap on threads per `parallel_indexed` fan-out.
/// 0 means uncapped (use `available_parallelism`).
static PAR_CAP: AtomicUsize = AtomicUsize::new(0);

/// Cap every subsequent [`parallel_indexed`] fan-out at `cap` threads
/// (`None` restores the uncapped default). The DSE server sets this to
/// `max(1, host_cores / pool_workers)` so concurrent jobs share the
/// host instead of each fanning out to every core.
pub fn set_parallelism_cap(cap: Option<usize>) {
    PAR_CAP.store(cap.unwrap_or(0), Ordering::Relaxed);
}

/// Threads one `parallel_indexed` fan-out may use right now: host
/// parallelism clamped by [`set_parallelism_cap`].
pub fn effective_parallelism() -> usize {
    let host = thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    match PAR_CAP.load(Ordering::Relaxed) {
        0 => host,
        cap => host.min(cap),
    }
}

/// Run `f(i)` for `i in 0..n` on up to [`effective_parallelism`] scoped
/// host threads (contiguous chunks); results come back in index order.
/// `n <= 1` (or an effective parallelism of 1) runs inline with no
/// threads spawned.
pub fn parallel_indexed<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = effective_parallelism().min(n);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    let chunks: Vec<Vec<T>> = thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .step_by(chunk)
            .map(|start| {
                let end = (start + chunk).min(n);
                scope.spawn(move || (start..end).map(f).collect::<Vec<T>>())
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel_indexed worker panicked"))
            .collect()
    });
    chunks.into_iter().flatten().collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Job>,
    shutdown: bool,
    /// Jobs popped but not yet finished, for `wait_idle`.
    active: usize,
}

struct PoolInner {
    state: Mutex<PoolState>,
    /// Signalled when a job is pushed or shutdown flips.
    work: Condvar,
    /// Signalled when the pool drains to empty-and-idle.
    idle: Condvar,
}

/// A fixed-size queued executor: `workers` long-lived threads drain a
/// FIFO job queue. Jobs are `'static` closures; panics in a job are
/// caught so one poisoned query cannot take a worker (or the queue)
/// down with it. Dropping the pool finishes queued work first.
pub struct Pool {
    inner: Arc<PoolInner>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `workers` threads (clamped to at least 1).
    pub fn new(workers: usize) -> Pool {
        let workers = workers.max(1);
        let inner = Arc::new(PoolInner {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                shutdown: false,
                active: 0,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                thread::Builder::new()
                    .name(format!("ptmc-pool-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn pool worker")
            })
            .collect();
        Pool {
            inner,
            workers: handles,
        }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job. Returns `false` (job dropped) after `shutdown`.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, job: F) -> bool {
        let mut state = self.inner.state.lock().unwrap();
        if state.shutdown {
            return false;
        }
        state.queue.push_back(Box::new(job));
        drop(state);
        self.inner.work.notify_one();
        true
    }

    /// Jobs queued but not yet started.
    pub fn queued(&self) -> usize {
        self.inner.state.lock().unwrap().queue.len()
    }

    /// Block until the queue is empty and no job is running.
    pub fn wait_idle(&self) {
        let mut state = self.inner.state.lock().unwrap();
        while !state.queue.is_empty() || state.active > 0 {
            state = self.inner.idle.wait(state).unwrap();
        }
    }

    fn begin_shutdown(&self) {
        let mut state = self.inner.state.lock().unwrap();
        state.shutdown = true;
        drop(state);
        self.inner.work.notify_all();
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.begin_shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &PoolInner) {
    loop {
        let job = {
            let mut state = inner.state.lock().unwrap();
            loop {
                if let Some(job) = state.queue.pop_front() {
                    state.active += 1;
                    break job;
                }
                if state.shutdown {
                    return;
                }
                state = inner.work.wait(state).unwrap();
            }
        };
        // A panicking job must not kill the worker: the server's
        // connection handler already turned job errors into typed
        // responses, so anything escaping here is a bug in the job
        // body — contain it and keep draining the queue.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        let mut state = inner.state.lock().unwrap();
        state.active -= 1;
        if state.queue.is_empty() && state.active == 0 {
            inner.idle.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn empty_and_singleton() {
        assert_eq!(parallel_indexed(0, |i| i), Vec::<usize>::new());
        assert_eq!(parallel_indexed(1, |i| i * 7), vec![0]);
    }

    #[test]
    fn results_come_back_in_index_order() {
        // Odd count over many threads: chunk boundaries must not
        // scramble or drop indices.
        let got = parallel_indexed(1_003, |i| i * 2);
        assert_eq!(got.len(), 1_003);
        for (i, v) in got.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn closure_sees_every_index_exactly_once() {
        let calls = AtomicUsize::new(0);
        let got = parallel_indexed(64, |i| {
            calls.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(calls.load(Ordering::Relaxed), 64);
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn parallelism_cap_is_honored_and_results_unchanged() {
        // Capped runs must produce identical results to uncapped ones;
        // cap 1 must not deadlock (inline path).
        set_parallelism_cap(Some(1));
        assert_eq!(effective_parallelism(), 1);
        let capped = parallel_indexed(257, |i| i * 3);
        set_parallelism_cap(None);
        let free = parallel_indexed(257, |i| i * 3);
        assert_eq!(capped, free);
    }

    #[test]
    fn pool_runs_every_job_once() {
        let pool = Pool::new(4);
        let calls = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let calls = Arc::clone(&calls);
            assert!(pool.spawn(move || {
                calls.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.wait_idle();
        assert_eq!(calls.load(Ordering::Relaxed), 100);
        assert_eq!(pool.queued(), 0);
    }

    #[test]
    fn pool_survives_a_panicking_job() {
        let pool = Pool::new(2);
        let calls = Arc::new(AtomicUsize::new(0));
        pool.spawn(|| panic!("job bug"));
        for _ in 0..10 {
            let calls = Arc::clone(&calls);
            pool.spawn(move || {
                calls.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(calls.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn pool_drop_finishes_queued_work() {
        let calls = Arc::new(AtomicUsize::new(0));
        {
            let pool = Pool::new(1);
            for _ in 0..20 {
                let calls = Arc::clone(&calls);
                pool.spawn(move || {
                    calls.fetch_add(1, Ordering::Relaxed);
                });
            }
        }
        assert_eq!(calls.load(Ordering::Relaxed), 20);
    }
}
