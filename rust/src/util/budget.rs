//! Memory-budget plumbing (S24): parse human-readable size strings
//! from `--memory-budget`, and observe the process's peak resident set
//! so the CLI can prove an out-of-core run actually stayed under it.
//!
//! The budget is an *observable contract*, not an allocator limit: the
//! streaming paths (block-streamed parse, windowed replay, spilled
//! remap columns, compressed-only traces) are what keep the footprint
//! bounded; [`peak_rss_bytes`] is the measurement that shows they did.

/// Parse a human-readable byte size: a plain integer (bytes) or an
/// integer with a `k`/`m`/`g`/`t` suffix (binary units, 1k = 1024),
/// optionally followed by `b`/`ib` — `"4g"`, `"4GiB"`, `"512m"`,
/// `"1048576"` all work, case-insensitively.
pub fn parse_size(s: &str) -> Result<u64, String> {
    let t = s.trim().to_ascii_lowercase();
    if t.is_empty() {
        return Err("empty size".into());
    }
    let digits_end = t
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(t.len());
    let (num, suffix) = t.split_at(digits_end);
    let n: u64 = num
        .parse()
        .map_err(|_| format!("invalid size '{s}': expected digits first"))?;
    let shift = match suffix {
        "" | "b" => 0,
        "k" | "kb" | "kib" => 10,
        "m" | "mb" | "mib" => 20,
        "g" | "gb" | "gib" => 30,
        "t" | "tb" | "tib" => 40,
        _ => {
            return Err(format!(
                "invalid size '{s}': unknown suffix '{suffix}' (use k/m/g/t)"
            ))
        }
    };
    n.checked_shl(shift)
        .filter(|v| v >> shift == n)
        .ok_or_else(|| format!("size '{s}' overflows u64"))
}

/// Render a byte count with a binary-unit suffix, e.g. `"3.72 GiB"`.
pub fn format_size(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Peak resident set size of this process, in bytes (`VmHWM` from
/// `/proc/self/status`).  `None` on platforms without procfs.
pub fn peak_rss_bytes() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: u64 = rest
                    .trim()
                    .trim_end_matches("kB")
                    .trim()
                    .parse()
                    .ok()?;
                return Some(kb * 1024);
            }
        }
        None
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_plain_bytes_and_suffixes() {
        assert_eq!(parse_size("123"), Ok(123));
        assert_eq!(parse_size("64k"), Ok(64 << 10));
        assert_eq!(parse_size("512m"), Ok(512 << 20));
        assert_eq!(parse_size("4g"), Ok(4 << 30));
        assert_eq!(parse_size("4G"), Ok(4 << 30));
        assert_eq!(parse_size("4GiB"), Ok(4 << 30));
        assert_eq!(parse_size("2tb"), Ok(2 << 40));
        assert_eq!(parse_size(" 8mb "), Ok(8 << 20));
    }

    #[test]
    fn rejects_malformed_sizes() {
        assert!(parse_size("").is_err());
        assert!(parse_size("g4").is_err());
        assert!(parse_size("4x").is_err());
        assert!(parse_size("4.5g").is_err(), "fractions are not supported");
        assert!(parse_size("99999999999g").is_err(), "overflow must error");
    }

    #[test]
    fn formats_binary_units() {
        assert_eq!(format_size(512), "512 B");
        assert_eq!(format_size(4 << 30), "4.00 GiB");
        assert_eq!(format_size((3 << 30) + (768 << 20)), "3.75 GiB");
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn peak_rss_is_positive_on_linux() {
        let rss = peak_rss_bytes().expect("procfs must expose VmHWM");
        assert!(rss > 1 << 20, "peak RSS {rss} suspiciously small");
    }
}
