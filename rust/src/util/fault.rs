//! Deterministic fault injection (S31): a zero-dependency registry of
//! named failpoint *sites* threaded through every disk-touching and
//! worker-spawning surface of the pipeline (`SpillCol`, the warm DSE
//! cache, streamed FROSTT ingestion, bench upserts, shard workers).
//!
//! A *plan* arms a set of sites with deterministic schedules: fail on
//! the Nth hit of a site (optionally repeating every `k` hits after)
//! with a chosen [`std::io::ErrorKind`], or inject a panic.  Plans come
//! from the `PTMC_FAULT_PLAN` environment variable (read once, lazily)
//! or from the test-only [`arm`] API, which also serializes armed test
//! sections behind a process-wide lock so concurrent `cargo test`
//! threads cannot observe each other's faults.
//!
//! Plan grammar (semicolon-separated entries):
//!
//! ```text
//! plan   := entry (';' entry)*
//! entry  := site '@' nth ['%' every] [':' effect]
//! effect := 'panic' | io-kind name (default: 'other')
//! ```
//!
//! `spill.write@1` fails the first spill write with `ErrorKind::Other`;
//! `warm.flush@2%1:interrupted` fails every flush from the second on
//! with `Interrupted`; `shard.worker@3:panic` panics the third worker.
//!
//! When no plan is armed, [`check_io`] compiles down to a single
//! relaxed atomic load — the disarmed overhead is benchmarked in
//! `benches/classify_kernel.rs` (`fault_overhead` section, ≤1% of a
//! guarded block parse).

use std::io;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// `SpillCol` writing a spilled column to disk.
pub const SPILL_WRITE: &str = "spill.write";
/// `SpillCol` reading a spilled column back.
pub const SPILL_READ: &str = "spill.read";
/// `WarmCache` flushing its verdict map + frontier to disk.
pub const WARM_FLUSH: &str = "warm.flush";
/// `WarmCache` loading a cache file on open.
pub const WARM_LOAD: &str = "warm.load";
/// `TnsBlockReader` pulling the next block from a FROSTT stream.
pub const FROSTT_READ_BLOCK: &str = "frostt.read_block";
/// Bench binaries upserting a section into `BENCH_dse.json`.
pub const BENCH_UPSERT: &str = "bench.upsert";
/// A shard worker body (supervised by `shard::exec`).
pub const SHARD_WORKER: &str = "shard.worker";
/// The DSE server accepting one incoming connection.
pub const SERVE_ACCEPT: &str = "serve.accept";
/// A DSE server connection handler reading one request frame.
pub const SERVE_FRAME: &str = "serve.frame";
/// The cross-query memo store flushing one context to its spill tier.
pub const MEMO_FLUSH: &str = "memo.flush";

/// Every registered failpoint site, in declaration order.
pub const SITES: &[&str] = &[
    SPILL_WRITE,
    SPILL_READ,
    WARM_FLUSH,
    WARM_LOAD,
    FROSTT_READ_BLOCK,
    BENCH_UPSERT,
    SHARD_WORKER,
    SERVE_ACCEPT,
    SERVE_FRAME,
    MEMO_FLUSH,
];

const UNINIT: u32 = 0;
const DISARMED: u32 = 1;
const ARMED: u32 = 2;

/// Tri-state so the post-initialization disarmed path is exactly one
/// relaxed load (`UNINIT` routes through the lazy env parse once).
static STATE: AtomicU32 = AtomicU32::new(UNINIT);
static PLAN: Mutex<Option<Plan>> = Mutex::new(None);
static INJECTED: AtomicU64 = AtomicU64::new(0);
/// Serializes armed test sections: held by [`FaultGuard`] for its
/// lifetime so two tests arming plans cannot interleave.
static TEST_LOCK: Mutex<()> = Mutex::new(());

/// What an armed rule does when its schedule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effect {
    /// Return `io::Error::new(kind, ...)` from [`check_io`].
    Io(io::ErrorKind),
    /// Panic at the failpoint (exercises `catch_unwind` supervision).
    Panic,
}

#[derive(Debug, Clone)]
struct Rule {
    site: usize,
    nth: u64,
    /// 0 = fire once on hit `nth`; k>0 = fire on `nth` and every `k`
    /// hits thereafter.
    every: u64,
    effect: Effect,
}

impl Rule {
    fn fires(&self, hit: u64) -> bool {
        if hit < self.nth {
            return false;
        }
        if hit == self.nth {
            return true;
        }
        self.every > 0 && (hit - self.nth) % self.every == 0
    }
}

#[derive(Debug)]
struct Plan {
    rules: Vec<Rule>,
    hits: [u64; SITES.len()],
}

impl Plan {
    fn new(rules: Vec<Rule>) -> Self {
        Plan {
            rules,
            hits: [0; SITES.len()],
        }
    }
}

fn site_index(site: &str) -> Option<usize> {
    SITES.iter().position(|s| *s == site)
}

fn lock_plan() -> MutexGuard<'static, Option<Plan>> {
    // A panic effect can unwind through a caller that still holds
    // state elsewhere; never let lock poisoning cascade.
    PLAN.lock().unwrap_or_else(|p| p.into_inner())
}

fn kind_from_name(name: &str) -> Option<io::ErrorKind> {
    Some(match name {
        "notfound" => io::ErrorKind::NotFound,
        "permissiondenied" => io::ErrorKind::PermissionDenied,
        "brokenpipe" => io::ErrorKind::BrokenPipe,
        "alreadyexists" => io::ErrorKind::AlreadyExists,
        "wouldblock" => io::ErrorKind::WouldBlock,
        "invaliddata" => io::ErrorKind::InvalidData,
        "timedout" => io::ErrorKind::TimedOut,
        "writezero" => io::ErrorKind::WriteZero,
        "interrupted" => io::ErrorKind::Interrupted,
        "unexpectedeof" => io::ErrorKind::UnexpectedEof,
        "outofmemory" => io::ErrorKind::OutOfMemory,
        "other" => io::ErrorKind::Other,
        _ => return None,
    })
}

fn parse_entry(entry: &str) -> Result<Rule, String> {
    let entry = entry.trim();
    let (head, effect) = match entry.split_once(':') {
        Some((h, e)) => (h, e.trim()),
        None => (entry, "other"),
    };
    let (site, sched) = head
        .split_once('@')
        .ok_or_else(|| format!("entry `{entry}` missing `@nth`"))?;
    let site = site.trim();
    let idx = site_index(site).ok_or_else(|| {
        format!(
            "unknown failpoint site `{site}` (known: {})",
            SITES.join(", ")
        )
    })?;
    let (nth_s, every_s) = match sched.split_once('%') {
        Some((n, e)) => (n.trim(), Some(e.trim())),
        None => (sched.trim(), None),
    };
    let nth: u64 = nth_s
        .parse()
        .map_err(|_| format!("entry `{entry}`: bad hit count `{nth_s}`"))?;
    if nth == 0 {
        return Err(format!("entry `{entry}`: hit counts are 1-based"));
    }
    let every: u64 = match every_s {
        Some(e) => e
            .parse()
            .map_err(|_| format!("entry `{entry}`: bad repeat period `{e}`"))?,
        None => 0,
    };
    let effect = if effect.eq_ignore_ascii_case("panic") {
        Effect::Panic
    } else {
        Effect::Io(kind_from_name(&effect.to_ascii_lowercase()).ok_or_else(|| {
            format!("entry `{entry}`: unknown effect `{effect}` (io kind name or `panic`)")
        })?)
    };
    Ok(Rule {
        site: idx,
        nth,
        every,
        effect,
    })
}

fn parse_plan(plan: &str) -> Result<Vec<Rule>, String> {
    let mut rules = Vec::new();
    for entry in plan.split(';') {
        if entry.trim().is_empty() {
            continue;
        }
        rules.push(parse_entry(entry)?);
    }
    if rules.is_empty() {
        return Err("empty fault plan".into());
    }
    Ok(rules)
}

/// Parse and install the `PTMC_FAULT_PLAN` environment plan, if any.
/// `Ok(true)` = a plan was armed; `Ok(false)` = no plan requested.
fn apply_env_plan() -> Result<bool, String> {
    match std::env::var("PTMC_FAULT_PLAN") {
        Ok(s) if !s.trim().is_empty() => {
            let rules = parse_plan(&s)?;
            *lock_plan() = Some(Plan::new(rules));
            eprintln!("fault: armed plan from PTMC_FAULT_PLAN: {}", s.trim());
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// Lazy one-shot environment arming for library users that never call
/// [`init_env`].  Races between threads are benign: every contender
/// parses the same string and stores the same terminal state.  A
/// malformed plan is warned about and ignored here — binaries that
/// want it fatal call [`init_env`] eagerly at startup.
fn init_from_env() {
    let state = match apply_env_plan() {
        Ok(true) => ARMED,
        Ok(false) => DISARMED,
        Err(e) => {
            eprintln!("warning: ignoring invalid PTMC_FAULT_PLAN: {e}");
            DISARMED
        }
    };
    STATE.store(state, Ordering::Relaxed);
}

/// Eager environment arming for binaries: parse `PTMC_FAULT_PLAN` at
/// startup (instead of on the first failpoint crossing) and surface a
/// malformed plan as an error, so a typo'd plan fails the run loudly
/// rather than silently executing fault-free.
pub fn init_env() -> Result<(), String> {
    match apply_env_plan() {
        Ok(true) => {
            STATE.store(ARMED, Ordering::Relaxed);
            Ok(())
        }
        Ok(false) => {
            STATE.store(DISARMED, Ordering::Relaxed);
            Ok(())
        }
        Err(e) => {
            STATE.store(DISARMED, Ordering::Relaxed);
            Err(e)
        }
    }
}

/// RAII handle returned by [`arm`]: keeps the plan armed (and other
/// armed tests excluded) until dropped, then disarms.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        STATE.store(DISARMED, Ordering::Relaxed);
        *lock_plan() = None;
    }
}

/// Test-only arming API: parse `plan` and arm it until the returned
/// guard drops.  Serializes with every other armed section in the
/// process.  Resets the injected-fault counter.
pub fn arm(plan: &str) -> Result<FaultGuard, String> {
    let lock = TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let rules = parse_plan(plan)?;
    *lock_plan() = Some(Plan::new(rules));
    INJECTED.store(0, Ordering::Relaxed);
    STATE.store(ARMED, Ordering::Relaxed);
    Ok(FaultGuard { _lock: lock })
}

/// How many faults (errors or panics) have been injected since the
/// last [`arm`] / process start.
pub fn injected_count() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Hits recorded at `site` by the currently armed plan (0 when no
/// plan is armed).  Lets tests probe how many times a path crosses a
/// failpoint — e.g. to size a kill schedule to the real number of
/// checkpoint flushes — by arming a never-firing rule for the site.
pub fn hit_count(site: &str) -> u64 {
    match site_index(site) {
        Some(i) => lock_plan().as_ref().map_or(0, |p| p.hits[i]),
        None => 0,
    }
}

/// The failpoint check.  Disarmed: one relaxed atomic load, `Ok(())`.
/// Armed: bump the site's hit counter and, if a rule's schedule fires,
/// return the injected [`io::Error`] or panic.
#[inline]
pub fn check_io(site: &str) -> io::Result<()> {
    let st = STATE.load(Ordering::Relaxed);
    if st == DISARMED {
        return Ok(());
    }
    if st == UNINIT {
        init_from_env();
        if STATE.load(Ordering::Relaxed) != ARMED {
            return Ok(());
        }
    }
    check_armed(site)
}

#[cold]
fn check_armed(site: &str) -> io::Result<()> {
    let idx = match site_index(site) {
        Some(i) => i,
        None => return Ok(()),
    };
    // Decide under the lock, act after releasing it: a panic effect
    // must not unwind while holding the plan mutex.
    let fired: Option<(Effect, u64)> = {
        let mut guard = lock_plan();
        match guard.as_mut() {
            Some(plan) => {
                plan.hits[idx] += 1;
                let hit = plan.hits[idx];
                plan.rules
                    .iter()
                    .find(|r| r.site == idx && r.fires(hit))
                    .map(|r| (r.effect, hit))
            }
            None => None,
        }
    };
    match fired {
        None => Ok(()),
        Some((Effect::Panic, hit)) => {
            INJECTED.fetch_add(1, Ordering::Relaxed);
            panic!("injected panic at failpoint {site} (hit {hit})");
        }
        Some((Effect::Io(kind), hit)) => {
            INJECTED.fetch_add(1, Ordering::Relaxed);
            Err(io::Error::new(
                kind,
                format!("injected {kind:?} at failpoint {site} (hit {hit})"),
            ))
        }
    }
}

/// Transient IO kinds worth retrying: the OS told us to try again, not
/// that the operation is doomed.
pub fn is_transient(kind: io::ErrorKind) -> bool {
    matches!(
        kind,
        io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// Run `op` up to `attempts` times, sleeping `1ms << i` between
/// attempts, retrying only transient kinds ([`is_transient`]).
/// Non-transient errors propagate immediately.
pub fn retry_transient<T>(attempts: u32, mut op: impl FnMut() -> io::Result<T>) -> io::Result<T> {
    let attempts = attempts.max(1);
    let mut last = None;
    for i in 0..attempts {
        match op() {
            Ok(v) => return Ok(v),
            Err(e) if is_transient(e.kind()) => {
                last = Some(e);
                if i + 1 < attempts {
                    std::thread::sleep(std::time::Duration::from_millis(1u64 << i.min(6)));
                }
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.expect("retry_transient: at least one attempt ran"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_check_is_ok() {
        // No guard held: either UNINIT (env empty in tests) or
        // DISARMED after a previous guard dropped.
        assert!(check_io(SPILL_WRITE).is_ok());
    }

    #[test]
    fn plan_parses_and_fires_on_schedule() {
        let _g = arm("spill.write@2%3:timedout").unwrap();
        assert!(check_io(SPILL_WRITE).is_ok()); // hit 1
        let e = check_io(SPILL_WRITE).unwrap_err(); // hit 2: nth
        assert_eq!(e.kind(), io::ErrorKind::TimedOut);
        assert!(check_io(SPILL_WRITE).is_ok()); // hit 3
        assert!(check_io(SPILL_WRITE).is_ok()); // hit 4
        assert!(check_io(SPILL_WRITE).is_err()); // hit 5: nth + every
        assert!(check_io(SPILL_READ).is_ok()); // other site untouched
        assert_eq!(injected_count(), 2);
        assert_eq!(hit_count(SPILL_WRITE), 5);
        assert_eq!(hit_count(SPILL_READ), 1);
    }

    #[test]
    fn guard_drop_disarms() {
        {
            let _g = arm("warm.flush@1").unwrap();
            assert!(check_io(WARM_FLUSH).is_err());
        }
        assert!(check_io(WARM_FLUSH).is_ok());
    }

    #[test]
    fn bad_plans_are_rejected() {
        assert!(arm("").is_err());
        assert!(arm("nosuch.site@1").is_err());
        assert!(arm("spill.write@0").is_err());
        assert!(arm("spill.write@x").is_err());
        assert!(arm("spill.write@1:frobnicate").is_err());
        assert!(arm("spill.write").is_err());
    }

    #[test]
    fn panic_effect_panics_at_site() {
        let _g = arm("shard.worker@1:panic").unwrap();
        let r = std::panic::catch_unwind(|| check_io(SHARD_WORKER));
        assert!(r.is_err());
        assert_eq!(injected_count(), 1);
    }

    #[test]
    fn retry_transient_recovers_and_gives_up() {
        let mut left = 2;
        let v = retry_transient(3, || {
            if left > 0 {
                left -= 1;
                Err(io::Error::new(io::ErrorKind::Interrupted, "again"))
            } else {
                Ok(42)
            }
        })
        .unwrap();
        assert_eq!(v, 42);

        let e = retry_transient(2, || -> io::Result<()> {
            Err(io::Error::new(io::ErrorKind::TimedOut, "still"))
        })
        .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::TimedOut);

        // Non-transient kinds do not burn retries.
        let mut calls = 0;
        let e = retry_transient(5, || -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::NotFound, "gone"))
        })
        .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::NotFound);
        assert_eq!(calls, 1);
    }
}
