//! Zero-dependency byte codec (S28): little-endian writer/reader,
//! FNV-1a hashing, and a binary serialization of [`ControllerConfig`]
//! — the persistence layer behind the warm-start DSE cache
//! ([`crate::dse::WarmCache`]).  The encoding is versioned at the file
//! level by its consumer; here every field is written in declaration
//! order as fixed-width little-endian words, so equal configurations
//! encode to equal byte strings (the cache keys on the encoding).

use crate::controller::{CacheConfig, ControllerConfig, DmaConfig, RemapperConfig};
use crate::dram::{DramConfig, RowPolicy};
use crate::mem::{Hbm2Config, MemTechConfig, OsramConfig};

/// Append-only little-endian byte sink.
#[derive(Debug, Default, Clone)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        ByteWriter::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `usize` travels as `u64` so the encoding is
    /// platform-independent.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked little-endian byte source: every read returns
/// `None` past the end instead of panicking, so truncated or corrupt
/// inputs decode to a clean failure.
#[derive(Debug, Clone)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    pub fn u8(&mut self) -> Option<u8> {
        let b = *self.buf.get(self.pos)?;
        self.pos += 1;
        Some(b)
    }

    pub fn u32(&mut self) -> Option<u32> {
        let b = self.take(4)?;
        Some(u32::from_le_bytes(b.try_into().expect("4-byte slice")))
    }

    pub fn u64(&mut self) -> Option<u64> {
        let b = self.take(8)?;
        Some(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    pub fn usize(&mut self) -> Option<usize> {
        Some(self.u64()? as usize)
    }

    /// The next `n` bytes, advancing past them.
    pub fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.pos.checked_add(n)?;
        let b = self.buf.get(self.pos..end)?;
        self.pos = end;
        Some(b)
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }
}

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64-bit hasher — the crate's fingerprint /
/// checksum primitive (fast, zero-dependency, stable across runs and
/// platforms; not cryptographic).
#[derive(Debug, Clone, Copy)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a(FNV_OFFSET)
    }
}

impl Fnv1a {
    pub fn new() -> Self {
        Fnv1a::default()
    }

    pub fn write(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        self.0 = h;
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot FNV-1a of a byte string.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

/// Atomically replace `path` with `bytes` via a sibling `.tmp` file and
/// a rename.  Readers never observe a partial file; on *any* error the
/// temp file is removed, so failed flushes cannot leak `.tmp` litter
/// (S31 — the leak fixed in PR 9).
pub fn write_atomic(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    let res = std::fs::write(&tmp, bytes).and_then(|()| std::fs::rename(&tmp, path));
    if res.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    res
}

/// Write one length-prefixed frame: a `u32` little-endian body length
/// followed by the body bytes.  The framing layer under the DSE serve
/// protocol ([`crate::serve`]).
pub fn write_frame<W: std::io::Write>(w: &mut W, body: &[u8]) -> std::io::Result<()> {
    let len = u32::try_from(body.len()).map_err(|_| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("frame body too large: {} bytes", body.len()),
        )
    })?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(body)?;
    Ok(())
}

/// Read one [`write_frame`] frame.  Returns `Ok(None)` on a clean EOF
/// *before* the length prefix (the peer closed between frames); a
/// truncated prefix or body is `UnexpectedEof`, and a length above
/// `max_len` is `InvalidData` — so a malformed or hostile stream
/// always surfaces as a typed error instead of an unbounded
/// allocation or a hang.
pub fn read_frame<R: std::io::Read>(r: &mut R, max_len: usize) -> std::io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        match r.read(&mut len_buf[filled..])? {
            0 if filled == 0 => return Ok(None),
            0 => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "frame length prefix truncated",
                ))
            }
            n => filled += n,
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > max_len {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {len} exceeds limit {max_len}"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "frame body truncated")
        } else {
            e
        }
    })?;
    Ok(Some(body))
}

fn row_policy_tag(p: RowPolicy) -> u8 {
    match p {
        RowPolicy::Open => 0,
        RowPolicy::Closed => 1,
    }
}

fn row_policy_of(tag: u8) -> Option<RowPolicy> {
    match tag {
        0 => Some(RowPolicy::Open),
        1 => Some(RowPolicy::Closed),
        _ => None,
    }
}

fn encode_dram(w: &mut ByteWriter, d: &DramConfig) {
    w.usize(d.channels);
    w.usize(d.banks);
    w.usize(d.row_bytes);
    w.usize(d.burst_bytes);
    w.u64(d.t_rcd);
    w.u64(d.t_rp);
    w.u64(d.t_cl);
    w.u64(d.t_burst);
    w.u8(row_policy_tag(d.row_policy));
}

fn decode_dram(r: &mut ByteReader) -> Option<DramConfig> {
    Some(DramConfig {
        channels: r.usize()?,
        banks: r.usize()?,
        row_bytes: r.usize()?,
        burst_bytes: r.usize()?,
        t_rcd: r.u64()?,
        t_rp: r.u64()?,
        t_cl: r.u64()?,
        t_burst: r.u64()?,
        row_policy: row_policy_of(r.u8()?)?,
    })
}

fn encode_hbm2(w: &mut ByteWriter, h: &Hbm2Config) {
    w.usize(h.stacks);
    w.usize(h.channels_per_stack);
    w.usize(h.pseudo_channels);
    w.usize(h.banks);
    w.usize(h.row_bytes);
    w.usize(h.burst_bytes);
    w.u64(h.t_rcd);
    w.u64(h.t_rp);
    w.u64(h.t_cl);
    w.u64(h.t_burst);
    w.u8(row_policy_tag(h.row_policy));
}

fn decode_hbm2(r: &mut ByteReader) -> Option<Hbm2Config> {
    Some(Hbm2Config {
        stacks: r.usize()?,
        channels_per_stack: r.usize()?,
        pseudo_channels: r.usize()?,
        banks: r.usize()?,
        row_bytes: r.usize()?,
        burst_bytes: r.usize()?,
        t_rcd: r.u64()?,
        t_rp: r.u64()?,
        t_cl: r.u64()?,
        t_burst: r.u64()?,
        row_policy: row_policy_of(r.u8()?)?,
    })
}

fn encode_osram(w: &mut ByteWriter, o: &OsramConfig) {
    w.usize(o.banks);
    w.usize(o.word_bytes);
    w.u64(o.t_access);
    w.u64(o.t_word);
}

fn decode_osram(r: &mut ByteReader) -> Option<OsramConfig> {
    Some(OsramConfig {
        banks: r.usize()?,
        word_bytes: r.usize()?,
        t_access: r.u64()?,
        t_word: r.u64()?,
    })
}

/// Serialize a full controller configuration.  Equal configurations
/// produce equal byte strings (and vice versa: every field round-trips
/// exactly), so the encoding doubles as a hash/equality key.
pub fn encode_config(cfg: &ControllerConfig) -> Vec<u8> {
    let mut w = ByteWriter::new();
    match &cfg.mem {
        MemTechConfig::Ddr4(d) => {
            w.u8(0);
            encode_dram(&mut w, d);
        }
        MemTechConfig::Hbm2(h) => {
            w.u8(1);
            encode_hbm2(&mut w, h);
        }
        MemTechConfig::Osram(o) => {
            w.u8(2);
            encode_osram(&mut w, o);
        }
    }
    w.usize(cfg.cache.line_bytes);
    w.usize(cfg.cache.num_lines);
    w.usize(cfg.cache.assoc);
    w.u64(cfg.cache.hit_latency);
    w.usize(cfg.dma.num_dmas);
    w.usize(cfg.dma.buffers_per_dma);
    w.usize(cfg.dma.buffer_bytes);
    w.u64(cfg.dma.setup_cycles);
    w.usize(cfg.remapper.buffer_bytes);
    w.usize(cfg.remapper.elem_bytes);
    w.usize(cfg.remapper.max_pointers);
    w.u64(cfg.remapper.store_setup_cycles);
    w.into_bytes()
}

/// Deserialize [`encode_config`] output.  Returns `None` on a
/// truncated buffer, an unknown tag, or trailing garbage.
pub fn decode_config(bytes: &[u8]) -> Option<ControllerConfig> {
    let mut r = ByteReader::new(bytes);
    let mem = match r.u8()? {
        0 => MemTechConfig::Ddr4(decode_dram(&mut r)?),
        1 => MemTechConfig::Hbm2(decode_hbm2(&mut r)?),
        2 => MemTechConfig::Osram(decode_osram(&mut r)?),
        _ => return None,
    };
    let cfg = ControllerConfig {
        mem,
        cache: CacheConfig {
            line_bytes: r.usize()?,
            num_lines: r.usize()?,
            assoc: r.usize()?,
            hit_latency: r.u64()?,
        },
        dma: DmaConfig {
            num_dmas: r.usize()?,
            buffers_per_dma: r.usize()?,
            buffer_bytes: r.usize()?,
            setup_cycles: r.u64()?,
        },
        remapper: RemapperConfig {
            buffer_bytes: r.usize()?,
            elem_bytes: r.usize()?,
            max_pointers: r.usize()?,
            store_setup_cycles: r.u64()?,
        },
    };
    if !r.is_empty() {
        return None;
    }
    Some(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        assert_eq!(fnv1a(b""), FNV_OFFSET);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
        let mut inc = Fnv1a::new();
        inc.write(b"foo");
        inc.write(b"bar");
        assert_eq!(inc.finish(), fnv1a(b"foobar"));
    }

    #[test]
    fn writer_reader_round_trip_and_bounds() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xdead_beef);
        w.u64(u64::MAX - 1);
        w.usize(12345);
        w.bytes(b"xyz");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8(), Some(7));
        assert_eq!(r.u32(), Some(0xdead_beef));
        assert_eq!(r.u64(), Some(u64::MAX - 1));
        assert_eq!(r.usize(), Some(12345));
        assert_eq!(r.take(3), Some(&b"xyz"[..]));
        assert!(r.is_empty());
        assert_eq!(r.u8(), None, "reads past the end must fail cleanly");
        let mut t = ByteReader::new(&bytes[..5]);
        assert_eq!(t.u8(), Some(7));
        assert_eq!(t.u64(), None, "truncated read must fail, not panic");
    }

    #[test]
    fn frames_round_trip_and_reject_malformed_streams() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, b"hello").unwrap();
        let mut r = std::io::Cursor::new(&buf);
        assert_eq!(read_frame(&mut r, 1024).unwrap(), Some(Vec::new()));
        assert_eq!(read_frame(&mut r, 1024).unwrap(), Some(b"hello".to_vec()));
        assert_eq!(read_frame(&mut r, 1024).unwrap(), None, "clean EOF");

        // Truncated length prefix.
        let mut r = std::io::Cursor::new(&buf[..2]);
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);

        // Truncated body.
        let mut r = std::io::Cursor::new(&buf[..buf.len() - 2]);
        read_frame(&mut r, 1024).unwrap();
        let err = read_frame(&mut r, 1024).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);

        // Oversized length rejects before allocating.
        let mut huge = Vec::new();
        huge.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut std::io::Cursor::new(&huge), 1024).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn config_codec_round_trips_every_mem_tech() {
        let mut cfgs = vec![ControllerConfig::default_for(16)];
        let mut hbm = ControllerConfig::default_for(20);
        hbm.mem = MemTechConfig::Hbm2(Hbm2Config::default_u280());
        hbm.cache.num_lines = 4096;
        hbm.dma.num_dmas = 4;
        cfgs.push(hbm);
        let mut osram = ControllerConfig::default_for(16);
        osram.mem = MemTechConfig::Osram(OsramConfig::default_16p());
        osram.remapper.max_pointers = 1 << 18;
        cfgs.push(osram);
        let mut closed = ControllerConfig::default_for(16);
        if let MemTechConfig::Ddr4(d) = &mut closed.mem {
            d.row_policy = RowPolicy::Closed;
        }
        cfgs.push(closed);
        for cfg in &cfgs {
            let enc = encode_config(cfg);
            assert_eq!(decode_config(&enc).as_ref(), Some(cfg));
        }
        // Distinct configs must key differently.
        for (i, a) in cfgs.iter().enumerate() {
            for b in &cfgs[i + 1..] {
                assert_ne!(encode_config(a), encode_config(b));
            }
        }
    }

    #[test]
    fn config_decode_rejects_truncation_and_garbage() {
        let enc = encode_config(&ControllerConfig::default_for(16));
        for cut in [0, 1, 5, enc.len() - 1] {
            assert_eq!(decode_config(&enc[..cut]), None, "cut at {cut}");
        }
        let mut long = enc.clone();
        long.push(0);
        assert_eq!(decode_config(&long), None, "trailing bytes must reject");
        let mut bad = enc;
        bad[0] = 9;
        assert_eq!(decode_config(&bad), None, "unknown mem-tech tag");
    }
}
