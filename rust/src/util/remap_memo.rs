//! Shared remap-pass memoization (S23): one type for the
//! per-(mode, DRAM, remapper) cycle memo that the single-controller DSE
//! evaluator ([`crate::dse::SimMemo`]) and the sharded sweep
//! ([`crate::shard::ShardedSweep`]) each used to hand-roll.
//!
//! The Tensor-Remapper pass runs on a fresh controller and never
//! touches the Cache Engine or the DMA Engine, so its simulated cycle
//! count depends only on the mode being remapped, the DRAM timing
//! knobs, and the remapper knobs.  Every candidate of a cache / DMA
//! grid — and every cell of a joint cross-product sweep that shares
//! those knobs — therefore reuses one simulation.  How the pass is
//! simulated differs per call site (the DSE evaluator replays a
//! snapshot column, the sharded sweep replays the live tensor column),
//! so the memo takes the simulation as a closure and owns only the
//! keying and the interior-mutable map.

use std::collections::HashMap;
use std::fs;
use std::io::{self, Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::fault;

use crate::controller::{ControllerConfig, RemapperConfig};
use crate::mem::MemTechConfig;
use crate::tensor::Coord;

/// Key of one memoized remap-pass simulation: the only knobs the pass
/// is sensitive to.
pub type RemapKey = (usize, MemTechConfig, RemapperConfig);

/// Interior-mutable memo of remap-pass cycles per [`RemapKey`], shared
/// across every candidate a sweep scores.
#[derive(Debug, Default)]
pub struct RemapMemo {
    map: Mutex<HashMap<RemapKey, u64>>,
}

impl RemapMemo {
    /// An empty memo.
    pub fn new() -> Self {
        RemapMemo::default()
    }

    /// The remap-pass cycles of `mode` under `cfg`'s DRAM / remapper
    /// knobs, running `simulate` only on the first request for this
    /// key.  Concurrent first requests may both simulate; they compute
    /// the identical (deterministic) value, so last-insert-wins is
    /// harmless — the lock is never held across the simulation.
    pub fn cycles(
        &self,
        mode: usize,
        cfg: &ControllerConfig,
        simulate: impl FnOnce() -> u64,
    ) -> u64 {
        let key: RemapKey = (mode, cfg.mem.clone(), cfg.remapper);
        if let Some(&c) = self.map.lock().expect("remap memo poisoned").get(&key) {
            return c;
        }
        let cycles = simulate();
        self.map
            .lock()
            .expect("remap memo poisoned")
            .insert(key, cycles);
        cycles
    }

    /// Number of distinct keys simulated so far.
    pub fn len(&self) -> usize {
        self.map.lock().expect("remap memo poisoned").len()
    }

    /// True when nothing has been memoized yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Distinguishes concurrently-spilled columns within one process.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Set once the RAM-degradation warning has been printed, so a sweep
/// that fails to spill hundreds of columns warns exactly once.
static SPILL_DEGRADE_WARNED: AtomicBool = AtomicBool::new(false);

/// A per-mode coordinate column that can live on disk instead of in
/// RAM (S24).  The DSE evaluator snapshots one mode-`m` coordinate
/// column per tensor mode so the remap-pass simulation can replay it
/// later; at 100M nnz each snapshot is ~400 MB, and N of them retained
/// for the sweep's lifetime would eat most of a 4 GB budget on their
/// own.  Under a memory budget the snapshot is written to a temp file
/// (little-endian `u32`s) and re-read only on the rare, memoized
/// remap-cycle simulation; without a budget it stays a plain `Vec`.
#[derive(Debug)]
pub enum SpillCol {
    /// Column held in RAM (no budget, or spilling failed/was declined).
    Ram(Vec<Coord>),
    /// Column spilled to `path` (`len` little-endian `u32`s); the file
    /// is removed on drop.
    Disk { path: PathBuf, len: usize },
}

impl SpillCol {
    /// Wrap `col`, spilling it to a temp file when `spill` is set.
    /// Falls back to keeping the column in RAM if the spill write
    /// fails (a budget is a goal, not a correctness requirement).
    pub fn new(col: Vec<Coord>, spill: bool) -> Self {
        if !spill {
            return SpillCol::Ram(col);
        }
        match Self::write_spill(&col) {
            Ok(path) => SpillCol::Disk {
                path,
                len: col.len(),
            },
            Err(e) => {
                // Degrade to the RAM path; warn once per process so a
                // sweep spilling many columns stays legible (S31).
                if !SPILL_DEGRADE_WARNED.swap(true, Ordering::Relaxed) {
                    eprintln!(
                        "warning: spill write failed ({e}); keeping column in RAM \
                         (memory budget may be exceeded)"
                    );
                }
                SpillCol::Ram(col)
            }
        }
    }

    fn write_spill(col: &[Coord]) -> io::Result<PathBuf> {
        fault::check_io(fault::SPILL_WRITE)?;
        let path = std::env::temp_dir().join(format!(
            "ptmc-spill-{}-{}.bin",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed),
        ));
        let res = (|| -> io::Result<()> {
            let mut w = io::BufWriter::new(fs::File::create(&path)?);
            for &c in col {
                w.write_all(&c.to_le_bytes())?;
            }
            w.flush()
        })();
        match res {
            Ok(()) => Ok(path),
            Err(e) => {
                // Never leak a partial spill file on a failed write.
                let _ = fs::remove_file(&path);
                Err(e)
            }
        }
    }

    /// The column, re-read from disk if spilled; a typed error on any
    /// read failure (including injected `spill.read` faults).
    pub fn try_load(&self) -> io::Result<Vec<Coord>> {
        match self {
            SpillCol::Ram(col) => Ok(col.clone()),
            SpillCol::Disk { path, len } => {
                fault::check_io(fault::SPILL_READ)?;
                let mut r = io::BufReader::new(fs::File::open(path)?);
                let mut col = Vec::with_capacity(*len);
                let mut buf = [0u8; 4];
                for _ in 0..*len {
                    r.read_exact(&mut buf)?;
                    col.push(Coord::from_le_bytes(buf));
                }
                Ok(col)
            }
        }
    }

    /// The column, re-read from disk if spilled.  Transient read
    /// faults are retried with backoff; a persistent failure panics
    /// with the underlying error (the infallible signature is relied
    /// on deep inside memoized simulation closures — callers that can
    /// propagate use [`SpillCol::try_load`]).
    pub fn load(&self) -> Vec<Coord> {
        fault::retry_transient(3, || self.try_load())
            .unwrap_or_else(|e| panic!("spilled column unreadable: {e}"))
    }

    /// Number of coordinates in the column.
    pub fn len(&self) -> usize {
        match self {
            SpillCol::Ram(col) => col.len(),
            SpillCol::Disk { len, .. } => *len,
        }
    }

    /// True when the column holds no coordinates.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the column lives on disk.
    pub fn spilled(&self) -> bool {
        matches!(self, SpillCol::Disk { .. })
    }
}

impl Drop for SpillCol {
    fn drop(&mut self) {
        if let SpillCol::Disk { path, .. } = self {
            let _ = fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memo_simulates_once_per_key() {
        let memo = RemapMemo::new();
        let cfg = ControllerConfig::default_for(16);
        let mut calls = 0u32;
        let a = memo.cycles(0, &cfg, || {
            calls += 1;
            42
        });
        let b = memo.cycles(0, &cfg, || {
            calls += 1;
            unreachable!("second lookup must hit the memo")
        });
        assert_eq!((a, b), (42, 42));
        assert_eq!(calls, 1);
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn distinct_modes_and_knobs_key_separately() {
        let memo = RemapMemo::new();
        let cfg = ControllerConfig::default_for(16);
        let mut spilly = cfg.clone();
        spilly.remapper.max_pointers = 4;
        let mut wide = cfg.clone();
        wide.mem.ddr4_mut().channels = 4;
        assert_eq!(memo.cycles(0, &cfg, || 1), 1);
        assert_eq!(memo.cycles(1, &cfg, || 2), 2);
        assert_eq!(memo.cycles(0, &spilly, || 3), 3);
        assert_eq!(memo.cycles(0, &wide, || 4), 4);
        // Cache / DMA knobs are NOT part of the key: a candidate that
        // differs only there reuses the memoized pass.
        let mut cachey = cfg.clone();
        cachey.cache.num_lines = 64;
        cachey.dma.num_dmas = 4;
        assert_eq!(memo.cycles(0, &cachey, || unreachable!()), 1);
        assert_eq!(memo.len(), 4);
    }

    #[test]
    fn empty_and_len_track_inserts() {
        let memo = RemapMemo::new();
        assert!(memo.is_empty());
        memo.cycles(2, &ControllerConfig::default_for(16), || 9);
        assert!(!memo.is_empty());
    }

    #[test]
    fn ram_column_round_trips_without_touching_disk() {
        let col: Vec<Coord> = (0..1_000).rev().collect();
        let s = SpillCol::new(col.clone(), false);
        assert!(!s.spilled());
        assert_eq!(s.len(), 1_000);
        assert_eq!(s.load(), col);
    }

    #[test]
    fn spilled_column_round_trips_and_cleans_up() {
        let col: Vec<Coord> = vec![0, u32::MAX, 7, 0x0102_0304, 42];
        let s = SpillCol::new(col.clone(), true);
        assert!(s.spilled(), "temp dir must be writable in tests");
        assert_eq!(s.len(), col.len());
        assert_eq!(s.load(), col, "first load");
        assert_eq!(s.load(), col, "load must be repeatable");
        let path = match &s {
            SpillCol::Disk { path, .. } => path.clone(),
            SpillCol::Ram(_) => unreachable!(),
        };
        assert!(path.exists());
        drop(s);
        assert!(!path.exists(), "drop must remove the spill file");
    }

    #[test]
    fn empty_column_spills_harmlessly() {
        let s = SpillCol::new(Vec::new(), true);
        assert!(s.is_empty());
        assert_eq!(s.load(), Vec::<Coord>::new());
    }

    #[test]
    fn spill_write_fault_degrades_to_ram_bit_identically() {
        let col: Vec<Coord> = (0..257).map(|i| i * 3 + 1).collect();
        let s = {
            let _g = fault::arm("spill.write@1").unwrap();
            SpillCol::new(col.clone(), true)
        };
        assert!(!s.spilled(), "write fault must fall back to RAM");
        assert_eq!(s.load(), col, "degraded column must be bit-identical");
    }

    #[test]
    fn spill_read_faults_are_typed_then_retried() {
        let col: Vec<Coord> = vec![9, 8, 7];
        let s = SpillCol::new(col.clone(), true);
        assert!(s.spilled());
        let _g = fault::arm("spill.read@1:interrupted").unwrap();
        let e = s.try_load().unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::Interrupted);
        // The infallible path retries transient faults away: arm a
        // fresh single-shot fault and load() must still succeed.
        drop(_g);
        let _g = fault::arm("spill.read@1:timedout").unwrap();
        assert_eq!(s.load(), col, "transient fault must be retried away");
    }
}
