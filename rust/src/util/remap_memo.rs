//! Shared remap-pass memoization (S23): one type for the
//! per-(mode, DRAM, remapper) cycle memo that the single-controller DSE
//! evaluator ([`crate::dse::SimMemo`]) and the sharded sweep
//! ([`crate::shard::ShardedSweep`]) each used to hand-roll.
//!
//! The Tensor-Remapper pass runs on a fresh controller and never
//! touches the Cache Engine or the DMA Engine, so its simulated cycle
//! count depends only on the mode being remapped, the DRAM timing
//! knobs, and the remapper knobs.  Every candidate of a cache / DMA
//! grid — and every cell of a joint cross-product sweep that shares
//! those knobs — therefore reuses one simulation.  How the pass is
//! simulated differs per call site (the DSE evaluator replays a
//! snapshot column, the sharded sweep replays the live tensor column),
//! so the memo takes the simulation as a closure and owns only the
//! keying and the interior-mutable map.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::controller::{ControllerConfig, RemapperConfig};
use crate::mem::MemTechConfig;

/// Key of one memoized remap-pass simulation: the only knobs the pass
/// is sensitive to.
pub type RemapKey = (usize, MemTechConfig, RemapperConfig);

/// Interior-mutable memo of remap-pass cycles per [`RemapKey`], shared
/// across every candidate a sweep scores.
#[derive(Debug, Default)]
pub struct RemapMemo {
    map: Mutex<HashMap<RemapKey, u64>>,
}

impl RemapMemo {
    /// An empty memo.
    pub fn new() -> Self {
        RemapMemo::default()
    }

    /// The remap-pass cycles of `mode` under `cfg`'s DRAM / remapper
    /// knobs, running `simulate` only on the first request for this
    /// key.  Concurrent first requests may both simulate; they compute
    /// the identical (deterministic) value, so last-insert-wins is
    /// harmless — the lock is never held across the simulation.
    pub fn cycles(
        &self,
        mode: usize,
        cfg: &ControllerConfig,
        simulate: impl FnOnce() -> u64,
    ) -> u64 {
        let key: RemapKey = (mode, cfg.mem.clone(), cfg.remapper);
        if let Some(&c) = self.map.lock().expect("remap memo poisoned").get(&key) {
            return c;
        }
        let cycles = simulate();
        self.map
            .lock()
            .expect("remap memo poisoned")
            .insert(key, cycles);
        cycles
    }

    /// Number of distinct keys simulated so far.
    pub fn len(&self) -> usize {
        self.map.lock().expect("remap memo poisoned").len()
    }

    /// True when nothing has been memoized yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memo_simulates_once_per_key() {
        let memo = RemapMemo::new();
        let cfg = ControllerConfig::default_for(16);
        let mut calls = 0u32;
        let a = memo.cycles(0, &cfg, || {
            calls += 1;
            42
        });
        let b = memo.cycles(0, &cfg, || {
            calls += 1;
            unreachable!("second lookup must hit the memo")
        });
        assert_eq!((a, b), (42, 42));
        assert_eq!(calls, 1);
        assert_eq!(memo.len(), 1);
    }

    #[test]
    fn distinct_modes_and_knobs_key_separately() {
        let memo = RemapMemo::new();
        let cfg = ControllerConfig::default_for(16);
        let mut spilly = cfg.clone();
        spilly.remapper.max_pointers = 4;
        let mut wide = cfg.clone();
        wide.mem.ddr4_mut().channels = 4;
        assert_eq!(memo.cycles(0, &cfg, || 1), 1);
        assert_eq!(memo.cycles(1, &cfg, || 2), 2);
        assert_eq!(memo.cycles(0, &spilly, || 3), 3);
        assert_eq!(memo.cycles(0, &wide, || 4), 4);
        // Cache / DMA knobs are NOT part of the key: a candidate that
        // differs only there reuses the memoized pass.
        let mut cachey = cfg.clone();
        cachey.cache.num_lines = 64;
        cachey.dma.num_dmas = 4;
        assert_eq!(memo.cycles(0, &cachey, || unreachable!()), 1);
        assert_eq!(memo.len(), 4);
    }

    #[test]
    fn empty_and_len_track_inserts() {
        let memo = RemapMemo::new();
        assert!(memo.is_empty());
        memo.cycles(2, &ControllerConfig::default_for(16), || 9);
        assert!(!memo.is_empty());
    }
}
