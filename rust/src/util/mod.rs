//! Small shared utilities (S22): the scoped-thread fan-out helper used
//! by every batch-parallel path in the crate, the shared remap-pass
//! cycle memo the DSE evaluators key per (mode, DRAM, remapper), the
//! memory-budget plumbing (size parsing, peak-RSS observation,
//! spill-to-disk coordinate columns) behind `--memory-budget` (S24),
//! and the deterministic fault-injection registry (S31) guarding every
//! disk-touching surface.

pub mod budget;
pub mod codec;
pub mod fault;
pub mod par;
pub mod remap_memo;

pub use budget::{format_size, parse_size, peak_rss_bytes};
pub use codec::{
    decode_config, encode_config, fnv1a, read_frame, write_atomic, write_frame, ByteReader,
    ByteWriter, Fnv1a,
};
pub use fault::{retry_transient, FaultGuard};
pub use par::{effective_parallelism, parallel_indexed, set_parallelism_cap, Pool};
pub use remap_memo::{RemapKey, RemapMemo, SpillCol};
