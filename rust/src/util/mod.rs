//! Small shared utilities (S22): the scoped-thread fan-out helper used
//! by every batch-parallel path in the crate, and the shared
//! remap-pass cycle memo the DSE evaluators key per
//! (mode, DRAM, remapper).

pub mod par;
pub mod remap_memo;

pub use par::parallel_indexed;
pub use remap_memo::{RemapKey, RemapMemo};
