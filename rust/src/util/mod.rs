//! Small shared utilities (S22): the scoped-thread fan-out helper used
//! by every batch-parallel path in the crate.

pub mod par;

pub use par::parallel_indexed;
