//! Shard execution: one worker thread per shard, each computing its
//! partial MTTKRP and replaying its access trace on a private
//! [`MemoryController`].
//!
//! Numerics: each worker walks its shard's non-zeros in storage order
//! and owns every output row it touches, so the merged output is
//! bit-identical to the sequential oracle (same per-row accumulation
//! order) — no tolerance games between worker counts.
//!
//! Timing: workers model K controller instances running concurrently
//! (one per DRAM channel group, the paper's multi-SLR layout); the
//! simulated time of a mode is the *slowest* worker's makespan while
//! statistics are the *sum* over workers ([`AggregateStats`]).
//!
//! Pool-aware scheduling (S32): every host-thread fan-out in this
//! module goes through [`parallel_indexed`], which honours the
//! process-wide parallelism cap
//! ([`crate::util::set_parallelism_cap`]).  Inside the DSE server each
//! pool worker therefore fans its shard workers out over at most
//! `host_threads / pool_workers` threads — N concurrent jobs saturate
//! the host without oversubscribing it.  The cap changes scheduling
//! only: shard outputs and makespans stay bit-identical at any
//! setting.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

use super::{partition_indices, AggregateStats, ShardPlan, ShardSpec};
use crate::controller::{Access, CacheConfig, ControllerConfig, MemLayout, MemoryController};
use crate::coordinator::Metrics;
use crate::cpd::linalg::Mat;
use crate::engine::{
    EngineKind, GridClassification, JointIndex, PreparedTrace, TimingCandidate, TimingOps,
};
use crate::error::Error;
use crate::mttkrp::{oracle, STREAM_CHUNK_ELEMS};
use crate::tensor::{Coord, SparseTensor};
use crate::util::{fault, parallel_indexed, RemapMemo};

/// Result of one sharded MTTKRP mode execution.
#[derive(Debug)]
pub struct ShardedRun {
    /// The mode's full MTTKRP output (rows merged from all shards).
    pub output: Mat,
    /// The plan that produced it.
    pub plan: ShardPlan,
    /// Simulated cycles of the slowest worker (parallel makespan);
    /// 0 when run without controller simulation.
    pub makespan: u64,
    /// Per-shard controller statistics, summed.
    pub stats: AggregateStats,
    /// Wall-clock phase timings, merged across workers
    /// ([`Metrics::merge`]): `execute` = compute, `gather` = trace
    /// compilation, `accumulate` = controller replay.
    pub metrics: Metrics,
}

/// Compile the §4 access trace a shard's worker issues.
///
/// Addressing models the *mode-sorted* (post-remap) image of the
/// tensor: because shards are contiguous coordinate ranges, shard `i`'s
/// records occupy one contiguous region starting `record_offset`
/// records into the sorted image, so tensor loads stream in DMA-sized
/// chunks — Approach 1's layout precondition, met per shard by
/// construction.  Factor rows load through the worker's Cache Engine in
/// the shard's nnz order, and each owned output row stores once.
pub fn shard_trace(
    t: &SparseTensor,
    rank: usize,
    mode: usize,
    layout: &MemLayout,
    spec: &ShardSpec,
    zs: &[usize],
    record_offset: usize,
) -> Vec<Access> {
    let n = t.n_modes();
    let eb = t.record_bytes();
    let row_bytes = rank * 4;
    let tensor_base = layout.tensor_base[0];
    let mut trace = Vec::with_capacity(zs.len() * n + spec.rows());

    // 1. Tensor-record loads: one bulk stream per DMA-buffer chunk.
    let mut z = 0usize;
    while z < zs.len() {
        let n_chunk = (zs.len() - z).min(STREAM_CHUNK_ELEMS);
        trace.push(Access::Stream {
            addr: tensor_base + ((record_offset + z) * eb) as u64,
            bytes: n_chunk * eb,
        });
        z += n_chunk;
    }

    // 2. Input factor-row loads through the worker's Cache Engine.
    for &z in zs {
        for m in 0..n {
            if m == mode {
                continue;
            }
            trace.push(Access::Cached {
                addr: layout.factor_row_addr(m, t.mode_col(m)[z]),
                bytes: row_bytes,
            });
        }
    }

    // 3. One streaming store per output row this shard touched.
    let lo = spec.coord_lo as usize;
    let mut used = vec![false; spec.rows()];
    let col = t.mode_col(mode);
    for &z in zs {
        used[col[z] as usize - lo] = true;
    }
    for (off, &u) in used.iter().enumerate() {
        if u {
            trace.push(Access::Stream {
                addr: layout.factor_row_addr(mode, (lo + off) as Coord),
                bytes: row_bytes,
            });
        }
    }
    trace
}

/// One worker's numeric kernel: the shared oracle inner loop
/// ([`oracle::accumulate_into`]) over the shard's non-zeros,
/// accumulated into the shard's local row block.
fn shard_mttkrp(
    t: &SparseTensor,
    factors: &[Mat],
    mode: usize,
    spec: &ShardSpec,
    zs: &[usize],
) -> Mat {
    let mut out = Mat::zeros(spec.rows(), factors[0].cols());
    oracle::accumulate_into(
        t,
        factors,
        mode,
        zs.iter().copied(),
        spec.coord_lo as usize,
        &mut out,
    );
    out
}

/// Per-worker controller configuration.  The memory device's parallel
/// units (DDR4 channels, HBM2 pseudo-channels, oSRAM ports) are split
/// equally across the K instances (rounded down to a power of two for
/// the address map); once the split reaches one unit, each further
/// instance models its *own* single-unit group — the paper's multi-SLR
/// scale-out layout (one DIMM per SLR), not K instances time-sharing
/// one bus.  Deployments on a fixed device must therefore bound K by
/// the device's unit count, which is exactly what
/// [`crate::dse::Evaluator::ShardedSim`] enforces.  Every other knob
/// models per-instance on-chip resources and stays as configured.
fn worker_cfg(cfg: &ControllerConfig, k: usize) -> ControllerConfig {
    let mut c = cfg.clone();
    c.mem = c.mem.split_for_workers(k);
    c
}

/// Per-worker simulation request: controller parameters, memory
/// layout, and which replay core drives the shard's trace.
#[derive(Clone, Copy)]
struct SimSpec<'a> {
    cfg: &'a ControllerConfig,
    layout: &'a MemLayout,
    engine: EngineKind,
}

/// Render a `catch_unwind` payload to text (panic messages are almost
/// always `&str` or `String`).
fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked with a non-string payload".to_string()
    }
}

/// Supervise one shard worker body (S31): catch panics instead of
/// poisoning the join, retry transient IO faults with exponential
/// backoff, and convert any terminal failure into a clean
/// [`Error::worker_failed`] naming the shard.
fn supervised<T>(shard: usize, body: impl Fn() -> T) -> crate::error::Result<T> {
    const ATTEMPTS: u32 = 3;
    let mut delay = Duration::from_millis(1);
    for attempt in 0..ATTEMPTS {
        match catch_unwind(AssertUnwindSafe(|| -> std::io::Result<T> {
            fault::check_io(fault::SHARD_WORKER)?;
            Ok(body())
        })) {
            Ok(Ok(v)) => return Ok(v),
            Ok(Err(e)) if fault::is_transient(e.kind()) && attempt + 1 < ATTEMPTS => {
                std::thread::sleep(delay);
                delay *= 2;
            }
            Ok(Err(e)) => return Err(Error::worker_failed(shard, e)),
            Err(payload) => return Err(Error::worker_failed(shard, panic_text(&*payload))),
        }
    }
    unreachable!("the final attempt always returns")
}

/// The full worker body: compute, then (optionally) compile and replay
/// the shard's trace on a fresh controller.
fn worker(
    t: &SparseTensor,
    factors: &[Mat],
    mode: usize,
    spec: &ShardSpec,
    zs: &[usize],
    record_offset: usize,
    sim: Option<SimSpec<'_>>,
) -> (Mat, Metrics, Option<MemoryController>) {
    let t0 = Instant::now();
    let local = shard_mttkrp(t, factors, mode, spec, zs);
    let execute = t0.elapsed();

    let mut gather = Duration::ZERO;
    let mut accumulate = Duration::ZERO;
    let ctl = sim.map(|s| {
        let t1 = Instant::now();
        let trace = shard_trace(t, factors[0].cols(), mode, s.layout, spec, zs, record_offset);
        gather = t1.elapsed();
        let mut ctl = MemoryController::new(s.cfg.clone());
        let t2 = Instant::now();
        s.engine.replay_raw(&mut ctl, &trace);
        accumulate = t2.elapsed();
        ctl
    });

    let metrics = Metrics {
        blocks: 1,
        nnz: zs.len() as u64,
        gather,
        execute,
        accumulate,
        ..Default::default()
    };
    (local, metrics, ctl)
}

/// Execute one mode's MTTKRP across `k` shard worker threads.
///
/// With `sim = Some((cfg, layout))` every worker also drives its own
/// [`MemoryController`] instance over its shard's trace; the run's
/// `makespan` is the slowest worker's clock and `stats` the merged
/// counters.  With `sim = None` only the numeric result is produced
/// (the fast path `cp_als` uses through [`super::ParallelBackend`]).
///
/// The tensor is *not* re-ordered — sharding works in any storage
/// order, so no host-side sort happens here.  The *simulated* cost of
/// producing the mode-sorted image the traces assume is charged by the
/// callers that model it ([`super::ParallelBackend`] and
/// [`ShardedSweep::makespan`] each add a Tensor-Remapper pass).
pub fn mttkrp_sharded(
    t: &SparseTensor,
    factors: &[Mat],
    mode: usize,
    k: usize,
    sim: Option<(&ControllerConfig, &MemLayout)>,
) -> ShardedRun {
    mttkrp_sharded_with_engine(t, factors, mode, k, sim, EngineKind::Lockstep)
}

/// [`mttkrp_sharded`] with an explicit replay core for the per-worker
/// controller simulation.  The two engines are bit-identical in cycles
/// and statistics ([`crate::engine`]); `Event` is faster on large
/// shards, `Lockstep` is the legacy default.
pub fn mttkrp_sharded_with_engine(
    t: &SparseTensor,
    factors: &[Mat],
    mode: usize,
    k: usize,
    sim: Option<(&ControllerConfig, &MemLayout)>,
    engine: EngineKind,
) -> ShardedRun {
    try_mttkrp_sharded_with_engine(t, factors, mode, k, sim, engine)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible [`mttkrp_sharded_with_engine`]: a worker panic or a
/// persistent IO fault surfaces as [`Error::worker_failed`] instead of
/// a poisoned join.
pub fn try_mttkrp_sharded_with_engine(
    t: &SparseTensor,
    factors: &[Mat],
    mode: usize,
    k: usize,
    sim: Option<(&ControllerConfig, &MemLayout)>,
    engine: EngineKind,
) -> crate::error::Result<ShardedRun> {
    assert!(k >= 1, "need at least one worker");
    let plan = ShardPlan::balance(t, mode, k);
    let parts = partition_indices(t, &plan);
    try_mttkrp_planned_with_engine(t, factors, &plan, &parts, sim, engine)
}

/// Like [`mttkrp_sharded`] with a precomputed plan and partition —
/// callers that reuse a plan across ALS iterations (the tensor never
/// changes on [`super::ParallelBackend`]) skip the two O(nnz) planning
/// passes on every call.  `parts` must be the output of
/// [`partition_indices`] for `plan` on this tensor.
pub fn mttkrp_planned(
    t: &SparseTensor,
    factors: &[Mat],
    plan: &ShardPlan,
    parts: &[Vec<usize>],
    sim: Option<(&ControllerConfig, &MemLayout)>,
) -> ShardedRun {
    mttkrp_planned_with_engine(t, factors, plan, parts, sim, EngineKind::Lockstep)
}

/// [`mttkrp_planned`] with an explicit replay core (see
/// [`mttkrp_sharded_with_engine`]).
pub fn mttkrp_planned_with_engine(
    t: &SparseTensor,
    factors: &[Mat],
    plan: &ShardPlan,
    parts: &[Vec<usize>],
    sim: Option<(&ControllerConfig, &MemLayout)>,
    engine: EngineKind,
) -> ShardedRun {
    try_mttkrp_planned_with_engine(t, factors, plan, parts, sim, engine)
        .unwrap_or_else(|e| panic!("{e}"))
}

/// Fallible [`mttkrp_planned_with_engine`]: every shard worker runs
/// under [`supervised`] — panics are caught, transient IO faults are
/// retried with backoff, and the first failed shard aborts the mode
/// with a typed [`Error::worker_failed`] (the merge never sees partial
/// results).
pub fn try_mttkrp_planned_with_engine(
    t: &SparseTensor,
    factors: &[Mat],
    plan: &ShardPlan,
    parts: &[Vec<usize>],
    sim: Option<(&ControllerConfig, &MemLayout)>,
    engine: EngineKind,
) -> crate::error::Result<ShardedRun> {
    debug_assert_eq!(parts.len(), plan.k(), "partition/plan mismatch");
    let mode = plan.mode;
    let r = factors[0].cols();

    // Record offset of each shard in the mode-sorted tensor image
    // (prefix sums of shard nnz) — the trace's streaming base.
    let offsets: Vec<usize> = plan
        .shards
        .iter()
        .scan(0usize, |acc, s| {
            let off = *acc;
            *acc += s.nnz;
            Some(off)
        })
        .collect();

    // K concurrent instances share the board's DRAM channels: each
    // worker's controller models its slice, not the whole bus.
    let wcfg = sim.map(|(cfg, _)| worker_cfg(cfg, plan.k()));
    let sim_w: Option<SimSpec<'_>> = match (&wcfg, sim) {
        (Some(c), Some((_, layout))) => Some(SimSpec {
            cfg: c,
            layout,
            engine,
        }),
        _ => None,
    };

    let results: Vec<crate::error::Result<(Mat, Metrics, Option<MemoryController>)>> =
        parallel_indexed(plan.shards.len(), |i| {
            supervised(i, || {
                worker(t, factors, mode, &plan.shards[i], &parts[i], offsets[i], sim_w)
            })
        });

    let mut output = Mat::zeros(t.dims()[mode], r);
    let mut metrics = Metrics::default();
    let mut stats = AggregateStats::default();
    let mut makespan = 0u64;
    for (spec, res) in plan.shards.iter().zip(results) {
        let (local, m, ctl) = res?;
        for (off, c) in (spec.coord_lo..spec.coord_hi).enumerate() {
            output.row_mut(c as usize).copy_from_slice(local.row(off));
        }
        metrics.merge(&m);
        if let Some(ctl) = ctl {
            makespan = makespan.max(ctl.now());
            stats.absorb(&ctl);
        }
    }

    Ok(ShardedRun {
        output,
        plan: plan.clone(),
        makespan,
        stats,
        metrics,
    })
}

/// Precomputed, configuration-independent inputs of a sharded DSE
/// sweep: per-mode shard plans and prepared access traces (raw +
/// delta-encoded, [`PreparedTrace`]).  Trace addresses depend only on
/// tensor shape, rank, and worker count — never on the controller
/// parameters being scored — so the expensive planning and trace
/// compilation runs once per (tensor, mode) while
/// [`ShardedSweep::makespan`] scores each candidate configuration with
/// replay only (no numeric MTTKRP is computed at all on this path).
///
/// The replay core is selectable ([`EngineKind`]): the legacy
/// `Lockstep` path re-simulates everything per candidate; the `Event`
/// path replays the compressed traces with the batched kernels, runs
/// the K shard replays on concurrent host threads (they are
/// independent fresh controller instances — the max is
/// order-invariant), and memoizes the sequential remap pass per
/// (mode, DRAM, remapper) key.  Both paths return bit-identical
/// makespans.
pub struct ShardedSweep<'a> {
    t: &'a SparseTensor,
    layout: MemLayout,
    workers: usize,
    rank: usize,
    engine: EngineKind,
    /// Per mode: the shard plan and each shard's prepared trace.
    modes: Vec<(ShardPlan, Vec<PreparedTrace>)>,
    /// Shared memo of remap-pass cycles per (mode, DRAM, remapper) key
    /// ([`crate::util::RemapMemo`] — the same type the single-controller
    /// DSE evaluator uses).
    remap_memo: RemapMemo,
}

impl<'a> ShardedSweep<'a> {
    /// Plan and compile every mode's per-shard traces for `workers`
    /// shards at factor rank `rank`, scored with the event engine.
    pub fn prepare(t: &'a SparseTensor, rank: usize, workers: usize) -> Self {
        Self::prepare_with_engine(t, rank, workers, EngineKind::Event)
    }

    /// [`ShardedSweep::prepare`] with an explicit default replay core.
    pub fn prepare_with_engine(
        t: &'a SparseTensor,
        rank: usize,
        workers: usize,
        engine: EngineKind,
    ) -> Self {
        let workers = workers.max(1);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
        let modes = (0..t.n_modes())
            .map(|mode| {
                let plan = ShardPlan::balance(t, mode, workers);
                let parts = partition_indices(t, &plan);
                let mut offset = 0usize;
                let traces: Vec<PreparedTrace> = plan
                    .shards
                    .iter()
                    .zip(&parts)
                    .map(|(spec, zs)| {
                        let tr = shard_trace(t, rank, mode, &layout, spec, zs, offset);
                        offset += spec.nnz;
                        PreparedTrace::new(tr)
                    })
                    .collect();
                (plan, traces)
            })
            .collect();
        ShardedSweep {
            t,
            layout,
            workers,
            rank,
            engine,
            modes,
            remap_memo: RemapMemo::new(),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Factor rank the traces were compiled for (part of the
    /// warm-cache context key, [`crate::dse::warm::KeyBuilder`]).
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The tensor the sweep was prepared over (fingerprinted by the
    /// warm-start layer, [`crate::dse::warm::tensor_fingerprint`]).
    pub fn tensor(&self) -> &SparseTensor {
        self.t
    }

    /// The sweep's default replay core.
    pub fn engine(&self) -> EngineKind {
        self.engine
    }

    /// Simulated cycles of a full sweep under `cfg` with the sweep's
    /// default engine: per mode, one sequential Tensor-Remapper pass
    /// (the mode-sorted image the shard traces assume has to be
    /// produced first; it owns the whole memory system) plus the
    /// slowest shard's replay, each shard on its own controller
    /// instance with its slice of the DRAM channels.
    pub fn makespan(&self, cfg: &ControllerConfig) -> u64 {
        self.makespan_with(cfg, self.engine)
    }

    /// [`ShardedSweep::makespan`] under an explicit replay core.  Both
    /// cores return the same value; `Event` gets there faster (batched
    /// replay, concurrent shards, memoized remap passes).
    pub fn makespan_with(&self, cfg: &ControllerConfig, engine: EngineKind) -> u64 {
        let wcfg = worker_cfg(cfg, self.workers);
        let mut total = 0u64;
        for (mode, (_plan, traces)) in self.modes.iter().enumerate() {
            let (remap_cycles, worst) = match engine {
                EngineKind::Lockstep => {
                    let remap = self.remap_cycles(mode, cfg);
                    let worst = traces
                        .iter()
                        .map(|tr| MemoryController::new(wcfg.clone()).replay(tr.raw()))
                        .max()
                        .unwrap_or(0);
                    (remap, worst)
                }
                // A single-configuration makespan has no grid to
                // amortize, so `Grid` scores it exactly like `Event`;
                // the one-pass path is `makespans_for_cache_grid`.
                EngineKind::Event | EngineKind::Grid => {
                    let remap = self.remap_cycles_memoized(mode, cfg);
                    // Shards are independent fresh controller instances;
                    // the max is order-invariant, so the concurrent
                    // fan-out cannot change the score.
                    let per_shard = parallel_indexed(traces.len(), |i| {
                        MemoryController::new(wcfg.clone()).replay_events(traces[i].compressed())
                    });
                    (remap, per_shard.into_iter().max().unwrap_or(0))
                }
            };
            total += remap_cycles + worst;
        }
        total
    }

    /// Score a whole cache-module grid in one pass per shard trace:
    /// classify every `(line_bytes, num_lines, assoc)` candidate
    /// simultaneously with the stack-distance grid core
    /// ([`GridClassification`]), then time each candidate by replaying
    /// only its miss stream.  `base` supplies the fixed DRAM / DMA /
    /// remapper knobs (the remap pass is cache-independent, so the
    /// whole grid shares one memoized remap simulation per mode).
    /// Returns one makespan per candidate, in `caches` order — each
    /// bit-identical to `makespan_with` of the same full configuration
    /// under either classic engine.
    pub fn makespans_for_cache_grid(
        &self,
        base: &ControllerConfig,
        caches: &[CacheConfig],
    ) -> Vec<u64> {
        let wcfg = worker_cfg(base, self.workers);
        let mut totals = vec![0u64; caches.len()];
        if caches.is_empty() {
            return totals;
        }
        for (mode, (_plan, traces)) in self.modes.iter().enumerate() {
            let remap = self.remap_cycles_memoized(mode, base);
            // Per shard: one classification pass, then the per-candidate
            // miss-only replays.  Shards are independent controller
            // instances — classify and replay them on concurrent host
            // threads, exactly like the event path replays them.
            let replay_shard = |tr: &PreparedTrace| -> Vec<u64> {
                let cls = GridClassification::classify(tr.compressed(), caches);
                caches
                    .iter()
                    .enumerate()
                    .map(|(ci, cc)| {
                        let mut cfg = wcfg.clone();
                        cfg.cache = *cc;
                        cls.replay(ci, tr.compressed(), &cfg).cycles
                    })
                    .collect()
            };
            let per_shard: Vec<Vec<u64>> =
                parallel_indexed(traces.len(), |i| replay_shard(&traces[i]));
            for (ci, total) in totals.iter_mut().enumerate() {
                let worst = per_shard.iter().map(|v| v[ci]).max().unwrap_or(0);
                *total += remap + worst;
            }
        }
        totals
    }

    /// Score a whole DRAM/DMA timing grid in one walk per shard trace:
    /// classify the (fixed) `base.cache` once per shard, extract its
    /// miss/stream op queue, then advance every candidate's DRAM/DMA
    /// lane simultaneously with the vectorized timing core
    /// ([`crate::engine::timing`]).  `cands` are full configurations
    /// whose `cache` must equal `base.cache`; their DRAM, DMA, and
    /// remapper knobs may all differ (the per-candidate remap pass is
    /// memoized per (mode, DRAM, remapper) key, and each candidate's
    /// worker lanes model its own channel split).  Shards classify and
    /// time on concurrent host threads, exactly like the event path
    /// replays them.  Returns one makespan per candidate, in `cands`
    /// order — each bit-identical to `makespan_with` of the same
    /// configuration under either classic engine.
    pub fn makespans_for_timing_grid(
        &self,
        base: &ControllerConfig,
        cands: &[ControllerConfig],
    ) -> Vec<u64> {
        let mut totals = vec![0u64; cands.len()];
        if cands.is_empty() {
            return totals;
        }
        for c in cands {
            assert_eq!(
                c.cache, base.cache,
                "timing-grid candidates must share the classified cache module"
            );
        }
        // Each candidate's lane models a *worker instance*: its slice
        // of the candidate's own DRAM channels plus its DMA engine.
        // Candidates that collapse to the same worker lane (remapper
        // variants, channel counts with the same per-worker split)
        // are timed once and fanned back out.
        let (lanes, lane_of) = TimingCandidate::dedup(
            cands
                .iter()
                .map(|c| TimingCandidate::of(&worker_cfg(c, self.workers)))
                .collect(),
        );
        for (mode, (_plan, traces)) in self.modes.iter().enumerate() {
            let single_shard = traces.len() == 1;
            let time_shard = |tr: &PreparedTrace| -> Vec<u64> {
                let cls = GridClassification::classify(tr.compressed(), &[base.cache]);
                let ops = TimingOps::extract(&cls, 0, tr.compressed());
                // With one shard the host threads are free for the
                // lanes themselves; with many shards the shard fan-out
                // below already saturates them.
                let runs = if single_shard {
                    ops.time_grid_parallel(&lanes)
                } else {
                    ops.time_grid(&lanes)
                };
                runs.into_iter().map(|r| r.cycles).collect()
            };
            let per_shard: Vec<Vec<u64>> =
                parallel_indexed(traces.len(), |i| time_shard(&traces[i]));
            for (ci, total) in totals.iter_mut().enumerate() {
                let lane = lane_of[ci];
                let worst = per_shard.iter().map(|v| v[lane]).max().unwrap_or(0);
                *total += self.remap_cycles_memoized(mode, &cands[ci]) + worst;
            }
        }
        totals
    }

    /// Score an arbitrary **joint** cross product — candidates free in
    /// cache, DRAM, DMA, *and* remapper knobs — with the hierarchical
    /// sweep core ([`crate::engine::sweep`]): per shard trace, one
    /// classification pass per distinct `line_bytes`, one op-queue
    /// extraction per distinct cache candidate, one multi-lane walk per
    /// cache's DRAM/DMA lane set.  Each candidate's lane models its own
    /// worker instance (its channel split under this sweep's worker
    /// count), candidates collapsing to the same `(cache, lane)` cell
    /// are timed once, and the per-candidate remap pass is memoized per
    /// (mode, DRAM, remapper) key.  The traversal fans out over the
    /// flattened (shard x cache) task grid, so the host saturates even
    /// when one dimension is small.  Returns one makespan per
    /// candidate, in `cands` order — each bit-identical to
    /// `makespan_with` of the same configuration under either classic
    /// engine.
    pub fn makespans_for_joint_grid(&self, cands: &[ControllerConfig]) -> Vec<u64> {
        let mut totals = vec![0u64; cands.len()];
        if cands.is_empty() {
            return totals;
        }
        let pairs: Vec<(CacheConfig, TimingCandidate)> = cands
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(&worker_cfg(c, self.workers))))
            .collect();
        let index = JointIndex::build(&pairs);
        for (mode, (_plan, traces)) in self.modes.iter().enumerate() {
            // One flattened (shard x cache) fan-out per mode: neither
            // the shard count nor the cache count alone has to cover
            // the host's cores ([`JointIndex::sweep_many`]).
            let refs: Vec<_> = traces.iter().map(|t| t.compressed()).collect();
            let per_shard = index.sweep_many(&refs);
            for (ci, total) in totals.iter_mut().enumerate() {
                let worst = per_shard.iter().map(|v| v[ci]).max().unwrap_or(0);
                *total += self.remap_cycles_memoized(mode, &cands[ci]) + worst;
            }
        }
        totals
    }

    /// Memoized [`ShardedSweep::remap_cycles`]: the remap pass depends
    /// only on (mode, DRAM, remapper), so every candidate sharing those
    /// knobs — the entire cache/DMA grid, and every joint-sweep cell —
    /// reuses one simulation ([`RemapMemo`]).
    fn remap_cycles_memoized(&self, mode: usize, cfg: &ControllerConfig) -> u64 {
        self.remap_memo
            .cycles(mode, cfg, || self.remap_cycles(mode, cfg))
    }

    /// One mode's remap-pass cycles under `cfg`, on a fresh controller
    /// (exactly how both engines account the sequential remap phase).
    fn remap_cycles(&self, mode: usize, cfg: &ControllerConfig) -> u64 {
        let mut remap_ctl = MemoryController::new(cfg.clone());
        remap_ctl.remap_pass(
            self.t.mode_col(mode),
            self.t.dims()[mode],
            &self.layout,
            0,
            1,
        )
    }
}

/// Total simulated cycles of a full K-worker sweep over every mode —
/// the objective the DSE minimizes when evaluating a controller
/// configuration per-shard ([`crate::dse::Evaluator::ShardedSim`]).
/// One-shot convenience over [`ShardedSweep`]; scoring many
/// configurations should [`ShardedSweep::prepare`] once instead.
pub fn sweep_makespan(
    t: &SparseTensor,
    factors: &[Mat],
    cfg: &ControllerConfig,
    workers: usize,
) -> u64 {
    ShardedSweep::prepare(t, factors[0].cols(), workers).makespan(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle;
    use crate::tensor::synth::{generate, Profile, SynthConfig};

    fn setup(seed: u64, nnz: usize) -> (SparseTensor, Vec<Mat>) {
        let t = generate(&SynthConfig {
            dims: vec![250, 180, 120],
            nnz,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed,
        });
        let factors = t
            .dims()
            .iter()
            .enumerate()
            .map(|(m, &d)| Mat::randn(d, 8, seed + m as u64))
            .collect();
        (t, factors)
    }

    #[test]
    fn sharded_matches_oracle_for_1_2_4_workers() {
        let (t, factors) = setup(11, 4_000);
        for mode in 0..3 {
            let want = oracle::mttkrp(&t, &factors, mode);
            for k in [1, 2, 4] {
                let run = mttkrp_sharded(&t, &factors, mode, k, None);
                // Same per-row accumulation order as the oracle: the
                // results are bit-identical, not merely close.
                assert_eq!(
                    run.output.data(),
                    want.data(),
                    "mode {mode} k {k} diverged from oracle"
                );
            }
        }
    }

    #[test]
    fn merged_stats_equal_sum_of_per_shard_replays() {
        use crate::controller::ControllerConfig;
        let (t, factors) = setup(12, 3_000);
        let cfg = ControllerConfig::default_for(t.record_bytes());
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
        let k = 3;
        let run = mttkrp_sharded(&t, &factors, 1, k, Some((&cfg, &layout)));

        // Recompute each shard's trace independently and sum the stats;
        // the run's aggregate must match exactly.
        let plan = ShardPlan::balance(&t, 1, k);
        let parts = partition_indices(&t, &plan);
        let mut want = AggregateStats::default();
        let mut want_makespan = 0u64;
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, 8, 1, &layout, spec, zs, offset);
            offset += spec.nnz;
            let mut ctl = MemoryController::new(cfg.clone());
            ctl.replay(&trace);
            want_makespan = want_makespan.max(ctl.now());
            want.absorb(&ctl);
        }
        assert_eq!(run.stats.controller, want.controller);
        assert_eq!(run.stats.cache, want.cache);
        assert_eq!(run.stats.dma, want.dma);
        assert_eq!(run.stats.dram, want.dram);
        assert_eq!(run.stats.controllers, k as u64);
        assert_eq!(run.makespan, want_makespan);
        assert!(run.makespan > 0);
    }

    #[test]
    fn parallel_makespan_beats_single_worker() {
        use crate::controller::ControllerConfig;
        let (t, factors) = setup(13, 8_000);
        let cfg = ControllerConfig::default_for(t.record_bytes());
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
        let m1 = mttkrp_sharded(&t, &factors, 0, 1, Some((&cfg, &layout))).makespan;
        let m4 = mttkrp_sharded(&t, &factors, 0, 4, Some((&cfg, &layout))).makespan;
        assert!(
            m4 < m1,
            "4 workers ({m4} cycles) must beat 1 worker ({m1} cycles)"
        );
    }

    #[test]
    fn trace_covers_all_shard_bytes() {
        let (t, factors) = setup(14, 2_000);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
        let plan = ShardPlan::balance(&t, 0, 4);
        let parts = partition_indices(&t, &plan);
        let r = factors[0].cols();
        let mut tensor_bytes = 0usize;
        let mut cached = 0usize;
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, r, 0, &layout, spec, zs, offset);
            offset += spec.nnz;
            for a in trace {
                match a {
                    Access::Stream { addr, bytes } if addr < layout.tensor_base[1] => {
                        tensor_bytes += bytes
                    }
                    Access::Stream { .. } => {} // output-row store
                    Access::Cached { .. } => cached += 1,
                    _ => panic!("sharded Approach-1 trace must not issue {a:?}"),
                }
            }
        }
        assert_eq!(tensor_bytes, t.nnz() * t.record_bytes());
        assert_eq!(cached, t.nnz() * 2);
    }

    #[test]
    fn metrics_merge_across_workers() {
        let (t, factors) = setup(15, 1_000);
        let run = mttkrp_sharded(&t, &factors, 2, 4, None);
        assert_eq!(run.metrics.blocks, 4, "one block entry per worker");
        assert_eq!(run.metrics.nnz, 1_000);
        assert_eq!(run.makespan, 0, "no simulation requested");
        assert_eq!(run.stats.controllers, 0);
    }

    #[test]
    fn workers_split_the_dram_channels() {
        use crate::controller::ControllerConfig;
        // On a 4-channel board, 4 workers get 1 channel each: the run's
        // makespan must equal replaying each shard trace on an
        // explicitly single-channel controller — not on the full bus.
        let (t, factors) = setup(18, 4_000);
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.mem.ddr4_mut().channels = 4;
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
        let run = mttkrp_sharded(&t, &factors, 0, 4, Some((&cfg, &layout)));

        let plan = ShardPlan::balance(&t, 0, 4);
        let parts = partition_indices(&t, &plan);
        let mut single = cfg.clone();
        single.mem.ddr4_mut().channels = 1;
        let mut want = 0u64;
        let mut offset = 0usize;
        for (spec, zs) in plan.shards.iter().zip(&parts) {
            let trace = shard_trace(&t, 8, 0, &layout, spec, zs, offset);
            offset += spec.nnz;
            want = want.max(MemoryController::new(single.clone()).replay(&trace));
        }
        assert_eq!(run.makespan, want);
    }

    #[test]
    fn sweep_charges_remap_on_top_of_slowest_shard() {
        use crate::controller::ControllerConfig;
        let (t, factors) = setup(16, 1_500);
        let cfg = ControllerConfig::default_for(t.record_bytes());
        let total = sweep_makespan(&t, &factors, &cfg, 2);
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
        let compute_only: u64 = (0..3)
            .map(|m| mttkrp_sharded(&t, &factors, m, 2, Some((&cfg, &layout))).makespan)
            .sum();
        assert!(
            total > compute_only,
            "sweep must also charge the remap passes: {total} vs {compute_only}"
        );
        // Deterministic, and equal to the prepared-sweep path it wraps.
        let sweep = ShardedSweep::prepare(&t, 8, 2);
        assert_eq!(sweep.workers(), 2);
        assert_eq!(total, sweep.makespan(&cfg));
    }

    #[test]
    fn cache_grid_makespans_match_per_candidate_scoring() {
        use crate::controller::ControllerConfig;
        // The one-pass grid path must return exactly what scoring each
        // candidate individually returns, for every candidate.
        let (t, _factors) = setup(19, 3_000);
        let sweep = ShardedSweep::prepare(&t, 8, 3);
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut caches = Vec::new();
        for &(line_bytes, num_lines, assoc) in
            &[(64usize, 256usize, 2usize), (64, 1024, 4), (128, 512, 4), (32, 4096, 8)]
        {
            caches.push(CacheConfig {
                line_bytes,
                num_lines,
                assoc,
                hit_latency: base.cache.hit_latency,
            });
        }
        let grid_scores = sweep.makespans_for_cache_grid(&base, &caches);
        assert_eq!(grid_scores.len(), caches.len());
        for (cc, &got) in caches.iter().zip(&grid_scores) {
            let mut cfg = base.clone();
            cfg.cache = *cc;
            assert_eq!(
                got,
                sweep.makespan_with(&cfg, EngineKind::Event),
                "grid makespan diverged for {cc:?}"
            );
            assert_eq!(got, sweep.makespan_with(&cfg, EngineKind::Lockstep));
        }
    }

    #[test]
    fn timing_grid_makespans_match_per_candidate_scoring() {
        use crate::controller::ControllerConfig;
        use crate::dram::RowPolicy;
        // The one-walk DRAM/DMA path must return exactly what scoring
        // each candidate individually returns — including candidates
        // whose channel count splits differently across workers and
        // candidates that vary the remapper (distinct remap-memo keys).
        let (t, _factors) = setup(20, 3_000);
        let sweep = ShardedSweep::prepare(&t, 8, 3);
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cands = Vec::new();
        for &(channels, banks, policy) in &[
            (1usize, 16usize, RowPolicy::Open),
            (4, 8, RowPolicy::Open),
            (2, 16, RowPolicy::Closed),
        ] {
            for &(num_dmas, buffer_bytes) in &[(1usize, 1024usize), (2, 4096)] {
                let mut cfg = base.clone();
                {
                    let dram = cfg.mem.ddr4_mut();
                    dram.channels = channels;
                    dram.banks = banks;
                    dram.row_policy = policy;
                }
                cfg.dma.num_dmas = num_dmas;
                cfg.dma.buffer_bytes = buffer_bytes;
                cands.push(cfg);
            }
        }
        let mut spilly = base.clone();
        spilly.remapper.max_pointers = 4;
        cands.push(spilly);
        let grid_scores = sweep.makespans_for_timing_grid(&base, &cands);
        assert_eq!(grid_scores.len(), cands.len());
        for (cfg, &got) in cands.iter().zip(&grid_scores) {
            assert_eq!(
                got,
                sweep.makespan_with(cfg, EngineKind::Event),
                "timing makespan diverged for {:?}/{:?}",
                cfg.mem,
                cfg.dma
            );
            assert_eq!(got, sweep.makespan_with(cfg, EngineKind::Lockstep));
        }
    }

    #[test]
    fn joint_grid_makespans_match_per_candidate_scoring() {
        use crate::controller::ControllerConfig;
        use crate::dram::RowPolicy;
        // The hierarchical joint path must return exactly what scoring
        // each full (cache x DRAM x DMA x remapper) candidate
        // individually returns — candidates vary every module at once,
        // including worker channel splits and distinct remap-memo keys.
        let (t, _factors) = setup(21, 3_000);
        let sweep = ShardedSweep::prepare(&t, 8, 3);
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cands = Vec::new();
        for &(line_bytes, num_lines, assoc) in
            &[(64usize, 256usize, 2usize), (32, 1024, 4), (128, 512, 4)]
        {
            for &(channels, policy, num_dmas) in &[
                (1usize, RowPolicy::Open, 1usize),
                (4, RowPolicy::Closed, 2),
            ] {
                let mut cfg = base.clone();
                cfg.cache.line_bytes = line_bytes;
                cfg.cache.num_lines = num_lines;
                cfg.cache.assoc = assoc;
                cfg.mem.ddr4_mut().channels = channels;
                cfg.mem.ddr4_mut().row_policy = policy;
                cfg.dma.num_dmas = num_dmas;
                cands.push(cfg);
            }
        }
        let mut spilly = base.clone();
        spilly.remapper.max_pointers = 4;
        cands.push(spilly);
        let got = sweep.makespans_for_joint_grid(&cands);
        assert_eq!(got.len(), cands.len());
        for (cfg, &score) in cands.iter().zip(&got) {
            assert_eq!(
                score,
                sweep.makespan_with(cfg, EngineKind::Event),
                "joint makespan diverged for {:?}",
                cfg.cache
            );
            assert_eq!(score, sweep.makespan_with(cfg, EngineKind::Lockstep));
        }
    }

    #[test]
    fn sweep_is_sensitive_to_remapper_pointer_budget() {
        use crate::controller::ControllerConfig;
        let (t, factors) = setup(17, 2_000);
        let cfg = ControllerConfig::default_for(t.record_bytes());
        let base = sweep_makespan(&t, &factors, &cfg, 2);
        let mut spills = cfg.clone();
        spills.remapper.max_pointers = 4;
        let spilled = sweep_makespan(&t, &factors, &spills, 2);
        assert!(
            spilled > base,
            "pointer spills must cost remap cycles: {spilled} vs {base}"
        );
    }
}
