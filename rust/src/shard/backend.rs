//! [`ParallelBackend`]: the sharded executor packaged as a
//! [`MttkrpBackend`], so [`crate::cpd::cp_als`] runs unchanged on K
//! worker threads.

use std::collections::HashMap;

use super::exec::try_mttkrp_planned_with_engine;
use super::{partition_indices, AggregateStats, ShardPlan};
use crate::controller::{ControllerConfig, MemLayout, MemoryController};
use crate::coordinator::Metrics;
use crate::cpd::linalg::Mat;
use crate::cpd::MttkrpBackend;
use crate::tensor::{SortOrder, SparseTensor};

/// Multi-threaded MTTKRP backend: every call shards the output mode
/// across `workers` threads.  Optionally simulates one
/// [`crate::controller::MemoryController`] per worker; simulated time
/// accumulates as the sum over modes of the slowest worker's makespan
/// (modes are sequential in CP-ALS, workers within a mode are parallel).
///
/// Numerically the backend is bit-identical to
/// [`crate::cpd::NativeBackend`] for any worker count (each output row
/// is owned by one shard and accumulated in oracle order).
pub struct ParallelBackend {
    workers: usize,
    cfg: Option<ControllerConfig>,
    layout: Option<MemLayout>,
    stats: AggregateStats,
    metrics: Metrics,
    cycles: u64,
    last_plan: Option<ShardPlan>,
    /// Per-mode (plan, partition) cache: the backend never re-orders
    /// the tensor, so across ALS iterations the two O(nnz) planning
    /// passes only run once per mode.  Invalidated (together with the
    /// layout and sim memo) when the tensor's fingerprint
    /// (dims, nnz, sort order) changes.
    plan_cache: HashMap<usize, (ShardPlan, Vec<Vec<usize>>)>,
    /// Per-mode memoized simulation accounting: traces and replays are
    /// iteration-invariant (addresses depend on indices and rank, not
    /// factor values), so the full per-shard simulation runs once per
    /// mode and later iterations merge the memoized numbers.
    sim_cache: HashMap<usize, SimMemo>,
    /// (dims, nnz, sort order, rank) the caches were computed for.
    fingerprint: Option<(Vec<usize>, usize, SortOrder, usize)>,
    /// The typed worker failure stashed just before `mttkrp` unwinds
    /// (the [`MttkrpBackend`] trait is infallible, so supervision
    /// errors leave the ALS loop as a panic).  Callers that
    /// `catch_unwind` the loop recover it via [`Self::take_failure`]
    /// instead of scraping the panic payload.
    failure: Option<crate::error::Error>,
}

/// Memoized per-mode simulation result: parallel makespan plus remap
/// cycles, the merged controller statistics (workers + remap pass), and
/// the remap count to add to the metrics per call.
struct SimMemo {
    cycles: u64,
    stats: AggregateStats,
    remaps: u64,
}

impl ParallelBackend {
    /// Pure-compute parallel backend (no memory-controller simulation).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "need at least one worker");
        ParallelBackend {
            workers,
            cfg: None,
            layout: None,
            stats: AggregateStats::default(),
            metrics: Metrics::default(),
            cycles: 0,
            last_plan: None,
            plan_cache: HashMap::new(),
            sim_cache: HashMap::new(),
            fingerprint: None,
            failure: None,
        }
    }

    /// Parallel backend that also drives one controller instance per
    /// worker with `cfg` (the external-memory layout is planned from the
    /// first tensor it sees).
    pub fn with_controller(workers: usize, cfg: ControllerConfig) -> Self {
        let mut b = Self::new(workers);
        b.cfg = Some(cfg);
        b
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Merged per-shard controller statistics across all calls so far.
    pub fn stats(&self) -> &AggregateStats {
        &self.stats
    }

    /// Merged wall-clock phase metrics across all calls so far.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The shard plan of the most recent MTTKRP call.
    pub fn last_plan(&self) -> Option<&ShardPlan> {
        self.last_plan.as_ref()
    }

    /// Take the typed worker failure that made the last `mttkrp` call
    /// unwind, if any (see the `failure` field).
    pub fn take_failure(&mut self) -> Option<crate::error::Error> {
        self.failure.take()
    }
}

impl MttkrpBackend for ParallelBackend {
    fn mttkrp(&mut self, t: &mut SparseTensor, factors: &[Mat], mode: usize) -> Mat {
        // A different tensor (shape, size, storage order) or rank
        // invalidates everything derived from the previous one: plans,
        // partitions, the external-memory layout, and the memoized
        // simulations.
        let fp = (t.dims().to_vec(), t.nnz(), t.order(), factors[0].cols());
        if self.fingerprint.as_ref() != Some(&fp) {
            self.plan_cache.clear();
            self.sim_cache.clear();
            self.layout = None;
            self.fingerprint = Some(fp);
        }
        if self.cfg.is_some() && self.layout.is_none() {
            self.layout = Some(MemLayout::plan(
                t.dims(),
                t.nnz(),
                t.record_bytes(),
                factors[0].cols(),
            ));
        }
        let workers = self.workers;
        let (plan, parts) = self.plan_cache.entry(mode).or_insert_with(|| {
            let plan = ShardPlan::balance(t, mode, workers);
            let parts = partition_indices(t, &plan);
            (plan, parts)
        });

        // Simulate only on this mode's first call; later iterations
        // reuse the memoized accounting (see `sim_cache`).
        let sim_needed = self.cfg.is_some() && !self.sim_cache.contains_key(&mode);
        let sim = if sim_needed {
            match (&self.cfg, &self.layout) {
                (Some(cfg), Some(layout)) => Some((cfg, layout)),
                _ => None,
            }
        } else {
            None
        };
        let run = match try_mttkrp_planned_with_engine(
            t,
            factors,
            plan,
            parts,
            sim,
            crate::engine::EngineKind::Lockstep,
        ) {
            Ok(run) => run,
            Err(e) => {
                let msg = e.to_string();
                self.failure = Some(e);
                panic!("{msg}");
            }
        };
        self.metrics.merge(&run.metrics);
        self.last_plan = Some(run.plan);

        if sim_needed {
            let mut memo = SimMemo {
                cycles: run.makespan,
                stats: run.stats,
                remaps: 0,
            };
            // The shard traces model the mode-sorted tensor image;
            // charge the sequential Tensor-Remapper pass that produces
            // it (same accounting as SimBackend and
            // ShardedSweep::makespan), unless the tensor already
            // arrives in direction.
            if t.order() != SortOrder::ByMode(mode) {
                if let (Some(cfg), Some(layout)) = (self.cfg.as_ref(), self.layout.as_ref()) {
                    let mut rctl = MemoryController::new(cfg.clone());
                    rctl.remap_pass(t.mode_col(mode), t.dims()[mode], layout, 0, 1);
                    memo.cycles += rctl.now();
                    memo.stats.absorb(&rctl);
                    memo.remaps = 1;
                }
            }
            self.sim_cache.insert(mode, memo);
        }
        if let Some(memo) = self.sim_cache.get(&mode) {
            self.cycles += memo.cycles;
            self.stats.merge(&memo.stats);
            self.metrics.remaps += memo.remaps;
        }
        run.output
    }

    fn cycles(&self) -> u64 {
        self.cycles
    }

    fn name(&self) -> &'static str {
        "parallel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpd::{cp_als, AlsConfig, NativeBackend};
    use crate::tensor::synth::{generate, Profile, SynthConfig};

    fn tensor(seed: u64) -> SparseTensor {
        generate(&SynthConfig {
            dims: vec![120, 90, 70],
            nnz: 3_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed,
        })
    }

    #[test]
    fn cp_als_identical_to_native_for_any_worker_count() {
        let cfg = AlsConfig {
            rank: 4,
            max_iters: 4,
            tol: 0.0,
            ..Default::default()
        };
        let mut t0 = tensor(21);
        let native = cp_als(&mut t0, &cfg, &mut NativeBackend);
        for k in [1, 2, 4] {
            let mut t = tensor(21);
            let mut b = ParallelBackend::new(k);
            let par = cp_als(&mut t, &cfg, &mut b);
            assert_eq!(
                par.fit_history, native.fit_history,
                "k={k} fit curve diverged"
            );
            for (fp, fa) in par.factors.iter().zip(&native.factors) {
                assert_eq!(fp.data(), fa.data(), "k={k} factors diverged");
            }
        }
    }

    #[test]
    fn controller_mode_accumulates_cycles_and_stats() {
        use crate::controller::ControllerConfig;
        let mut t = tensor(22);
        let cfg = AlsConfig {
            rank: 8,
            max_iters: 2,
            tol: 0.0,
            ..Default::default()
        };
        let ctl_cfg = ControllerConfig::default_for(t.record_bytes());
        let mut b = ParallelBackend::with_controller(4, ctl_cfg);
        let model = cp_als(&mut t, &cfg, &mut b);
        assert!(model.cycles > 0, "simulated clock must advance");
        // Per mode per iteration: 4 worker controllers + 1 remap-pass
        // controller, over 2 iterations x 3 modes.
        assert_eq!(b.stats().controllers, 2 * 3 * 5);
        assert!(b.stats().cache.accesses > 0);
        assert!(b.stats().dma.stream_bytes > 0);
        assert_eq!(b.stats().remapper.elements, 2 * 3 * 3_000);
        assert_eq!(b.metrics().remaps, 2 * 3);
        assert_eq!(b.metrics().nnz, 2 * 3 * 3_000);
        assert_eq!(b.last_plan().unwrap().k(), 4);
    }

    #[test]
    fn pure_compute_mode_reports_zero_cycles() {
        let mut t = tensor(23);
        let factors: Vec<Mat> = t
            .dims()
            .iter()
            .map(|&d| Mat::randn(d, 4, 5))
            .collect();
        let mut b = ParallelBackend::new(2);
        let _ = b.mttkrp(&mut t, &factors, 0);
        assert_eq!(b.cycles(), 0);
        assert_eq!(b.name(), "parallel");
    }
}
