//! Sharded multi-threaded spMTTKRP execution (S17).
//!
//! The paper's controller exists to keep many parallel compute units fed
//! ("dumb, fast compute" behind a smart memory subsystem); until now the
//! reproduction executed every engine on a single thread.  This module
//! supplies the missing parallel substrate:
//!
//! 1. [`ShardPlan`] partitions the *output-mode coordinate axis* into K
//!    contiguous, disjoint ranges, load-balanced over the per-coordinate
//!    nnz histogram (the same fiber-length distribution
//!    [`crate::tensor::stats`] measures).  Output disjointness is the
//!    whole trick: every output row is owned by exactly one shard, so
//!    workers never contend and no cross-shard reduction is needed.
//! 2. [`exec::mttkrp_sharded`] runs one `std::thread` worker per shard.
//!    Each worker computes its shard's partial MTTKRP *and* drives its
//!    own [`MemoryController`] over the shard's access trace — modeling
//!    K controller instances running concurrently, each owning its own
//!    DRAM channel group (the paper's multi-SLR layout; a configured
//!    multi-channel bus is split across instances, and the DSE bounds
//!    K by the device's channel count).  The simulated time of the
//!    mode is the slowest worker's makespan.
//! 3. [`AggregateStats`] merges the per-shard engine statistics
//!    ([`CacheStats::merge`], [`DmaStats::merge`], ...) into one
//!    aggregate view, and [`backend::ParallelBackend`] packages the whole
//!    thing as a [`crate::cpd::MttkrpBackend`] so `cp_als` runs unchanged.

pub mod backend;
pub mod exec;

pub use backend::ParallelBackend;
pub use exec::{
    mttkrp_planned, mttkrp_planned_with_engine, mttkrp_sharded, mttkrp_sharded_with_engine,
    shard_trace, sweep_makespan, try_mttkrp_planned_with_engine, try_mttkrp_sharded_with_engine,
    ShardedRun, ShardedSweep,
};

use crate::controller::{CacheStats, ControllerStats, DmaStats, MemoryController, RemapperStats};
use crate::dram::DramStats;
use crate::tensor::{Coord, SparseTensor};

/// One shard: a contiguous output-mode coordinate range and the number
/// of non-zeros whose output coordinate falls inside it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// Owned coordinate range `[coord_lo, coord_hi)` of the output mode.
    pub coord_lo: Coord,
    pub coord_hi: Coord,
    /// Non-zeros this shard processes.
    pub nnz: usize,
}

impl ShardSpec {
    /// Number of output coordinates (rows) the shard owns.
    pub fn rows(&self) -> usize {
        (self.coord_hi - self.coord_lo) as usize
    }
}

/// An output-disjoint exact cover of a tensor's non-zeros for one mode:
/// K contiguous coordinate ranges that tile `[0, I_mode)`.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// The output mode the plan shards.
    pub mode: usize,
    /// The K shards, in coordinate order; ranges are contiguous,
    /// disjoint, and cover the whole axis.
    pub shards: Vec<ShardSpec>,
}

impl ShardPlan {
    /// Build a K-shard plan for `mode`, balancing nnz counts from the
    /// tensor's coordinate column (one counting pass, no sort needed).
    pub fn balance(t: &SparseTensor, mode: usize, k: usize) -> ShardPlan {
        let mut counts = vec![0usize; t.dims()[mode]];
        for &c in t.mode_col(mode) {
            counts[c as usize] += 1;
        }
        Self::from_counts(mode, &counts, k)
    }

    /// Greedy prefix partition of a fiber-length histogram: each shard
    /// takes coordinates until it holds its share of the *remaining*
    /// nnz (`ceil(remaining / shards_left)`), re-targeting after every
    /// cut so an overweight shard shrinks the ones after it.  A single
    /// ultra-dense fiber can exceed the share — a coordinate is never
    /// split across shards, which is what keeps outputs disjoint.
    pub fn from_counts(mode: usize, counts: &[usize], k: usize) -> ShardPlan {
        assert!(k >= 1, "need at least one shard");
        let n = counts.len();
        let total: usize = counts.iter().sum();
        let mut shards = Vec::with_capacity(k);
        let mut lo = 0usize;
        let mut remaining = total;
        for s in 0..k {
            let shards_left = k - s;
            let (hi, nnz) = if shards_left == 1 {
                (n, remaining)
            } else {
                // Leave at least one coordinate for each later shard
                // while coordinates remain.
                let max_hi = n.saturating_sub(shards_left - 1).max(lo);
                let target = remaining.div_ceil(shards_left);
                let mut hi = lo;
                let mut nnz = 0usize;
                while hi < max_hi && nnz < target {
                    nnz += counts[hi];
                    hi += 1;
                }
                (hi, nnz)
            };
            shards.push(ShardSpec {
                coord_lo: lo as Coord,
                coord_hi: hi as Coord,
                nnz,
            });
            remaining -= nnz;
            lo = hi;
        }
        debug_assert_eq!(lo, n, "shards must cover the coordinate axis");
        debug_assert_eq!(remaining, 0, "shards must cover all nnz");
        ShardPlan { mode, shards }
    }

    /// Number of shards.
    pub fn k(&self) -> usize {
        self.shards.len()
    }

    /// Total non-zeros across shards.
    pub fn total_nnz(&self) -> usize {
        self.shards.iter().map(|s| s.nnz).sum()
    }

    /// Load imbalance: heaviest shard over the ideal `total/k` share
    /// (1.0 = perfectly balanced; K = everything on one shard).
    pub fn imbalance(&self) -> f64 {
        let total = self.total_nnz();
        if total == 0 {
            return 1.0;
        }
        let max = self.shards.iter().map(|s| s.nnz).max().unwrap_or(0);
        max as f64 / (total as f64 / self.k() as f64)
    }

    /// Shard owning output coordinate `c`.
    pub fn shard_of(&self, c: Coord) -> usize {
        self.shards
            .iter()
            .position(|s| s.coord_lo <= c && c < s.coord_hi)
            .expect("coordinate outside the plan's axis")
    }
}

/// One-pass coordinate-histogram sketch for out-of-core shard planning:
/// per-mode fiber-length counts accumulated block by block from a
/// streamed ingestion pass ([`crate::tensor::frostt::TnsBlockReader`]),
/// so a [`ShardPlan`] can be built without ever materializing (or
/// sorting) the tensor.  Memory is O(sum of mode lengths), independent
/// of nnz.
///
/// [`ShardPlan::balance`] is exactly `CoordHistogram::observe` over the
/// materialized columns followed by [`ShardPlan::from_counts`], so the
/// streamed plan is bit-identical to the in-RAM plan by construction
/// (pinned by `tests/streaming_props.rs`).
#[derive(Debug, Clone, Default)]
pub struct CoordHistogram {
    /// Per-mode fiber-length counts, grown on demand as coordinates
    /// appear (the `.tns` format declares no dims up front).
    counts: Vec<Vec<usize>>,
    nnz: usize,
}

impl CoordHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one block of per-mode coordinate columns into the sketch.
    /// All columns must have equal length (one entry per nonzero).
    pub fn observe(&mut self, cols: &[Vec<Coord>]) {
        if cols.is_empty() {
            return;
        }
        if self.counts.len() < cols.len() {
            self.counts.resize_with(cols.len(), Vec::new);
        }
        for (m, col) in cols.iter().enumerate() {
            debug_assert_eq!(col.len(), cols[0].len(), "ragged coordinate block");
            let counts = &mut self.counts[m];
            for &c in col {
                let c = c as usize;
                if c >= counts.len() {
                    counts.resize(c + 1, 0);
                }
                counts[c] += 1;
            }
        }
        self.nnz += cols[0].len();
    }

    /// Nonzeros observed so far.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Mode lengths observed so far (coordinate maxima + 1).
    pub fn dims(&self) -> Vec<usize> {
        self.counts.iter().map(Vec::len).collect()
    }

    /// Fiber-length histogram of one mode.
    pub fn mode_counts(&self, mode: usize) -> &[usize] {
        &self.counts[mode]
    }

    /// Build the K-shard plan for `mode` from the sketch alone.
    pub fn plan(&self, mode: usize, k: usize) -> ShardPlan {
        ShardPlan::from_counts(mode, &self.counts[mode], k)
    }

    /// Like [`Self::plan`], but padding the axis to `dim` coordinates —
    /// for tensors whose declared mode length exceeds the observed
    /// coordinate maximum (trailing empty fibers carry no nnz, so the
    /// plan matches [`ShardPlan::balance`] on the materialized tensor).
    pub fn plan_for_dim(&self, mode: usize, dim: usize, k: usize) -> ShardPlan {
        let counts = &self.counts[mode];
        if counts.len() >= dim {
            return ShardPlan::from_counts(mode, counts, k);
        }
        let mut padded = counts.clone();
        padded.resize(dim, 0);
        ShardPlan::from_counts(mode, &padded, k)
    }
}

/// Per-shard nnz storage indices, in storage order — so each worker's
/// per-row accumulation order matches the sequential oracle exactly
/// (bit-identical floating-point results).
pub fn partition_indices(t: &SparseTensor, plan: &ShardPlan) -> Vec<Vec<usize>> {
    let mode_len = t.dims()[plan.mode];
    let mut owner = vec![0u32; mode_len];
    for (sid, s) in plan.shards.iter().enumerate() {
        for c in s.coord_lo..s.coord_hi {
            owner[c as usize] = sid as u32;
        }
    }
    let mut out: Vec<Vec<usize>> = plan
        .shards
        .iter()
        .map(|s| Vec::with_capacity(s.nnz))
        .collect();
    for (z, &c) in t.mode_col(plan.mode).iter().enumerate() {
        out[owner[c as usize] as usize].push(z);
    }
    out
}

/// Merged statistics of K per-shard memory controllers: every engine's
/// counters summed across workers.  Rates derived from the sums (e.g.
/// [`CacheStats::hit_rate`]) are the nnz-weighted aggregate rates.
#[derive(Debug, Clone, Default)]
pub struct AggregateStats {
    pub cache: CacheStats,
    pub dma: DmaStats,
    pub dram: DramStats,
    pub remapper: RemapperStats,
    pub controller: ControllerStats,
    /// Controller instances absorbed (per mode: one per worker, plus
    /// one for the remap pass when the backend simulates it).
    pub controllers: u64,
}

impl AggregateStats {
    /// Fold one worker's controller into the aggregate.
    pub fn absorb(&mut self, ctl: &MemoryController) {
        self.cache.merge(ctl.cache_stats());
        self.dma.merge(ctl.dma_stats());
        self.dram.merge(ctl.dram_stats());
        self.remapper.merge(ctl.remapper_stats());
        self.controller.merge(ctl.stats());
        self.controllers += 1;
    }

    /// Fold another aggregate (e.g. the next mode's) into this one.
    pub fn merge(&mut self, other: &AggregateStats) {
        self.cache.merge(&other.cache);
        self.dma.merge(&other.dma);
        self.dram.merge(&other.dram);
        self.remapper.merge(&other.remapper);
        self.controller.merge(&other.controller);
        self.controllers += other.controllers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::testkit::forall;

    fn tensor(seed: u64, nnz: usize) -> SparseTensor {
        generate(&SynthConfig {
            dims: vec![300, 200, 150],
            nnz,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed,
        })
    }

    #[test]
    fn plan_tiles_the_coordinate_axis() {
        let t = tensor(1, 5_000);
        for mode in 0..3 {
            for k in [1, 2, 4, 7] {
                let plan = ShardPlan::balance(&t, mode, k);
                assert_eq!(plan.k(), k);
                let mut expect_lo = 0;
                for s in &plan.shards {
                    assert_eq!(s.coord_lo, expect_lo, "ranges must be contiguous");
                    assert!(s.coord_lo <= s.coord_hi);
                    expect_lo = s.coord_hi;
                }
                assert_eq!(expect_lo as usize, t.dims()[mode]);
                assert_eq!(plan.total_nnz(), t.nnz());
            }
        }
    }

    #[test]
    fn partition_is_a_disjoint_exact_cover() {
        forall("shard_partition_cover", 24, |rng| {
            let t = tensor(rng.next_u64(), rng.range(1, 3_000));
            let mode = rng.range(0, 3);
            let k = rng.range(1, 9);
            let plan = ShardPlan::balance(&t, mode, k);
            let parts = partition_indices(&t, &plan);
            assert_eq!(parts.len(), k);
            // Every nnz appears exactly once, and in its owning range.
            let mut seen = vec![false; t.nnz()];
            for (sid, zs) in parts.iter().enumerate() {
                assert_eq!(zs.len(), plan.shards[sid].nnz);
                for &z in zs {
                    assert!(!seen[z], "nnz {z} assigned to two shards");
                    seen[z] = true;
                    let c = t.mode_col(mode)[z];
                    assert!(
                        plan.shards[sid].coord_lo <= c && c < plan.shards[sid].coord_hi,
                        "nnz {z} (coord {c}) outside shard {sid}"
                    );
                }
                // Storage order is preserved within the shard.
                assert!(zs.windows(2).all(|w| w[0] < w[1]));
            }
            assert!(seen.iter().all(|&s| s), "some nnz unassigned");
        });
    }

    #[test]
    fn balance_is_reasonable_on_uniform_tensors() {
        let t = generate(&SynthConfig {
            dims: vec![400, 300, 200],
            nnz: 20_000,
            profile: Profile::Uniform,
            seed: 3,
        });
        for k in [2, 4, 8] {
            let plan = ShardPlan::balance(&t, 0, k);
            assert!(
                plan.imbalance() < 1.25,
                "k={k} imbalance {}",
                plan.imbalance()
            );
        }
    }

    #[test]
    fn dense_fiber_is_never_split() {
        // Coordinate 5 holds 90% of nnz: it must land in exactly one
        // shard (output disjointness), making that shard heavy.
        let mut counts = vec![10usize; 20];
        counts[5] = 2_000;
        let plan = ShardPlan::from_counts(0, &counts, 4);
        let owner = plan.shard_of(5);
        assert!(plan.shards[owner].nnz >= 2_000);
        assert_eq!(plan.total_nnz(), 2_000 + 19 * 10);
        assert!(plan.imbalance() > 2.0, "hot fiber must show as imbalance");
    }

    #[test]
    fn more_shards_than_coordinates_degrades_gracefully() {
        let counts = vec![7usize; 3];
        let plan = ShardPlan::from_counts(1, &counts, 8);
        assert_eq!(plan.k(), 8);
        assert_eq!(plan.total_nnz(), 21);
        let nonempty = plan.shards.iter().filter(|s| s.rows() > 0).count();
        assert!(nonempty <= 3);
        // Cover still holds.
        assert_eq!(plan.shards.last().unwrap().coord_hi, 3);
    }

    #[test]
    fn shard_of_matches_ranges() {
        let t = tensor(9, 2_000);
        let plan = ShardPlan::balance(&t, 1, 5);
        for (sid, s) in plan.shards.iter().enumerate() {
            if s.rows() > 0 {
                assert_eq!(plan.shard_of(s.coord_lo), sid);
                assert_eq!(plan.shard_of(s.coord_hi - 1), sid);
            }
        }
    }

    #[test]
    fn histogram_sketch_plans_match_balance() {
        forall("coord_histogram_plan_identity", 16, |rng| {
            let t = tensor(rng.next_u64(), rng.range(1, 4_000));
            // Feed the sketch in random-sized blocks, as the streamed
            // ingestion path would.
            let mut hist = CoordHistogram::new();
            let mut z = 0;
            while z < t.nnz() {
                let end = (z + rng.range(1, 700)).min(t.nnz());
                let block: Vec<Vec<Coord>> = (0..t.n_modes())
                    .map(|m| t.mode_col(m)[z..end].to_vec())
                    .collect();
                hist.observe(&block);
                z = end;
            }
            assert_eq!(hist.nnz(), t.nnz());
            for mode in 0..t.n_modes() {
                for k in [1, 3, 6] {
                    let streamed = hist.plan_for_dim(mode, t.dims()[mode], k);
                    let in_ram = ShardPlan::balance(&t, mode, k);
                    assert_eq!(streamed.shards, in_ram.shards, "mode {mode} k {k}");
                }
            }
        });
    }

    #[test]
    fn aggregate_merge_sums_counters() {
        use crate::controller::{Access, ControllerConfig};
        let mk = |n_req: u64| {
            let mut ctl = MemoryController::new(ControllerConfig::default_for(16));
            for i in 0..n_req {
                ctl.request(Access::Cached {
                    addr: i * 64,
                    bytes: 64,
                });
            }
            ctl
        };
        let (a, b) = (mk(10), mk(25));
        let mut agg = AggregateStats::default();
        agg.absorb(&a);
        agg.absorb(&b);
        assert_eq!(agg.controllers, 2);
        assert_eq!(agg.controller.requests, 35);
        assert_eq!(
            agg.cache.accesses,
            a.cache_stats().accesses + b.cache_stats().accesses
        );
        assert_eq!(agg.dram.bursts, a.dram_stats().bursts + b.dram_stats().bursts);

        let mut c = AggregateStats::default();
        c.merge(&agg);
        c.merge(&agg);
        assert_eq!(c.controller.requests, 70);
        assert_eq!(c.controllers, 4);
    }
}
