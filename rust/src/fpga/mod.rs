//! FPGA on-chip resource model (S7, paper §5.2/§5.3): BRAM/URAM block
//! accounting for a memory-controller configuration, plus a device
//! catalog of Alveo-class parts.
//!
//! The PMS (§5.3) "should estimate the total FPGA on-chip memory
//! requirement for a given set of programmable parameters to make sure
//! the memory controller fits in the FPGA device" — this module is that
//! estimator.  Block RAM granularity matters: a 4-line cache still burns
//! whole BRAM36 blocks per way, which is why module budgets trade off
//! against each other in the DSE.

use crate::controller::ControllerConfig;
use crate::mem::MemTechConfig;

/// One BRAM36 block: 36 Kbit = 4.5 KiB usable as 4 KiB data + parity.
pub const BRAM36_BYTES: usize = 4 * 1024;
/// One URAM288 block: 288 Kbit = 36 KiB.
pub const URAM_BYTES: usize = 36 * 1024;

/// An FPGA device's memory resources, including which external-memory
/// technologies the board can host and at what capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Device {
    pub name: &'static str,
    pub bram36: usize,
    pub uram: usize,
    /// DDR4 channels on the board (bounds `DramConfig::channels`).
    pub dram_channels: usize,
    /// HBM2 pseudo-channels on the package (0 = no HBM stacks).
    pub hbm_pseudo_channels: usize,
    /// Optical-SRAM-class scratchpad ports attachable through the
    /// board's transceivers (0 = no such attachment).
    pub osram_ports: usize,
}

impl Device {
    /// Xilinx Alveo U250 (paper's reference platform family): 2,000
    /// BRAM36 + 1,280 URAM, 4 DDR4 channels, no HBM; a
    /// transceiver-attached optical scratchpad of up to 16 ports.
    pub fn alveo_u250() -> Self {
        Device {
            name: "alveo-u250",
            bram36: 2000,
            uram: 1280,
            dram_channels: 4,
            hbm_pseudo_channels: 0,
            osram_ports: 16,
        }
    }

    /// Alveo U280: 1,824 BRAM36 + 960 URAM, and the package HBM2 —
    /// 2 stacks exposing 32 pseudo-channels (modeled as dram_channels=8
    /// when driven through the legacy DDR4-shaped path).
    pub fn alveo_u280() -> Self {
        Device {
            name: "alveo-u280",
            bram36: 1824,
            uram: 960,
            dram_channels: 8,
            hbm_pseudo_channels: 32,
            osram_ports: 16,
        }
    }

    /// A mid-size VU9P-class part with a single DIMM.
    pub fn vu9p() -> Self {
        Device {
            name: "vu9p",
            bram36: 2160,
            uram: 960,
            dram_channels: 1,
            hbm_pseudo_channels: 0,
            osram_ports: 8,
        }
    }

    /// Total on-chip memory bytes.
    pub fn total_bytes(&self) -> usize {
        self.bram36 * BRAM36_BYTES + self.uram * URAM_BYTES
    }

    /// Can this board host `mem` at the configured capacity?  Each
    /// technology is bounded by its own attachment resource: DDR4 by
    /// board channels, HBM2 by package pseudo-channels, oSRAM by
    /// transceiver ports.
    pub fn supports(&self, mem: &MemTechConfig) -> bool {
        match mem {
            MemTechConfig::Ddr4(c) => c.channels <= self.dram_channels,
            MemTechConfig::Hbm2(h) => h.total_pseudo_channels() <= self.hbm_pseudo_channels,
            MemTechConfig::Osram(o) => o.banks <= self.osram_ports,
        }
    }
}

/// Resource usage of a controller configuration on a device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Usage {
    pub bram36_used: usize,
    pub uram_used: usize,
    /// True iff the configuration fits the device.
    pub fits: bool,
}

impl Usage {
    /// Fraction of the device's total memory bytes consumed.
    pub fn utilization(&self, dev: &Device) -> f64 {
        (self.bram36_used * BRAM36_BYTES + self.uram_used * URAM_BYTES) as f64
            / dev.total_bytes() as f64
    }
}

/// Fraction of a device's memory blocks available to the *memory
/// controller*: the compute units (MAC pipelines, FIFOs, AXI
/// infrastructure) claim the rest.  This is why the paper's §3 example —
/// a 40 MB pointer table on a ~53 MB-of-SRAM device — "does not fit in
/// the FPGA on-chip memory".
pub const MC_BUDGET_FRACTION: f64 = 0.5;

/// BRAM36 blocks the memory-side PHY/interconnect claims per
/// technology.  DDR4 controllers are hardened (or budgeted outside
/// `MC_BUDGET_FRACTION`), so DDR4 charges **zero** here — keeping every
/// pre-refactor resource number byte-identical.  HBM2 needs an AXI
/// switch buffer per active pseudo-channel; an optical scratchpad needs
/// a transceiver elastic buffer per port.
fn phy_bram36(mem: &MemTechConfig) -> usize {
    match mem {
        MemTechConfig::Ddr4(_) => 0,
        MemTechConfig::Hbm2(h) => 2 * h.total_pseudo_channels(),
        MemTechConfig::Osram(o) => o.banks,
    }
}

/// Map a controller configuration onto `dev`'s block budget.
///
/// Allocation policy (typical synthesis outcome):
/// * Cache data+tag arrays -> BRAM (need per-way independent ports);
///   tags add ~8 bytes/line.
/// * DMA buffers -> URAM first (deep sequential FIFOs), overflow to BRAM.
/// * Remapper pointer table + stream buffer -> URAM first, overflow BRAM.
/// * Memory-PHY interconnect buffers -> BRAM ([`phy_bram36`]; 0 for DDR4).
pub fn estimate(cfg: &ControllerConfig, dev: &Device) -> Usage {
    let bram_budget = (dev.bram36 as f64 * MC_BUDGET_FRACTION) as usize;
    let uram_budget = (dev.uram as f64 * MC_BUDGET_FRACTION) as usize;

    let cache_bytes = cfg.cache.capacity_bytes() + cfg.cache.num_lines * 8;
    let bram_for_cache = cache_bytes.div_ceil(BRAM36_BYTES);

    let uram_wanted_bytes = cfg.dma.buffer_capacity_bytes() + cfg.remapper.onchip_bytes();
    let uram_blocks_wanted = uram_wanted_bytes.div_ceil(URAM_BYTES);
    let uram_used = uram_blocks_wanted.min(uram_budget);
    let overflow_bytes = uram_blocks_wanted.saturating_sub(uram_budget) * URAM_BYTES;
    let bram_overflow = overflow_bytes.div_ceil(BRAM36_BYTES);

    // URAM overflow was re-homed to BRAM above, so fitting reduces to
    // the BRAM budget (uram_used is clamped to the budget by construction).
    let bram36_used = bram_for_cache + bram_overflow + phy_bram36(&cfg.mem);
    Usage {
        bram36_used,
        uram_used,
        fits: bram36_used <= bram_budget,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{CacheConfig, ControllerConfig, DmaConfig, RemapperConfig};
    use crate::dram::DramConfig;

    fn cfg(cache_lines: usize, max_pointers: usize) -> ControllerConfig {
        ControllerConfig {
            mem: MemTechConfig::Ddr4(DramConfig::default_ddr4()),
            cache: CacheConfig {
                line_bytes: 64,
                num_lines: cache_lines,
                assoc: 4,
                hit_latency: 2,
            },
            dma: DmaConfig::default_2x4k(),
            remapper: RemapperConfig {
                buffer_bytes: 16 * 1024,
                elem_bytes: 16,
                max_pointers,
                store_setup_cycles: 4,
            },
        }
    }

    #[test]
    fn default_config_fits_u250() {
        let u = estimate(&cfg(1024, 64 * 1024), &Device::alveo_u250());
        assert!(u.fits, "{u:?}");
        assert!(u.bram36_used > 0 && u.uram_used > 0);
    }

    #[test]
    fn monster_cache_does_not_fit() {
        // 64 MiB cache >> U250's ~12.7 MiB of BRAM.
        let u = estimate(&cfg(1 << 20, 1024), &Device::alveo_u250());
        assert!(!u.fits);
    }

    #[test]
    fn pointer_table_scales_uram() {
        let small = estimate(&cfg(1024, 1024), &Device::alveo_u250());
        let big = estimate(&cfg(1024, 4 << 20), &Device::alveo_u250());
        assert!(big.uram_used > small.uram_used);
    }

    #[test]
    fn paper_example_10m_pointers_exceed_onchip() {
        // §3: "a tensor with an output mode with 10 million coordinate
        // values requires 40 MB ... does not fit in the FPGA on-chip
        // memory."  Our model must agree for every catalog device.
        let c = cfg(1024, 10_000_000);
        for dev in [Device::alveo_u250(), Device::alveo_u280(), Device::vu9p()] {
            let u = estimate(&c, &dev);
            assert!(!u.fits, "{}: 40MB pointer table must not fit", dev.name);
        }
    }

    #[test]
    fn utilization_is_monotone_in_cache_size() {
        let dev = Device::alveo_u250();
        let a = estimate(&cfg(256, 1024), &dev).utilization(&dev);
        let b = estimate(&cfg(4096, 1024), &dev).utilization(&dev);
        assert!(b > a);
    }

    #[test]
    fn devices_support_their_own_memory_technologies() {
        use crate::mem::MemTech;
        let ddr4 = MemTech::Ddr4.default_config();
        let hbm2 = MemTech::Hbm2.default_config();
        let osram = MemTech::Osram.default_config();
        assert!(Device::alveo_u250().supports(&ddr4));
        assert!(!Device::alveo_u250().supports(&hbm2), "U250 has no HBM");
        assert!(Device::alveo_u250().supports(&osram));
        assert!(Device::alveo_u280().supports(&hbm2));
        assert!(Device::vu9p().supports(&ddr4));
        assert!(!Device::vu9p().supports(&hbm2));
        // Capacity bounds, not just presence flags.
        let mut wide = crate::dram::DramConfig::default_ddr4();
        wide.channels = 8;
        assert!(!Device::alveo_u250().supports(&MemTechConfig::Ddr4(wide)));
        let mut many = crate::mem::OsramConfig::default_16p();
        many.banks = 32;
        assert!(!Device::alveo_u280().supports(&MemTechConfig::Osram(many)));
    }

    #[test]
    fn ddr4_pays_no_phy_blocks_but_hbm2_and_osram_do() {
        use crate::mem::MemTech;
        let dev = Device::alveo_u280();
        let base = cfg(1024, 1024);
        let ddr4 = estimate(&base, &dev);
        let mut hbm = base.clone();
        hbm.mem = MemTech::Hbm2.default_config();
        let mut os = base.clone();
        os.mem = MemTech::Osram.default_config();
        // DDR4 charges zero PHY blocks: byte-identical to pre-refactor.
        assert_eq!(phy_bram36(&base.mem), 0);
        assert!(estimate(&hbm, &dev).bram36_used > ddr4.bram36_used);
        assert!(estimate(&os, &dev).bram36_used > ddr4.bram36_used);
        assert_eq!(estimate(&hbm, &dev).uram_used, ddr4.uram_used);
    }

    #[test]
    fn device_totals_are_sane() {
        // U250: 2000*4KiB + 1280*36KiB ≈ 52.8 MiB.
        let t = Device::alveo_u250().total_bytes();
        assert!(t > 50 << 20 && t < 56 << 20, "{t}");
    }
}
