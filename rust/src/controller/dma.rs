//! DMA Engine (S4, paper §5.1.2): bulk transfers between FPGA compute
//! units and external DRAM.
//!
//! Two transfer types from the paper's §4 taxonomy:
//! * **stream** — large sequential transfers chunked into DMA buffers;
//!   multiple buffers per DMA give issue-ahead depth (double buffering),
//!   and multiple DMAs serve independent streams concurrently.
//! * **element** — element-wise transfers for data with no locality
//!   (e.g. remapped tensor stores); each element is its own request and
//!   pays per-request setup.
//!
//! All §5.2.1 parameters are programmable: number of DMAs, buffers per
//! DMA, and buffer size.

use crate::mem::MemoryDevice;

/// Programmable DMA Engine parameters (paper §5.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DmaConfig {
    /// Independent DMA units.
    pub num_dmas: usize,
    /// Buffers per DMA: outstanding chunks a stream can have in flight.
    pub buffers_per_dma: usize,
    /// Bytes per DMA buffer (chunk granularity of streams).
    pub buffer_bytes: usize,
    /// Fixed per-request setup cycles (descriptor fetch + channel setup).
    pub setup_cycles: u64,
}

impl DmaConfig {
    /// Two DMAs, double-buffered 4 KiB — a sensible default.
    pub fn default_2x4k() -> Self {
        DmaConfig {
            num_dmas: 2,
            buffers_per_dma: 2,
            buffer_bytes: 4096,
            setup_cycles: 8,
        }
    }

    /// Total on-chip buffer bytes this engine occupies.
    pub fn buffer_capacity_bytes(&self) -> usize {
        self.num_dmas * self.buffers_per_dma * self.buffer_bytes
    }

    fn validate(&self) {
        assert!(self.num_dmas >= 1);
        assert!(self.buffers_per_dma >= 1);
        assert!(self.buffer_bytes >= 64, "buffer smaller than a burst");
    }
}

/// DMA statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DmaStats {
    pub stream_requests: u64,
    pub stream_bytes: u64,
    pub element_requests: u64,
    pub element_bytes: u64,
    /// Buffer chunks issued for streams.
    pub chunks: u64,
}

impl DmaStats {
    /// Accumulate another engine's counters (per-shard aggregation,
    /// [`crate::shard`]).
    pub fn merge(&mut self, other: &DmaStats) {
        self.stream_requests += other.stream_requests;
        self.stream_bytes += other.stream_bytes;
        self.element_requests += other.element_requests;
        self.element_bytes += other.element_bytes;
        self.chunks += other.chunks;
    }
}

/// The DMA Engine simulator.
///
/// In-flight buffer state is one flat queue-depth vector over all
/// (dma, slot) pairs (`slots[dma * buffers_per_dma + slot]`) — the
/// structure-of-arrays form the vectorized multi-candidate timing core
/// ([`crate::engine::timing`]) relies on to keep per-candidate engines
/// allocation-flat.
#[derive(Debug, Clone)]
pub struct DmaEngine {
    cfg: DmaConfig,
    /// Completion time of each in-flight buffer slot, flattened over
    /// DMAs with stride `buffers_per_dma`.
    slots: Vec<u64>,
    stats: DmaStats,
    /// Round-robin cursor for stream-to-DMA assignment.
    next_dma: usize,
}

impl DmaEngine {
    pub fn new(cfg: DmaConfig) -> Self {
        cfg.validate();
        DmaEngine {
            cfg,
            slots: vec![0; cfg.buffers_per_dma * cfg.num_dmas],
            stats: DmaStats::default(),
            next_dma: 0,
        }
    }

    pub fn config(&self) -> &DmaConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &DmaStats {
        &self.stats
    }

    pub fn reset(&mut self) {
        self.slots.iter_mut().for_each(|t| *t = 0);
        self.stats = DmaStats::default();
        self.next_dma = 0;
    }

    /// Stream `bytes` sequential bytes at `addr` (load or store — the
    /// DRAM model is direction-symmetric), starting at `now`.  Chunks the
    /// transfer into buffer-sized DMA requests; up to `buffers_per_dma`
    /// chunks are outstanding, so DRAM latency of the next chunk hides
    /// behind the drain of the previous one.  Returns completion cycle.
    pub fn stream<M: MemoryDevice>(&mut self, dram: &mut M, addr: u64, bytes: usize, now: u64) -> u64 {
        assert!(bytes > 0);
        self.stats.stream_requests += 1;
        self.stats.stream_bytes += bytes as u64;
        let dma = self.next_dma;
        self.next_dma = (self.next_dma + 1) % self.cfg.num_dmas;
        let slot_base = dma * self.cfg.buffers_per_dma;

        let mut done = now;
        let mut off = 0usize;
        let mut slot = 0usize;
        while off < bytes {
            let chunk = (bytes - off).min(self.cfg.buffer_bytes);
            // The chunk may issue as soon as its buffer slot is free.
            let slot_free = self.slots[slot_base + slot];
            let start = now.max(slot_free) + self.cfg.setup_cycles;
            let t = dram.access(addr + off as u64, chunk, start);
            self.slots[slot_base + slot] = t;
            done = done.max(t);
            self.stats.chunks += 1;
            off += chunk;
            slot = (slot + 1) % self.cfg.buffers_per_dma;
        }
        done
    }

    /// Batched kernel for the event engine ([`crate::engine`]): issue a
    /// run of `count` contiguous stream requests — request `i` covers
    /// `chunk` bytes at `base + i*chunk`, the final request covers
    /// `tail` bytes — threading the FIFO clock through the run exactly
    /// as the controller threads it between per-access
    /// [`DmaEngine::stream`] calls.  Bit-identical by construction: it
    /// delegates each request to [`DmaEngine::stream`].
    pub fn stream_run<M: MemoryDevice>(
        &mut self,
        dram: &mut M,
        base: u64,
        chunk: usize,
        count: u32,
        tail: usize,
        now: u64,
    ) -> u64 {
        let mut t = now;
        for i in 0..count as u64 {
            let bytes = if i + 1 == count as u64 { tail } else { chunk };
            t = self.stream(dram, base + i * chunk as u64, bytes, t);
        }
        t
    }

    /// Element-wise transfer: one request of `bytes` at `addr` with full
    /// per-request setup (paper §4 transfer type 3 — no locality).
    pub fn element<M: MemoryDevice>(&mut self, dram: &mut M, addr: u64, bytes: usize, now: u64) -> u64 {
        assert!(bytes > 0);
        self.stats.element_requests += 1;
        self.stats.element_bytes += bytes as u64;
        dram.access(addr, bytes, now + self.cfg.setup_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{Dram, DramConfig};

    fn dram() -> Dram {
        Dram::new(DramConfig::default_ddr4())
    }

    #[test]
    fn stream_moves_all_bytes() {
        let mut d = dram();
        let mut e = DmaEngine::new(DmaConfig::default_2x4k());
        e.stream(&mut d, 0, 10_000, 0);
        assert_eq!(e.stats().stream_bytes, 10_000);
        assert_eq!(e.stats().chunks, 3); // 4096+4096+1808
        assert_eq!(d.stats().bytes as usize, 10_048); // burst-rounded
    }

    #[test]
    fn element_pays_setup_every_time() {
        let mut d = dram();
        let cfg = DmaConfig {
            setup_cycles: 50,
            ..DmaConfig::default_2x4k()
        };
        let mut e = DmaEngine::new(cfg);
        let mut t = 0;
        for i in 0..10 {
            t = e.element(&mut d, i * 16384, 16, t);
        }
        assert!(t >= 10 * 50, "setup must dominate: {t}");
        assert_eq!(e.stats().element_requests, 10);
    }

    #[test]
    fn streaming_beats_element_wise_for_bulk() {
        let total = 1 << 18;
        let mut d1 = dram();
        let mut e1 = DmaEngine::new(DmaConfig::default_2x4k());
        let t_stream = e1.stream(&mut d1, 0, total, 0);

        let mut d2 = dram();
        let mut e2 = DmaEngine::new(DmaConfig::default_2x4k());
        let mut t_elem = 0;
        for off in (0..total).step_by(16) {
            t_elem = e2.element(&mut d2, off as u64, 16, t_elem);
        }
        assert!(
            t_elem > 10 * t_stream,
            "element {t_elem} should be >>10x stream {t_stream}"
        );
    }

    #[test]
    fn more_buffers_help_until_dram_bound() {
        // With 1 buffer each chunk's setup serializes after the previous
        // drain; with 2+ the setup hides. Expect measurable improvement.
        let run = |buffers| {
            let mut d = dram();
            let mut e = DmaEngine::new(DmaConfig {
                num_dmas: 1,
                buffers_per_dma: buffers,
                buffer_bytes: 1024,
                setup_cycles: 40,
            });
            e.stream(&mut d, 0, 1 << 16, 0)
        };
        let single = run(1);
        let double = run(2);
        let quad = run(4);
        assert!(double < single, "double {double} !< single {single}");
        // Diminishing returns: 2 -> 4 gains less than 1 -> 2.
        assert!(single - double >= double - quad);
    }

    #[test]
    fn streams_round_robin_across_dmas() {
        let mut d = dram();
        let mut e = DmaEngine::new(DmaConfig {
            num_dmas: 2,
            buffers_per_dma: 1,
            buffer_bytes: 4096,
            setup_cycles: 0,
        });
        // Two interleaved streams land on different DMAs, so the second
        // does not wait for the first DMA's slot.
        let t1 = e.stream(&mut d, 0, 4096, 0);
        let _t2 = e.stream(&mut d, 1 << 20, 4096, 0);
        // Third stream wraps to DMA 0 whose slot frees at t1.
        let t3 = e.stream(&mut d, 2 << 20, 4096, 0);
        assert!(t3 >= t1);
        assert_eq!(e.stats().stream_requests, 3);
    }

    #[test]
    fn stream_run_matches_scalar_streams_exactly() {
        let mut d1 = dram();
        let mut e1 = DmaEngine::new(DmaConfig::default_2x4k());
        let mut t_scalar = 0u64;
        let (base, chunk, count, tail) = (1u64 << 20, 4096usize, 6u32, 1_000usize);
        for i in 0..count as u64 {
            let bytes = if i + 1 == count as u64 { tail } else { chunk };
            t_scalar = e1.stream(&mut d1, base + i * chunk as u64, bytes, t_scalar);
        }
        let mut d2 = dram();
        let mut e2 = DmaEngine::new(DmaConfig::default_2x4k());
        let t_batched = e2.stream_run(&mut d2, base, chunk, count, tail, 0);
        assert_eq!(t_scalar, t_batched);
        assert_eq!(e1.stats(), e2.stats());
        assert_eq!(d1.stats(), d2.stats());
    }

    #[test]
    fn reset_clears_slots_and_stats() {
        let mut d = dram();
        let mut e = DmaEngine::new(DmaConfig::default_2x4k());
        e.stream(&mut d, 0, 8192, 0);
        e.reset();
        assert_eq!(e.stats(), &DmaStats::default());
    }

    #[test]
    fn stats_merge_sums_every_field() {
        let mut d = dram();
        let mut a = DmaEngine::new(DmaConfig::default_2x4k());
        a.stream(&mut d, 0, 10_000, 0);
        let mut b = DmaEngine::new(DmaConfig::default_2x4k());
        b.element(&mut d, 1 << 20, 16, 0);
        let mut merged = a.stats().clone();
        merged.merge(b.stats());
        assert_eq!(merged.stream_requests, 1);
        assert_eq!(merged.stream_bytes, 10_000);
        assert_eq!(merged.element_requests, 1);
        assert_eq!(merged.element_bytes, 16);
        assert_eq!(merged.chunks, a.stats().chunks);
    }

    #[test]
    fn buffer_capacity_formula() {
        let cfg = DmaConfig {
            num_dmas: 3,
            buffers_per_dma: 2,
            buffer_bytes: 1024,
            setup_cycles: 0,
        };
        assert_eq!(cfg.buffer_capacity_bytes(), 6144);
    }
}
