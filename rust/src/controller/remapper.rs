//! Tensor Remapper (S5, paper §5.1.3): streams the tensor in via a DMA
//! buffer and stores each element, element-wise, at the position its
//! *output-mode* coordinate dictates (paper Alg. 5 lines 3–6).
//!
//! The address-pointer table (one write cursor per output coordinate) is
//! the §3 overhead discussion made concrete: up to `max_pointers` cursors
//! live on-chip (allocated densest-coordinate-first, the ideal-layout
//! goal); the rest spill to external memory and cost a pointer load +
//! store per affected element.

use crate::mem::MemoryDevice;
use crate::tensor::Coord;

/// Programmable Tensor Remapper parameters (paper §5.2.1: buffer size,
/// tensor-element width, max tracked pointers).  `Hash` so (DRAM,
/// remapper) pairs can key the event engine's remap-pass memo
/// ([`crate::shard::ShardedSweep`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RemapperConfig {
    /// Stream-in DMA buffer size in bytes.
    pub buffer_bytes: usize,
    /// Width of one tensor record in bytes (N coords x 4 + value).
    pub elem_bytes: usize,
    /// Address pointers the remapper can keep on-chip.
    pub max_pointers: usize,
    /// Per-element-store setup cycles (descriptor issue).
    pub store_setup_cycles: u64,
}

impl RemapperConfig {
    pub fn default_16k(elem_bytes: usize) -> Self {
        RemapperConfig {
            buffer_bytes: 16 * 1024,
            elem_bytes,
            max_pointers: 64 * 1024,
            store_setup_cycles: 4,
        }
    }

    /// On-chip bytes: the stream buffer plus the pointer table (32-bit
    /// pointers, as in the paper's 40 MB-for-10M-coordinates example).
    pub fn onchip_bytes(&self) -> usize {
        self.buffer_bytes + self.max_pointers * 4
    }
}

/// Remapper statistics for one pass.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RemapperStats {
    pub elements: u64,
    /// Elements whose cursor was served on-chip.
    pub onchip_cursor_elems: u64,
    /// Elements that paid an external pointer load + store.
    pub spilled_cursor_elems: u64,
    pub stream_bytes: u64,
    pub store_bytes: u64,
    pub pointer_bytes: u64,
}

impl RemapperStats {
    /// Accumulate another remapper's counters (per-shard aggregation,
    /// [`crate::shard`]).
    pub fn merge(&mut self, other: &RemapperStats) {
        self.elements += other.elements;
        self.onchip_cursor_elems += other.onchip_cursor_elems;
        self.spilled_cursor_elems += other.spilled_cursor_elems;
        self.stream_bytes += other.stream_bytes;
        self.store_bytes += other.store_bytes;
        self.pointer_bytes += other.pointer_bytes;
    }
}

/// The Tensor Remapper simulator.
#[derive(Debug, Clone)]
pub struct TensorRemapper {
    cfg: RemapperConfig,
    stats: RemapperStats,
}

impl TensorRemapper {
    pub fn new(cfg: RemapperConfig) -> Self {
        assert!(cfg.buffer_bytes >= cfg.elem_bytes);
        TensorRemapper {
            cfg,
            stats: RemapperStats::default(),
        }
    }

    pub fn config(&self) -> &RemapperConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &RemapperStats {
        &self.stats
    }

    pub fn reset(&mut self) {
        self.stats = RemapperStats::default();
    }

    /// Simulate one remap pass over `mode_col` (the output-mode
    /// coordinate of each element, in current storage order).
    ///
    /// * `src_base` / `dst_base` — external-memory bases of the current
    ///   and remapped tensor copies (the §3 "additional space equal to
    ///   the size of the tensor").
    /// * `ptr_base` — base of the spilled pointer-table region.
    ///
    /// Returns the completion cycle.
    pub fn run<M: MemoryDevice>(
        &mut self,
        dram: &mut M,
        mode_col: &[Coord],
        mode_len: usize,
        src_base: u64,
        dst_base: u64,
        ptr_base: u64,
        now: u64,
    ) -> u64 {
        let eb = self.cfg.elem_bytes;

        // Build cursors exactly like tensor::remap: counts -> prefix sum.
        let mut counts = vec![0u32; mode_len];
        for &c in mode_col {
            counts[c as usize] += 1;
        }
        // Densest-first on-chip cursor allocation.
        let mut onchip = vec![false; mode_len];
        let used: Vec<usize> = {
            let mut v: Vec<usize> = (0..mode_len).filter(|&c| counts[c] > 0).collect();
            v.sort_unstable_by(|&a, &b| counts[b].cmp(&counts[a]));
            v
        };
        for &c in used.iter().take(self.cfg.max_pointers) {
            onchip[c] = true;
        }
        let mut cursors = vec![0u64; mode_len];
        let mut acc = 0u64;
        for c in 0..mode_len {
            cursors[c] = acc;
            acc += counts[c] as u64;
        }

        // Stream elements in, buffer_bytes at a time; within a buffered
        // chunk the loads are one bulk DRAM transfer, then each element
        // is stored element-wise (plus pointer traffic when spilled).
        let per_chunk = self.cfg.buffer_bytes / eb;
        let mut t = now;
        let mut z = 0usize;
        while z < mode_col.len() {
            let n = per_chunk.min(mode_col.len() - z);
            // Bulk load of the chunk (the remapper's internal DMA buffer).
            t = dram.access(src_base + (z * eb) as u64, n * eb, t);
            self.stats.stream_bytes += (n * eb) as u64;
            for k in 0..n {
                let c = mode_col[z + k] as usize;
                // Pointer access: on-chip is free; spilled pays a 4-byte
                // read-modify-write in external memory.
                if onchip[c] {
                    self.stats.onchip_cursor_elems += 1;
                } else {
                    self.stats.spilled_cursor_elems += 1;
                    self.stats.pointer_bytes += 8;
                    t = dram.access(ptr_base + (c as u64) * 4, 4, t);
                    t = dram.access(ptr_base + (c as u64) * 4, 4, t);
                }
                // Element-wise store at the cursor target.
                let dst = dst_base + cursors[c] * eb as u64;
                cursors[c] += 1;
                t = dram.access(dst, eb, t + self.cfg.store_setup_cycles);
                self.stats.store_bytes += eb as u64;
            }
            self.stats.elements += n as u64;
            z += n;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{Dram, DramConfig};
    use crate::tensor::synth::{generate, Profile, SynthConfig};

    fn dram() -> Dram {
        Dram::new(DramConfig::default_ddr4())
    }

    fn zipf_tensor() -> crate::tensor::SparseTensor {
        generate(&SynthConfig {
            dims: vec![500, 400, 300],
            nnz: 5_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 21,
        })
    }

    #[test]
    fn all_onchip_when_budget_sufficient() {
        let t = zipf_tensor();
        let mut d = dram();
        let mut r = TensorRemapper::new(RemapperConfig::default_16k(t.record_bytes()));
        r.run(&mut d, t.mode_col(0), t.dims()[0], 0, 1 << 24, 1 << 28, 0);
        assert_eq!(r.stats().elements, 5_000);
        assert_eq!(r.stats().spilled_cursor_elems, 0);
        assert_eq!(r.stats().pointer_bytes, 0);
        assert_eq!(r.stats().stream_bytes, 5_000 * 16);
        assert_eq!(r.stats().store_bytes, 5_000 * 16);
    }

    #[test]
    fn spilling_kicks_in_with_tiny_pointer_budget() {
        let t = zipf_tensor();
        let mut d = dram();
        let mut cfg = RemapperConfig::default_16k(t.record_bytes());
        cfg.max_pointers = 8;
        let mut r = TensorRemapper::new(cfg);
        r.run(&mut d, t.mode_col(0), t.dims()[0], 0, 1 << 24, 1 << 28, 0);
        let s = r.stats();
        assert!(s.spilled_cursor_elems > 0);
        assert_eq!(s.onchip_cursor_elems + s.spilled_cursor_elems, 5_000);
        // Densest-first: 8 on-chip cursors of a zipf(1.2) tensor should
        // still cover a large share of the elements.
        assert!(
            s.onchip_cursor_elems as f64 / 5_000.0 > 0.2,
            "densest-first share too low: {}",
            s.onchip_cursor_elems
        );
        assert_eq!(s.pointer_bytes, 8 * s.spilled_cursor_elems);
    }

    #[test]
    fn spilling_costs_time() {
        let t = zipf_tensor();
        let run = |max_pointers| {
            let mut d = dram();
            let mut cfg = RemapperConfig::default_16k(t.record_bytes());
            cfg.max_pointers = max_pointers;
            let mut r = TensorRemapper::new(cfg);
            r.run(&mut d, t.mode_col(0), t.dims()[0], 0, 1 << 24, 1 << 28, 0)
        };
        let fits = run(1 << 20);
        let spills = run(4);
        assert!(
            spills > fits + fits / 10,
            "spilling should cost >10% extra: {spills} vs {fits}"
        );
    }

    #[test]
    fn bigger_stream_buffer_reduces_time() {
        let t = zipf_tensor();
        let run = |buffer_bytes| {
            let mut d = dram();
            let cfg = RemapperConfig {
                buffer_bytes,
                elem_bytes: t.record_bytes(),
                max_pointers: 1 << 20,
                store_setup_cycles: 4,
            };
            let mut r = TensorRemapper::new(cfg);
            r.run(&mut d, t.mode_col(0), t.dims()[0], 0, 1 << 24, 1 << 28, 0)
        };
        assert!(run(64 * 1024) <= run(256));
    }

    #[test]
    fn onchip_bytes_accounts_table_and_buffer() {
        let cfg = RemapperConfig {
            buffer_bytes: 1024,
            elem_bytes: 16,
            max_pointers: 1000,
            store_setup_cycles: 0,
        };
        assert_eq!(cfg.onchip_bytes(), 1024 + 4000);
    }
}
