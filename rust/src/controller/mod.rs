//! The programmable memory controller (S6, paper §5, Fig. 4): Cache
//! Engine + DMA Engine + Tensor Remapper over a shared DRAM model.
//!
//! The controller exposes the paper's three transfer types (§4) as a
//! request interface ([`Access`]) and processes requests **in order**
//! (the paper's weak consistency: each module is FIFO, and module-to-
//! module ordering is first-in-first-served; disjoint address ranges make
//! that sufficient).  spMTTKRP engines ([`crate::mttkrp`]) compile their
//! memory behaviour into an access trace; [`MemoryController::replay`]
//! produces the total memory access time the paper optimizes.

pub mod cache;
pub mod dma;
pub mod remapper;

pub use cache::{CacheConfig, CacheEngine, CacheStats, LineGeom};
pub use dma::{DmaConfig, DmaEngine, DmaStats};
pub use remapper::{RemapperConfig, RemapperStats, TensorRemapper};

use crate::dram::DramStats;
use crate::mem::{MemDevice, MemTechConfig};
use crate::tensor::Coord;

/// One memory request, tagged with the §4 transfer type that serves it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    /// Bulk sequential transfer through the DMA Engine (tensor element
    /// streams, output factor-row stores).
    Stream { addr: u64, bytes: usize },
    /// Element-wise transfer through the DMA Engine (no locality).
    Element { addr: u64, bytes: usize },
    /// Cached load through the Cache Engine (random accesses with
    /// temporal/spatial locality: input factor-matrix rows).
    Cached { addr: u64, bytes: usize },
    /// Store routed through the Cache Engine (write-allocate,
    /// write-back) — the §5.1.2(b) anti-pattern, modeled for ablations.
    CachedStore { addr: u64, bytes: usize },
}

impl Access {
    pub fn bytes(&self) -> usize {
        match *self {
            Access::Stream { bytes, .. }
            | Access::Element { bytes, .. }
            | Access::Cached { bytes, .. }
            | Access::CachedStore { bytes, .. } => bytes,
        }
    }
}

/// Full controller configuration: one knob set per module (§5.2),
/// including the external-memory *technology* ([`MemTechConfig`]).
/// Equality is knob-for-knob — the DSE search layers dedup candidate
/// configurations with it before scoring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ControllerConfig {
    /// External-memory technology + knobs (DDR4 / HBM2 / optical SRAM).
    pub mem: MemTechConfig,
    pub cache: CacheConfig,
    pub dma: DmaConfig,
    pub remapper: RemapperConfig,
}

impl ControllerConfig {
    /// Default configuration for a tensor with `elem_bytes`-wide records.
    pub fn default_for(elem_bytes: usize) -> Self {
        ControllerConfig {
            mem: MemTechConfig::default_ddr4(),
            cache: CacheConfig::default_64k(),
            dma: DmaConfig::default_2x4k(),
            remapper: RemapperConfig::default_16k(elem_bytes),
        }
    }

    /// Total on-chip buffer/cache bytes the configuration occupies —
    /// the quantity the PMS checks against the FPGA device (§5.3).
    pub fn onchip_bytes(&self) -> usize {
        self.cache.capacity_bytes()
            + self.dma.buffer_capacity_bytes()
            + self.remapper.onchip_bytes()
    }
}

/// External-memory layout of a decomposition run: where the two tensor
/// copies (ping-pong for remap), the factor matrices, the output region,
/// and the spilled pointer table live.  Regions are disjoint; the paper's
/// weak-consistency argument relies on exactly this disjointness.
#[derive(Debug, Clone)]
pub struct MemLayout {
    /// Base of tensor copy 0 and copy 1 (remap ping-pong).
    pub tensor_base: [u64; 2],
    /// Base address of each mode's factor matrix.
    pub factor_base: Vec<u64>,
    /// Row stride in bytes of factor matrices (R * 4).
    pub row_bytes: usize,
    /// Base of the spilled pointer table.
    pub ptr_base: u64,
    /// Base of the Approach-2 partial-sum region (|T| x R floats + tags).
    pub partial_base: u64,
}

impl MemLayout {
    /// Lay out a tensor with `dims`, `nnz` non-zeros of `elem_bytes` each
    /// and rank `r`, regions aligned to 1 MiB.
    pub fn plan(dims: &[usize], nnz: usize, elem_bytes: usize, r: usize) -> Self {
        const ALIGN: u64 = 1 << 20;
        let align = |x: u64| x.div_ceil(ALIGN) * ALIGN;
        let mut cursor = 0u64;
        let tensor_bytes = align((nnz * elem_bytes) as u64);
        let t0 = cursor;
        cursor += tensor_bytes;
        let t1 = cursor;
        cursor += tensor_bytes;
        let row_bytes = r * 4;
        let mut factor_base = Vec::with_capacity(dims.len());
        for &d in dims {
            factor_base.push(cursor);
            cursor += align((d * row_bytes) as u64);
        }
        let ptr_base = cursor;
        cursor += align((dims.iter().max().copied().unwrap_or(0) * 4) as u64);
        let partial_base = cursor;
        MemLayout {
            tensor_base: [t0, t1],
            factor_base,
            row_bytes,
            ptr_base,
            partial_base,
        }
    }

    /// Address of row `row` of mode-`m` factor matrix.
    pub fn factor_row_addr(&self, m: usize, row: Coord) -> u64 {
        self.factor_base[m] + row as u64 * self.row_bytes as u64
    }
}

/// Aggregated controller statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ControllerStats {
    pub requests: u64,
    pub total_bytes: u64,
}

impl ControllerStats {
    /// Accumulate another controller's counters (per-shard aggregation,
    /// [`crate::shard`]).
    pub fn merge(&mut self, other: &ControllerStats) {
        self.requests += other.requests;
        self.total_bytes += other.total_bytes;
    }
}

/// The memory-controller simulator top.
#[derive(Debug, Clone)]
pub struct MemoryController {
    cfg: ControllerConfig,
    mem: MemDevice,
    cache: CacheEngine,
    dma: DmaEngine,
    remapper: TensorRemapper,
    stats: ControllerStats,
    /// Current cycle (requests are processed FIFO).
    now: u64,
}

impl MemoryController {
    pub fn new(cfg: ControllerConfig) -> Self {
        MemoryController {
            mem: MemDevice::new(&cfg.mem),
            cache: CacheEngine::new(cfg.cache),
            dma: DmaEngine::new(cfg.dma),
            remapper: TensorRemapper::new(cfg.remapper),
            cfg,
            stats: ControllerStats::default(),
            now: 0,
        }
    }

    pub fn config(&self) -> &ControllerConfig {
        &self.cfg
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    pub fn cache_stats(&self) -> &CacheStats {
        self.cache.stats()
    }

    pub fn dma_stats(&self) -> &DmaStats {
        self.dma.stats()
    }

    pub fn remapper_stats(&self) -> &RemapperStats {
        self.remapper.stats()
    }

    /// External-memory device statistics (the field keeps its historic
    /// name; all technologies share the [`DramStats`] counter set).
    pub fn dram_stats(&self) -> &DramStats {
        self.mem.stats()
    }

    pub fn stats(&self) -> &ControllerStats {
        &self.stats
    }

    /// Reset time, engine state, and statistics.
    pub fn reset(&mut self) {
        self.mem.reset();
        self.cache.reset();
        self.dma.reset();
        self.remapper.reset();
        self.stats = ControllerStats::default();
        self.now = 0;
    }

    /// Route one access to its serving engine starting at `now`;
    /// returns the completion cycle.  The single §4 routing table,
    /// shared by the lockstep path ([`Self::request`]) and the event
    /// engine's verbatim runs ([`Self::replay_events`]) so the two
    /// cores cannot diverge.
    fn dispatch(&mut self, access: Access, now: u64) -> u64 {
        match access {
            Access::Stream { addr, bytes } => self.dma.stream(&mut self.mem, addr, bytes, now),
            Access::Element { addr, bytes } => self.dma.element(&mut self.mem, addr, bytes, now),
            Access::Cached { addr, bytes } => self.cache.load(&mut self.mem, addr, bytes, now),
            Access::CachedStore { addr, bytes } => {
                self.cache.store(&mut self.mem, addr, bytes, now)
            }
        }
    }

    /// Process one request (FIFO: starts no earlier than the previous
    /// request's completion).  Returns the completion cycle.
    pub fn request(&mut self, access: Access) -> u64 {
        self.stats.requests += 1;
        self.stats.total_bytes += access.bytes() as u64;
        self.now = self.dispatch(access, self.now);
        self.now
    }

    /// Replay a full access trace; returns total cycles.
    pub fn replay(&mut self, trace: &[Access]) -> u64 {
        for &a in trace {
            self.request(a);
        }
        self.now
    }

    /// Event-driven batched replay of a delta-encoded trace
    /// ([`crate::engine`]): processes the trace run by run, dispatching
    /// each run to the matching engine's batched kernel and folding the
    /// controller-level counters in per epoch (one bulk update instead
    /// of two adds per request).  Bit-identical to [`Self::replay`] of
    /// the same trace's raw form in both the returned completion cycle
    /// and every statistics counter.
    pub fn replay_events(&mut self, trace: &crate::engine::CompressedTrace) -> u64 {
        use crate::engine::trace::Run;
        self.stats.requests += trace.requests();
        self.stats.total_bytes += trace.total_bytes();
        let mut now = self.now;
        for run in trace.runs() {
            match *run {
                Run::Stream {
                    base,
                    chunk,
                    count,
                    tail,
                } => {
                    now = self.dma.stream_run(
                        &mut self.mem,
                        base,
                        chunk as usize,
                        count,
                        tail as usize,
                        now,
                    );
                }
                Run::Cached {
                    base,
                    bytes,
                    off,
                    count,
                } => {
                    now = self.cache.load_run(
                        &mut self.mem,
                        base,
                        trace.words_at(off, count),
                        bytes as usize,
                        now,
                    );
                }
                Run::Verbatim { off, count } => {
                    for &a in trace.raw_at(off, count) {
                        now = self.dispatch(a, now);
                    }
                }
            }
        }
        self.now = now;
        self.now
    }

    /// Run a tensor-remap pass through the Tensor Remapper module
    /// (paper Alg. 5 lines 3–6).  `src`/`dst` select the ping-pong copy.
    pub fn remap_pass(
        &mut self,
        mode_col: &[Coord],
        mode_len: usize,
        layout: &MemLayout,
        src: usize,
        dst: usize,
    ) -> u64 {
        self.now = self.remapper.run(
            &mut self.mem,
            mode_col,
            mode_len,
            layout.tensor_base[src],
            layout.tensor_base[dst],
            layout.ptr_base,
            self.now,
        );
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn ctl() -> MemoryController {
        MemoryController::new(ControllerConfig::default_for(16))
    }

    #[test]
    fn fifo_time_is_monotonic() {
        let mut c = ctl();
        let mut prev = 0;
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let addr = rng.below(1 << 24);
            let t = c.request(Access::Cached { addr, bytes: 64 });
            assert!(t >= prev);
            prev = t;
        }
        assert_eq!(c.stats().requests, 100);
    }

    #[test]
    fn cached_rereads_are_fast() {
        let mut c = ctl();
        let t1 = c.request(Access::Cached { addr: 0, bytes: 64 });
        let t2 = c.request(Access::Cached { addr: 0, bytes: 64 });
        assert_eq!(t2 - t1, c.config().cache.hit_latency);
    }

    #[test]
    fn replay_matches_sequential_requests() {
        let trace: Vec<Access> = (0..50)
            .map(|i| Access::Stream {
                addr: i * 4096,
                bytes: 4096,
            })
            .collect();
        let mut a = ctl();
        let t_replay = a.replay(&trace);
        let mut b = ctl();
        let mut t_seq = 0;
        for &acc in &trace {
            t_seq = b.request(acc);
        }
        assert_eq!(t_replay, t_seq);
    }

    #[test]
    fn layout_regions_are_disjoint_and_aligned() {
        let l = MemLayout::plan(&[1000, 800, 600], 50_000, 16, 16);
        assert!(l.tensor_base[0] < l.tensor_base[1]);
        assert!(l.tensor_base[1] < l.factor_base[0]);
        assert!(l.factor_base[0] < l.factor_base[1]);
        assert!(l.factor_base[2] < l.ptr_base);
        assert!(l.ptr_base < l.partial_base);
        for base in l.factor_base.iter().chain(l.tensor_base.iter()) {
            assert_eq!(base % (1 << 20), 0);
        }
        assert_eq!(l.factor_row_addr(1, 3), l.factor_base[1] + 3 * 64);
    }

    #[test]
    fn onchip_bytes_sums_modules() {
        let cfg = ControllerConfig::default_for(16);
        assert_eq!(
            cfg.onchip_bytes(),
            cfg.cache.capacity_bytes()
                + cfg.dma.buffer_capacity_bytes()
                + cfg.remapper.onchip_bytes()
        );
    }

    #[test]
    fn remap_pass_advances_time_and_records_stats() {
        use crate::tensor::synth::{generate, Profile, SynthConfig};
        let t = generate(&SynthConfig {
            dims: vec![100, 80, 60],
            nnz: 1_000,
            profile: Profile::Uniform,
            seed: 4,
        });
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 16);
        let mut c = MemoryController::new(ControllerConfig::default_for(t.record_bytes()));
        let done = c.remap_pass(t.mode_col(1), t.dims()[1], &layout, 0, 1);
        assert!(done > 0);
        assert_eq!(c.remapper_stats().elements, 1_000);
    }

    #[test]
    fn reset_restores_fresh_state() {
        let mut c = ctl();
        c.request(Access::Stream {
            addr: 0,
            bytes: 8192,
        });
        c.reset();
        assert_eq!(c.now(), 0);
        assert_eq!(c.stats().requests, 0);
        assert_eq!(c.dram_stats().bursts, 0);
    }
}
