//! Cache Engine (S3, paper §5.1.1): serves the *random* factor-matrix row
//! accesses with minimum latency, exploiting their temporal and spatial
//! locality.
//!
//! Set-associative with true-LRU replacement.  All three §5.2.1
//! parameters are programmable: line width, number of lines, and
//! associativity.  Backing fetches go to the shared external-memory
//! device (any [`MemoryDevice`]: DDR4, HBM2, or the optical-SRAM
//! scratchpad).

use crate::mem::MemoryDevice;

/// Programmable Cache Engine parameters (paper §5.2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Cache line width in bytes (power of two).
    pub line_bytes: usize,
    /// Total number of lines (power of two, multiple of `assoc`).
    pub num_lines: usize,
    /// Associativity (1 = direct-mapped; `num_lines` = fully assoc.).
    pub assoc: usize,
    /// Lookup/service latency on a hit, in cycles (BRAM access).
    pub hit_latency: u64,
}

impl CacheConfig {
    /// 64 KiB, 64 B lines, 4-way — a sensible mid-size default.
    pub fn default_64k() -> Self {
        CacheConfig {
            line_bytes: 64,
            num_lines: 1024,
            assoc: 4,
            hit_latency: 2,
        }
    }

    /// Total data capacity in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.line_bytes * self.num_lines
    }

    /// Number of sets.
    pub fn num_sets(&self) -> usize {
        self.num_lines / self.assoc
    }

    /// The index/tag geometry of this configuration ([`LineGeom`]).
    pub fn geom(&self) -> LineGeom {
        LineGeom::new(self.line_bytes, self.num_sets())
    }

    pub(crate) fn validate(&self) {
        assert!(self.line_bytes.is_power_of_two(), "line_bytes must be 2^k");
        assert!(self.assoc >= 1 && self.assoc <= self.num_lines);
        assert_eq!(
            self.num_lines % self.assoc,
            0,
            "num_lines must be a multiple of assoc"
        );
        assert!(
            self.num_sets().is_power_of_two(),
            "num_sets must be a power of two"
        );
    }
}

/// Power-of-two index/tag arithmetic of a cache geometry, shared by the
/// scalar path ([`CacheEngine::load`]), the batched event kernel
/// ([`CacheEngine::load_run`]), and the one-pass grid classifier
/// ([`crate::engine::grid`]) so the three cores cannot disagree on
/// which set and tag an address maps to.  All divisions/modulos the
/// validated configuration performs are exactly these shifts and masks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LineGeom {
    line_shift: u32,
    set_mask: u64,
    tag_shift: u32,
}

impl LineGeom {
    /// Geometry for `line_bytes`-wide lines over `num_sets` sets (both
    /// must be powers of two).
    pub fn new(line_bytes: usize, num_sets: usize) -> Self {
        assert!(line_bytes.is_power_of_two(), "line_bytes must be 2^k");
        assert!(num_sets.is_power_of_two(), "num_sets must be 2^k");
        LineGeom {
            line_shift: line_bytes.trailing_zeros(),
            set_mask: num_sets as u64 - 1,
            tag_shift: num_sets.trailing_zeros(),
        }
    }

    /// First line index a `addr` access touches (`addr / line_bytes`).
    pub fn first_line(&self, addr: u64) -> u64 {
        addr >> self.line_shift
    }

    /// Last line index an `addr`/`bytes` access touches
    /// (`(addr + bytes - 1) / line_bytes`; `bytes` must be > 0).
    pub fn last_line(&self, addr: u64, bytes: usize) -> u64 {
        (addr + bytes as u64 - 1) >> self.line_shift
    }

    /// Number of lines an `addr`/`bytes` access touches.
    pub fn line_count(&self, addr: u64, bytes: usize) -> u64 {
        self.last_line(addr, bytes) - self.first_line(addr) + 1
    }

    /// Set index of a line (`line % num_sets`).
    pub fn set(&self, line: u64) -> usize {
        (line & self.set_mask) as usize
    }

    /// Tag of a line (`line / num_sets`).
    pub fn tag(&self, line: u64) -> u64 {
        line >> self.tag_shift
    }

    /// Rebuild a line index from its set and tag
    /// (`tag * num_sets + set`) — the writeback address math.
    pub fn line_of(&self, set: usize, tag: u64) -> u64 {
        (tag << self.tag_shift) | set as u64
    }
}

/// Cache Engine statistics.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Dirty lines written back to DRAM on eviction.
    pub writebacks: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }

    /// Accumulate another engine's counters — the per-shard aggregation
    /// path ([`crate::shard`]): K workers each run their own Cache
    /// Engine, and the aggregate view sums their statistics.
    pub fn merge(&mut self, other: &CacheStats) {
        self.accesses += other.accesses;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.writebacks += other.writebacks;
    }
}

#[derive(Debug, Clone, Copy)]
struct Line {
    tag: u64,
    valid: bool,
    /// Written since fill; eviction costs a DRAM writeback.
    dirty: bool,
    /// LRU timestamp (larger = more recent).
    lru: u64,
}

/// The Cache Engine simulator.
#[derive(Debug, Clone)]
pub struct CacheEngine {
    cfg: CacheConfig,
    sets: Vec<Line>,
    tick: u64,
    stats: CacheStats,
}

impl CacheEngine {
    pub fn new(cfg: CacheConfig) -> Self {
        cfg.validate();
        CacheEngine {
            cfg,
            sets: vec![
                Line {
                    tag: 0,
                    valid: false,
                    dirty: false,
                    lru: 0
                };
                cfg.num_lines
            ],
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Invalidate all lines and clear stats.
    pub fn reset(&mut self) {
        for l in &mut self.sets {
            l.valid = false;
        }
        self.tick = 0;
        self.stats = CacheStats::default();
    }

    /// Serve a load of `bytes` at `addr` starting at cycle `now`; fetches
    /// missing lines from `dram`.  Returns the completion cycle.
    pub fn load<M: MemoryDevice>(&mut self, dram: &mut M, addr: u64, bytes: usize, now: u64) -> u64 {
        self.transfer(dram, addr, bytes, now, false)
    }

    /// Serve a store through the cache (write-allocate, write-back):
    /// partial-line writes fetch the line on a miss, dirty lines cost a
    /// DRAM writeback when evicted.  This is what the paper's §5.1.2(b)
    /// warns about when scattered stores go through the Cache Engine.
    pub fn store<M: MemoryDevice>(&mut self, dram: &mut M, addr: u64, bytes: usize, now: u64) -> u64 {
        self.transfer(dram, addr, bytes, now, true)
    }

    fn transfer<M: MemoryDevice>(
        &mut self,
        dram: &mut M,
        addr: u64,
        bytes: usize,
        now: u64,
        write: bool,
    ) -> u64 {
        assert!(bytes > 0);
        let geom = self.cfg.geom();
        let first = geom.first_line(addr);
        let last = geom.last_line(addr, bytes);
        let mut t = now;
        for line in first..=last {
            t = self.access_line(dram, line, t, write);
        }
        t
    }

    /// Batched kernel for the event engine ([`crate::engine`]): serve a
    /// run of same-width loads at `base + 4*word` for each delta word,
    /// threading the clock through the run.  Bit-identical to calling
    /// [`CacheEngine::load`] once per word — the per-line state machine
    /// is shared ([`CacheEngine::serve_line`]); only the line/set/tag
    /// arithmetic is hoisted out of the loop (shift/mask forms of the
    /// same power-of-two divisions the scalar path performs).
    pub fn load_run<M: MemoryDevice>(
        &mut self,
        dram: &mut M,
        base: u64,
        words: &[u32],
        bytes: usize,
        now: u64,
    ) -> u64 {
        assert!(bytes > 0);
        // line_bytes and num_sets are validated powers of two, so the
        // scalar path's `/` and `%` are exactly the [`LineGeom`] shifts
        // and masks (the same arithmetic the grid classifier uses).
        let geom = self.cfg.geom();
        let mut t = now;
        for &w in words {
            let addr = base + 4 * w as u64;
            let first = geom.first_line(addr);
            let last = geom.last_line(addr, bytes);
            let mut line = first;
            loop {
                let set = geom.set(line);
                let tag = geom.tag(line);
                t = self.serve_line(dram, line, set, tag, t, false);
                if line == last {
                    break;
                }
                line += 1;
            }
        }
        t
    }

    /// Access one line; returns completion cycle.
    fn access_line<M: MemoryDevice>(
        &mut self,
        dram: &mut M,
        line_idx: u64,
        now: u64,
        write: bool,
    ) -> u64 {
        let geom = self.cfg.geom();
        let set = geom.set(line_idx);
        let tag = geom.tag(line_idx);
        self.serve_line(dram, line_idx, set, tag, now, write)
    }

    /// The per-line state machine shared by the scalar and batched
    /// paths: lookup, LRU update, miss fill, dirty-victim writeback.
    fn serve_line<M: MemoryDevice>(
        &mut self,
        dram: &mut M,
        line_idx: u64,
        set: usize,
        tag: u64,
        now: u64,
        write: bool,
    ) -> u64 {
        self.tick += 1;
        self.stats.accesses += 1;
        let base = set * self.cfg.assoc;
        let ways = &mut self.sets[base..base + self.cfg.assoc];

        if let Some(way) = ways.iter_mut().find(|w| w.valid && w.tag == tag) {
            way.lru = self.tick;
            way.dirty |= write;
            self.stats.hits += 1;
            return now + self.cfg.hit_latency;
        }

        // Miss: fetch the whole line from DRAM (write-allocate for
        // stores), install with LRU evict; dirty victims write back.
        self.stats.misses += 1;
        let victim = ways
            .iter_mut()
            .min_by_key(|w| if w.valid { w.lru } else { 0 })
            .expect("assoc >= 1");
        let mut t = now;
        if victim.valid {
            self.stats.evictions += 1;
            if victim.dirty {
                // Writeback: the victim's line goes out before the fill.
                let victim_line = self.cfg.geom().line_of(set, victim.tag);
                t = dram.access(
                    victim_line * self.cfg.line_bytes as u64,
                    self.cfg.line_bytes,
                    t,
                );
                self.stats.writebacks += 1;
            }
        }
        let done = dram.access(line_idx * self.cfg.line_bytes as u64, self.cfg.line_bytes, t);
        victim.valid = true;
        victim.tag = tag;
        victim.dirty = write;
        victim.lru = self.tick;
        done + self.cfg.hit_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::{Dram, DramConfig};
    use crate::testkit::Rng;

    fn dram() -> Dram {
        Dram::new(DramConfig::default_ddr4())
    }

    fn tiny(assoc: usize) -> CacheEngine {
        CacheEngine::new(CacheConfig {
            line_bytes: 64,
            num_lines: 8,
            assoc,
            hit_latency: 2,
        })
    }

    #[test]
    fn repeat_access_hits() {
        let mut d = dram();
        let mut c = tiny(2);
        let t1 = c.load(&mut d, 0, 64, 0);
        let t2 = c.load(&mut d, 0, 64, t1);
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
        assert_eq!(t2 - t1, 2, "hit costs only hit_latency");
    }

    #[test]
    fn spatial_locality_within_line() {
        let mut d = dram();
        let mut c = CacheEngine::new(CacheConfig {
            line_bytes: 256,
            num_lines: 8,
            assoc: 2,
            hit_latency: 2,
        });
        c.load(&mut d, 0, 4, 0);
        c.load(&mut d, 128, 4, 100); // same 256B line
        assert_eq!(c.stats().hits, 1);
    }

    #[test]
    fn multi_line_load_counts_each_line() {
        let mut d = dram();
        let mut c = tiny(2);
        c.load(&mut d, 0, 256, 0); // 4 lines of 64B
        assert_eq!(c.stats().accesses, 4);
        assert_eq!(c.stats().misses, 4);
    }

    #[test]
    fn direct_mapped_conflicts_thrash() {
        let mut d = dram();
        let mut c = tiny(1); // 8 sets, direct mapped
        // Two addresses 8 lines apart map to the same set.
        for i in 0..10 {
            let addr = if i % 2 == 0 { 0 } else { 8 * 64 };
            c.load(&mut d, addr, 64, i * 100);
        }
        assert_eq!(c.stats().hits, 0, "direct-mapped ping-pong never hits");
        assert_eq!(c.stats().evictions, 9, "all but the cold miss evict");
    }

    #[test]
    fn two_way_fixes_the_same_thrash() {
        let mut d = dram();
        let mut c = tiny(2); // 4 sets, 2-way
        for i in 0..10 {
            let addr = if i % 2 == 0 { 0 } else { 4 * 2 * 64 };
            c.load(&mut d, addr, 64, i * 100);
        }
        assert_eq!(c.stats().misses, 2, "only the two cold misses remain");
        assert_eq!(c.stats().hits, 8);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut d = dram();
        // Fully associative, 2 lines.
        let mut c = CacheEngine::new(CacheConfig {
            line_bytes: 64,
            num_lines: 2,
            assoc: 2,
            hit_latency: 1,
        });
        c.load(&mut d, 0, 1, 0); // A
        c.load(&mut d, 64, 1, 10); // B
        c.load(&mut d, 0, 1, 20); // touch A -> B is LRU
        c.load(&mut d, 128, 1, 30); // C evicts B
        c.load(&mut d, 0, 1, 40); // A still resident
        assert_eq!(c.stats().hits, 2);
        c.load(&mut d, 64, 1, 50); // B was evicted -> miss
        assert_eq!(c.stats().misses, 4);
    }

    #[test]
    fn stores_write_allocate_and_write_back() {
        let mut d = dram();
        // 2 lines fully associative.
        let mut c = CacheEngine::new(CacheConfig {
            line_bytes: 64,
            num_lines: 2,
            assoc: 2,
            hit_latency: 1,
        });
        c.store(&mut d, 0, 16, 0); // miss + allocate, dirty
        assert_eq!(c.stats().misses, 1);
        c.store(&mut d, 16, 16, 10); // same line: hit, stays dirty
        assert_eq!(c.stats().hits, 1);
        // Fill the other way, then evict the dirty line -> writeback.
        c.load(&mut d, 64, 1, 20);
        c.load(&mut d, 128, 1, 30); // evicts LRU = line 0 (dirty)
        assert_eq!(c.stats().writebacks, 1);
        // Clean eviction does not write back.
        c.load(&mut d, 192, 1, 40);
        assert_eq!(c.stats().writebacks, 1);
    }

    #[test]
    fn scattered_stores_via_cache_cost_more_dram_than_element_sized_traffic() {
        // The §5.1.2(b) effect: write-allocate turns each 16B scattered
        // store into a 64B fill + eventual 64B writeback.
        let mut d = dram();
        let mut c = CacheEngine::new(CacheConfig {
            line_bytes: 64,
            num_lines: 64,
            assoc: 4,
            hit_latency: 1,
        });
        let mut t = 0;
        for i in 0..10_000u64 {
            t = c.store(&mut d, (i % 4096) * 16384, 16, t);
        }
        let cache_bytes = d.stats().bytes;
        // Raw element-wise stores of the same records:
        let mut d2 = dram();
        let mut t2 = 0;
        for i in 0..10_000u64 {
            t2 = d2.access((i % 4096) * 16384, 16, t2);
        }
        assert!(
            cache_bytes > d2.stats().bytes * 3 / 2,
            "write-allocate+writeback must inflate DRAM traffic: {} vs {}",
            cache_bytes,
            d2.stats().bytes
        );
    }

    #[test]
    fn working_set_knee_appears_at_capacity() {
        // Cycling through W lines: hit rate ~1 when W <= lines, ~0 when
        // W > lines (LRU worst case) — the knee the DSE must find.
        let run = |num_lines: usize, w: usize| {
            let mut d = dram();
            let mut c = CacheEngine::new(CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc: num_lines,
                hit_latency: 1,
            });
            let mut t = 0;
            for i in 0..w * 50 {
                t = c.load(&mut d, ((i % w) * 64) as u64, 64, t);
            }
            c.stats().hit_rate()
        };
        assert!(run(64, 32) > 0.95);
        assert!(run(64, 128) < 0.05);
    }

    #[test]
    fn random_hit_rate_increases_with_capacity() {
        let run = |num_lines: usize| {
            let mut d = dram();
            let mut c = CacheEngine::new(CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc: 4,
                hit_latency: 1,
            });
            let mut rng = Rng::new(3);
            let mut t = 0;
            for _ in 0..20_000 {
                // Zipf-skewed line index over 4096 lines.
                let line = rng.zipf(4096, 1.2);
                t = c.load(&mut d, line * 64, 64, t);
            }
            c.stats().hit_rate()
        };
        let small = run(64);
        let big = run(2048);
        assert!(big > small + 0.1, "big {big} small {small}");
    }

    #[test]
    fn stats_merge_sums_every_field() {
        let mut d = dram();
        let mut a = tiny(2);
        a.load(&mut d, 0, 64, 0); // miss
        a.load(&mut d, 0, 64, 10); // hit
        let mut b = tiny(2);
        b.store(&mut d, 4096, 64, 0); // miss, dirty
        let mut merged = a.stats().clone();
        merged.merge(b.stats());
        assert_eq!(merged.accesses, 3);
        assert_eq!(merged.hits, 1);
        assert_eq!(merged.misses, 2);
        assert_eq!(
            merged.hit_rate(),
            (a.stats().hits + b.stats().hits) as f64
                / (a.stats().accesses + b.stats().accesses) as f64
        );
    }

    #[test]
    fn load_run_matches_scalar_loads_exactly() {
        // The batched kernel must be bit-identical to per-access
        // load() — same stats, same completion cycles — including
        // multi-line accesses (bytes > line_bytes).
        for bytes in [8usize, 64, 200] {
            let mut rng = Rng::new(17);
            let base = 8u64 << 20;
            let words: Vec<u32> = (0..2_000).map(|_| rng.below(1 << 16) as u32).collect();
            let mut d1 = dram();
            let mut c1 = tiny(2);
            let mut t_scalar = 0u64;
            for &w in &words {
                t_scalar = c1.load(&mut d1, base + 4 * w as u64, bytes, t_scalar);
            }
            let mut d2 = dram();
            let mut c2 = tiny(2);
            let t_batched = c2.load_run(&mut d2, base, &words, bytes, 0);
            assert_eq!(t_scalar, t_batched, "bytes={bytes}");
            assert_eq!(c1.stats(), c2.stats(), "bytes={bytes}");
            assert_eq!(d1.stats(), d2.stats(), "bytes={bytes}");
        }
    }

    #[test]
    #[should_panic(expected = "multiple of assoc")]
    fn rejects_bad_geometry() {
        CacheEngine::new(CacheConfig {
            line_bytes: 64,
            num_lines: 6,
            assoc: 4,
            hit_latency: 1,
        });
    }
}
