//! Design-space exploration (S11, paper §5.3): "a module-by-module
//! (e.g., Cache Engine and DMA Engine) exhaustive parameter search can be
//! proposed to identify the optimal parameters for the memory
//! controller."
//!
//! The explorer sweeps one module's grid at a time while holding the
//! others at their current best (coordinate descent over module grids —
//! exactly the paper's proposal), scoring each candidate with either the
//! fast analytic PMS or the cycle-level simulator, and rejecting
//! configurations that do not fit the device ([`crate::fpga`]).

use crate::controller::{CacheConfig, ControllerConfig, DmaConfig, MemLayout, MemoryController};
use crate::cpd::linalg::Mat;
use crate::engine::EngineKind;
use crate::fpga::{self, Device};
use crate::mttkrp::{approach1, Tracing};
use crate::pms::{self, TensorProfile};
use crate::tensor::SparseTensor;

/// How candidates are scored.
pub enum Evaluator<'a> {
    /// Analytic PMS over a measured profile (fast: microseconds/config).
    Pms {
        profile: &'a TensorProfile,
        rank: usize,
    },
    /// Cycle-level simulation of a full Approach-1 sweep over a concrete
    /// tensor (slow but exact; used to validate the PMS ranking).
    /// `engine` selects the replay core ([`crate::engine`]): both
    /// produce identical scores; `Event` replays the compiled trace
    /// through the batched kernels.
    CycleSim {
        tensor: &'a SparseTensor,
        factors: &'a [Mat],
        engine: EngineKind,
    },
    /// Sharded cycle-level simulation ([`crate::shard`]): every candidate
    /// configuration is evaluated as K per-shard controller instances
    /// running concurrently; the score is the sum over modes of the
    /// remap pass plus the slowest shard's replay makespan.  The sweep
    /// is prepared once ([`crate::shard::ShardedSweep::prepare`]) so
    /// per-candidate scoring replays traces only.  This is how a
    /// multi-controller (multi-SLR) deployment should pick its
    /// per-instance parameters.
    ShardedSim {
        sweep: &'a crate::shard::ShardedSweep<'a>,
    },
}

impl Evaluator<'_> {
    /// Score = estimated/measured total cycles (lower is better), or
    /// `None` if the configuration does not fit `dev`.
    pub fn score(&self, cfg: &ControllerConfig, dev: &Device) -> Option<f64> {
        if !fpga::estimate(cfg, dev).fits {
            return None;
        }
        match self {
            Evaluator::Pms { profile, rank } => {
                Some(pms::estimate_with_rank(profile, cfg, dev, *rank).total_cycles())
            }
            Evaluator::CycleSim {
                tensor,
                factors,
                engine,
            } => {
                let rank = factors[0].cols();
                let layout =
                    MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
                let mut ctl = MemoryController::new(cfg.clone());
                let mut total = 0u64;
                let mut t = (*tensor).clone();
                for mode in 0..t.n_modes() {
                    ctl.remap_pass(t.mode_col(mode), t.dims()[mode], &layout, 0, 1);
                    crate::tensor::remap::remap(&mut t, mode, cfg.remapper.max_pointers);
                    let run = approach1::run(&t, factors, mode, &layout, Tracing::On);
                    total = engine.replay_raw(&mut ctl, &run.trace);
                }
                Some(total as f64)
            }
            Evaluator::ShardedSim { sweep } => {
                // K concurrent controller instances must *all* fit the
                // device: each needs a 1/K slice of the block budget
                // (the whole-device check above only covers one
                // instance), and each instance owns a DRAM channel
                // group, so the device must have K channel groups and
                // the configured bus must exist on the board.
                let w = sweep.workers();
                if w > dev.dram_channels || cfg.dram.channels > dev.dram_channels {
                    return None;
                }
                let slice = Device {
                    bram36: dev.bram36 / w,
                    uram: dev.uram / w,
                    ..*dev
                };
                if !fpga::estimate(cfg, &slice).fits {
                    return None;
                }
                Some(sweep.makespan(cfg) as f64)
            }
        }
    }
}

/// One explored point.
#[derive(Debug, Clone)]
pub struct Point {
    pub cfg: ControllerConfig,
    pub cycles: f64,
    pub bram36: usize,
    pub uram: usize,
}

/// Result of a full exploration.
#[derive(Debug, Clone)]
pub struct Exploration {
    pub best: Point,
    /// Every feasible point visited, in visit order.
    pub visited: Vec<Point>,
    /// Candidates rejected for not fitting the device.
    pub rejected: usize,
}

/// Default sweep grids (§5.2.1 parameters).
pub struct Grids {
    pub cache_line_bytes: Vec<usize>,
    pub cache_num_lines: Vec<usize>,
    pub cache_assoc: Vec<usize>,
    pub dma_num: Vec<usize>,
    pub dma_buffers: Vec<usize>,
    pub dma_buffer_bytes: Vec<usize>,
    pub remap_max_pointers: Vec<usize>,
}

impl Default for Grids {
    fn default() -> Self {
        Grids {
            cache_line_bytes: vec![32, 64, 128, 256],
            cache_num_lines: vec![256, 1024, 4096, 16384],
            cache_assoc: vec![1, 2, 4, 8],
            dma_num: vec![1, 2, 4],
            dma_buffers: vec![1, 2, 4],
            dma_buffer_bytes: vec![1024, 4096, 16384],
            remap_max_pointers: vec![1 << 10, 1 << 14, 1 << 18, 1 << 22],
        }
    }
}

/// Run the module-by-module exhaustive search starting from `base`.
/// Order: Cache Engine grid, then DMA Engine, then Tensor Remapper —
/// each module fixed to its best before the next is swept.
pub fn explore(
    base: &ControllerConfig,
    grids: &Grids,
    dev: &Device,
    eval: &Evaluator<'_>,
) -> Exploration {
    let mut best_cfg = base.clone();
    let mut visited = Vec::new();
    let mut rejected = 0usize;

    let consider =
        |cfg: ControllerConfig, visited: &mut Vec<Point>, rejected: &mut usize| -> Option<Point> {
            let usage = fpga::estimate(&cfg, dev);
            match eval.score(&cfg, dev) {
                None => {
                    *rejected += 1;
                    None
                }
                Some(cycles) => {
                    let p = Point {
                        cfg,
                        cycles,
                        bram36: usage.bram36_used,
                        uram: usage.uram_used,
                    };
                    visited.push(p.clone());
                    Some(p)
                }
            }
        };

    let mut best_point = consider(best_cfg.clone(), &mut visited, &mut rejected)
        .expect("base configuration must fit the device");

    // --- Module 1: Cache Engine ---
    for &line_bytes in &grids.cache_line_bytes {
        for &num_lines in &grids.cache_num_lines {
            for &assoc in &grids.cache_assoc {
                if num_lines % assoc != 0 || !(num_lines / assoc).is_power_of_two() {
                    continue;
                }
                let mut cfg = best_cfg.clone();
                cfg.cache = CacheConfig {
                    line_bytes,
                    num_lines,
                    assoc,
                    hit_latency: cfg.cache.hit_latency,
                };
                if let Some(p) = consider(cfg, &mut visited, &mut rejected) {
                    if p.cycles < best_point.cycles {
                        best_point = p;
                    }
                }
            }
        }
    }
    best_cfg = best_point.cfg.clone();

    // --- Module 2: DMA Engine ---
    for &num_dmas in &grids.dma_num {
        for &buffers_per_dma in &grids.dma_buffers {
            for &buffer_bytes in &grids.dma_buffer_bytes {
                let mut cfg = best_cfg.clone();
                cfg.dma = DmaConfig {
                    num_dmas,
                    buffers_per_dma,
                    buffer_bytes,
                    setup_cycles: cfg.dma.setup_cycles,
                };
                if let Some(p) = consider(cfg, &mut visited, &mut rejected) {
                    if p.cycles < best_point.cycles {
                        best_point = p;
                    }
                }
            }
        }
    }
    best_cfg = best_point.cfg.clone();

    // --- Module 3: Tensor Remapper ---
    for &max_pointers in &grids.remap_max_pointers {
        let mut cfg = best_cfg.clone();
        cfg.remapper.max_pointers = max_pointers;
        if let Some(p) = consider(cfg, &mut visited, &mut rejected) {
            if p.cycles < best_point.cycles {
                best_point = p;
            }
        }
    }

    Exploration {
        best: best_point,
        visited,
        rejected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::{generate, Profile, SynthConfig};

    fn tensor() -> SparseTensor {
        generate(&SynthConfig {
            dims: vec![400, 300, 200],
            nnz: 8_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 77,
        })
    }

    #[test]
    fn pms_exploration_finds_no_worse_than_base() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let ex = explore(&base, &Grids::default(), &dev, &eval);
        let base_score = eval.score(&base, &dev).unwrap();
        assert!(ex.best.cycles <= base_score);
        assert!(ex.visited.len() > 20);
    }

    #[test]
    fn infeasible_configs_are_rejected_not_chosen() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let mut grids = Grids::default();
        grids.cache_num_lines.push(1 << 22); // 256 MiB cache: never fits
        let ex = explore(&base, &grids, &dev, &eval);
        assert!(ex.rejected > 0);
        assert!(fpga::estimate(&ex.best.cfg, &dev).fits);
    }

    #[test]
    fn cycle_sim_exploration_small_grid() {
        // Dims large enough that 256 cache lines thrash while 4096 hold
        // the zipf-hot factor rows (rank 16 -> one 64B line per row).
        let t = generate(&SynthConfig {
            dims: vec![4000, 3000, 2000],
            nnz: 20_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 78,
        });
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 16, 1)).collect();
        let eval = Evaluator::CycleSim {
            tensor: &t,
            factors: &factors,
            engine: crate::engine::EngineKind::Event,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let grids = Grids {
            cache_line_bytes: vec![64],
            cache_num_lines: vec![256, 4096],
            cache_assoc: vec![4],
            dma_num: vec![2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            remap_max_pointers: vec![1 << 18],
        };
        let ex = explore(&base, &grids, &dev, &eval);
        // The bigger cache must win for a zipf-skewed tensor whose hot
        // rows fit at 4096 lines but not at 256.
        assert_eq!(ex.best.cfg.cache.num_lines, 4096);
    }

    #[test]
    fn sharded_evaluation_ranks_like_serial_and_scores_lower() {
        // A crippled cache must lose under the sharded evaluator too,
        // and parallel makespans must come in under the serial sweep.
        let t = generate(&SynthConfig {
            dims: vec![800, 600, 400],
            nnz: 10_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 79,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep4 = crate::shard::ShardedSweep::prepare(&t, 16, 4);
        let sharded = Evaluator::ShardedSim { sweep: &sweep4 };
        let good = sharded.score(&base, &dev).unwrap();
        let mut crippled = base.clone();
        crippled.cache.num_lines = 64;
        crippled.cache.assoc = 1;
        let bad = sharded.score(&crippled, &dev).unwrap();
        assert!(good < bad, "crippled cache must lose: {good} vs {bad}");

        let sweep1 = crate::shard::ShardedSweep::prepare(&t, 16, 1);
        let serial = Evaluator::ShardedSim { sweep: &sweep1 };
        let serial_score = serial.score(&base, &dev).unwrap();
        assert!(
            good < serial_score,
            "4-worker makespan {good} must beat 1-worker {serial_score}"
        );

        // A config that fits as ONE instance but not as four concurrent
        // instances must be rejected by the sharded evaluator.
        let mut big = base.clone();
        big.cache.num_lines = 1 << 14; // ~1.1 MiB cache + tags per instance
        assert!(fpga::estimate(&big, &dev).fits, "fits as a single instance");
        assert!(
            sharded.score(&big, &dev).is_none(),
            "4 instances must not fit the device"
        );

        // More worker instances than the device has DRAM channel groups
        // is not a realizable deployment either.
        let sweep8 = crate::shard::ShardedSweep::prepare(&t, 16, 8);
        let oversubscribed = Evaluator::ShardedSim { sweep: &sweep8 };
        assert!(
            oversubscribed.score(&base, &dev).is_none(),
            "u250 has 4 channel groups; 8 instances must be rejected"
        );
    }

    #[test]
    fn cycle_sim_engines_score_identically() {
        // The event core is an execution strategy, not a model change:
        // the same configuration must score to the exact same cycle
        // count under both engines, including remap phases.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 2)).collect();
        let dev = Device::alveo_u250();
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.cache.num_lines = 512;
        for max_pointers in [1usize << 4, 1 << 18] {
            cfg.remapper.max_pointers = max_pointers;
            let lockstep = Evaluator::CycleSim {
                tensor: &t,
                factors: &factors,
                engine: crate::engine::EngineKind::Lockstep,
            }
            .score(&cfg, &dev)
            .unwrap();
            let event = Evaluator::CycleSim {
                tensor: &t,
                factors: &factors,
                engine: crate::engine::EngineKind::Event,
            }
            .score(&cfg, &dev)
            .unwrap();
            assert_eq!(lockstep, event, "engines diverged at {max_pointers} pointers");
        }
    }

    #[test]
    fn module_order_is_respected() {
        // After exploration the best config's DMA comes from the DMA
        // sweep holding the best cache — verify the best point's cache
        // equals what a cache-only sweep would pick.
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let cache_only = Grids {
            dma_num: vec![base.dma.num_dmas],
            dma_buffers: vec![base.dma.buffers_per_dma],
            dma_buffer_bytes: vec![base.dma.buffer_bytes],
            remap_max_pointers: vec![base.remapper.max_pointers],
            ..Grids::default()
        };
        let ex_cache = explore(&base, &cache_only, &dev, &eval);
        let ex_full = explore(&base, &Grids::default(), &dev, &eval);
        assert_eq!(
            ex_full.best.cfg.cache, ex_cache.best.cfg.cache,
            "full search must keep the cache module's winner"
        );
    }
}
