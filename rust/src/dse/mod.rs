//! Design-space exploration (S11, paper §5.3): "a module-by-module
//! (e.g., Cache Engine and DMA Engine) exhaustive parameter search can be
//! proposed to identify the optimal parameters for the memory
//! controller."
//!
//! The search layer is pluggable ([`SearchStrategy`]):
//!
//! * `Coordinate` — the paper's proposal and the legacy default: sweep
//!   one module's grid at a time while holding the others at their
//!   current best.  Fast, but it can miss jointly-optimal points.
//! * `Joint` — exhaustive search of the **joint** cross product
//!   `remapper × line_bytes × (num_lines, assoc) × memory × DMA`
//!   (unioned per dimension with the base configuration's values, so
//!   its best is never worse than coordinate descent's).  Infeasible
//!   points are pruned with the device check *before* any simulation.
//! * `Beam` — the middle ground: keep the best `width` incumbents
//!   after each module sweep and sweep the next module from each.
//!
//! The external-memory module is a first-class search axis: the
//! memory grid spans **technologies** ([`Grids::mem_techs`] —
//! DDR4 / HBM2 / optical SRAM, [`crate::mem`]) as well as per-tech
//! knobs, so a joint exploration over a board that hosts several
//! technologies compares them head to head.
//!
//! Every strategy reports a Pareto frontier (cycles vs on-chip blocks
//! vs memory-device power proxy) and the top-k points
//! ([`Exploration`]) on top of the single winner.
//!
//! Candidates within one batch are independent, so all strategies score
//! through [`Evaluator::score_batch`]: candidates fan out across host
//! threads, and — under the grid engine ([`EngineKind::Grid`]) — the
//! cross product factorizes.  A cache-module sweep is classified in
//! **one trace pass** by the stack-distance grid core
//! ([`crate::engine::grid`]); a DRAM/DMA (timing-module) sweep runs
//! through the vectorized timing core ([`crate::engine::timing`]); and
//! a genuinely **joint** batch — cache AND timing knobs both varying,
//! the `Joint` strategy's shape — runs through the hierarchical sweep
//! core ([`crate::engine::sweep`]): classify per line width, extract
//! the miss/stream op queue per cache candidate, then walk each
//! cache's DRAM/DMA lane set once.  Scores are bit-identical to
//! per-candidate scoring under either classic engine.

use std::sync::{Arc, Mutex};

use crate::controller::{
    CacheConfig, ControllerConfig, DmaConfig, MemLayout, MemoryController, RemapperConfig,
};
use crate::cpd::linalg::Mat;
use crate::dram::{DramConfig, RowPolicy};
use crate::engine::{
    CompressedTrace, EngineKind, GridClassification, JointIndex, PreparedTrace, TimingCandidate,
    TimingOps,
};
use crate::fpga::{self, Device};
use crate::mem::{MemTech, MemTechConfig};
use crate::mttkrp::{approach1, Tracing};
use crate::pms::{self, TensorProfile};
use crate::tensor::{remap, SparseTensor};
use crate::util::{parallel_indexed, RemapMemo, SpillCol};

pub mod memo;
pub mod warm;

pub use memo::{MemoStore, MemoView, ScoreCache};
pub use warm::{tensor_fingerprint, Fingerprint, KeyBuilder, WarmCache};

/// Per-mode precomputation of a CycleSim scoring pass under one
/// remapper pointer budget: the mode column the (simulated) remap pass
/// reads — a snapshot of the tensor *before* this mode's host remap —
/// and the compiled Approach-1 trace of the remapped tensor.  Under a
/// memory budget the column spills to disk ([`SpillCol`]) and the
/// trace keeps only its compressed form.
struct ModePrep {
    remap_col: SpillCol,
    trace: PreparedTrace,
}

/// Interior-mutable memo shared by every scoring of one
/// [`Evaluator::CycleSim`]: the remapped tensor is cloned and
/// re-remapped **once** instead of once per candidate (the host
/// permutation `remap` applies is a counting sort — independent of
/// every controller knob, including the pointer budget, which only
/// changes the *simulated* pointer traffic), and the remap-pass
/// simulation — identical for every candidate sharing (mode, DRAM,
/// remapper) knobs, i.e. the whole cache/DMA grid and every joint-sweep
/// cell — runs once per key through the shared
/// [`crate::util::RemapMemo`] (the same type `ShardedSweep` keys its
/// remap memo with).
pub struct SimMemo {
    prep: Mutex<Option<Arc<Vec<ModePrep>>>>,
    remap: RemapMemo,
    /// Memory policy (S24): `Some(budget)` enables the bounded-memory
    /// prep — remap columns spill to disk and per-mode traces retain
    /// only the compressed view (unless the replay core needs raw).
    budget: Option<u64>,
    /// Whether prep must retain the raw access list alongside the
    /// compressed trace.  Only the Lockstep core replays raw; the
    /// Event/Grid cores (and every batch path) consume the compressed
    /// trace exclusively, so under a budget raw is dropped.
    keep_raw: bool,
}

impl Default for SimMemo {
    /// Unbudgeted: everything in RAM, raw traces retained.
    fn default() -> Self {
        SimMemo {
            prep: Mutex::new(None),
            remap: RemapMemo::new(),
            budget: None,
            keep_raw: true,
        }
    }
}

impl SimMemo {
    /// A memo whose prep obeys `budget` for a sweep replayed by
    /// `engine`.  `None` keeps everything in RAM (the historical
    /// behaviour); `Some(_)` spills remap columns and drops raw traces
    /// when `engine` permits.  Scores are bit-identical either way.
    pub fn with_policy(budget: Option<u64>, engine: EngineKind) -> Self {
        SimMemo {
            keep_raw: budget.is_none() || engine == EngineKind::Lockstep,
            budget,
            ..SimMemo::default()
        }
    }
    /// The per-mode traces + remap columns, built on first use: one
    /// tensor clone, remapped mode by mode in sweep order (the state
    /// the original per-candidate loop reproduced from scratch for
    /// every single candidate).
    fn prep(&self, t: &SparseTensor, factors: &[Mat], layout: &MemLayout) -> Arc<Vec<ModePrep>> {
        if let Some(p) = self.prep.lock().expect("prep memo poisoned").as_ref() {
            return Arc::clone(p);
        }
        let mut tt = t.clone();
        let n = tt.n_modes();
        let built: Vec<ModePrep> = (0..n)
            .map(|mode| {
                let remap_col =
                    SpillCol::new(tt.mode_col(mode).to_vec(), self.budget.is_some());
                // The budget does not affect the data movement, only
                // the (separately simulated) pointer traffic.
                remap::remap(&mut tt, mode, usize::MAX);
                let run = approach1::run(&tt, factors, mode, layout, Tracing::On);
                // Under a memory budget the raw access list (the
                // dominant retained allocation — tens of bytes per
                // access) is compressed and dropped per mode; only the
                // Lockstep core needs raw, and `with_policy` keeps it
                // in that case.
                let trace = if self.keep_raw {
                    PreparedTrace::new(run.trace)
                } else {
                    PreparedTrace::from_compressed(CompressedTrace::compress(&run.trace))
                };
                ModePrep { remap_col, trace }
            })
            .collect();
        let mut memo = self.prep.lock().expect("prep memo poisoned");
        Arc::clone(memo.get_or_insert_with(|| Arc::new(built)))
    }

    /// One mode's remap-pass cycles under `cfg`, on a fresh controller,
    /// memoized per (mode, DRAM, remapper) key ([`RemapMemo`]).
    fn remap_cycles(
        &self,
        p: &ModePrep,
        mode: usize,
        mode_len: usize,
        layout: &MemLayout,
        cfg: &ControllerConfig,
    ) -> u64 {
        self.remap.cycles(mode, cfg, || {
            let mut ctl = MemoryController::new(cfg.clone());
            // Re-reads the column from disk if spilled — rare (once
            // per (mode, DRAM, remapper) key) and transient.
            ctl.remap_pass(&p.remap_col.load(), mode_len, layout, 0, 1)
        })
    }
}

/// How candidates are scored.
pub enum Evaluator<'a> {
    /// Analytic PMS over a measured profile (fast: microseconds/config).
    Pms {
        profile: &'a TensorProfile,
        rank: usize,
    },
    /// Cycle-level simulation of a full Approach-1 sweep over a concrete
    /// tensor (slow but exact; used to validate the PMS ranking).  The
    /// score is the sum over modes of a fresh-controller remap pass plus
    /// a fresh-controller trace replay — the same phase model
    /// [`crate::shard::ShardedSweep::makespan`] uses — so both phases
    /// memoize across candidates ([`SimMemo`]).  `engine` selects the
    /// replay core ([`crate::engine`]): all cores produce identical
    /// scores; `Grid` additionally scores whole cache-module batches in
    /// one classification pass ([`Evaluator::score_batch`]).  Construct
    /// with [`Evaluator::cycle_sim`] (or supply `SimMemo::default()`).
    CycleSim {
        tensor: &'a SparseTensor,
        factors: &'a [Mat],
        engine: EngineKind,
        /// Shared via `Arc` so the DSE server can hand N concurrent
        /// same-tensor queries one memo ([`EvaluatorBuilder::sim_memo`]).
        memo: Arc<SimMemo>,
    },
    /// Sharded cycle-level simulation ([`crate::shard`]): every candidate
    /// configuration is evaluated as K per-shard controller instances
    /// running concurrently; the score is the sum over modes of the
    /// remap pass plus the slowest shard's replay makespan.  The sweep
    /// is prepared once ([`crate::shard::ShardedSweep::prepare`]) so
    /// per-candidate scoring replays traces only.  This is how a
    /// multi-controller (multi-SLR) deployment should pick its
    /// per-instance parameters.
    ShardedSim {
        sweep: &'a crate::shard::ShardedSweep<'a>,
    },
    /// Warm-start wrapper (S28): serves scores and feasibility
    /// verdicts from a [`ScoreCache`] keyed by the full scoring
    /// context (tensor fingerprint, evaluator kind, engine, rank,
    /// device, factors) and delegates only cache misses to the
    /// wrapped evaluator.  The cache is either a persistent
    /// single-context [`WarmCache`] or a per-context view of the
    /// concurrent cross-query [`MemoStore`] (S34) — scores are
    /// bit-identical to the inner evaluator's either way:
    /// per-candidate scores are deterministic pure functions of the
    /// context, and the cache stores their exact `f64` bits, so a
    /// warm `explore` returns byte-identical results while re-scoring
    /// only the delta of unseen candidates.  Construct with
    /// [`EvaluatorBuilder::warm_cache`] or
    /// [`EvaluatorBuilder::score_cache`].
    Warm {
        inner: Box<Evaluator<'a>>,
        cache: Arc<dyn ScoreCache>,
    },
}

impl<'a> Evaluator<'a> {
    /// A [`Evaluator::CycleSim`] with a fresh memo.
    #[deprecated(note = "use `EvaluatorBuilder::new().engine(engine).cycle_sim(tensor, factors)`")]
    pub fn cycle_sim(
        tensor: &'a SparseTensor,
        factors: &'a [Mat],
        engine: EngineKind,
    ) -> Evaluator<'a> {
        EvaluatorBuilder::new().engine(engine).cycle_sim(tensor, factors)
    }
}

/// The one entry point for constructing an [`Evaluator`] — shared
/// defaults first, then one terminal call per scoring model:
///
/// ```text
/// EvaluatorBuilder::new()            defaults: Grid engine, rank 16
///     .engine(EngineKind::Event)     replay core for the sim paths
///     .rank(32)                      factor rank for the PMS path
///     .pms(&profile)        -> Evaluator::Pms        (analytic, µs/config)
///     .cycle_sim(&t, &f)    -> Evaluator::CycleSim   (exact, fresh memo)
///     .sharded(&sweep)      -> Evaluator::ShardedSim (K instances)
/// ```
///
/// The three `Evaluator` variants remain public as the data
/// representation (`match` sites need them), but new code should
/// construct through the builder: it owns the defaults, and the legacy
/// free-standing constructors ([`Evaluator::cycle_sim`]) are
/// deprecated shims over it.
#[derive(Clone)]
pub struct EvaluatorBuilder {
    engine: EngineKind,
    rank: usize,
    memory_budget: Option<u64>,
    warm: Option<Arc<dyn ScoreCache>>,
    sim: Option<Arc<SimMemo>>,
}

impl std::fmt::Debug for EvaluatorBuilder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EvaluatorBuilder")
            .field("engine", &self.engine)
            .field("rank", &self.rank)
            .field("memory_budget", &self.memory_budget)
            .field("warm", &self.warm)
            .field("sim", &self.sim.as_ref().map(|_| "Arc<SimMemo>"))
            .finish()
    }
}

impl Default for EvaluatorBuilder {
    fn default() -> Self {
        EvaluatorBuilder::new()
    }
}

impl EvaluatorBuilder {
    /// Defaults: the grid replay core (fastest; bit-identical scores to
    /// the classic engines) and PMS rank 16.
    pub fn new() -> Self {
        EvaluatorBuilder {
            engine: EngineKind::Grid,
            rank: 16,
            memory_budget: None,
            warm: None,
            sim: None,
        }
    }

    /// Wrap every evaluator this builder produces in
    /// [`Evaluator::Warm`] (S28): scores and feasibility verdicts are
    /// served from `cache` and only misses reach the underlying
    /// model.  The caller is responsible for opening the cache under
    /// the right context key ([`warm::KeyBuilder`]) — a key that
    /// omits a score-relevant input will serve stale scores.
    pub fn warm_cache(mut self, cache: Option<Arc<WarmCache>>) -> Self {
        self.warm = cache.map(|c| c as Arc<dyn ScoreCache>);
        self
    }

    /// Like [`Self::warm_cache`], for any [`ScoreCache`] — in
    /// particular a per-context [`MemoView`] of the concurrent
    /// cross-query [`MemoStore`] (S34), which is how the DSE server
    /// shares verdicts between N concurrent explores of one tensor.
    pub fn score_cache(mut self, cache: Option<Arc<dyn ScoreCache>>) -> Self {
        self.warm = cache;
        self
    }

    /// Share a prepared simulation memo across evaluators (S34): the
    /// per-mode remap + trace prep and the (mode, DRAM, remapper)
    /// remap-pass cycles are computed once and reused by every
    /// [`Self::cycle_sim`] evaluator built with the same memo — the
    /// cross-query analogue of what [`SimMemo`] already does across
    /// candidates within one query.  The caller must only share a
    /// memo between evaluators scoring the *same* (tensor, factors,
    /// engine): the memo caches their derived state.  `None` (the
    /// default) builds a fresh memo per terminal call.
    pub fn sim_memo(mut self, memo: Option<Arc<SimMemo>>) -> Self {
        self.sim = memo;
        self
    }

    /// Apply the optional warm-start wrapper to a terminal evaluator.
    fn wrap<'a>(&self, inner: Evaluator<'a>) -> Evaluator<'a> {
        match &self.warm {
            Some(cache) => Evaluator::Warm {
                inner: Box::new(inner),
                cache: Arc::clone(cache),
            },
            None => inner,
        }
    }

    /// Peak-memory target in bytes for the simulation paths (S24):
    /// when set, [`Self::cycle_sim`] builds its memo with the
    /// bounded-memory policy ([`SimMemo::with_policy`]) — per-mode
    /// traces keep only the compressed view (for the Event/Grid cores)
    /// and remap-column snapshots spill to disk.  Scores are
    /// bit-identical with and without a budget.  `None` (the default)
    /// keeps everything in RAM.
    pub fn memory_budget(mut self, budget: Option<u64>) -> Self {
        self.memory_budget = budget;
        self
    }

    /// Replay core for the simulation paths ([`Evaluator::CycleSim`];
    /// a sharded sweep carries its own engine choice from
    /// [`crate::shard::ShardedSweep::prepare_with_engine`]).
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Factor-matrix rank the analytic PMS path estimates with.
    pub fn rank(mut self, rank: usize) -> Self {
        self.rank = rank;
        self
    }

    /// Analytic PMS evaluator over a measured tensor profile
    /// (microseconds per configuration).
    pub fn pms<'a>(&self, profile: &'a TensorProfile) -> Evaluator<'a> {
        self.wrap(Evaluator::Pms {
            profile,
            rank: self.rank,
        })
    }

    /// Cycle-level simulation of a full Approach-1 sweep over a
    /// concrete tensor, with a fresh cross-candidate memo (or the
    /// shared one installed by [`Self::sim_memo`]).
    pub fn cycle_sim<'a>(&self, tensor: &'a SparseTensor, factors: &'a [Mat]) -> Evaluator<'a> {
        let memo = self
            .sim
            .clone()
            .unwrap_or_else(|| Arc::new(SimMemo::with_policy(self.memory_budget, self.engine)));
        self.wrap(Evaluator::CycleSim {
            tensor,
            factors,
            engine: self.engine,
            memo,
        })
    }

    /// Sharded multi-instance simulation over a prepared sweep (the
    /// sweep was prepared with its own engine choice, which this
    /// evaluator inherits).
    pub fn sharded<'a>(&self, sweep: &'a crate::shard::ShardedSweep<'a>) -> Evaluator<'a> {
        self.wrap(Evaluator::ShardedSim { sweep })
    }
}

impl Evaluator<'_> {
    /// True when `cfg` is realizable on `dev` under this evaluator's
    /// deployment model.
    pub fn feasible(&self, cfg: &ControllerConfig, dev: &Device) -> bool {
        if let Evaluator::Warm { inner, cache } = self {
            // Hoisted per-board feasibility (S28): the device is part
            // of the cache's context key, so a verdict cached by any
            // earlier query on this board short-circuits re-pruning.
            if let Some(ok) = cache.lookup_feasible(cfg) {
                return ok;
            }
            let ok = inner.feasible(cfg, dev);
            cache.record_feasible(cfg, ok);
            return ok;
        }
        if !device_feasible(cfg, dev) {
            return false;
        }
        match self {
            Evaluator::ShardedSim { sweep } => {
                // K concurrent controller instances must *all* fit the
                // device: each needs a 1/K slice of the block budget
                // (the whole-device check above only covers one
                // instance), and each instance owns a group of the
                // configured technology's parallel units (DDR4
                // channels / HBM2 pseudo-channels / oSRAM ports), so
                // the board must have K such groups (per-config
                // capacity itself is device_feasible's job).
                let w = sweep.workers();
                let units = match cfg.mem.tech() {
                    MemTech::Ddr4 => dev.dram_channels,
                    MemTech::Hbm2 => dev.hbm_pseudo_channels,
                    MemTech::Osram => dev.osram_ports,
                };
                if w > units {
                    return false;
                }
                let slice = Device {
                    bram36: dev.bram36 / w,
                    uram: dev.uram / w,
                    ..*dev
                };
                fpga::estimate(cfg, &slice).fits
            }
            _ => true,
        }
    }

    /// Score = estimated/measured total cycles (lower is better), or
    /// `None` if the configuration does not fit `dev`.
    pub fn score(&self, cfg: &ControllerConfig, dev: &Device) -> Option<f64> {
        if let Evaluator::Warm { inner, cache } = self {
            if let Some(cached) = cache.lookup_score(cfg) {
                return cached;
            }
            let s = inner.score(cfg, dev);
            cache.record_score(cfg, s);
            return s;
        }
        if !self.feasible(cfg, dev) {
            return None;
        }
        Some(match self {
            Evaluator::Pms { profile, rank } => {
                pms::estimate_with_rank(profile, cfg, dev, *rank).total_cycles()
            }
            Evaluator::CycleSim {
                tensor,
                factors,
                engine,
                memo,
            } => cycle_sim_score(tensor, factors, *engine, memo, cfg) as f64,
            Evaluator::ShardedSim { sweep } => sweep.makespan(cfg) as f64,
            Evaluator::Warm { .. } => unreachable!("warm wrapper returned above"),
        })
    }

    /// Score a batch of candidate configurations; returns one score per
    /// candidate in input order (`None` = does not fit the device).
    /// Candidates are independent, so the generic path fans them out
    /// across host threads.  Under the grid engine the cross product is
    /// factorized instead: a **cache-module sweep** (all candidates
    /// sharing DRAM/DMA/remapper knobs) is scored by the one-pass grid
    /// core — one trace classification for the whole batch — a
    /// **timing-module sweep** (all candidates sharing the cache
    /// module; DRAM/DMA/remapper free) by the vectorized timing core —
    /// classify once, extract the miss/stream op queue once, then time
    /// every DRAM/DMA candidate in one walk — and a genuinely
    /// **joint** batch (cache AND timing knobs both varying) by the
    /// hierarchical sweep core ([`crate::engine::sweep`]).  Same
    /// scores every way.
    pub fn score_batch(&self, cfgs: &[ControllerConfig], dev: &Device) -> Vec<Option<f64>> {
        if cfgs.is_empty() {
            return Vec::new();
        }
        if let Evaluator::Warm { inner, cache } = self {
            // Partition into cache hits and unseen candidates; only
            // the unseen delta reaches the inner batch paths.  Scores
            // are bit-identical either way: every batch routing below
            // produces the same per-candidate score, and hits replay
            // the exact f64 bits the inner evaluator produced.
            let mut out: Vec<Option<f64>> = Vec::with_capacity(cfgs.len());
            let mut miss_idx: Vec<usize> = Vec::new();
            for (i, cfg) in cfgs.iter().enumerate() {
                match cache.lookup_score(cfg) {
                    Some(cached) => out.push(cached),
                    None => {
                        out.push(None);
                        miss_idx.push(i);
                    }
                }
            }
            if !miss_idx.is_empty() {
                let miss_cfgs: Vec<ControllerConfig> =
                    miss_idx.iter().map(|&i| cfgs[i].clone()).collect();
                let scored = inner.score_batch(&miss_cfgs, dev);
                for (&i, s) in miss_idx.iter().zip(scored) {
                    cache.record_score(&cfgs[i], s);
                    out[i] = s;
                }
            }
            return out;
        }
        if cfgs.len() >= 2 && cache_module_sweep(cfgs) {
            match self {
                Evaluator::CycleSim {
                    tensor,
                    factors,
                    engine: EngineKind::Grid,
                    memo,
                } => return cycle_sim_grid_batch(tensor, factors, memo, cfgs, dev),
                Evaluator::ShardedSim { sweep } if sweep.engine() == EngineKind::Grid => {
                    return self.sharded_grid_batch(sweep, cfgs, dev)
                }
                _ => {}
            }
        } else if cfgs.len() >= 2 && timing_module_sweep(cfgs) {
            match self {
                Evaluator::CycleSim {
                    tensor,
                    factors,
                    engine: EngineKind::Grid,
                    memo,
                } => return cycle_sim_timing_batch(tensor, factors, memo, cfgs, dev),
                Evaluator::ShardedSim { sweep } if sweep.engine() == EngineKind::Grid => {
                    return self.sharded_timing_batch(sweep, cfgs, dev)
                }
                _ => {}
            }
        } else if cfgs.len() >= 2 {
            // A genuinely joint batch — cache AND timing knobs both
            // vary (the `Joint` search strategy's shape): under the
            // grid engine, the hierarchical sweep core scores it in one
            // structured traversal per trace.
            match self {
                Evaluator::CycleSim {
                    tensor,
                    factors,
                    engine: EngineKind::Grid,
                    memo,
                } => return cycle_sim_joint_batch(tensor, factors, memo, cfgs, dev),
                Evaluator::ShardedSim { sweep } if sweep.engine() == EngineKind::Grid => {
                    return self.sharded_joint_batch(sweep, cfgs, dev)
                }
                _ => {}
            }
        }
        // Prime the CycleSim memos sequentially — traces AND the
        // remap-pass cycles of every key the batch will need — so the
        // concurrent scorers below only ever hit the memo; otherwise N
        // threads would race the check-then-insert and each re-simulate
        // the identical remap pass.
        if let Evaluator::CycleSim {
            tensor,
            factors,
            memo,
            ..
        } = self
        {
            let rank = factors[0].cols();
            let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
            let mut primed: Vec<(MemTechConfig, RemapperConfig)> = Vec::new();
            for cfg in cfgs {
                if !self.feasible(cfg, dev) {
                    continue;
                }
                let key = (cfg.mem.clone(), cfg.remapper);
                if primed.contains(&key) {
                    continue;
                }
                primed.push(key);
                let prep = memo.prep(tensor, factors, &layout);
                for (mode, p) in prep.iter().enumerate() {
                    memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, cfg);
                }
            }
        }
        // A sharded makespan already fans out one thread per shard;
        // adding an outer candidate layer would only oversubscribe the
        // host, so ShardedSim keeps the sequential candidate loop.
        if matches!(self, Evaluator::ShardedSim { .. }) {
            return cfgs.iter().map(|c| self.score(c, dev)).collect();
        }
        parallel_indexed(cfgs.len(), |i| self.score(&cfgs[i], dev))
    }

    /// Cache-module batch under the sharded evaluator: feasibility per
    /// candidate, then one grid classification per shard trace
    /// ([`crate::shard::ShardedSweep::makespans_for_cache_grid`]).
    fn sharded_grid_batch(
        &self,
        sweep: &crate::shard::ShardedSweep<'_>,
        cfgs: &[ControllerConfig],
        dev: &Device,
    ) -> Vec<Option<f64>> {
        let feasible: Vec<bool> = cfgs.iter().map(|c| self.feasible(c, dev)).collect();
        let caches: Vec<CacheConfig> = cfgs
            .iter()
            .zip(&feasible)
            .filter(|&(_, &ok)| ok)
            .map(|(c, _)| c.cache)
            .collect();
        if caches.is_empty() {
            return vec![None; cfgs.len()];
        }
        let base = cfgs
            .iter()
            .zip(&feasible)
            .find(|&(_, &ok)| ok)
            .map(|(c, _)| c.clone())
            .expect("at least one feasible candidate");
        let scores = sweep.makespans_for_cache_grid(&base, &caches);
        scatter_feasible(&feasible, scores)
    }

    /// Timing-module batch under the sharded evaluator: feasibility per
    /// candidate, then one classification + op-queue walk per shard
    /// trace times every feasible candidate's lanes simultaneously
    /// ([`crate::shard::ShardedSweep::makespans_for_timing_grid`]).
    fn sharded_timing_batch(
        &self,
        sweep: &crate::shard::ShardedSweep<'_>,
        cfgs: &[ControllerConfig],
        dev: &Device,
    ) -> Vec<Option<f64>> {
        let feasible: Vec<bool> = cfgs.iter().map(|c| self.feasible(c, dev)).collect();
        let live: Vec<ControllerConfig> = cfgs
            .iter()
            .zip(&feasible)
            .filter(|&(_, &ok)| ok)
            .map(|(c, _)| c.clone())
            .collect();
        if live.is_empty() {
            return vec![None; cfgs.len()];
        }
        let base = live[0].clone();
        let scores = sweep.makespans_for_timing_grid(&base, &live);
        scatter_feasible(&feasible, scores)
    }

    /// Joint cross-product batch under the sharded evaluator:
    /// feasibility per candidate, then the hierarchical sweep core
    /// traverses every shard trace once for the whole batch
    /// ([`crate::shard::ShardedSweep::makespans_for_joint_grid`]).
    fn sharded_joint_batch(
        &self,
        sweep: &crate::shard::ShardedSweep<'_>,
        cfgs: &[ControllerConfig],
        dev: &Device,
    ) -> Vec<Option<f64>> {
        let feasible: Vec<bool> = cfgs.iter().map(|c| self.feasible(c, dev)).collect();
        let live: Vec<ControllerConfig> = cfgs
            .iter()
            .zip(&feasible)
            .filter(|&(_, &ok)| ok)
            .map(|(c, _)| c.clone())
            .collect();
        if live.is_empty() {
            return vec![None; cfgs.len()];
        }
        let scores = sweep.makespans_for_joint_grid(&live);
        scatter_feasible(&feasible, scores)
    }
}

/// CycleSim score of one configuration: Σ over modes of (memoized
/// fresh-controller remap pass + fresh-controller trace replay).
fn cycle_sim_score(
    tensor: &SparseTensor,
    factors: &[Mat],
    engine: EngineKind,
    memo: &SimMemo,
    cfg: &ControllerConfig,
) -> u64 {
    let rank = factors[0].cols();
    let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
    let prep = memo.prep(tensor, factors, &layout);
    let mut total = 0u64;
    for (mode, p) in prep.iter().enumerate() {
        total += memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, cfg);
        let mut ctl = MemoryController::new(cfg.clone());
        total += match engine {
            EngineKind::Lockstep => ctl.replay(p.trace.raw()),
            EngineKind::Event | EngineKind::Grid => ctl.replay_events(p.trace.compressed()),
        };
    }
    total
}

/// Cache-module batch under CycleSim + grid engine: one classification
/// pass per mode trace scores every feasible candidate; per-candidate
/// miss-only replays fan out across host threads.
fn cycle_sim_grid_batch(
    tensor: &SparseTensor,
    factors: &[Mat],
    memo: &SimMemo,
    cfgs: &[ControllerConfig],
    dev: &Device,
) -> Vec<Option<f64>> {
    let feasible: Vec<bool> = cfgs.iter().map(|c| device_feasible(c, dev)).collect();
    let caches: Vec<CacheConfig> = cfgs
        .iter()
        .zip(&feasible)
        .filter(|&(_, &ok)| ok)
        .map(|(c, _)| c.cache)
        .collect();
    if caches.is_empty() {
        return vec![None; cfgs.len()];
    }
    let base = cfgs
        .iter()
        .zip(&feasible)
        .find(|&(_, &ok)| ok)
        .map(|(c, _)| c.clone())
        .expect("at least one feasible candidate");
    let rank = factors[0].cols();
    let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
    let prep = memo.prep(tensor, factors, &layout);
    // The remap pass never touches the Cache Engine: one memoized value
    // serves the entire batch.
    let remap_total: u64 = prep
        .iter()
        .enumerate()
        .map(|(mode, p)| memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, &base))
        .sum();
    let mut compute = vec![0u64; caches.len()];
    for p in prep.iter() {
        let cls = GridClassification::classify(p.trace.compressed(), &caches);
        let per: Vec<u64> = parallel_indexed(caches.len(), |ci| {
            let mut cfg = base.clone();
            cfg.cache = caches[ci];
            cls.replay(ci, p.trace.compressed(), &cfg).cycles
        });
        for (t, c) in compute.iter_mut().zip(per) {
            *t += c;
        }
    }
    scatter_feasible(&feasible, compute.into_iter().map(|c| remap_total + c))
}

/// DRAM/DMA (and remapper) module batch under CycleSim + grid engine:
/// the cache module is fixed across the batch, so **one**
/// single-candidate classification per mode trace feeds the vectorized
/// timing core ([`crate::engine::timing`]) — the hit-dominated cache
/// loop runs once per mode and every candidate is then timed from the
/// shared miss/stream op queue in one walk.  Remap totals are
/// candidate-dependent (keyed (mode, DRAM, remapper)) but memoized, so
/// each distinct key simulates once for the whole batch.
fn cycle_sim_timing_batch(
    tensor: &SparseTensor,
    factors: &[Mat],
    memo: &SimMemo,
    cfgs: &[ControllerConfig],
    dev: &Device,
) -> Vec<Option<f64>> {
    let feasible: Vec<bool> = cfgs.iter().map(|c| device_feasible(c, dev)).collect();
    let live: Vec<&ControllerConfig> = cfgs
        .iter()
        .zip(&feasible)
        .filter(|&(_, &ok)| ok)
        .map(|(c, _)| c)
        .collect();
    if live.is_empty() {
        return vec![None; cfgs.len()];
    }
    let rank = factors[0].cols();
    let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
    let prep = memo.prep(tensor, factors, &layout);
    let remap_totals: Vec<u64> = live
        .iter()
        .map(|cfg| {
            prep.iter()
                .enumerate()
                .map(|(mode, p)| memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, cfg))
                .sum()
        })
        .collect();
    // Candidates differing only in remapper knobs share a lane: time
    // each distinct (DRAM, DMA) pair once.
    let (lanes, lane_of) =
        TimingCandidate::dedup(live.iter().map(|c| TimingCandidate::of(c)).collect());
    let cache = cfgs[0].cache;
    let mut compute = vec![0u64; live.len()];
    for p in prep.iter() {
        let cls = GridClassification::classify(p.trace.compressed(), &[cache]);
        let ops = TimingOps::extract(&cls, 0, p.trace.compressed());
        let runs = ops.time_grid_parallel(&lanes);
        for (total, &lane) in compute.iter_mut().zip(&lane_of) {
            *total += runs[lane].cycles;
        }
    }
    scatter_feasible(
        &feasible,
        remap_totals.into_iter().zip(compute).map(|(r, c)| r + c),
    )
}

/// Joint cross-product batch under CycleSim + grid engine: candidates
/// free in **every** module are factorized by the hierarchical sweep
/// core ([`crate::engine::sweep`]) — per mode trace, one classification
/// pass per distinct line width, one op-queue extraction per distinct
/// cache candidate, one multi-lane walk per cache's DRAM/DMA lane set —
/// while the remap phase stays memoized per (mode, DRAM, remapper) key.
/// Candidates collapsing to the same (cache, lane) cell (remapper-only
/// variants) are simulated once and fanned back out.
fn cycle_sim_joint_batch(
    tensor: &SparseTensor,
    factors: &[Mat],
    memo: &SimMemo,
    cfgs: &[ControllerConfig],
    dev: &Device,
) -> Vec<Option<f64>> {
    let feasible: Vec<bool> = cfgs.iter().map(|c| device_feasible(c, dev)).collect();
    let live: Vec<&ControllerConfig> = cfgs
        .iter()
        .zip(&feasible)
        .filter(|&(_, &ok)| ok)
        .map(|(c, _)| c)
        .collect();
    if live.is_empty() {
        return vec![None; cfgs.len()];
    }
    let rank = factors[0].cols();
    let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
    let prep = memo.prep(tensor, factors, &layout);
    let remap_totals: Vec<u64> = live
        .iter()
        .map(|cfg| {
            prep.iter()
                .enumerate()
                .map(|(mode, p)| memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, cfg))
                .sum()
        })
        .collect();
    let pairs: Vec<(CacheConfig, TimingCandidate)> = live
        .iter()
        .map(|c| (c.cache, TimingCandidate::of(c)))
        .collect();
    let index = JointIndex::build(&pairs);
    // One flattened (mode x cache) fan-out for all mode traces at once.
    let refs: Vec<_> = prep.iter().map(|p| p.trace.compressed()).collect();
    let mut compute = vec![0u64; live.len()];
    for per in index.sweep_many(&refs) {
        for (total, c) in compute.iter_mut().zip(per) {
            *total += c;
        }
    }
    scatter_feasible(
        &feasible,
        remap_totals.into_iter().zip(compute).map(|(r, c)| r + c),
    )
}

/// Device-level feasibility shared by every evaluator: the on-chip
/// blocks must fit the device budget, and the board must host the
/// configured memory technology at the configured capacity
/// ([`Device::supports`] — a sweep must not "win" with DDR4 channels,
/// HBM2 pseudo-channels, or oSRAM ports the device does not have).
fn device_feasible(cfg: &ControllerConfig, dev: &Device) -> bool {
    fpga::estimate(cfg, dev).fits && dev.supports(&cfg.mem)
}

/// Scatter the scores of the feasible ("live") candidates back onto
/// the full candidate list: `scores` holds one cycle count per `true`
/// in `feasible`, in order; infeasible slots come back `None`.  Every
/// batch scorer funnels through this so the candidate/score alignment
/// rule lives in exactly one place.
fn scatter_feasible<I: IntoIterator<Item = u64>>(feasible: &[bool], scores: I) -> Vec<Option<f64>> {
    let mut it = scores.into_iter();
    feasible
        .iter()
        .map(|&ok| {
            if ok {
                Some(it.next().expect("one score per feasible candidate") as f64)
            } else {
                None
            }
        })
        .collect()
}

/// True when every candidate shares the non-cache knobs of the first —
/// the shape of a cache-module sweep.
fn cache_module_sweep(cfgs: &[ControllerConfig]) -> bool {
    let base = &cfgs[0];
    cfgs.iter()
        .all(|c| c.mem == base.mem && c.dma == base.dma && c.remapper == base.remapper)
}

/// True when every candidate shares the first's cache module — the
/// shape of a DRAM / DMA / remapper (timing-dimension) sweep, which the
/// vectorized timing core scores from one shared op queue.
fn timing_module_sweep(cfgs: &[ControllerConfig]) -> bool {
    let base = &cfgs[0];
    cfgs.iter().all(|c| c.cache == base.cache)
}

/// One explored point.
#[derive(Debug, Clone)]
pub struct Point {
    pub cfg: ControllerConfig,
    pub cycles: f64,
    pub bram36: usize,
    pub uram: usize,
}

impl Point {
    /// Total on-chip blocks (BRAM36 + URAM) — the resource axis the
    /// Pareto frontier trades against cycles.
    pub fn blocks(&self) -> usize {
        self.bram36 + self.uram
    }

    /// Memory-device power proxy in mW
    /// ([`MemTechConfig::power_proxy_mw`]) — the third Pareto axis,
    /// which separates memory technologies whose on-chip footprints
    /// coincide.
    pub fn power_mw(&self) -> u64 {
        self.cfg.mem.power_proxy_mw()
    }
}

/// How the configuration space is searched (see [`explore_with`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchStrategy {
    /// Module-by-module coordinate descent (the paper's §5.3 proposal
    /// and the legacy default): sweep one module's grid while holding
    /// the others at the incumbent best.  Cheap, but greedy — it can
    /// miss jointly-optimal configurations.
    Coordinate,
    /// Exhaustive search of the joint cross product
    /// `remapper × line_bytes × (num_lines, assoc) × memory × DMA`
    /// (the memory dimension spans technologies when
    /// [`Grids::mem_techs`] does), each
    /// dimension unioned with the base configuration's value so the
    /// joint space contains every point coordinate descent could visit
    /// (its best is therefore never worse).  Infeasible points are
    /// pruned with the device check *before* any simulation; under the
    /// grid engine the whole space scores through the hierarchical
    /// sweep core ([`crate::engine::sweep`]).
    Joint,
    /// Beam search over the module sequence: keep the best `width`
    /// incumbents after each module sweep and sweep the next module
    /// from each of them.  `width = 1` degenerates to greedy
    /// coordinate descent; wider beams recover cross-module couplings
    /// at a fraction of the joint space's cost.
    Beam {
        /// Incumbents kept between module sweeps (clamped to >= 1).
        width: usize,
    },
}

/// Search-layer options for [`explore_with`].
#[derive(Debug, Clone, Copy)]
pub struct SearchOptions {
    pub strategy: SearchStrategy,
    /// How many best points [`Exploration::top`] reports (clamped to
    /// >= 1; `top[0]` is always the winner).
    pub top_k: usize,
    /// Warm-start resume (S28): when the evaluator is
    /// [`Evaluator::Warm`] and its cache holds a Pareto frontier from
    /// an earlier exploration, seed [`SearchStrategy::Beam`] with the
    /// stored frontier points so the search continues from where the
    /// last session ended instead of rediscovering them.  Ignored for
    /// other strategies and for cold caches; `false` (the default)
    /// keeps every search byte-identical to a cold run.
    pub resume: bool,
    /// Periodic checkpointing (S31): when nonzero and the evaluator is
    /// [`Evaluator::Warm`], flush the interim Pareto frontier and the
    /// verdict map through the warm cache's atomic writer after every
    /// module sweep that added at least this many newly scored points.
    /// A SIGKILL'd explore then resumes via `--warm-cache` from the
    /// last checkpoint, byte-identical to an uninterrupted run.  `0`
    /// (the default) disables mid-search flushes; the final flush at
    /// the end of the search always happens.
    pub checkpoint_every: usize,
}

impl Default for SearchOptions {
    fn default() -> Self {
        SearchOptions {
            strategy: SearchStrategy::Coordinate,
            top_k: 1,
            resume: false,
            checkpoint_every: 0,
        }
    }
}

/// Result of a full exploration.
#[derive(Debug, Clone)]
pub struct Exploration {
    pub best: Point,
    /// Every feasible point visited, in visit order.
    pub visited: Vec<Point>,
    /// Candidates rejected for not fitting the device.
    pub rejected: usize,
    /// The Pareto frontier of the visited points under (cycles,
    /// on-chip blocks, memory-device power proxy): no frontier member
    /// is dominated — beaten or tied on every axis and strictly beaten
    /// on at least one — by any visited point.  When the search spans
    /// memory technologies ([`Grids::mem_techs`]) this is the
    /// cross-technology frontier: an HBM2 point may hold the cycles
    /// end while DDR4/oSRAM points hold the blocks and power ends.
    /// Ascending in cycles; `pareto[0]` always has the winner's cycle
    /// count (on a cycles tie it may be a different config than
    /// `best`, which keeps the first-visited point).
    pub pareto: Vec<Point>,
    /// The `top_k` best distinct configurations by cycles, ascending;
    /// `top[0]` equals `best`.
    pub top: Vec<Point>,
}

/// Default sweep grids (§5.2.1 parameters plus the paper's §2 DRAM
/// knobs: channel/bank counts and the row-buffer policy), extended
/// with the memory **technology** axis ([`Grids::mem_techs`]).
pub struct Grids {
    pub cache_line_bytes: Vec<usize>,
    pub cache_num_lines: Vec<usize>,
    pub cache_assoc: Vec<usize>,
    pub dma_num: Vec<usize>,
    pub dma_buffers: Vec<usize>,
    pub dma_buffer_bytes: Vec<usize>,
    /// Memory technologies the external-memory module sweeps over.
    /// DDR4 expands to the `dram_*` grids below; HBM2 and oSRAM
    /// contribute their default device shapes
    /// ([`MemTech::default_config`] — their geometry is a package
    /// property, not a board-level knob).  Defaults to `[Ddr4]`, which
    /// keeps every legacy exploration's candidate list identical.
    pub mem_techs: Vec<MemTech>,
    /// DDR4 channels (power of two; candidates beyond the device's
    /// channel count are rejected as infeasible).
    pub dram_channels: Vec<usize>,
    /// Banks per DDR4 channel (power of two).
    pub dram_banks: Vec<usize>,
    /// Open- vs closed-page row policy (DDR4).
    pub dram_row_policy: Vec<RowPolicy>,
    pub remap_max_pointers: Vec<usize>,
}

impl Default for Grids {
    fn default() -> Self {
        Grids {
            cache_line_bytes: vec![32, 64, 128, 256],
            cache_num_lines: vec![256, 1024, 4096, 16384],
            cache_assoc: vec![1, 2, 4, 8],
            dma_num: vec![1, 2, 4],
            dma_buffers: vec![1, 2, 4],
            dma_buffer_bytes: vec![1024, 4096, 16384],
            mem_techs: vec![MemTech::Ddr4],
            dram_channels: vec![1, 2, 4],
            dram_banks: vec![8, 16],
            dram_row_policy: vec![RowPolicy::Open, RowPolicy::Closed],
            remap_max_pointers: vec![1 << 10, 1 << 14, 1 << 18, 1 << 22],
        }
    }
}

impl Grids {
    /// The default grids with every memory technology in the sweep —
    /// the cross-technology search space.
    pub fn all_mem_techs() -> Self {
        Grids {
            mem_techs: vec![MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram],
            ..Grids::default()
        }
    }

    /// A tiny grid for smoke tests and the serve protocol's smoke
    /// preset: two candidates per module, one memory technology.  The
    /// joint space stays in the dozens of points, so a full explore
    /// finishes in milliseconds while still exercising every module
    /// sweep.
    pub fn smoke() -> Self {
        Grids {
            cache_line_bytes: vec![64, 128],
            cache_num_lines: vec![256, 1024],
            cache_assoc: vec![1, 2],
            dma_num: vec![1, 2],
            dma_buffers: vec![1, 2],
            dma_buffer_bytes: vec![1024, 4096],
            mem_techs: vec![MemTech::Ddr4],
            dram_channels: vec![1, 2],
            dram_banks: vec![8],
            dram_row_policy: vec![RowPolicy::Open],
            remap_max_pointers: vec![1 << 10, 1 << 14],
        }
    }
}

/// A visited point with its device usage attached.
fn point_at(cfg: ControllerConfig, cycles: f64, dev: &Device) -> Point {
    let usage = fpga::estimate(&cfg, dev);
    Point {
        cfg,
        cycles,
        bram36: usage.bram36_used,
        uram: usage.uram_used,
    }
}

/// Batch-score one candidate list, recording visits/rejections and
/// lowering the incumbent (first strictly-better candidate wins ties
/// exactly like the original sequential sweep did).  Returns the fresh
/// feasible points in candidate order (the beam strategy's selection
/// pool).
fn sweep_module(
    eval: &Evaluator<'_>,
    dev: &Device,
    cands: Vec<ControllerConfig>,
    best: &mut Point,
    visited: &mut Vec<Point>,
    rejected: &mut usize,
) -> Vec<Point> {
    let scores = eval.score_batch(&cands, dev);
    let mut fresh = Vec::new();
    for (cfg, score) in cands.into_iter().zip(scores) {
        match score {
            None => *rejected += 1,
            Some(cycles) => {
                let p = point_at(cfg, cycles, dev);
                visited.push(p.clone());
                if cycles < best.cycles {
                    *best = p.clone();
                }
                fresh.push(p);
            }
        }
    }
    fresh
}

/// Periodic mid-search persistence (S31): after each module sweep,
/// once at least `every` new points have been scored since the last
/// flush, push the interim Pareto frontier into the warm cache and
/// flush it through the atomic temp+rename writer.  Each checkpoint
/// is a complete, valid cache file, so a run killed at *any* moment
/// leaves either the previous checkpoint or the new one on disk —
/// never a torn state — and `--warm-cache` resume replays the scored
/// verdicts bit-exactly.
struct Checkpointer<'a> {
    cache: Option<&'a dyn ScoreCache>,
    every: usize,
    /// `visited.len()` at the last checkpoint.
    last: usize,
}

impl<'a> Checkpointer<'a> {
    fn new(eval: &'a Evaluator<'_>, every: usize) -> Self {
        let cache = match eval {
            Evaluator::Warm { cache, .. } if every > 0 => Some(cache.as_ref()),
            _ => None,
        };
        Checkpointer {
            cache,
            every,
            last: 0,
        }
    }

    fn tick(&mut self, visited: &[Point]) {
        let Some(cache) = self.cache else { return };
        if visited.len().saturating_sub(self.last) < self.every {
            return;
        }
        self.last = visited.len();
        cache.set_frontier(&pareto_frontier(visited));
        cache.flush_or_degrade();
    }
}

/// The Cache Engine module grid swept from `from` (module 1).
fn cache_candidates(grids: &Grids, from: &ControllerConfig) -> Vec<ControllerConfig> {
    let mut cands = Vec::new();
    for &line_bytes in &grids.cache_line_bytes {
        for &num_lines in &grids.cache_num_lines {
            for &assoc in &grids.cache_assoc {
                if num_lines % assoc != 0 || !(num_lines / assoc).is_power_of_two() {
                    continue;
                }
                let mut cfg = from.clone();
                cfg.cache = CacheConfig {
                    line_bytes,
                    num_lines,
                    assoc,
                    hit_latency: cfg.cache.hit_latency,
                };
                cands.push(cfg);
            }
        }
    }
    cands
}

/// The DMA Engine module grid swept from `from` (module 2).
fn dma_candidates(grids: &Grids, from: &ControllerConfig) -> Vec<ControllerConfig> {
    let mut cands = Vec::new();
    for &num_dmas in &grids.dma_num {
        for &buffers_per_dma in &grids.dma_buffers {
            for &buffer_bytes in &grids.dma_buffer_bytes {
                let mut cfg = from.clone();
                cfg.dma = DmaConfig {
                    num_dmas,
                    buffers_per_dma,
                    buffer_bytes,
                    setup_cycles: cfg.dma.setup_cycles,
                };
                cands.push(cfg);
            }
        }
    }
    cands
}

/// The external-memory module candidates of one grid, as bare
/// [`MemTechConfig`]s: DDR4 expands to the full
/// channels × banks × row-policy grid (timing fields inherited from
/// `from` when it is DDR4-configured, so a tuned base keeps its
/// timings); HBM2 and oSRAM contribute their default device shapes.
/// With the default `mem_techs = [Ddr4]` this enumerates exactly the
/// legacy DRAM-module grid, in the same order.
fn mem_candidates(grids: &Grids, from: &ControllerConfig) -> Vec<MemTechConfig> {
    let mut out = Vec::new();
    for &tech in &grids.mem_techs {
        match tech {
            MemTech::Ddr4 => {
                let base = match from.mem.ddr4() {
                    Some(d) => d.clone(),
                    None => DramConfig::default_ddr4(),
                };
                for &channels in &grids.dram_channels {
                    for &banks in &grids.dram_banks {
                        for &row_policy in &grids.dram_row_policy {
                            if !channels.is_power_of_two() || !banks.is_power_of_two() {
                                continue;
                            }
                            let mut d = base.clone();
                            d.channels = channels;
                            d.banks = banks;
                            d.row_policy = row_policy;
                            out.push(MemTechConfig::Ddr4(d));
                        }
                    }
                }
            }
            MemTech::Hbm2 | MemTech::Osram => {
                let cand = tech.default_config();
                if !out.contains(&cand) {
                    out.push(cand);
                }
            }
        }
    }
    out
}

/// The external-memory module grid (technology × per-tech knobs,
/// [`mem_candidates`]) swept from `from` (module 3).
fn dram_candidates(grids: &Grids, from: &ControllerConfig) -> Vec<ControllerConfig> {
    mem_candidates(grids, from)
        .into_iter()
        .map(|mem| {
            let mut cfg = from.clone();
            cfg.mem = mem;
            cfg
        })
        .collect()
}

/// The Tensor Remapper module grid swept from `from` (module 4).
fn remapper_candidates(grids: &Grids, from: &ControllerConfig) -> Vec<ControllerConfig> {
    grids
        .remap_max_pointers
        .iter()
        .map(|&max_pointers| {
            let mut cfg = from.clone();
            cfg.remapper.max_pointers = max_pointers;
            cfg
        })
        .collect()
}

/// One module's candidates from one incumbent, by module index (the
/// fixed §5.3 sweep order).
fn module_candidates(
    stage: usize,
    grids: &Grids,
    from: &ControllerConfig,
) -> Vec<ControllerConfig> {
    match stage {
        0 => cache_candidates(grids, from),
        1 => dma_candidates(grids, from),
        2 => dram_candidates(grids, from),
        _ => remapper_candidates(grids, from),
    }
}

/// Number of module stages the coordinate / beam strategies sweep.
const MODULE_STAGES: usize = 4;

/// The full joint cross product of `grids` —
/// `remapper × line_bytes × (num_lines, assoc) × memory × DMA` — each
/// dimension unioned with `base`'s knob value: every configuration
/// coordinate descent could ever visit takes each knob from either
/// `base` or its grid, so the union guarantees the joint space is a
/// superset of the coordinate search space (and the joint optimum is
/// never worse).  The memory dimension spans technologies when
/// `grids.mem_techs` does.  Invalid geometry combinations
/// (non-power-of-two set counts, DDR4 channels or banks) are skipped,
/// mirroring the per-module generators — but the validity filters
/// exempt `base`'s own values: coordinate descent can keep an off-grid
/// base knob as an incumbent whatever its shape, so dropping it here
/// would break the superset guarantee.
fn joint_candidates(base: &ControllerConfig, grids: &Grids) -> Vec<ControllerConfig> {
    fn with<T: PartialEq + Copy>(mut v: Vec<T>, b: T) -> Vec<T> {
        if !v.contains(&b) {
            v.push(b);
        }
        v
    }
    let line_bytes = with(grids.cache_line_bytes.clone(), base.cache.line_bytes);
    let num_lines = with(grids.cache_num_lines.clone(), base.cache.num_lines);
    let assocs = with(grids.cache_assoc.clone(), base.cache.assoc);
    let dma_num = with(grids.dma_num.clone(), base.dma.num_dmas);
    let dma_buffers = with(grids.dma_buffers.clone(), base.dma.buffers_per_dma);
    let dma_bytes = with(grids.dma_buffer_bytes.clone(), base.dma.buffer_bytes);
    // The memory dimension: every technology candidate the module grid
    // generates ([`mem_candidates`] — DDR4 validity filters included),
    // unioned with the base's own memory configuration whatever its
    // shape (the same off-grid-incumbent exemption the scalar knobs
    // get from `with`).
    let mut mems = mem_candidates(grids, base);
    if !mems.contains(&base.mem) {
        mems.push(base.mem.clone());
    }
    let pointers = with(grids.remap_max_pointers.clone(), base.remapper.max_pointers);

    let mut cands = Vec::new();
    for &max_pointers in &pointers {
        for &lb in &line_bytes {
            if lb != base.cache.line_bytes && !lb.is_power_of_two() {
                continue;
            }
            for &nl in &num_lines {
                for &assoc in &assocs {
                    let base_geom = nl == base.cache.num_lines && assoc == base.cache.assoc;
                    if !base_geom && (nl % assoc != 0 || !(nl / assoc).is_power_of_two()) {
                        continue;
                    }
                    for mem in &mems {
                        for &num_dmas in &dma_num {
                            for &buffers_per_dma in &dma_buffers {
                                for &buffer_bytes in &dma_bytes {
                                    let mut cfg = base.clone();
                                    cfg.cache.line_bytes = lb;
                                    cfg.cache.num_lines = nl;
                                    cfg.cache.assoc = assoc;
                                    cfg.mem = mem.clone();
                                    cfg.dma.num_dmas = num_dmas;
                                    cfg.dma.buffers_per_dma = buffers_per_dma;
                                    cfg.dma.buffer_bytes = buffer_bytes;
                                    cfg.remapper.max_pointers = max_pointers;
                                    cands.push(cfg);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    cands
}

/// True when `a` Pareto-dominates `b` under (cycles, on-chip blocks,
/// memory-device power proxy): no worse on every axis and strictly
/// better on at least one.
fn dominates(a: &Point, b: &Point) -> bool {
    a.cycles <= b.cycles
        && a.blocks() <= b.blocks()
        && a.power_mw() <= b.power_mw()
        && (a.cycles < b.cycles || a.blocks() < b.blocks() || a.power_mw() < b.power_mw())
}

/// The non-dominated subset of `visited` under (cycles, on-chip
/// blocks, power proxy) — see [`dominates`].  Returned ascending in
/// cycles (then blocks, then power); coincident (cycles, blocks,
/// power) triples keep the first-visited point.
fn pareto_frontier(visited: &[Point]) -> Vec<Point> {
    let mut order: Vec<usize> = (0..visited.len()).collect();
    order.sort_by(|&a, &b| {
        visited[a]
            .cycles
            .total_cmp(&visited[b].cycles)
            .then_with(|| visited[a].blocks().cmp(&visited[b].blocks()))
            .then_with(|| visited[a].power_mw().cmp(&visited[b].power_mw()))
            .then(a.cmp(&b))
    });
    // Any dominator of a point sorts strictly before it (it is no
    // worse on every sort key and better on one), and dominance is
    // transitive, so scanning in sort order and testing against the
    // kept set alone is exact.
    let mut out: Vec<Point> = Vec::new();
    for i in order {
        let p = &visited[i];
        let covered = out.iter().any(|q| {
            dominates(q, p)
                || (q.cycles == p.cycles && q.blocks() == p.blocks() && q.power_mw() == p.power_mw())
        });
        if !covered {
            out.push(p.clone());
        }
    }
    out
}

/// The `k` best distinct configurations of `visited` by cycles,
/// ascending (earliest-visited wins ties, matching the incumbent
/// rule).
fn top_points(visited: &[Point], k: usize) -> Vec<Point> {
    let mut order: Vec<usize> = (0..visited.len()).collect();
    order.sort_by(|&a, &b| {
        visited[a]
            .cycles
            .total_cmp(&visited[b].cycles)
            .then(a.cmp(&b))
    });
    let mut out: Vec<Point> = Vec::new();
    for i in order {
        if out.iter().any(|p| p.cfg == visited[i].cfg) {
            continue;
        }
        out.push(visited[i].clone());
        if out.len() == k {
            break;
        }
    }
    out
}

/// Module-by-module coordinate descent (the legacy search): each
/// module's grid is swept from the incumbent best, which is fixed
/// before the next module.  Behavior — visit order, tie-breaking,
/// re-scored incumbents included — is exactly the pre-strategy
/// `explore`.
fn search_coordinate(
    grids: &Grids,
    dev: &Device,
    eval: &Evaluator<'_>,
    best: &mut Point,
    visited: &mut Vec<Point>,
    rejected: &mut usize,
    ckpt: &mut Checkpointer<'_>,
) {
    for stage in 0..MODULE_STAGES {
        let cands = module_candidates(stage, grids, &best.cfg);
        sweep_module(eval, dev, cands, best, visited, rejected);
        ckpt.tick(visited);
    }
}

/// Beam search over the module sequence: after each module sweep the
/// best `width` points seen so far (old beam plus this sweep's fresh
/// points, stable on ties) seed the next module's candidates.  Already
/// scored configurations are not re-scored.
#[allow(clippy::too_many_arguments)]
fn search_beam(
    grids: &Grids,
    dev: &Device,
    eval: &Evaluator<'_>,
    width: usize,
    seeds: Vec<Point>,
    best: &mut Point,
    visited: &mut Vec<Point>,
    rejected: &mut usize,
    ckpt: &mut Checkpointer<'_>,
) {
    let width = width.max(1);
    let mut beam: Vec<Point> = vec![best.clone()];
    // Warm-start resume (S28): frontier points from a previous
    // session join the initial beam.  Empty seeds reproduce the cold
    // search exactly.
    for s in seeds {
        if beam.iter().all(|b| b.cfg != s.cfg) {
            beam.push(s);
        }
    }
    beam.sort_by(|a, b| a.cycles.total_cmp(&b.cycles));
    beam.truncate(width);
    if beam[0].cycles < best.cycles {
        *best = beam[0].clone();
    }
    let mut scored: Vec<ControllerConfig> = beam.iter().map(|p| p.cfg.clone()).collect();
    for stage in 0..MODULE_STAGES {
        let mut cands: Vec<ControllerConfig> = Vec::new();
        for p in &beam {
            for cfg in module_candidates(stage, grids, &p.cfg) {
                if scored.contains(&cfg) || cands.contains(&cfg) {
                    continue;
                }
                cands.push(cfg);
            }
        }
        scored.extend(cands.iter().cloned());
        let fresh = sweep_module(eval, dev, cands, best, visited, rejected);
        ckpt.tick(visited);
        let mut pool = beam;
        pool.extend(fresh);
        // Stable sort: the old beam precedes this sweep's points, so a
        // tie keeps the incumbent — width 1 reproduces the greedy
        // coordinate-descent winner.
        pool.sort_by(|a, b| a.cycles.total_cmp(&b.cycles));
        pool.truncate(width);
        beam = pool;
    }
}

/// Exhaustive joint cross-product search: enumerate
/// `remapper × cache × memory × DMA` ([`joint_candidates`]) and score it
/// as one batch.  The batch scorer prunes infeasible points with the
/// evaluator's device feasibility **before** any simulation (they come
/// back `None` and count as rejections), and the grid engine routes
/// the survivors through the hierarchical sweep core.
#[allow(clippy::too_many_arguments)]
fn search_joint(
    base: &ControllerConfig,
    grids: &Grids,
    dev: &Device,
    eval: &Evaluator<'_>,
    best: &mut Point,
    visited: &mut Vec<Point>,
    rejected: &mut usize,
    ckpt: &mut Checkpointer<'_>,
) {
    let cands: Vec<ControllerConfig> = joint_candidates(base, grids)
        .into_iter()
        .filter(|cfg| cfg != base) // base is already scored as the starting point
        .collect();
    sweep_module(eval, dev, cands, best, visited, rejected);
    ckpt.tick(visited);
}

/// [`explore_with`] under the default options (coordinate descent,
/// top-1) — the legacy module-by-module search, byte-for-byte.
pub fn explore(
    base: &ControllerConfig,
    grids: &Grids,
    dev: &Device,
    eval: &Evaluator<'_>,
) -> Exploration {
    explore_with(base, grids, dev, eval, &SearchOptions::default())
}

/// Run a design-space search starting from `base` under the chosen
/// [`SearchStrategy`].  Every strategy scores candidates in batches
/// ([`Evaluator::score_batch`]), so under the grid engine the cross
/// product factorizes: module sweeps hit the one-pass cache grid /
/// vectorized timing cores, and the joint strategy's full cross
/// product runs through the hierarchical sweep core
/// ([`crate::engine::sweep`]).  The returned [`Exploration`] carries
/// the winner, the Pareto frontier (cycles vs on-chip blocks vs
/// memory-device power proxy), and the `top_k` best points.
pub fn explore_with(
    base: &ControllerConfig,
    grids: &Grids,
    dev: &Device,
    eval: &Evaluator<'_>,
    opts: &SearchOptions,
) -> Exploration {
    let mut visited = Vec::new();
    let mut rejected = 0usize;

    let base_cycles = eval
        .score(base, dev)
        .expect("base configuration must fit the device");
    let mut best = point_at(base.clone(), base_cycles, dev);
    visited.push(best.clone());

    // Warm-start resume (S28): under `resume`, a warm evaluator seeds
    // the beam with the Pareto frontier persisted by the previous
    // exploration of this context.  Scoring the seeds is free — their
    // scores are cache hits by construction.
    let mut seeds: Vec<Point> = Vec::new();
    if opts.resume && matches!(opts.strategy, SearchStrategy::Beam { .. }) {
        if let Evaluator::Warm { cache, .. } = eval {
            for cfg in cache.frontier() {
                if &cfg == base {
                    continue;
                }
                if let Some(c) = eval.score(&cfg, dev) {
                    seeds.push(point_at(cfg, c, dev));
                }
            }
        }
    }
    visited.extend(seeds.iter().cloned());

    let mut ckpt = Checkpointer::new(eval, opts.checkpoint_every);
    match opts.strategy {
        SearchStrategy::Coordinate => search_coordinate(
            grids,
            dev,
            eval,
            &mut best,
            &mut visited,
            &mut rejected,
            &mut ckpt,
        ),
        SearchStrategy::Beam { width } => search_beam(
            grids,
            dev,
            eval,
            width,
            seeds,
            &mut best,
            &mut visited,
            &mut rejected,
            &mut ckpt,
        ),
        SearchStrategy::Joint => search_joint(
            base,
            grids,
            dev,
            eval,
            &mut best,
            &mut visited,
            &mut rejected,
            &mut ckpt,
        ),
    }

    let pareto = pareto_frontier(&visited);
    let top = top_points(&visited, opts.top_k.max(1));
    if let Evaluator::Warm { cache, .. } = eval {
        // Persist this exploration's frontier (the next session's
        // beam seeds) and the scored-point cache.  A persistent flush
        // failure degrades to cold with one warning; the in-memory
        // results are unaffected.
        cache.set_frontier(&pareto);
        cache.flush_or_degrade();
    }
    Exploration {
        best,
        visited,
        rejected,
        pareto,
        top,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::{generate, Profile, SynthConfig};

    fn tensor() -> SparseTensor {
        generate(&SynthConfig {
            dims: vec![400, 300, 200],
            nnz: 8_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 77,
        })
    }

    #[test]
    fn pms_exploration_finds_no_worse_than_base() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let ex = explore(&base, &Grids::default(), &dev, &eval);
        let base_score = eval.score(&base, &dev).unwrap();
        assert!(ex.best.cycles <= base_score);
        assert!(ex.visited.len() > 20);
    }

    #[test]
    fn infeasible_configs_are_rejected_not_chosen() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let mut grids = Grids::default();
        grids.cache_num_lines.push(1 << 22); // 256 MiB cache: never fits
        let ex = explore(&base, &grids, &dev, &eval);
        assert!(ex.rejected > 0);
        assert!(fpga::estimate(&ex.best.cfg, &dev).fits);
    }

    #[test]
    fn score_batch_matches_sequential_scores() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cands = Vec::new();
        for &buffer_bytes in &[1024usize, 4096, 16384] {
            let mut cfg = base.clone();
            cfg.dma.buffer_bytes = buffer_bytes;
            cands.push(cfg);
        }
        let mut big = base.clone();
        big.cache.num_lines = 1 << 22; // never fits
        big.cache.assoc = 1;
        cands.push(big);
        let batch = eval.score_batch(&cands, &dev);
        let seq: Vec<Option<f64>> = cands.iter().map(|c| eval.score(c, &dev)).collect();
        assert_eq!(batch, seq);
        assert!(batch[3].is_none(), "oversized cache must be rejected");
    }

    #[test]
    fn cycle_sim_exploration_small_grid() {
        // Dims large enough that 256 cache lines thrash while 4096 hold
        // the zipf-hot factor rows (rank 16 -> one 64B line per row).
        let t = generate(&SynthConfig {
            dims: vec![4000, 3000, 2000],
            nnz: 20_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 78,
        });
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 16, 1)).collect();
        let eval = EvaluatorBuilder::new()
            .engine(EngineKind::Event)
            .cycle_sim(&t, &factors);
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let grids = Grids {
            cache_line_bytes: vec![64],
            cache_num_lines: vec![256, 4096],
            cache_assoc: vec![4],
            dma_num: vec![2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            mem_techs: vec![MemTech::Ddr4],
            dram_channels: vec![1],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open],
            remap_max_pointers: vec![1 << 18],
        };
        let ex = explore(&base, &grids, &dev, &eval);
        // The bigger cache must win for a zipf-skewed tensor whose hot
        // rows fit at 4096 lines but not at 256.
        assert_eq!(ex.best.cfg.cache.num_lines, 4096);
    }

    #[test]
    fn sharded_evaluation_ranks_like_serial_and_scores_lower() {
        // A crippled cache must lose under the sharded evaluator too,
        // and parallel makespans must come in under the serial sweep.
        let t = generate(&SynthConfig {
            dims: vec![800, 600, 400],
            nnz: 10_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 79,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep4 = crate::shard::ShardedSweep::prepare(&t, 16, 4);
        let sharded = Evaluator::ShardedSim { sweep: &sweep4 };
        let good = sharded.score(&base, &dev).unwrap();
        let mut crippled = base.clone();
        crippled.cache.num_lines = 64;
        crippled.cache.assoc = 1;
        let bad = sharded.score(&crippled, &dev).unwrap();
        assert!(good < bad, "crippled cache must lose: {good} vs {bad}");

        let sweep1 = crate::shard::ShardedSweep::prepare(&t, 16, 1);
        let serial = Evaluator::ShardedSim { sweep: &sweep1 };
        let serial_score = serial.score(&base, &dev).unwrap();
        assert!(
            good < serial_score,
            "4-worker makespan {good} must beat 1-worker {serial_score}"
        );

        // A config that fits as ONE instance but not as four concurrent
        // instances must be rejected by the sharded evaluator.
        let mut big = base.clone();
        big.cache.num_lines = 1 << 14; // ~1.1 MiB cache + tags per instance
        assert!(fpga::estimate(&big, &dev).fits, "fits as a single instance");
        assert!(
            sharded.score(&big, &dev).is_none(),
            "4 instances must not fit the device"
        );

        // More worker instances than the device has DRAM channel groups
        // is not a realizable deployment either.
        let sweep8 = crate::shard::ShardedSweep::prepare(&t, 16, 8);
        let oversubscribed = Evaluator::ShardedSim { sweep: &sweep8 };
        assert!(
            oversubscribed.score(&base, &dev).is_none(),
            "u250 has 4 channel groups; 8 instances must be rejected"
        );
    }

    #[test]
    fn cycle_sim_engines_score_identically() {
        // The event and grid cores are execution strategies, not model
        // changes: the same configuration must score to the exact same
        // cycle count under every engine, including remap phases.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 2)).collect();
        let dev = Device::alveo_u250();
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.cache.num_lines = 512;
        for max_pointers in [1usize << 4, 1 << 18] {
            cfg.remapper.max_pointers = max_pointers;
            let scores: Vec<f64> = [EngineKind::Lockstep, EngineKind::Event, EngineKind::Grid]
                .iter()
                .map(|&e| {
                    EvaluatorBuilder::new()
                        .engine(e)
                        .cycle_sim(&t, &factors)
                        .score(&cfg, &dev)
                        .unwrap()
                })
                .collect();
            assert_eq!(scores[0], scores[1], "event diverged at {max_pointers}");
            assert_eq!(scores[0], scores[2], "grid diverged at {max_pointers}");
        }
    }

    #[test]
    fn memory_budget_does_not_change_scores() {
        // The bounded-memory prep (S24: compressed-only traces, remap
        // columns spilled to disk) is a storage policy, not a model
        // change: every engine must score bit-identically with and
        // without a budget, under both single scoring and the grid
        // batch path.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 2)).collect();
        let dev = Device::alveo_u250();
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.cache.num_lines = 512;
        for engine in [EngineKind::Lockstep, EngineKind::Event, EngineKind::Grid] {
            let base = EvaluatorBuilder::new().engine(engine);
            let plain = base.cycle_sim(&t, &factors).score(&cfg, &dev).unwrap();
            let tight = base
                .memory_budget(Some(1)) // policy switch, not an RSS cap
                .cycle_sim(&t, &factors)
                .score(&cfg, &dev)
                .unwrap();
            assert_eq!(plain, tight, "{engine} diverged under a budget");
        }
        let grids = Grids {
            cache_line_bytes: vec![32, 64],
            cache_num_lines: vec![256, 1024],
            cache_assoc: vec![2],
            dma_num: vec![1],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            mem_techs: vec![MemTech::Ddr4],
            dram_channels: vec![1],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open],
            remap_max_pointers: vec![1 << 18],
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let plain = EvaluatorBuilder::new()
            .engine(EngineKind::Grid)
            .cycle_sim(&t, &factors);
        let tight = EvaluatorBuilder::new()
            .engine(EngineKind::Grid)
            .memory_budget(Some(1))
            .cycle_sim(&t, &factors);
        let ex_plain = explore(&base, &grids, &dev, &plain);
        let ex_tight = explore(&base, &grids, &dev, &tight);
        assert_eq!(ex_plain.visited.len(), ex_tight.visited.len());
        for (a, b) in ex_plain.visited.iter().zip(&ex_tight.visited) {
            assert_eq!(a.cycles, b.cycles, "batch scores diverged under a budget");
        }
    }

    #[test]
    fn grid_exploration_matches_event_exploration_exactly() {
        // The one-pass cache-grid batch must not change a single score:
        // full explore() under the grid engine returns the same visited
        // points and the same winner as under the event engine.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 3)).collect();
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let grids = Grids {
            cache_line_bytes: vec![32, 64],
            cache_num_lines: vec![256, 1024],
            cache_assoc: vec![2, 4],
            dma_num: vec![1, 2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            mem_techs: vec![MemTech::Ddr4],
            dram_channels: vec![1, 2],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open, RowPolicy::Closed],
            remap_max_pointers: vec![1 << 10, 1 << 18],
        };
        let ev_event = EvaluatorBuilder::new()
            .engine(EngineKind::Event)
            .cycle_sim(&t, &factors);
        let ev_grid = EvaluatorBuilder::new()
            .engine(EngineKind::Grid)
            .cycle_sim(&t, &factors);
        let ex_event = explore(&base, &grids, &dev, &ev_event);
        let ex_grid = explore(&base, &grids, &dev, &ev_grid);
        assert_eq!(ex_event.visited.len(), ex_grid.visited.len());
        assert_eq!(ex_event.rejected, ex_grid.rejected);
        for (a, b) in ex_event.visited.iter().zip(&ex_grid.visited) {
            assert_eq!(a.cycles, b.cycles, "scores diverged between engines");
        }
        assert_eq!(ex_event.best.cycles, ex_grid.best.cycles);
        assert_eq!(ex_event.best.cfg.cache, ex_grid.best.cfg.cache);
        assert_eq!(ex_event.best.cfg.dma, ex_grid.best.cfg.dma);
        assert_eq!(ex_event.best.cfg.mem, ex_grid.best.cfg.mem);
    }

    #[test]
    fn timing_batch_scores_match_event_engine() {
        // A DRAM/DMA module sweep under the grid engine routes through
        // the vectorized timing core; every score — including the
        // infeasible hole for a channel count the device lacks — must
        // equal the event engine's per-candidate scoring exactly.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 4)).collect();
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cands = Vec::new();
        for &(channels, banks, policy) in &[
            (1usize, 16usize, RowPolicy::Open),
            (4, 8, RowPolicy::Open),
            (2, 16, RowPolicy::Closed),
        ] {
            for &num_dmas in &[1usize, 2] {
                let mut cfg = base.clone();
                {
                    let dram = cfg.mem.ddr4_mut();
                    dram.channels = channels;
                    dram.banks = banks;
                    dram.row_policy = policy;
                }
                cfg.dma.num_dmas = num_dmas;
                cands.push(cfg);
            }
        }
        // u250 has 4 DRAM channels: an 8-channel candidate mid-batch
        // must come back None and keep the index mapping honest.
        let mut wide = base.clone();
        wide.mem.ddr4_mut().channels = 8;
        cands.insert(2, wide);
        let ev_grid = EvaluatorBuilder::new()
            .engine(EngineKind::Grid)
            .cycle_sim(&t, &factors);
        let ev_event = EvaluatorBuilder::new()
            .engine(EngineKind::Event)
            .cycle_sim(&t, &factors);
        let grid_scores = ev_grid.score_batch(&cands, &dev);
        let event_scores = ev_event.score_batch(&cands, &dev);
        assert_eq!(grid_scores, event_scores);
        assert!(grid_scores[2].is_none(), "8 channels must not fit u250");
        assert!(grid_scores.iter().filter(|s| s.is_some()).count() >= 6);
    }

    #[test]
    fn sharded_timing_batch_matches_event_scores() {
        let t = generate(&SynthConfig {
            dims: vec![500, 400, 300],
            nnz: 6_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 82,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep_grid = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Grid,
        );
        let sweep_event = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Event,
        );
        let ev_grid = Evaluator::ShardedSim { sweep: &sweep_grid };
        let ev_event = Evaluator::ShardedSim { sweep: &sweep_event };
        let mut cands = Vec::new();
        for &(channels, policy, buffer_bytes) in &[
            (1usize, RowPolicy::Open, 1024usize),
            (4, RowPolicy::Open, 4096),
            (2, RowPolicy::Closed, 4096),
        ] {
            let mut cfg = base.clone();
            {
                let dram = cfg.mem.ddr4_mut();
                dram.channels = channels;
                dram.row_policy = policy;
            }
            cfg.dma.buffer_bytes = buffer_bytes;
            cands.push(cfg);
        }
        // Infeasible mid-batch: more channels than the board has.
        let mut wide = base.clone();
        wide.mem.ddr4_mut().channels = 8;
        cands.insert(1, wide);
        let grid_scores = ev_grid.score_batch(&cands, &dev);
        let event_scores = ev_event.score_batch(&cands, &dev);
        assert_eq!(grid_scores, event_scores);
        assert!(grid_scores[1].is_none());
    }

    #[test]
    fn sharded_grid_engine_matches_event_scores() {
        let t = generate(&SynthConfig {
            dims: vec![500, 400, 300],
            nnz: 6_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 81,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep_grid = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Grid,
        );
        let sweep_event = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Event,
        );
        let ev_grid = Evaluator::ShardedSim { sweep: &sweep_grid };
        let ev_event = Evaluator::ShardedSim { sweep: &sweep_event };
        let mut cands = Vec::new();
        for &num_lines in &[256usize, 1024, 4096] {
            let mut cfg = base.clone();
            cfg.cache.num_lines = num_lines;
            cands.push(cfg);
        }
        // One infeasible candidate mid-batch keeps the index mapping
        // honest.
        let mut big = base.clone();
        big.cache.num_lines = 1 << 22;
        big.cache.assoc = 1;
        cands.insert(1, big);
        let grid_scores = ev_grid.score_batch(&cands, &dev);
        let event_scores = ev_event.score_batch(&cands, &dev);
        assert_eq!(grid_scores, event_scores);
        assert!(grid_scores[1].is_none());
    }

    #[test]
    fn module_order_is_respected() {
        // After exploration the best config's DMA comes from the DMA
        // sweep holding the best cache — verify the best point's cache
        // equals what a cache-only sweep would pick.
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let base_dram = base.mem.ddr4().expect("base is DDR4").clone();
        let cache_only = Grids {
            dma_num: vec![base.dma.num_dmas],
            dma_buffers: vec![base.dma.buffers_per_dma],
            dma_buffer_bytes: vec![base.dma.buffer_bytes],
            dram_channels: vec![base_dram.channels],
            dram_banks: vec![base_dram.banks],
            dram_row_policy: vec![base_dram.row_policy],
            remap_max_pointers: vec![base.remapper.max_pointers],
            ..Grids::default()
        };
        let ex_cache = explore(&base, &cache_only, &dev, &eval);
        let ex_full = explore(&base, &Grids::default(), &dev, &eval);
        assert_eq!(
            ex_full.best.cfg.cache, ex_cache.best.cfg.cache,
            "full search must keep the cache module's winner"
        );
    }

    /// A small joint space every cycle-level strategy test shares.
    fn small_grids() -> Grids {
        Grids {
            cache_line_bytes: vec![32, 64],
            cache_num_lines: vec![256, 1024],
            cache_assoc: vec![2, 4],
            dma_num: vec![1, 2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            mem_techs: vec![MemTech::Ddr4],
            dram_channels: vec![1, 2],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open],
            remap_max_pointers: vec![1 << 10, 1 << 18],
        }
    }

    #[test]
    fn joint_search_never_scores_worse_than_coordinate() {
        // The joint space is a per-dimension superset of everything
        // coordinate descent can visit, so its winner must be at least
        // as good — under every evaluator.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 5)).collect();
        let profile = TensorProfile::measure(&t);
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let grids = small_grids();
        let joint = SearchOptions {
            strategy: SearchStrategy::Joint,
            top_k: 3,
            resume: false,
            checkpoint_every: 0,
        };
        let evals = [
            EvaluatorBuilder::new().rank(16).pms(&profile),
            EvaluatorBuilder::new()
                .engine(EngineKind::Event)
                .cycle_sim(&t, &factors),
            EvaluatorBuilder::new()
                .engine(EngineKind::Grid)
                .cycle_sim(&t, &factors),
        ];
        for (i, eval) in evals.iter().enumerate() {
            let ex_coord = explore(&base, &grids, &dev, eval);
            let ex_joint = explore_with(&base, &grids, &dev, eval, &joint);
            assert!(
                ex_joint.best.cycles <= ex_coord.best.cycles,
                "evaluator {i}: joint {} must be <= coordinate {}",
                ex_joint.best.cycles,
                ex_coord.best.cycles
            );
        }
    }

    #[test]
    fn joint_search_grid_engine_matches_event_engine_exactly() {
        // The hierarchical sweep core must not change a single score:
        // the joint strategy under the grid engine returns the same
        // visited points, the same rejections, and the same winner as
        // per-candidate scoring under the event engine.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 6)).collect();
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let grids = small_grids();
        let joint = SearchOptions {
            strategy: SearchStrategy::Joint,
            top_k: 5,
            resume: false,
            checkpoint_every: 0,
        };
        let ev_event = EvaluatorBuilder::new()
            .engine(EngineKind::Event)
            .cycle_sim(&t, &factors);
        let ev_grid = EvaluatorBuilder::new()
            .engine(EngineKind::Grid)
            .cycle_sim(&t, &factors);
        let ex_event = explore_with(&base, &grids, &dev, &ev_event, &joint);
        let ex_grid = explore_with(&base, &grids, &dev, &ev_grid, &joint);
        assert_eq!(ex_event.visited.len(), ex_grid.visited.len());
        assert_eq!(ex_event.rejected, ex_grid.rejected);
        for (a, b) in ex_event.visited.iter().zip(&ex_grid.visited) {
            assert_eq!(a.cycles, b.cycles, "joint scores diverged between engines");
            assert_eq!(a.cfg, b.cfg);
        }
        assert_eq!(ex_event.best.cycles, ex_grid.best.cycles);
        assert_eq!(ex_event.best.cfg, ex_grid.best.cfg);
        assert_eq!(ex_event.top.len(), ex_grid.top.len());
        for (a, b) in ex_event.top.iter().zip(&ex_grid.top) {
            assert_eq!(a.cfg, b.cfg);
        }
    }

    #[test]
    fn beam_width_one_matches_coordinate_winner() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let ex_coord = explore(&base, &Grids::default(), &dev, &eval);
        let ex_beam = explore_with(
            &base,
            &Grids::default(),
            &dev,
            &eval,
            &SearchOptions {
                strategy: SearchStrategy::Beam { width: 1 },
                top_k: 1,
                resume: false,
                checkpoint_every: 0,
            },
        );
        assert_eq!(ex_beam.best.cycles, ex_coord.best.cycles);
        assert_eq!(ex_beam.best.cfg, ex_coord.best.cfg);
    }

    #[test]
    fn joint_dominates_both_module_searches() {
        // Every configuration coordinate descent or a beam search can
        // visit takes each knob from {base} ∪ its grid, so the joint
        // space is a superset of both search spaces and the joint
        // winner can never be worse than either.  (Beam-vs-coordinate
        // has no such guarantee — a beam may prune the greedy
        // incumbent — so only the joint dominance is asserted.)
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let grids = Grids::default();
        let run = |strategy| {
            explore_with(
                &base,
                &grids,
                &dev,
                &eval,
                &SearchOptions { strategy, top_k: 1, resume: false, checkpoint_every: 0 },
            )
            .best
            .cycles
        };
        let coord = run(SearchStrategy::Coordinate);
        let beam = run(SearchStrategy::Beam { width: 4 });
        let joint = run(SearchStrategy::Joint);
        assert!(joint <= coord, "joint {joint} must be <= coordinate {coord}");
        assert!(joint <= beam, "joint {joint} must be <= beam(4) {beam}");
    }

    #[test]
    fn pareto_and_top_k_report_shapes() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let ex = explore_with(
            &base,
            &Grids::default(),
            &dev,
            &eval,
            &SearchOptions {
                strategy: SearchStrategy::Joint,
                top_k: 5,
                resume: false,
                checkpoint_every: 0,
            },
        );
        // Top-k: ascending cycles, distinct configs, winner first.
        assert_eq!(ex.top.len(), 5);
        assert_eq!(ex.top[0].cycles, ex.best.cycles);
        assert_eq!(ex.top[0].cfg, ex.best.cfg);
        for w in ex.top.windows(2) {
            assert!(w[0].cycles <= w[1].cycles, "top-k must be ascending");
            assert!(w[0].cfg != w[1].cfg, "top-k must be distinct configs");
        }
        // Pareto: ascending cycles, winner first, mutually
        // non-dominated under (cycles, blocks, power), and no visited
        // point dominates a frontier member.
        assert!(!ex.pareto.is_empty());
        assert_eq!(ex.pareto[0].cycles, ex.best.cycles);
        for w in ex.pareto.windows(2) {
            assert!(w[0].cycles <= w[1].cycles, "frontier cycles must ascend");
        }
        let dominates = |a: &Point, b: &Point| {
            a.cycles <= b.cycles
                && a.blocks() <= b.blocks()
                && a.power_mw() <= b.power_mw()
                && (a.cycles < b.cycles || a.blocks() < b.blocks() || a.power_mw() < b.power_mw())
        };
        for (i, p) in ex.pareto.iter().enumerate() {
            for (j, q) in ex.pareto.iter().enumerate() {
                assert!(
                    i == j || !dominates(q, p),
                    "frontier members must be mutually non-dominated"
                );
            }
            assert!(
                !ex.visited.iter().any(|v| dominates(v, p)),
                "frontier member is dominated by a visited point"
            );
        }
        // Every visited point is represented: dominated or tied by
        // some frontier member.
        for v in &ex.visited {
            assert!(
                ex.pareto.iter().any(|p| dominates(p, v)
                    || (p.cycles == v.cycles
                        && p.blocks() == v.blocks()
                        && p.power_mw() == v.power_mw())),
                "visited point escapes the frontier's cover"
            );
        }
    }

    #[test]
    fn sharded_joint_batch_matches_event_scores() {
        let t = generate(&SynthConfig {
            dims: vec![500, 400, 300],
            nnz: 6_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 83,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep_grid =
            crate::shard::ShardedSweep::prepare_with_engine(&t, 8, 2, EngineKind::Grid);
        let sweep_event =
            crate::shard::ShardedSweep::prepare_with_engine(&t, 8, 2, EngineKind::Event);
        let ev_grid = Evaluator::ShardedSim { sweep: &sweep_grid };
        let ev_event = Evaluator::ShardedSim { sweep: &sweep_event };
        // A genuinely joint batch: cache AND dram/dma/remapper all vary.
        let mut cands = Vec::new();
        for &(num_lines, channels, max_pointers) in &[
            (256usize, 1usize, 1usize << 10),
            (1024, 2, 1 << 18),
            (4096, 1, 1 << 10),
        ] {
            let mut cfg = base.clone();
            cfg.cache.num_lines = num_lines;
            cfg.mem.ddr4_mut().channels = channels;
            cfg.remapper.max_pointers = max_pointers;
            cands.push(cfg);
        }
        // Infeasible mid-batch keeps the index mapping honest.
        let mut wide = base.clone();
        wide.mem.ddr4_mut().channels = 8;
        wide.cache.num_lines = 256;
        cands.insert(1, wide);
        let grid_scores = ev_grid.score_batch(&cands, &dev);
        let event_scores = ev_event.score_batch(&cands, &dev);
        assert_eq!(grid_scores, event_scores);
        assert!(grid_scores[1].is_none());
        assert!(grid_scores.iter().filter(|s| s.is_some()).count() == 3);
    }

    #[test]
    fn default_grids_stay_ddr4_only() {
        // Legacy explorations must see the identical candidate list:
        // the default memory grid sweeps DDR4 alone, and the module
        // generator enumerates exactly channels x banks x row-policy.
        let grids = Grids::default();
        assert_eq!(grids.mem_techs, vec![MemTech::Ddr4]);
        let base = ControllerConfig::default_for(16);
        let cands = dram_candidates(&grids, &base);
        assert_eq!(
            cands.len(),
            grids.dram_channels.len() * grids.dram_banks.len() * grids.dram_row_policy.len()
        );
        assert!(cands.iter().all(|c| c.mem.tech() == MemTech::Ddr4));
    }

    #[test]
    fn joint_search_reports_cross_technology_pareto_frontier() {
        // A joint exploration whose memory grid spans all three
        // technologies on an HBM-bearing board must put more than one
        // technology on the (cycles, blocks, power) frontier: DDR4
        // pays zero PHY blocks, oSRAM has the lowest device power, and
        // HBM2's pseudo-channels buy bandwidth — no single technology
        // dominates the other two on every axis.
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = EvaluatorBuilder::new().rank(16).pms(&profile);
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u280();
        let grids = Grids {
            cache_line_bytes: vec![64],
            cache_num_lines: vec![1024],
            cache_assoc: vec![4],
            dma_num: vec![2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            mem_techs: vec![MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram],
            dram_channels: vec![1, 2],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open],
            remap_max_pointers: vec![1 << 14],
        };
        let ex = explore_with(
            &base,
            &grids,
            &dev,
            &eval,
            &SearchOptions {
                strategy: SearchStrategy::Joint,
                top_k: 3,
                resume: false,
                checkpoint_every: 0,
            },
        );
        let visited_techs: Vec<MemTech> = [MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram]
            .into_iter()
            .filter(|&tech| ex.visited.iter().any(|p| p.cfg.mem.tech() == tech))
            .collect();
        assert_eq!(visited_techs.len(), 3, "all technologies must be scored");
        let frontier_techs: Vec<MemTech> = [MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram]
            .into_iter()
            .filter(|&tech| ex.pareto.iter().any(|p| p.cfg.mem.tech() == tech))
            .collect();
        assert!(
            frontier_techs.len() >= 2,
            "frontier must span technologies, got {frontier_techs:?}"
        );
        // The min-blocks and min-power ends of the frontier belong to
        // the technologies that own those axes.
        let min_blocks = ex.pareto.iter().map(|p| p.blocks()).min().unwrap();
        assert!(ex
            .pareto
            .iter()
            .any(|p| p.blocks() == min_blocks && p.cfg.mem.tech() == MemTech::Ddr4));
        let min_power = ex.pareto.iter().map(|p| p.power_mw()).min().unwrap();
        assert!(ex
            .pareto
            .iter()
            .any(|p| p.power_mw() == min_power && p.cfg.mem.tech() == MemTech::Osram));
    }

    #[test]
    fn coordinate_search_crosses_technologies_too() {
        // The module-3 sweep carries the technology axis in every
        // strategy, not just the joint one: with all techs in the grid
        // the coordinate search must score HBM2 and oSRAM candidates.
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = EvaluatorBuilder::new().rank(16).pms(&profile);
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u280();
        let ex = explore(&base, &Grids::all_mem_techs(), &dev, &eval);
        for tech in [MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram] {
            assert!(
                ex.visited.iter().any(|p| p.cfg.mem.tech() == tech),
                "{tech:?} never visited"
            );
        }
    }

    #[test]
    fn hbm_candidates_are_infeasible_on_hbm_less_boards() {
        // On a board without HBM stacks the HBM2 grid point must be
        // rejected, not silently scored.
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = EvaluatorBuilder::new().rank(16).pms(&profile);
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let mut hbm = base.clone();
        hbm.mem = MemTech::Hbm2.default_config();
        assert!(eval.score(&hbm, &dev).is_none());
        let ex = explore(&base, &Grids::all_mem_techs(), &dev, &eval);
        assert!(ex.rejected > 0);
        assert!(ex
            .visited
            .iter()
            .all(|p| p.cfg.mem.tech() != MemTech::Hbm2));
    }

    #[test]
    fn builder_matches_legacy_constructors() {
        // The builder is a new front door, not a new model: every
        // evaluator it constructs scores identically to the legacy
        // construction path it wraps.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 9)).collect();
        let profile = TensorProfile::measure(&t);
        let dev = Device::alveo_u250();
        let cfg = ControllerConfig::default_for(t.record_bytes());
        let legacy_pms = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let built_pms = EvaluatorBuilder::new().rank(16).pms(&profile);
        assert_eq!(legacy_pms.score(&cfg, &dev), built_pms.score(&cfg, &dev));
        #[allow(deprecated)]
        let legacy_sim = Evaluator::cycle_sim(&t, &factors, EngineKind::Grid);
        let built_sim = EvaluatorBuilder::new()
            .engine(EngineKind::Grid)
            .cycle_sim(&t, &factors);
        assert_eq!(legacy_sim.score(&cfg, &dev), built_sim.score(&cfg, &dev));
        let sweep = crate::shard::ShardedSweep::prepare(&t, 8, 2);
        let legacy_sharded = Evaluator::ShardedSim { sweep: &sweep };
        let built_sharded = EvaluatorBuilder::new().sharded(&sweep);
        assert_eq!(
            legacy_sharded.score(&cfg, &dev),
            built_sharded.score(&cfg, &dev)
        );
    }
}
