//! Design-space exploration (S11, paper §5.3): "a module-by-module
//! (e.g., Cache Engine and DMA Engine) exhaustive parameter search can be
//! proposed to identify the optimal parameters for the memory
//! controller."
//!
//! The explorer sweeps one module's grid at a time while holding the
//! others at their current best (coordinate descent over module grids —
//! exactly the paper's proposal), scoring each candidate with either the
//! fast analytic PMS or the cycle-level simulator, and rejecting
//! configurations that do not fit the device ([`crate::fpga`]).
//!
//! Candidates within one module sweep are independent, so
//! [`explore`] scores each module's grid as a batch
//! ([`Evaluator::score_batch`]): candidates fan out across host threads,
//! and — under the grid engine ([`EngineKind::Grid`]) — the cross
//! product factorizes.  The whole cache-module grid is classified in
//! **one trace pass** by the stack-distance grid core
//! ([`crate::engine::grid`]), leaving only each candidate's miss stream
//! to be timed; and a DRAM/DMA (timing-module) sweep runs through the
//! vectorized timing core ([`crate::engine::timing`]) — classify once
//! per line geometry, extract the miss/stream op queue once per cache
//! candidate, then time all DRAM/DMA candidates in one walk of that
//! queue.  Scores are bit-identical to per-candidate scoring under
//! either classic engine.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::controller::{
    CacheConfig, ControllerConfig, DmaConfig, MemLayout, MemoryController, RemapperConfig,
};
use crate::cpd::linalg::Mat;
use crate::dram::{DramConfig, RowPolicy};
use crate::engine::{EngineKind, GridClassification, PreparedTrace, TimingCandidate, TimingOps};
use crate::fpga::{self, Device};
use crate::mttkrp::{approach1, Tracing};
use crate::pms::{self, TensorProfile};
use crate::tensor::{remap, Coord, SparseTensor};
use crate::util::parallel_indexed;

/// Key of one memoized remap-pass simulation (see
/// [`crate::shard::ShardedSweep`], which uses the same keying).
type RemapKey = (usize, DramConfig, RemapperConfig);

/// Per-mode precomputation of a CycleSim scoring pass under one
/// remapper pointer budget: the mode column the (simulated) remap pass
/// reads — a snapshot of the tensor *before* this mode's host remap —
/// and the compiled Approach-1 trace of the remapped tensor.
struct ModePrep {
    remap_col: Vec<Coord>,
    trace: PreparedTrace,
}

/// Interior-mutable memo shared by every scoring of one
/// [`Evaluator::CycleSim`]: the remapped tensor is cloned and
/// re-remapped **once** instead of once per candidate (the host
/// permutation `remap` applies is a counting sort — independent of
/// every controller knob, including the pointer budget, which only
/// changes the *simulated* pointer traffic), and the remap-pass
/// simulation — identical for every candidate sharing (mode, DRAM,
/// remapper) knobs, i.e. the whole cache/DMA grid — runs once per key
/// (mirroring `ShardedSweep::remap_memo`).
#[derive(Default)]
pub struct SimMemo {
    prep: Mutex<Option<Arc<Vec<ModePrep>>>>,
    remap: Mutex<HashMap<RemapKey, u64>>,
}

impl SimMemo {
    /// The per-mode traces + remap columns, built on first use: one
    /// tensor clone, remapped mode by mode in sweep order (the state
    /// the original per-candidate loop reproduced from scratch for
    /// every single candidate).
    fn prep(&self, t: &SparseTensor, factors: &[Mat], layout: &MemLayout) -> Arc<Vec<ModePrep>> {
        if let Some(p) = self.prep.lock().expect("prep memo poisoned").as_ref() {
            return Arc::clone(p);
        }
        let mut tt = t.clone();
        let n = tt.n_modes();
        let built: Vec<ModePrep> = (0..n)
            .map(|mode| {
                let remap_col = tt.mode_col(mode).to_vec();
                // The budget does not affect the data movement, only
                // the (separately simulated) pointer traffic.
                remap::remap(&mut tt, mode, usize::MAX);
                let run = approach1::run(&tt, factors, mode, layout, Tracing::On);
                ModePrep {
                    remap_col,
                    trace: PreparedTrace::new(run.trace),
                }
            })
            .collect();
        let mut memo = self.prep.lock().expect("prep memo poisoned");
        Arc::clone(memo.get_or_insert_with(|| Arc::new(built)))
    }

    /// One mode's remap-pass cycles under `cfg`, on a fresh controller,
    /// memoized per (mode, DRAM, remapper) key.
    fn remap_cycles(
        &self,
        p: &ModePrep,
        mode: usize,
        mode_len: usize,
        layout: &MemLayout,
        cfg: &ControllerConfig,
    ) -> u64 {
        let key = (mode, cfg.dram.clone(), cfg.remapper);
        if let Some(&c) = self.remap.lock().expect("remap memo poisoned").get(&key) {
            return c;
        }
        let mut ctl = MemoryController::new(cfg.clone());
        let cycles = ctl.remap_pass(&p.remap_col, mode_len, layout, 0, 1);
        self.remap
            .lock()
            .expect("remap memo poisoned")
            .insert(key, cycles);
        cycles
    }
}

/// How candidates are scored.
pub enum Evaluator<'a> {
    /// Analytic PMS over a measured profile (fast: microseconds/config).
    Pms {
        profile: &'a TensorProfile,
        rank: usize,
    },
    /// Cycle-level simulation of a full Approach-1 sweep over a concrete
    /// tensor (slow but exact; used to validate the PMS ranking).  The
    /// score is the sum over modes of a fresh-controller remap pass plus
    /// a fresh-controller trace replay — the same phase model
    /// [`crate::shard::ShardedSweep::makespan`] uses — so both phases
    /// memoize across candidates ([`SimMemo`]).  `engine` selects the
    /// replay core ([`crate::engine`]): all cores produce identical
    /// scores; `Grid` additionally scores whole cache-module batches in
    /// one classification pass ([`Evaluator::score_batch`]).  Construct
    /// with [`Evaluator::cycle_sim`] (or supply `SimMemo::default()`).
    CycleSim {
        tensor: &'a SparseTensor,
        factors: &'a [Mat],
        engine: EngineKind,
        memo: SimMemo,
    },
    /// Sharded cycle-level simulation ([`crate::shard`]): every candidate
    /// configuration is evaluated as K per-shard controller instances
    /// running concurrently; the score is the sum over modes of the
    /// remap pass plus the slowest shard's replay makespan.  The sweep
    /// is prepared once ([`crate::shard::ShardedSweep::prepare`]) so
    /// per-candidate scoring replays traces only.  This is how a
    /// multi-controller (multi-SLR) deployment should pick its
    /// per-instance parameters.
    ShardedSim {
        sweep: &'a crate::shard::ShardedSweep<'a>,
    },
}

impl<'a> Evaluator<'a> {
    /// A [`Evaluator::CycleSim`] with a fresh memo.
    pub fn cycle_sim(
        tensor: &'a SparseTensor,
        factors: &'a [Mat],
        engine: EngineKind,
    ) -> Evaluator<'a> {
        Evaluator::CycleSim {
            tensor,
            factors,
            engine,
            memo: SimMemo::default(),
        }
    }
}

impl Evaluator<'_> {
    /// True when `cfg` is realizable on `dev` under this evaluator's
    /// deployment model.
    pub fn feasible(&self, cfg: &ControllerConfig, dev: &Device) -> bool {
        if !device_feasible(cfg, dev) {
            return false;
        }
        match self {
            Evaluator::ShardedSim { sweep } => {
                // K concurrent controller instances must *all* fit the
                // device: each needs a 1/K slice of the block budget
                // (the whole-device check above only covers one
                // instance), and each instance owns a DRAM channel
                // group, so the device must have K channel groups
                // (channels-vs-board itself is device_feasible's job).
                let w = sweep.workers();
                if w > dev.dram_channels {
                    return false;
                }
                let slice = Device {
                    bram36: dev.bram36 / w,
                    uram: dev.uram / w,
                    ..*dev
                };
                fpga::estimate(cfg, &slice).fits
            }
            _ => true,
        }
    }

    /// Score = estimated/measured total cycles (lower is better), or
    /// `None` if the configuration does not fit `dev`.
    pub fn score(&self, cfg: &ControllerConfig, dev: &Device) -> Option<f64> {
        if !self.feasible(cfg, dev) {
            return None;
        }
        Some(match self {
            Evaluator::Pms { profile, rank } => {
                pms::estimate_with_rank(profile, cfg, dev, *rank).total_cycles()
            }
            Evaluator::CycleSim {
                tensor,
                factors,
                engine,
                memo,
            } => cycle_sim_score(tensor, factors, *engine, memo, cfg) as f64,
            Evaluator::ShardedSim { sweep } => sweep.makespan(cfg) as f64,
        })
    }

    /// Score a batch of candidate configurations; returns one score per
    /// candidate in input order (`None` = does not fit the device).
    /// Candidates are independent, so the generic path fans them out
    /// across host threads.  Under the grid engine the cross product is
    /// factorized instead: a **cache-module sweep** (all candidates
    /// sharing DRAM/DMA/remapper knobs) is scored by the one-pass grid
    /// core — one trace classification for the whole batch — and a
    /// **timing-module sweep** (all candidates sharing the cache
    /// module; DRAM/DMA/remapper free) by the vectorized timing core —
    /// classify once, extract the miss/stream op queue once, then time
    /// every DRAM/DMA candidate in one walk.  Same scores either way.
    pub fn score_batch(&self, cfgs: &[ControllerConfig], dev: &Device) -> Vec<Option<f64>> {
        if cfgs.is_empty() {
            return Vec::new();
        }
        if cfgs.len() >= 2 && cache_module_sweep(cfgs) {
            match self {
                Evaluator::CycleSim {
                    tensor,
                    factors,
                    engine: EngineKind::Grid,
                    memo,
                } => return cycle_sim_grid_batch(tensor, factors, memo, cfgs, dev),
                Evaluator::ShardedSim { sweep } if sweep.engine() == EngineKind::Grid => {
                    return self.sharded_grid_batch(sweep, cfgs, dev)
                }
                _ => {}
            }
        } else if cfgs.len() >= 2 && timing_module_sweep(cfgs) {
            match self {
                Evaluator::CycleSim {
                    tensor,
                    factors,
                    engine: EngineKind::Grid,
                    memo,
                } => return cycle_sim_timing_batch(tensor, factors, memo, cfgs, dev),
                Evaluator::ShardedSim { sweep } if sweep.engine() == EngineKind::Grid => {
                    return self.sharded_timing_batch(sweep, cfgs, dev)
                }
                _ => {}
            }
        }
        // Prime the CycleSim memos sequentially — traces AND the
        // remap-pass cycles of every key the batch will need — so the
        // concurrent scorers below only ever hit the memo; otherwise N
        // threads would race the check-then-insert and each re-simulate
        // the identical remap pass.
        if let Evaluator::CycleSim {
            tensor,
            factors,
            memo,
            ..
        } = self
        {
            let rank = factors[0].cols();
            let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
            let mut primed: Vec<(DramConfig, RemapperConfig)> = Vec::new();
            for cfg in cfgs {
                if !self.feasible(cfg, dev) {
                    continue;
                }
                let key = (cfg.dram.clone(), cfg.remapper);
                if primed.contains(&key) {
                    continue;
                }
                primed.push(key);
                let prep = memo.prep(tensor, factors, &layout);
                for (mode, p) in prep.iter().enumerate() {
                    memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, cfg);
                }
            }
        }
        // A sharded makespan already fans out one thread per shard;
        // adding an outer candidate layer would only oversubscribe the
        // host, so ShardedSim keeps the sequential candidate loop.
        if matches!(self, Evaluator::ShardedSim { .. }) {
            return cfgs.iter().map(|c| self.score(c, dev)).collect();
        }
        parallel_indexed(cfgs.len(), |i| self.score(&cfgs[i], dev))
    }

    /// Cache-module batch under the sharded evaluator: feasibility per
    /// candidate, then one grid classification per shard trace
    /// ([`crate::shard::ShardedSweep::makespans_for_cache_grid`]).
    fn sharded_grid_batch(
        &self,
        sweep: &crate::shard::ShardedSweep<'_>,
        cfgs: &[ControllerConfig],
        dev: &Device,
    ) -> Vec<Option<f64>> {
        let feasible: Vec<bool> = cfgs.iter().map(|c| self.feasible(c, dev)).collect();
        let caches: Vec<CacheConfig> = cfgs
            .iter()
            .zip(&feasible)
            .filter(|&(_, &ok)| ok)
            .map(|(c, _)| c.cache)
            .collect();
        if caches.is_empty() {
            return vec![None; cfgs.len()];
        }
        let base = cfgs
            .iter()
            .zip(&feasible)
            .find(|&(_, &ok)| ok)
            .map(|(c, _)| c.clone())
            .expect("at least one feasible candidate");
        let scores = sweep.makespans_for_cache_grid(&base, &caches);
        let mut it = scores.into_iter();
        feasible
            .iter()
            .map(|&ok| {
                if ok {
                    Some(it.next().expect("one grid score per feasible candidate") as f64)
                } else {
                    None
                }
            })
            .collect()
    }

    /// Timing-module batch under the sharded evaluator: feasibility per
    /// candidate, then one classification + op-queue walk per shard
    /// trace times every feasible candidate's lanes simultaneously
    /// ([`crate::shard::ShardedSweep::makespans_for_timing_grid`]).
    fn sharded_timing_batch(
        &self,
        sweep: &crate::shard::ShardedSweep<'_>,
        cfgs: &[ControllerConfig],
        dev: &Device,
    ) -> Vec<Option<f64>> {
        let feasible: Vec<bool> = cfgs.iter().map(|c| self.feasible(c, dev)).collect();
        let live: Vec<ControllerConfig> = cfgs
            .iter()
            .zip(&feasible)
            .filter(|&(_, &ok)| ok)
            .map(|(c, _)| c.clone())
            .collect();
        if live.is_empty() {
            return vec![None; cfgs.len()];
        }
        let base = live[0].clone();
        let scores = sweep.makespans_for_timing_grid(&base, &live);
        let mut it = scores.into_iter();
        feasible
            .iter()
            .map(|&ok| {
                if ok {
                    Some(it.next().expect("one timing score per feasible candidate") as f64)
                } else {
                    None
                }
            })
            .collect()
    }
}

/// CycleSim score of one configuration: Σ over modes of (memoized
/// fresh-controller remap pass + fresh-controller trace replay).
fn cycle_sim_score(
    tensor: &SparseTensor,
    factors: &[Mat],
    engine: EngineKind,
    memo: &SimMemo,
    cfg: &ControllerConfig,
) -> u64 {
    let rank = factors[0].cols();
    let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
    let prep = memo.prep(tensor, factors, &layout);
    let mut total = 0u64;
    for (mode, p) in prep.iter().enumerate() {
        total += memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, cfg);
        let mut ctl = MemoryController::new(cfg.clone());
        total += match engine {
            EngineKind::Lockstep => ctl.replay(p.trace.raw()),
            EngineKind::Event | EngineKind::Grid => ctl.replay_events(p.trace.compressed()),
        };
    }
    total
}

/// Cache-module batch under CycleSim + grid engine: one classification
/// pass per mode trace scores every feasible candidate; per-candidate
/// miss-only replays fan out across host threads.
fn cycle_sim_grid_batch(
    tensor: &SparseTensor,
    factors: &[Mat],
    memo: &SimMemo,
    cfgs: &[ControllerConfig],
    dev: &Device,
) -> Vec<Option<f64>> {
    let feasible: Vec<bool> = cfgs.iter().map(|c| device_feasible(c, dev)).collect();
    let caches: Vec<CacheConfig> = cfgs
        .iter()
        .zip(&feasible)
        .filter(|&(_, &ok)| ok)
        .map(|(c, _)| c.cache)
        .collect();
    if caches.is_empty() {
        return vec![None; cfgs.len()];
    }
    let base = cfgs
        .iter()
        .zip(&feasible)
        .find(|&(_, &ok)| ok)
        .map(|(c, _)| c.clone())
        .expect("at least one feasible candidate");
    let rank = factors[0].cols();
    let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
    let prep = memo.prep(tensor, factors, &layout);
    // The remap pass never touches the Cache Engine: one memoized value
    // serves the entire batch.
    let remap_total: u64 = prep
        .iter()
        .enumerate()
        .map(|(mode, p)| memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, &base))
        .sum();
    let mut compute = vec![0u64; caches.len()];
    for p in prep.iter() {
        let cls = GridClassification::classify(p.trace.compressed(), &caches);
        let per: Vec<u64> = parallel_indexed(caches.len(), |ci| {
            let mut cfg = base.clone();
            cfg.cache = caches[ci];
            cls.replay(ci, p.trace.compressed(), &cfg).cycles
        });
        for (t, c) in compute.iter_mut().zip(per) {
            *t += c;
        }
    }
    let mut it = compute.into_iter();
    feasible
        .iter()
        .map(|&ok| {
            if ok {
                Some((remap_total + it.next().expect("one score per feasible candidate")) as f64)
            } else {
                None
            }
        })
        .collect()
}

/// DRAM/DMA (and remapper) module batch under CycleSim + grid engine:
/// the cache module is fixed across the batch, so **one**
/// single-candidate classification per mode trace feeds the vectorized
/// timing core ([`crate::engine::timing`]) — the hit-dominated cache
/// loop runs once per mode and every candidate is then timed from the
/// shared miss/stream op queue in one walk.  Remap totals are
/// candidate-dependent (keyed (mode, DRAM, remapper)) but memoized, so
/// each distinct key simulates once for the whole batch.
fn cycle_sim_timing_batch(
    tensor: &SparseTensor,
    factors: &[Mat],
    memo: &SimMemo,
    cfgs: &[ControllerConfig],
    dev: &Device,
) -> Vec<Option<f64>> {
    let feasible: Vec<bool> = cfgs.iter().map(|c| device_feasible(c, dev)).collect();
    let live: Vec<&ControllerConfig> = cfgs
        .iter()
        .zip(&feasible)
        .filter(|&(_, &ok)| ok)
        .map(|(c, _)| c)
        .collect();
    if live.is_empty() {
        return vec![None; cfgs.len()];
    }
    let rank = factors[0].cols();
    let layout = MemLayout::plan(tensor.dims(), tensor.nnz(), tensor.record_bytes(), rank);
    let prep = memo.prep(tensor, factors, &layout);
    let remap_totals: Vec<u64> = live
        .iter()
        .map(|cfg| {
            prep.iter()
                .enumerate()
                .map(|(mode, p)| memo.remap_cycles(p, mode, tensor.dims()[mode], &layout, cfg))
                .sum()
        })
        .collect();
    // Candidates differing only in remapper knobs share a lane: time
    // each distinct (DRAM, DMA) pair once.
    let (lanes, lane_of) =
        TimingCandidate::dedup(live.iter().map(|c| TimingCandidate::of(c)).collect());
    let cache = cfgs[0].cache;
    let mut compute = vec![0u64; live.len()];
    for p in prep.iter() {
        let cls = GridClassification::classify(p.trace.compressed(), &[cache]);
        let ops = TimingOps::extract(&cls, 0, p.trace.compressed());
        let runs = ops.time_grid_parallel(&lanes);
        for (total, &lane) in compute.iter_mut().zip(&lane_of) {
            *total += runs[lane].cycles;
        }
    }
    let mut it = remap_totals.into_iter().zip(compute);
    feasible
        .iter()
        .map(|&ok| {
            if ok {
                let (remap, comp) = it.next().expect("one score per feasible candidate");
                Some((remap + comp) as f64)
            } else {
                None
            }
        })
        .collect()
}

/// Device-level feasibility shared by every evaluator: the on-chip
/// blocks must fit the device budget, and the configured DRAM bus must
/// exist on the board (a sweep over channel counts must not "win" with
/// channels the device does not have).
fn device_feasible(cfg: &ControllerConfig, dev: &Device) -> bool {
    fpga::estimate(cfg, dev).fits && cfg.dram.channels <= dev.dram_channels
}

/// True when every candidate shares the non-cache knobs of the first —
/// the shape of a cache-module sweep.
fn cache_module_sweep(cfgs: &[ControllerConfig]) -> bool {
    let base = &cfgs[0];
    cfgs.iter()
        .all(|c| c.dram == base.dram && c.dma == base.dma && c.remapper == base.remapper)
}

/// True when every candidate shares the first's cache module — the
/// shape of a DRAM / DMA / remapper (timing-dimension) sweep, which the
/// vectorized timing core scores from one shared op queue.
fn timing_module_sweep(cfgs: &[ControllerConfig]) -> bool {
    let base = &cfgs[0];
    cfgs.iter().all(|c| c.cache == base.cache)
}

/// One explored point.
#[derive(Debug, Clone)]
pub struct Point {
    pub cfg: ControllerConfig,
    pub cycles: f64,
    pub bram36: usize,
    pub uram: usize,
}

/// Result of a full exploration.
#[derive(Debug, Clone)]
pub struct Exploration {
    pub best: Point,
    /// Every feasible point visited, in visit order.
    pub visited: Vec<Point>,
    /// Candidates rejected for not fitting the device.
    pub rejected: usize,
}

/// Default sweep grids (§5.2.1 parameters plus the paper's §2 DRAM
/// knobs: channel/bank counts and the row-buffer policy).
pub struct Grids {
    pub cache_line_bytes: Vec<usize>,
    pub cache_num_lines: Vec<usize>,
    pub cache_assoc: Vec<usize>,
    pub dma_num: Vec<usize>,
    pub dma_buffers: Vec<usize>,
    pub dma_buffer_bytes: Vec<usize>,
    /// DRAM channels (power of two; candidates beyond the device's
    /// channel count are rejected as infeasible).
    pub dram_channels: Vec<usize>,
    /// Banks per DRAM channel (power of two).
    pub dram_banks: Vec<usize>,
    /// Open- vs closed-page row policy.
    pub dram_row_policy: Vec<RowPolicy>,
    pub remap_max_pointers: Vec<usize>,
}

impl Default for Grids {
    fn default() -> Self {
        Grids {
            cache_line_bytes: vec![32, 64, 128, 256],
            cache_num_lines: vec![256, 1024, 4096, 16384],
            cache_assoc: vec![1, 2, 4, 8],
            dma_num: vec![1, 2, 4],
            dma_buffers: vec![1, 2, 4],
            dma_buffer_bytes: vec![1024, 4096, 16384],
            dram_channels: vec![1, 2, 4],
            dram_banks: vec![8, 16],
            dram_row_policy: vec![RowPolicy::Open, RowPolicy::Closed],
            remap_max_pointers: vec![1 << 10, 1 << 14, 1 << 18, 1 << 22],
        }
    }
}

/// A visited point with its device usage attached.
fn point_at(cfg: ControllerConfig, cycles: f64, dev: &Device) -> Point {
    let usage = fpga::estimate(&cfg, dev);
    Point {
        cfg,
        cycles,
        bram36: usage.bram36_used,
        uram: usage.uram_used,
    }
}

/// Batch-score one module's candidate list, recording visits/rejections
/// and lowering the incumbent (first strictly-better candidate wins
/// ties exactly like the sequential sweep did).
fn sweep_module(
    eval: &Evaluator<'_>,
    dev: &Device,
    cands: Vec<ControllerConfig>,
    best: &mut Point,
    visited: &mut Vec<Point>,
    rejected: &mut usize,
) {
    let scores = eval.score_batch(&cands, dev);
    for (cfg, score) in cands.into_iter().zip(scores) {
        match score {
            None => *rejected += 1,
            Some(cycles) => {
                let p = point_at(cfg, cycles, dev);
                visited.push(p.clone());
                if cycles < best.cycles {
                    *best = p;
                }
            }
        }
    }
}

/// Run the module-by-module exhaustive search starting from `base`.
/// Order: Cache Engine grid, then DMA Engine, then DRAM timing
/// (channels/banks/row policy), then Tensor Remapper — each module
/// fixed to its best before the next is swept.  Every module's grid is
/// scored as one batch ([`Evaluator::score_batch`]), so under the grid
/// engine the cross product factorizes: the cache sweep classifies all
/// cache candidates in one trace pass, and the DMA/DRAM sweeps each
/// vector-time all their candidates from one shared op queue.
pub fn explore(
    base: &ControllerConfig,
    grids: &Grids,
    dev: &Device,
    eval: &Evaluator<'_>,
) -> Exploration {
    let mut visited = Vec::new();
    let mut rejected = 0usize;

    let base_cycles = eval
        .score(base, dev)
        .expect("base configuration must fit the device");
    let mut best_point = point_at(base.clone(), base_cycles, dev);
    visited.push(best_point.clone());

    // --- Module 1: Cache Engine ---
    let mut cands = Vec::new();
    for &line_bytes in &grids.cache_line_bytes {
        for &num_lines in &grids.cache_num_lines {
            for &assoc in &grids.cache_assoc {
                if num_lines % assoc != 0 || !(num_lines / assoc).is_power_of_two() {
                    continue;
                }
                let mut cfg = best_point.cfg.clone();
                cfg.cache = CacheConfig {
                    line_bytes,
                    num_lines,
                    assoc,
                    hit_latency: cfg.cache.hit_latency,
                };
                cands.push(cfg);
            }
        }
    }
    sweep_module(eval, dev, cands, &mut best_point, &mut visited, &mut rejected);

    // --- Module 2: DMA Engine ---
    let mut cands = Vec::new();
    for &num_dmas in &grids.dma_num {
        for &buffers_per_dma in &grids.dma_buffers {
            for &buffer_bytes in &grids.dma_buffer_bytes {
                let mut cfg = best_point.cfg.clone();
                cfg.dma = DmaConfig {
                    num_dmas,
                    buffers_per_dma,
                    buffer_bytes,
                    setup_cycles: cfg.dma.setup_cycles,
                };
                cands.push(cfg);
            }
        }
    }
    sweep_module(eval, dev, cands, &mut best_point, &mut visited, &mut rejected);

    // --- Module 3: DRAM timing (channels x banks x row policy) ---
    // Under the grid engine this whole sweep is a timing-module batch:
    // one cache classification pass per mode feeds the vectorized
    // timing core, which walks the shared op queue once for all
    // candidates.
    let mut cands = Vec::new();
    for &channels in &grids.dram_channels {
        for &banks in &grids.dram_banks {
            for &row_policy in &grids.dram_row_policy {
                if !channels.is_power_of_two() || !banks.is_power_of_two() {
                    continue;
                }
                let mut cfg = best_point.cfg.clone();
                cfg.dram.channels = channels;
                cfg.dram.banks = banks;
                cfg.dram.row_policy = row_policy;
                cands.push(cfg);
            }
        }
    }
    sweep_module(eval, dev, cands, &mut best_point, &mut visited, &mut rejected);

    // --- Module 4: Tensor Remapper ---
    let mut cands = Vec::new();
    for &max_pointers in &grids.remap_max_pointers {
        let mut cfg = best_point.cfg.clone();
        cfg.remapper.max_pointers = max_pointers;
        cands.push(cfg);
    }
    sweep_module(eval, dev, cands, &mut best_point, &mut visited, &mut rejected);

    Exploration {
        best: best_point,
        visited,
        rejected,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::{generate, Profile, SynthConfig};

    fn tensor() -> SparseTensor {
        generate(&SynthConfig {
            dims: vec![400, 300, 200],
            nnz: 8_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 77,
        })
    }

    #[test]
    fn pms_exploration_finds_no_worse_than_base() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let ex = explore(&base, &Grids::default(), &dev, &eval);
        let base_score = eval.score(&base, &dev).unwrap();
        assert!(ex.best.cycles <= base_score);
        assert!(ex.visited.len() > 20);
    }

    #[test]
    fn infeasible_configs_are_rejected_not_chosen() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let mut grids = Grids::default();
        grids.cache_num_lines.push(1 << 22); // 256 MiB cache: never fits
        let ex = explore(&base, &grids, &dev, &eval);
        assert!(ex.rejected > 0);
        assert!(fpga::estimate(&ex.best.cfg, &dev).fits);
    }

    #[test]
    fn score_batch_matches_sequential_scores() {
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cands = Vec::new();
        for &buffer_bytes in &[1024usize, 4096, 16384] {
            let mut cfg = base.clone();
            cfg.dma.buffer_bytes = buffer_bytes;
            cands.push(cfg);
        }
        let mut big = base.clone();
        big.cache.num_lines = 1 << 22; // never fits
        big.cache.assoc = 1;
        cands.push(big);
        let batch = eval.score_batch(&cands, &dev);
        let seq: Vec<Option<f64>> = cands.iter().map(|c| eval.score(c, &dev)).collect();
        assert_eq!(batch, seq);
        assert!(batch[3].is_none(), "oversized cache must be rejected");
    }

    #[test]
    fn cycle_sim_exploration_small_grid() {
        // Dims large enough that 256 cache lines thrash while 4096 hold
        // the zipf-hot factor rows (rank 16 -> one 64B line per row).
        let t = generate(&SynthConfig {
            dims: vec![4000, 3000, 2000],
            nnz: 20_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 78,
        });
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 16, 1)).collect();
        let eval = Evaluator::cycle_sim(&t, &factors, EngineKind::Event);
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let grids = Grids {
            cache_line_bytes: vec![64],
            cache_num_lines: vec![256, 4096],
            cache_assoc: vec![4],
            dma_num: vec![2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            dram_channels: vec![1],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open],
            remap_max_pointers: vec![1 << 18],
        };
        let ex = explore(&base, &grids, &dev, &eval);
        // The bigger cache must win for a zipf-skewed tensor whose hot
        // rows fit at 4096 lines but not at 256.
        assert_eq!(ex.best.cfg.cache.num_lines, 4096);
    }

    #[test]
    fn sharded_evaluation_ranks_like_serial_and_scores_lower() {
        // A crippled cache must lose under the sharded evaluator too,
        // and parallel makespans must come in under the serial sweep.
        let t = generate(&SynthConfig {
            dims: vec![800, 600, 400],
            nnz: 10_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 79,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep4 = crate::shard::ShardedSweep::prepare(&t, 16, 4);
        let sharded = Evaluator::ShardedSim { sweep: &sweep4 };
        let good = sharded.score(&base, &dev).unwrap();
        let mut crippled = base.clone();
        crippled.cache.num_lines = 64;
        crippled.cache.assoc = 1;
        let bad = sharded.score(&crippled, &dev).unwrap();
        assert!(good < bad, "crippled cache must lose: {good} vs {bad}");

        let sweep1 = crate::shard::ShardedSweep::prepare(&t, 16, 1);
        let serial = Evaluator::ShardedSim { sweep: &sweep1 };
        let serial_score = serial.score(&base, &dev).unwrap();
        assert!(
            good < serial_score,
            "4-worker makespan {good} must beat 1-worker {serial_score}"
        );

        // A config that fits as ONE instance but not as four concurrent
        // instances must be rejected by the sharded evaluator.
        let mut big = base.clone();
        big.cache.num_lines = 1 << 14; // ~1.1 MiB cache + tags per instance
        assert!(fpga::estimate(&big, &dev).fits, "fits as a single instance");
        assert!(
            sharded.score(&big, &dev).is_none(),
            "4 instances must not fit the device"
        );

        // More worker instances than the device has DRAM channel groups
        // is not a realizable deployment either.
        let sweep8 = crate::shard::ShardedSweep::prepare(&t, 16, 8);
        let oversubscribed = Evaluator::ShardedSim { sweep: &sweep8 };
        assert!(
            oversubscribed.score(&base, &dev).is_none(),
            "u250 has 4 channel groups; 8 instances must be rejected"
        );
    }

    #[test]
    fn cycle_sim_engines_score_identically() {
        // The event and grid cores are execution strategies, not model
        // changes: the same configuration must score to the exact same
        // cycle count under every engine, including remap phases.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 2)).collect();
        let dev = Device::alveo_u250();
        let mut cfg = ControllerConfig::default_for(t.record_bytes());
        cfg.cache.num_lines = 512;
        for max_pointers in [1usize << 4, 1 << 18] {
            cfg.remapper.max_pointers = max_pointers;
            let scores: Vec<f64> = [EngineKind::Lockstep, EngineKind::Event, EngineKind::Grid]
                .iter()
                .map(|&e| {
                    Evaluator::cycle_sim(&t, &factors, e)
                        .score(&cfg, &dev)
                        .unwrap()
                })
                .collect();
            assert_eq!(scores[0], scores[1], "event diverged at {max_pointers}");
            assert_eq!(scores[0], scores[2], "grid diverged at {max_pointers}");
        }
    }

    #[test]
    fn grid_exploration_matches_event_exploration_exactly() {
        // The one-pass cache-grid batch must not change a single score:
        // full explore() under the grid engine returns the same visited
        // points and the same winner as under the event engine.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 3)).collect();
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let grids = Grids {
            cache_line_bytes: vec![32, 64],
            cache_num_lines: vec![256, 1024],
            cache_assoc: vec![2, 4],
            dma_num: vec![1, 2],
            dma_buffers: vec![2],
            dma_buffer_bytes: vec![4096],
            dram_channels: vec![1, 2],
            dram_banks: vec![16],
            dram_row_policy: vec![RowPolicy::Open, RowPolicy::Closed],
            remap_max_pointers: vec![1 << 10, 1 << 18],
        };
        let ev_event = Evaluator::cycle_sim(&t, &factors, EngineKind::Event);
        let ev_grid = Evaluator::cycle_sim(&t, &factors, EngineKind::Grid);
        let ex_event = explore(&base, &grids, &dev, &ev_event);
        let ex_grid = explore(&base, &grids, &dev, &ev_grid);
        assert_eq!(ex_event.visited.len(), ex_grid.visited.len());
        assert_eq!(ex_event.rejected, ex_grid.rejected);
        for (a, b) in ex_event.visited.iter().zip(&ex_grid.visited) {
            assert_eq!(a.cycles, b.cycles, "scores diverged between engines");
        }
        assert_eq!(ex_event.best.cycles, ex_grid.best.cycles);
        assert_eq!(ex_event.best.cfg.cache, ex_grid.best.cfg.cache);
        assert_eq!(ex_event.best.cfg.dma, ex_grid.best.cfg.dma);
        assert_eq!(ex_event.best.cfg.dram, ex_grid.best.cfg.dram);
    }

    #[test]
    fn timing_batch_scores_match_event_engine() {
        // A DRAM/DMA module sweep under the grid engine routes through
        // the vectorized timing core; every score — including the
        // infeasible hole for a channel count the device lacks — must
        // equal the event engine's per-candidate scoring exactly.
        let t = tensor();
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 4)).collect();
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let mut cands = Vec::new();
        for &(channels, banks, policy) in &[
            (1usize, 16usize, RowPolicy::Open),
            (4, 8, RowPolicy::Open),
            (2, 16, RowPolicy::Closed),
        ] {
            for &num_dmas in &[1usize, 2] {
                let mut cfg = base.clone();
                cfg.dram.channels = channels;
                cfg.dram.banks = banks;
                cfg.dram.row_policy = policy;
                cfg.dma.num_dmas = num_dmas;
                cands.push(cfg);
            }
        }
        // u250 has 4 DRAM channels: an 8-channel candidate mid-batch
        // must come back None and keep the index mapping honest.
        let mut wide = base.clone();
        wide.dram.channels = 8;
        cands.insert(2, wide);
        let ev_grid = Evaluator::cycle_sim(&t, &factors, EngineKind::Grid);
        let ev_event = Evaluator::cycle_sim(&t, &factors, EngineKind::Event);
        let grid_scores = ev_grid.score_batch(&cands, &dev);
        let event_scores = ev_event.score_batch(&cands, &dev);
        assert_eq!(grid_scores, event_scores);
        assert!(grid_scores[2].is_none(), "8 channels must not fit u250");
        assert!(grid_scores.iter().filter(|s| s.is_some()).count() >= 6);
    }

    #[test]
    fn sharded_timing_batch_matches_event_scores() {
        let t = generate(&SynthConfig {
            dims: vec![500, 400, 300],
            nnz: 6_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 82,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep_grid = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Grid,
        );
        let sweep_event = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Event,
        );
        let ev_grid = Evaluator::ShardedSim { sweep: &sweep_grid };
        let ev_event = Evaluator::ShardedSim { sweep: &sweep_event };
        let mut cands = Vec::new();
        for &(channels, policy, buffer_bytes) in &[
            (1usize, RowPolicy::Open, 1024usize),
            (4, RowPolicy::Open, 4096),
            (2, RowPolicy::Closed, 4096),
        ] {
            let mut cfg = base.clone();
            cfg.dram.channels = channels;
            cfg.dram.row_policy = policy;
            cfg.dma.buffer_bytes = buffer_bytes;
            cands.push(cfg);
        }
        // Infeasible mid-batch: more channels than the board has.
        let mut wide = base.clone();
        wide.dram.channels = 8;
        cands.insert(1, wide);
        let grid_scores = ev_grid.score_batch(&cands, &dev);
        let event_scores = ev_event.score_batch(&cands, &dev);
        assert_eq!(grid_scores, event_scores);
        assert!(grid_scores[1].is_none());
    }

    #[test]
    fn sharded_grid_engine_matches_event_scores() {
        let t = generate(&SynthConfig {
            dims: vec![500, 400, 300],
            nnz: 6_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 81,
        });
        let dev = Device::alveo_u250();
        let base = ControllerConfig::default_for(t.record_bytes());
        let sweep_grid = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Grid,
        );
        let sweep_event = crate::shard::ShardedSweep::prepare_with_engine(
            &t,
            8,
            2,
            EngineKind::Event,
        );
        let ev_grid = Evaluator::ShardedSim { sweep: &sweep_grid };
        let ev_event = Evaluator::ShardedSim { sweep: &sweep_event };
        let mut cands = Vec::new();
        for &num_lines in &[256usize, 1024, 4096] {
            let mut cfg = base.clone();
            cfg.cache.num_lines = num_lines;
            cands.push(cfg);
        }
        // One infeasible candidate mid-batch keeps the index mapping
        // honest.
        let mut big = base.clone();
        big.cache.num_lines = 1 << 22;
        big.cache.assoc = 1;
        cands.insert(1, big);
        let grid_scores = ev_grid.score_batch(&cands, &dev);
        let event_scores = ev_event.score_batch(&cands, &dev);
        assert_eq!(grid_scores, event_scores);
        assert!(grid_scores[1].is_none());
    }

    #[test]
    fn module_order_is_respected() {
        // After exploration the best config's DMA comes from the DMA
        // sweep holding the best cache — verify the best point's cache
        // equals what a cache-only sweep would pick.
        let t = tensor();
        let profile = TensorProfile::measure(&t);
        let eval = Evaluator::Pms {
            profile: &profile,
            rank: 16,
        };
        let base = ControllerConfig::default_for(t.record_bytes());
        let dev = Device::alveo_u250();
        let cache_only = Grids {
            dma_num: vec![base.dma.num_dmas],
            dma_buffers: vec![base.dma.buffers_per_dma],
            dma_buffer_bytes: vec![base.dma.buffer_bytes],
            dram_channels: vec![base.dram.channels],
            dram_banks: vec![base.dram.banks],
            dram_row_policy: vec![base.dram.row_policy],
            remap_max_pointers: vec![base.remapper.max_pointers],
            ..Grids::default()
        };
        let ex_cache = explore(&base, &cache_only, &dev, &eval);
        let ex_full = explore(&base, &Grids::default(), &dev, &eval);
        assert_eq!(
            ex_full.best.cfg.cache, ex_cache.best.cfg.cache,
            "full search must keep the cache module's winner"
        );
    }
}
