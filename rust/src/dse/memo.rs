//! Cross-query shared-work memo (S34).
//!
//! The warm-start cache (S28, [`super::warm`]) makes one *repeat*
//! query cheap, but it is single-context and single-owner: every
//! `explore` opens its own [`WarmCache`], so N concurrent queries of
//! the same tensor still score every candidate N times.  This module
//! generalizes it into the substrate the DSE server
//! ([`crate::serve`]) shares between tenants:
//!
//! - [`ScoreCache`] is the verdict-cache interface `Evaluator::Warm`
//!   routes through — implemented by the existing [`WarmCache`]
//!   (unchanged semantics) and by [`MemoView`].
//! - [`MemoStore`] is a concurrent, sharded in-memory verdict store
//!   keyed by `(context key, encoded ControllerConfig)`.  Shard
//!   mutexes keep contention low when N worker threads score
//!   concurrently; the *existing* warm-cache on-disk format is its
//!   spill/persistence tier — one `warm_{key:016x}.bin` file per
//!   context, byte-compatible with [`WarmCache`], flushed behind the
//!   `memo.flush` failpoint.  A server restart (or a later plain
//!   `explore --warm-cache` pointed at the same directory) warm-starts
//!   from the spilled files.
//! - [`MemoView`] scopes a store to one context key: the thing a job
//!   hands to [`super::EvaluatorBuilder::score_cache`].  It keeps
//!   per-view hit/miss counters so each query reports its own memo
//!   economics while the verdicts themselves are shared store-wide.
//!
//! Scores are deterministic pure functions of the context, and the
//! store keeps their exact `f64` bits — so a query served from the
//! memo is byte-identical to a cold run, the same contract the S28
//! warm layer proves in `tests/warm_props.rs`.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::controller::ControllerConfig;
use crate::util::codec::{decode_config, encode_config, fnv1a, write_atomic};
use crate::util::fault;

use super::warm::{parse_state, serialize_state, state_file_path, Entry, State};
use super::{Point, WarmCache};

/// The verdict-cache interface behind `Evaluator::Warm`: lookup and
/// record score / feasibility verdicts, carry the Pareto frontier
/// between sessions, and flush to a persistence tier.  Implemented by
/// the single-context [`WarmCache`] and the cross-query [`MemoView`].
pub trait ScoreCache: Send + Sync + std::fmt::Debug {
    /// Cached score for `cfg`: `None` = unseen (score it and call
    /// [`record_score`](Self::record_score)), `Some(None)` = known
    /// infeasible, `Some(Some(c))` = known cycle count.
    fn lookup_score(&self, cfg: &ControllerConfig) -> Option<Option<f64>>;
    /// Record the outcome of scoring `cfg` (`None` = infeasible).
    fn record_score(&self, cfg: &ControllerConfig, score: Option<f64>);
    /// Cached feasibility verdict for `cfg`, if any.
    fn lookup_feasible(&self, cfg: &ControllerConfig) -> Option<bool>;
    /// Record a feasibility verdict.
    fn record_feasible(&self, cfg: &ControllerConfig, ok: bool);
    /// The stored Pareto frontier (beam resume seeds).
    fn frontier(&self) -> Vec<ControllerConfig>;
    /// Replace the stored Pareto frontier.
    fn set_frontier(&self, pts: &[Point]);
    /// Flush to the persistence tier; a persistent failure degrades
    /// with one warning instead of propagating.  Returns whether the
    /// flush landed (in-memory-only caches trivially return `true`).
    fn flush_or_degrade(&self) -> bool;
    /// Lookups served from the cache this session.
    fn hits(&self) -> u64;
    /// Lookups that fell through to the inner evaluator this session.
    fn misses(&self) -> u64;
}

impl ScoreCache for WarmCache {
    fn lookup_score(&self, cfg: &ControllerConfig) -> Option<Option<f64>> {
        WarmCache::lookup_score(self, cfg)
    }
    fn record_score(&self, cfg: &ControllerConfig, score: Option<f64>) {
        WarmCache::record_score(self, cfg, score)
    }
    fn lookup_feasible(&self, cfg: &ControllerConfig) -> Option<bool> {
        WarmCache::lookup_feasible(self, cfg)
    }
    fn record_feasible(&self, cfg: &ControllerConfig, ok: bool) {
        WarmCache::record_feasible(self, cfg, ok)
    }
    fn frontier(&self) -> Vec<ControllerConfig> {
        WarmCache::frontier(self)
    }
    fn set_frontier(&self, pts: &[Point]) {
        WarmCache::set_frontier(self, pts)
    }
    fn flush_or_degrade(&self) -> bool {
        WarmCache::flush_or_degrade(self)
    }
    fn hits(&self) -> u64 {
        WarmCache::hits(self)
    }
    fn misses(&self) -> u64 {
        WarmCache::misses(self)
    }
}

/// Number of independently locked shards.  Verdict lookups are
/// sub-microsecond, so a modest shard count keeps N worker threads
/// out of each other's way.
const DEFAULT_SHARDS: usize = 16;

/// Concurrent cross-query verdict store: `(context key, encoded
/// config) -> verdict`, sharded by hash across independent mutexes,
/// plus one stored frontier per context.  See the module docs.
#[derive(Debug)]
pub struct MemoStore {
    shards: Vec<Mutex<HashMap<(u64, Vec<u8>), Entry>>>,
    frontiers: Mutex<HashMap<u64, Vec<Vec<u8>>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Spill/persistence directory (the warm-cache on-disk format);
    /// `None` keeps the store purely in-memory.
    spill: Option<PathBuf>,
    /// Contexts whose spill file has already been consulted, so each
    /// is read at most once per store lifetime.
    loaded: Mutex<HashSet<u64>>,
    /// Set once an IO fault degraded persistence; the warning prints
    /// exactly once per store.
    degraded: AtomicBool,
}

impl MemoStore {
    /// A purely in-memory store.
    pub fn new() -> Arc<MemoStore> {
        Self::build(None)
    }

    /// A store spilling each context to `dir` in the warm-cache file
    /// format — byte-compatible with [`WarmCache`], so the directory
    /// can seed (and be seeded by) plain `--warm-cache` runs.  Stale
    /// `.tmp` litter from a crashed flush is swept on the way in.
    pub fn with_spill(dir: impl Into<PathBuf>) -> Arc<MemoStore> {
        let dir = dir.into();
        WarmCache::sweep_stale_tmp(&dir);
        Self::build(Some(dir))
    }

    fn build(spill: Option<PathBuf>) -> Arc<MemoStore> {
        Arc::new(MemoStore {
            shards: (0..DEFAULT_SHARDS)
                .map(|_| Mutex::new(HashMap::new()))
                .collect(),
            frontiers: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            spill,
            loaded: Mutex::new(HashSet::new()),
            degraded: AtomicBool::new(false),
        })
    }

    /// A [`ScoreCache`] view of this store scoped to context `ctx`
    /// (a [`super::KeyBuilder`] key).  The first view of a context
    /// lazily absorbs its spill file, if any.
    pub fn view(self: &Arc<Self>, ctx: u64) -> Arc<MemoView> {
        self.ensure_loaded(ctx);
        Arc::new(MemoView {
            store: Arc::clone(self),
            ctx,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        })
    }

    fn shard(&self, ctx: u64, enc: &[u8]) -> &Mutex<HashMap<(u64, Vec<u8>), Entry>> {
        let h = fnv1a(enc) ^ ctx;
        &self.shards[(h as usize) % self.shards.len()]
    }

    /// Read the spill file for `ctx` (at most once per store) and
    /// merge it *under* the in-memory state: live verdicts win over
    /// spilled ones.
    fn ensure_loaded(&self, ctx: u64) {
        let Some(dir) = &self.spill else { return };
        {
            let mut loaded = self.loaded.lock().unwrap();
            if !loaded.insert(ctx) {
                return;
            }
        }
        let path = state_file_path(dir, ctx);
        let bytes = match fault::retry_transient(3, || {
            fault::check_io(fault::WARM_LOAD)?;
            match std::fs::read(&path) {
                Ok(b) => Ok(Some(b)),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
                Err(e) => Err(e),
            }
        }) {
            Ok(Some(b)) => b,
            Ok(None) => return,
            Err(e) => {
                self.degrade(&format!("load failed: {e}"));
                return;
            }
        };
        // Corrupt or mismatched bytes are a cold context, same as
        // WarmCache::open.
        let Some(state) = parse_state(&bytes, ctx) else {
            return;
        };
        for (enc, entry) in state.entries {
            let shard = self.shard(ctx, &enc);
            shard
                .lock()
                .unwrap()
                .entry((ctx, enc))
                .or_insert(entry);
        }
        let mut frontiers = self.frontiers.lock().unwrap();
        frontiers.entry(ctx).or_insert(state.frontier);
    }

    /// Collect context `ctx`'s verdicts + frontier into one [`State`]
    /// (the spill serialization unit).
    fn collect(&self, ctx: u64) -> State {
        let mut entries = HashMap::new();
        for shard in &self.shards {
            let guard = shard.lock().unwrap();
            for ((c, enc), entry) in guard.iter() {
                if *c == ctx {
                    entries.insert(enc.clone(), *entry);
                }
            }
        }
        let frontier = self
            .frontiers
            .lock()
            .unwrap()
            .get(&ctx)
            .cloned()
            .unwrap_or_default();
        State { entries, frontier }
    }

    /// Flush context `ctx` to its spill file (atomic temp + rename,
    /// behind the `memo.flush` failpoint, transient faults retried).
    /// A no-op `Ok` for in-memory stores.
    pub fn flush_context(&self, ctx: u64) -> std::io::Result<()> {
        let Some(dir) = &self.spill else { return Ok(()) };
        let bytes = serialize_state(&self.collect(ctx), ctx);
        let path = state_file_path(dir, ctx);
        fault::retry_transient(3, || {
            fault::check_io(fault::MEMO_FLUSH)?;
            std::fs::create_dir_all(dir)?;
            write_atomic(&path, &bytes)
        })
    }

    /// [`flush_context`](Self::flush_context), but a persistent
    /// failure degrades persistence — one warning per store, the
    /// in-memory verdicts keep serving — instead of propagating.
    pub fn flush_context_or_degrade(&self, ctx: u64) -> bool {
        match self.flush_context(ctx) {
            Ok(()) => true,
            Err(e) => {
                self.degrade(&format!("flush failed: {e}"));
                false
            }
        }
    }

    fn degrade(&self, why: &str) {
        if !self.degraded.swap(true, Ordering::Relaxed) {
            eprintln!("warning: memo spill degraded to in-memory: {why}");
        }
    }

    /// True once an IO fault has degraded the spill tier.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Total verdicts held across all contexts.
    pub fn entries(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Store-wide lookups served from memory.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Store-wide lookups that missed.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    fn lookup_entry(&self, ctx: u64, cfg: &ControllerConfig) -> Option<Entry> {
        let enc = encode_config(cfg);
        let got = self.shard(ctx, &enc).lock().unwrap().get(&(ctx, enc)).copied();
        got
    }
}

/// One context's window onto a shared [`MemoStore`] — what a server
/// job plugs into [`super::EvaluatorBuilder::score_cache`].  Hit/miss
/// counters are per-view (each query reports its own memo economics);
/// verdicts live in the store and are shared by every view of the
/// same context.
#[derive(Debug)]
pub struct MemoView {
    store: Arc<MemoStore>,
    ctx: u64,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl MemoView {
    /// The context key this view is scoped to.
    pub fn ctx(&self) -> u64 {
        self.ctx
    }

    /// The store this view reads through.
    pub fn store(&self) -> &Arc<MemoStore> {
        &self.store
    }

    fn hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
        self.store.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.store.misses.fetch_add(1, Ordering::Relaxed);
    }
}

impl ScoreCache for MemoView {
    fn lookup_score(&self, cfg: &ControllerConfig) -> Option<Option<f64>> {
        match self.store.lookup_entry(self.ctx, cfg) {
            Some(Entry::Infeasible) => {
                self.hit();
                Some(None)
            }
            Some(Entry::Scored(bits)) => {
                self.hit();
                Some(Some(f64::from_bits(bits)))
            }
            // Feasible-unscored still needs the inner evaluator —
            // identical to WarmCache::lookup_score.
            Some(Entry::Feasible) | None => {
                self.miss();
                None
            }
        }
    }

    fn record_score(&self, cfg: &ControllerConfig, score: Option<f64>) {
        let entry = match score {
            None => Entry::Infeasible,
            Some(c) => Entry::Scored(c.to_bits()),
        };
        let enc = encode_config(cfg);
        let shard = self.store.shard(self.ctx, &enc);
        shard.lock().unwrap().insert((self.ctx, enc), entry);
    }

    fn lookup_feasible(&self, cfg: &ControllerConfig) -> Option<bool> {
        match self.store.lookup_entry(self.ctx, cfg) {
            Some(Entry::Infeasible) => {
                self.hit();
                Some(false)
            }
            Some(Entry::Feasible) | Some(Entry::Scored(_)) => {
                self.hit();
                Some(true)
            }
            None => {
                self.miss();
                None
            }
        }
    }

    fn record_feasible(&self, cfg: &ControllerConfig, ok: bool) {
        let enc = encode_config(cfg);
        let shard = self.store.shard(self.ctx, &enc);
        let mut guard = shard.lock().unwrap();
        let key = (self.ctx, enc);
        match guard.get(&key) {
            // Never downgrade a Scored entry to Feasible.
            Some(Entry::Scored(_)) if ok => {}
            _ => {
                let e = if ok { Entry::Feasible } else { Entry::Infeasible };
                guard.insert(key, e);
            }
        }
    }

    fn frontier(&self) -> Vec<ControllerConfig> {
        self.store
            .frontiers
            .lock()
            .unwrap()
            .get(&self.ctx)
            .map(|f| f.iter().filter_map(|e| decode_config(e)).collect())
            .unwrap_or_default()
    }

    fn set_frontier(&self, pts: &[Point]) {
        let encoded = pts.iter().map(|p| encode_config(&p.cfg)).collect();
        self.store
            .frontiers
            .lock()
            .unwrap()
            .insert(self.ctx, encoded);
    }

    fn flush_or_degrade(&self) -> bool {
        self.store.flush_context_or_degrade(self.ctx)
    }

    fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ptmc_memo_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn cfg_with_lines(num_lines: usize) -> ControllerConfig {
        let mut cfg = ControllerConfig::default_for(4);
        cfg.cache.num_lines = num_lines;
        cfg
    }

    #[test]
    fn views_of_one_context_share_verdicts_with_private_counters() {
        let store = MemoStore::new();
        let a = store.view(7);
        let b = store.view(7);
        let cfg = cfg_with_lines(256);
        assert_eq!(a.lookup_score(&cfg), None);
        a.record_score(&cfg, Some(1234.0));
        assert_eq!(b.lookup_score(&cfg), Some(Some(1234.0)), "cross-view hit");
        assert_eq!(a.hits(), 0);
        assert_eq!(a.misses(), 1);
        assert_eq!(b.hits(), 1);
        assert_eq!(b.misses(), 0);
        assert_eq!(store.hits(), 1);
        assert_eq!(store.misses(), 1);
    }

    #[test]
    fn contexts_are_isolated() {
        let store = MemoStore::new();
        let a = store.view(1);
        let b = store.view(2);
        let cfg = cfg_with_lines(512);
        a.record_score(&cfg, Some(5.0));
        a.set_frontier(&[Point {
            cfg: cfg.clone(),
            cycles: 5.0,
            bram36: 1,
            uram: 0,
        }]);
        assert_eq!(b.lookup_score(&cfg), None, "other context must miss");
        assert!(b.frontier().is_empty());
        assert_eq!(a.lookup_score(&cfg), Some(Some(5.0)));
        assert_eq!(a.frontier(), vec![cfg]);
    }

    #[test]
    fn feasible_semantics_match_warm_cache() {
        let store = MemoStore::new();
        let v = store.view(3);
        let cfg = cfg_with_lines(1024);
        assert_eq!(v.lookup_feasible(&cfg), None);
        v.record_feasible(&cfg, true);
        assert_eq!(v.lookup_feasible(&cfg), Some(true));
        assert_eq!(v.lookup_score(&cfg), None, "feasible-unscored misses");
        v.record_score(&cfg, Some(9.0));
        v.record_feasible(&cfg, true);
        assert_eq!(
            v.lookup_score(&cfg),
            Some(Some(9.0)),
            "scored entry must survive a feasible re-record"
        );
        v.record_feasible(&cfg, false);
        assert_eq!(v.lookup_score(&cfg), Some(None), "infeasible overwrites");
    }

    #[test]
    fn spill_round_trips_and_interops_with_warm_cache() {
        let dir = tmp_dir("interop");
        let ctx = 0xabcd;
        let cfg = cfg_with_lines(256);
        {
            let store = MemoStore::with_spill(&dir);
            let v = store.view(ctx);
            v.record_score(&cfg, Some(42.0));
            v.set_frontier(&[Point {
                cfg: cfg.clone(),
                cycles: 42.0,
                bram36: 1,
                uram: 0,
            }]);
            assert!(v.flush_or_degrade());
        }
        // A fresh store warm-starts from the spill file.
        let store = MemoStore::with_spill(&dir);
        let v = store.view(ctx);
        assert_eq!(v.lookup_score(&cfg), Some(Some(42.0)));
        assert_eq!(v.frontier(), vec![cfg.clone()]);
        // The spill file IS a warm-cache file: WarmCache reads it...
        let warm = WarmCache::open(&dir, ctx);
        assert_eq!(warm.len(), 1);
        assert_eq!(WarmCache::lookup_score(&warm, &cfg), Some(Some(42.0)));
        // ...and a WarmCache flush seeds a fresh MemoStore.
        let other = cfg_with_lines(4096);
        WarmCache::record_score(&warm, &other, None);
        warm.flush().unwrap();
        let seeded = MemoStore::with_spill(&dir);
        assert_eq!(seeded.view(ctx).lookup_score(&other), Some(None));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_verdicts_win_over_spilled_ones() {
        let dir = tmp_dir("livewins");
        let ctx = 9;
        let cfg = cfg_with_lines(512);
        {
            let store = MemoStore::with_spill(&dir);
            store.view(ctx).record_score(&cfg, Some(1.0));
            store.flush_context(ctx).unwrap();
        }
        let store = MemoStore::with_spill(&dir);
        let v = store.view(ctx);
        v.record_score(&cfg, Some(2.0));
        // A second view triggers no reload (loaded-once), and even the
        // merge path would keep the live value.
        let w = store.view(ctx);
        assert_eq!(w.lookup_score(&cfg), Some(Some(2.0)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_fault_degrades_once_and_keeps_serving() {
        let dir = tmp_dir("flushfault");
        let store = MemoStore::with_spill(&dir);
        let v = store.view(5);
        let cfg = cfg_with_lines(256);
        v.record_score(&cfg, Some(3.0));
        let _g = fault::arm("memo.flush@1%1:notfound").unwrap();
        assert!(!v.flush_or_degrade());
        assert!(store.is_degraded());
        assert!(!v.flush_or_degrade(), "still failing, but silent now");
        assert_eq!(
            v.lookup_score(&cfg),
            Some(Some(3.0)),
            "in-memory verdicts must keep serving after spill degradation"
        );
        drop(_g);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spill_file_is_a_cold_context() {
        let dir = tmp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(state_file_path(&dir, 4), b"garbage").unwrap();
        let store = MemoStore::with_spill(&dir);
        let v = store.view(4);
        assert_eq!(v.lookup_score(&cfg_with_lines(256)), None);
        assert!(!store.is_degraded(), "corruption is cold, not degraded");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
