//! Warm-start incremental DSE search (S28).
//!
//! Re-running `explore` on the same tensor — or on an adjacent grid —
//! re-scores every candidate from scratch even though per-candidate
//! scores are pure functions of (tensor, factors, evaluator, device,
//! configuration). This module persists those scores keyed by a
//! *context key* so repeat queries only pay for the delta of unseen
//! candidates:
//!
//! - [`Fingerprint`] folds a tensor's dims, nnz, coordinates, and
//!   value bits into a 64-bit FNV-1a hash. It is incremental
//!   ([`Fingerprint::push`]) so streaming ingestion can fold records
//!   as they arrive, and order-dependent by design: record order
//!   changes the traces the engines replay, so a reordered tensor is
//!   a different tensor for caching purposes.
//! - A context key ([`KeyBuilder`]) extends the fingerprint with
//!   everything else a score depends on: evaluator kind, rank, engine,
//!   shard worker count, device geometry, and factor matrices.
//!   Changing any input invalidates the cache by changing the key.
//! - [`WarmCache`] maps encoded [`ControllerConfig`]s
//!   (`util::codec::encode_config`) to verdicts — infeasible,
//!   feasible-unscored, or scored — plus the Pareto frontier of the
//!   last exploration, and round-trips through a checksummed
//!   zero-dependency on-disk format. A truncated or corrupt file is
//!   indistinguishable from a cold cache: `open` never fails.
//!
//! The cache is wired in as `Evaluator::Warm`, a transparent wrapper
//! constructed by `EvaluatorBuilder::warm_cache`; `explore_with`
//! additionally seeds `SearchStrategy::Beam` from the stored frontier
//! when `SearchOptions::resume` is set.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::controller::ControllerConfig;
use crate::cpd::linalg::Mat;
use crate::engine::EngineKind;
use crate::fpga::Device;
use crate::tensor::{Coord, SparseTensor};
use crate::util::codec::{decode_config, encode_config, write_atomic, ByteReader, ByteWriter, Fnv1a};
use crate::util::fault;

use super::Point;

/// Incremental tensor fingerprint: dims up front, then one
/// [`push`](Fingerprint::push) per record in storage order.
#[derive(Debug, Clone, Copy)]
pub struct Fingerprint {
    h: Fnv1a,
    records: u64,
}

impl Fingerprint {
    /// Start a fingerprint for a tensor with the given mode sizes.
    pub fn new(dims: &[usize]) -> Self {
        let mut h = Fnv1a::new();
        h.write(b"PTMC-FP-V1");
        h.write_u64(dims.len() as u64);
        for &d in dims {
            h.write_u64(d as u64);
        }
        Fingerprint { h, records: 0 }
    }

    /// Fold one record (its coordinates and value) during ingestion.
    pub fn push(&mut self, coords: &[Coord], value: f32) {
        for &c in coords {
            self.h.write_u32(c);
        }
        self.h.write_u32(value.to_bits());
        self.records += 1;
    }

    /// Records folded so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// The 64-bit fingerprint. Non-destructive: records can keep
    /// being pushed after a `finish` (used by streamed ingestion to
    /// checkpoint per window).
    pub fn finish(&self) -> u64 {
        let mut h = self.h;
        h.write_u64(self.records);
        h.finish()
    }
}

/// Fingerprint of a fully ingested tensor: record-major walk over the
/// mode-major coordinate columns, matching what streamed ingestion
/// folds record by record.
pub fn tensor_fingerprint(t: &SparseTensor) -> u64 {
    let mut fp = Fingerprint::new(t.dims());
    let n = t.n_modes();
    let cols: Vec<&[Coord]> = (0..n).map(|m| t.mode_col(m)).collect();
    let vals = t.values();
    let mut coords = vec![0 as Coord; n];
    for r in 0..t.nnz() {
        for (m, col) in cols.iter().enumerate() {
            coords[m] = col[r];
        }
        fp.push(&coords, vals[r]);
    }
    fp.finish()
}

/// Folds every score-relevant input besides the controller
/// configuration into the cache's context key. Each field is
/// length-prefixed so adjacent strings cannot alias.
#[derive(Debug, Clone, Copy)]
pub struct KeyBuilder(Fnv1a);

impl KeyBuilder {
    /// Start a key from a tensor fingerprint.
    pub fn new(tensor_fp: u64) -> Self {
        let mut h = Fnv1a::new();
        h.write(b"PTMC-WARM-KEY-V1");
        h.write_u64(tensor_fp);
        KeyBuilder(h)
    }

    fn str_field(mut self, s: &str) -> Self {
        self.0.write_u64(s.len() as u64);
        self.0.write(s.as_bytes());
        self
    }

    /// Evaluator kind label (`"pms"`, `"sim"`, `"grid"`, `"sharded"`).
    pub fn evaluator(self, label: &str) -> Self {
        self.str_field(label)
    }

    /// Replay engine driving the cycle model.
    pub fn engine(self, engine: EngineKind) -> Self {
        self.str_field(&engine.to_string())
    }

    /// CP rank the evaluator scores at.
    pub fn rank(mut self, rank: usize) -> Self {
        self.0.write_u64(rank as u64);
        self
    }

    /// Shard worker count (0 for unsharded evaluators).
    pub fn workers(mut self, workers: usize) -> Self {
        self.0.write_u64(workers as u64);
        self
    }

    /// Target device geometry.
    pub fn device(self, dev: &Device) -> Self {
        let mut kb = self.str_field(dev.name);
        kb.0.write_u64(dev.bram36 as u64);
        kb.0.write_u64(dev.uram as u64);
        kb.0.write_u64(dev.dram_channels as u64);
        kb.0.write_u64(dev.hbm_pseudo_channels as u64);
        kb.0.write_u64(dev.osram_ports as u64);
        kb
    }

    /// Factor matrices (their exact f32 bits — cycle-sim traces
    /// depend on them).
    pub fn factors(mut self, factors: &[Mat]) -> Self {
        self.0.write_u64(factors.len() as u64);
        for m in factors {
            self.0.write_u64(m.rows() as u64);
            self.0.write_u64(m.cols() as u64);
            for &v in m.data() {
                self.0.write_u32(v.to_bits());
            }
        }
        self
    }

    /// The finished 64-bit context key.
    pub fn finish(self) -> u64 {
        self.0.finish()
    }
}

/// Cached verdict for one configuration.  Shared with the cross-query
/// memo store ([`crate::dse::memo`]), which uses this same entry model
/// and on-disk format as its spill tier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum Entry {
    /// Fails `Evaluator::feasible` in this context.
    Infeasible,
    /// Passed feasibility, score not yet computed.
    Feasible,
    /// Scored; payload is the `f64` cycle count's bit pattern.
    Scored(u64),
}

const TAG_INFEASIBLE: u8 = 0;
const TAG_FEASIBLE: u8 = 1;
const TAG_SCORED: u8 = 2;

/// One context's verdicts + frontier — the unit both [`WarmCache`]
/// and the memo store's spill tier serialize.
#[derive(Debug, Default)]
pub(crate) struct State {
    pub(crate) entries: HashMap<Vec<u8>, Entry>,
    pub(crate) frontier: Vec<Vec<u8>>,
}

/// Persistent score cache for one (tensor, evaluator, device) context.
///
/// Thread-safe: lookups and recordings take an internal lock, and the
/// hit/miss counters are atomics so the parallel batch paths can
/// share one cache.
#[derive(Debug)]
pub struct WarmCache {
    dir: PathBuf,
    key: u64,
    state: Mutex<State>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Set once an IO fault degraded the cache to cold (failed load or
    /// persistent flush failure); the degradation warning prints
    /// exactly once per run.
    degraded: AtomicBool,
}

const MAGIC: &[u8; 8] = b"PTMCWARM";
const VERSION: u32 = 1;

impl WarmCache {
    /// Open (or cold-start) the cache for `key` under `dir`. Never
    /// fails: a missing, truncated, corrupt, or mismatched file is
    /// treated as an empty cache, and an IO fault degrades to cold
    /// with a single warning.  Stale `.tmp` litter from a flush that
    /// died mid-write is swept on the way in.
    pub fn open(dir: impl Into<PathBuf>, key: u64) -> WarmCache {
        let dir = dir.into();
        Self::sweep_stale_tmp(&dir);
        let mut degraded = false;
        let state = match fault::retry_transient(3, || {
            fault::check_io(fault::WARM_LOAD)?;
            match std::fs::read(Self::file_path(&dir, key)) {
                Ok(bytes) => Ok(Some(bytes)),
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
                Err(e) => Err(e),
            }
        }) {
            Ok(bytes) => bytes
                .and_then(|b| Self::parse(&b, key))
                .unwrap_or_default(),
            Err(e) => {
                eprintln!("warning: warm cache degraded to cold: load failed: {e}");
                degraded = true;
                State::default()
            }
        };
        WarmCache {
            dir,
            key,
            state: Mutex::new(state),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            degraded: AtomicBool::new(degraded),
        }
    }

    /// Remove `warm_*.tmp` files a crashed or fault-injected flush
    /// left behind (the atomic temp+rename's litter — S31 satellite).
    pub(crate) fn sweep_stale_tmp(dir: &Path) {
        if let Ok(rd) = std::fs::read_dir(dir) {
            for entry in rd.flatten() {
                let name = entry.file_name();
                let name = name.to_string_lossy();
                if name.starts_with("warm_") && name.ends_with(".tmp") {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
    }

    /// True once an IO fault has degraded this cache to cold.
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    fn file_path(dir: &Path, key: u64) -> PathBuf {
        state_file_path(dir, key)
    }

    /// Path of this cache's backing file.
    pub fn path(&self) -> PathBuf {
        Self::file_path(&self.dir, self.key)
    }

    /// The context key this cache was opened with.
    pub fn key(&self) -> u64 {
        self.key
    }

    /// Number of cached configuration verdicts.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().entries.len()
    }

    /// True when no verdicts are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache this session.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that fell through to the inner evaluator this session.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Cached score for `cfg`: `None` = unseen (score it and call
    /// [`record_score`](Self::record_score)), `Some(None)` = known
    /// infeasible, `Some(Some(c))` = known cycle count.
    pub fn lookup_score(&self, cfg: &ControllerConfig) -> Option<Option<f64>> {
        let enc = encode_config(cfg);
        let got = self.state.lock().unwrap().entries.get(&enc).copied();
        match got {
            Some(Entry::Infeasible) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(None)
            }
            Some(Entry::Scored(bits)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Some(f64::from_bits(bits)))
            }
            // Feasible-unscored still needs the inner evaluator.
            Some(Entry::Feasible) | None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record the outcome of scoring `cfg` (`None` = infeasible).
    pub fn record_score(&self, cfg: &ControllerConfig, score: Option<f64>) {
        let entry = match score {
            None => Entry::Infeasible,
            Some(c) => Entry::Scored(c.to_bits()),
        };
        let mut st = self.state.lock().unwrap();
        st.entries.insert(encode_config(cfg), entry);
    }

    /// Cached feasibility verdict for `cfg`, if any.
    pub fn lookup_feasible(&self, cfg: &ControllerConfig) -> Option<bool> {
        let enc = encode_config(cfg);
        let got = self.state.lock().unwrap().entries.get(&enc).copied();
        match got {
            Some(Entry::Infeasible) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(false)
            }
            Some(Entry::Feasible) | Some(Entry::Scored(_)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(true)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a feasibility verdict. Never downgrades a `Scored`
    /// entry to `Feasible`.
    pub fn record_feasible(&self, cfg: &ControllerConfig, ok: bool) {
        let mut st = self.state.lock().unwrap();
        let enc = encode_config(cfg);
        match st.entries.get(&enc) {
            Some(Entry::Scored(_)) if ok => {}
            _ => {
                let e = if ok { Entry::Feasible } else { Entry::Infeasible };
                st.entries.insert(enc, e);
            }
        }
    }

    /// Replace the stored Pareto frontier with the configurations of
    /// `pts` (beam resume seeds for the next session).
    pub fn set_frontier(&self, pts: &[Point]) {
        let mut st = self.state.lock().unwrap();
        st.frontier = pts.iter().map(|p| encode_config(&p.cfg)).collect();
    }

    /// Decode the stored frontier, skipping entries that no longer
    /// decode (future-proofing against codec changes).
    pub fn frontier(&self) -> Vec<ControllerConfig> {
        let st = self.state.lock().unwrap();
        st.frontier.iter().filter_map(|e| decode_config(e)).collect()
    }

    /// Serialize the cache to its backing file (temp file + rename so
    /// a crash never leaves a half-written cache behind; the temp file
    /// is removed on failure).  Transient IO faults are retried with
    /// backoff before the error propagates.
    pub fn flush(&self) -> std::io::Result<()> {
        let bytes = self.serialize();
        fault::retry_transient(3, || {
            fault::check_io(fault::WARM_FLUSH)?;
            std::fs::create_dir_all(&self.dir)?;
            write_atomic(&self.path(), &bytes)
        })
    }

    /// [`flush`](Self::flush), but a persistent failure degrades the
    /// cache to cold — one warning per run, search continues — instead
    /// of propagating.  Returns whether the flush landed.
    pub fn flush_or_degrade(&self) -> bool {
        match self.flush() {
            Ok(()) => true,
            Err(e) => {
                if !self.degraded.swap(true, Ordering::Relaxed) {
                    eprintln!("warning: warm cache degraded to cold: flush failed: {e}");
                }
                false
            }
        }
    }

    fn serialize(&self) -> Vec<u8> {
        serialize_state(&self.state.lock().unwrap(), self.key)
    }

    fn parse(bytes: &[u8], key: u64) -> Option<State> {
        parse_state(bytes, key)
    }
}

/// Backing-file name for a context key — shared by [`WarmCache`] and
/// the memo store's spill tier, so the two read each other's files.
pub(crate) fn state_file_path(dir: &Path, key: u64) -> PathBuf {
    dir.join(format!("warm_{key:016x}.bin"))
}

/// Serialize one context's [`State`] into the checksummed on-disk
/// format.  Deterministic: HashMap order never leaks into the bytes.
pub(crate) fn serialize_state(st: &State, key: u64) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.bytes(MAGIC);
    w.u32(VERSION);
    w.u64(key);
    w.u64(st.entries.len() as u64);
    // Deterministic file bytes regardless of HashMap order.
    let mut keys: Vec<&Vec<u8>> = st.entries.keys().collect();
    keys.sort();
    for enc in keys {
        w.u32(enc.len() as u32);
        w.bytes(enc);
        match st.entries[enc] {
            Entry::Infeasible => {
                w.u8(TAG_INFEASIBLE);
                w.u64(0);
            }
            Entry::Feasible => {
                w.u8(TAG_FEASIBLE);
                w.u64(0);
            }
            Entry::Scored(bits) => {
                w.u8(TAG_SCORED);
                w.u64(bits);
            }
        }
    }
    w.u64(st.frontier.len() as u64);
    for enc in st.frontier.iter() {
        w.u32(enc.len() as u32);
        w.bytes(enc);
    }
    let sum = crate::util::fnv1a(w.as_slice());
    w.u64(sum);
    w.into_bytes()
}

/// Parse [`serialize_state`] output.  `None` on truncation, checksum
/// mismatch, version skew, or a key that belongs to another context.
pub(crate) fn parse_state(bytes: &[u8], key: u64) -> Option<State> {
    if bytes.len() < 8 {
        return None;
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let mut sum_r = ByteReader::new(tail);
    if sum_r.u64()? != crate::util::fnv1a(body) {
        return None;
    }
    let mut r = ByteReader::new(body);
    if r.take(8)? != MAGIC {
        return None;
    }
    if r.u32()? != VERSION || r.u64()? != key {
        return None;
    }
    let n_entries = r.usize()?;
    let mut entries = HashMap::with_capacity(n_entries);
    for _ in 0..n_entries {
        let len = r.u32()? as usize;
        let enc = r.take(len)?.to_vec();
        let tag = r.u8()?;
        let payload = r.u64()?;
        let entry = match tag {
            TAG_INFEASIBLE => Entry::Infeasible,
            TAG_FEASIBLE => Entry::Feasible,
            TAG_SCORED => Entry::Scored(payload),
            _ => return None,
        };
        entries.insert(enc, entry);
    }
    let n_frontier = r.usize()?;
    let mut frontier = Vec::with_capacity(n_frontier);
    for _ in 0..n_frontier {
        let len = r.u32()? as usize;
        frontier.push(r.take(len)?.to_vec());
    }
    if !r.is_empty() {
        return None;
    }
    Some(State { entries, frontier })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::MemTechConfig;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ptmc_warm_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn cfg_with_lines(num_lines: usize) -> ControllerConfig {
        let mut cfg = ControllerConfig::default_for(4);
        cfg.cache.num_lines = num_lines;
        cfg
    }

    #[test]
    fn fingerprint_is_order_and_value_sensitive() {
        let mut a = Fingerprint::new(&[8, 8, 8]);
        a.push(&[1, 2, 3], 1.0);
        a.push(&[4, 5, 6], 2.0);
        let mut b = Fingerprint::new(&[8, 8, 8]);
        b.push(&[4, 5, 6], 2.0);
        b.push(&[1, 2, 3], 1.0);
        assert_ne!(a.finish(), b.finish(), "record order must matter");

        let mut c = Fingerprint::new(&[8, 8, 8]);
        c.push(&[1, 2, 3], 1.0);
        c.push(&[4, 5, 6], 2.5);
        assert_ne!(a.finish(), c.finish(), "values must matter");

        let mut d = Fingerprint::new(&[8, 8, 16]);
        d.push(&[1, 2, 3], 1.0);
        d.push(&[4, 5, 6], 2.0);
        assert_ne!(a.finish(), d.finish(), "dims must matter");

        let mut e = Fingerprint::new(&[8, 8, 8]);
        e.push(&[1, 2, 3], 1.0);
        e.push(&[4, 5, 6], 2.0);
        assert_eq!(a.finish(), e.finish(), "same inputs, same fingerprint");
    }

    #[test]
    fn key_builder_separates_contexts() {
        let base = KeyBuilder::new(42).evaluator("grid").rank(16).finish();
        let other_rank = KeyBuilder::new(42).evaluator("grid").rank(8).finish();
        let other_eval = KeyBuilder::new(42).evaluator("pms").rank(16).finish();
        let other_fp = KeyBuilder::new(43).evaluator("grid").rank(16).finish();
        assert_ne!(base, other_rank);
        assert_ne!(base, other_eval);
        assert_ne!(base, other_fp);
        let dev = Device::alveo_u250();
        let with_dev = KeyBuilder::new(42).device(&dev).finish();
        assert_ne!(with_dev, KeyBuilder::new(42).finish());
    }

    #[test]
    fn cache_round_trips_through_disk() {
        let dir = tmp_dir("roundtrip");
        let cache = WarmCache::open(&dir, 7);
        let a = cfg_with_lines(256);
        let b = cfg_with_lines(1024);
        let mut c = cfg_with_lines(4096);
        c.mem = MemTechConfig::default_ddr4();
        cache.record_score(&a, Some(1234.0));
        cache.record_score(&b, None);
        cache.record_feasible(&c, true);
        cache.set_frontier(&[Point {
            cfg: a.clone(),
            cycles: 1234.0,
            bram36: 1,
            uram: 0,
        }]);
        cache.flush().unwrap();

        let back = WarmCache::open(&dir, 7);
        assert_eq!(back.len(), 3);
        assert_eq!(back.lookup_score(&a), Some(Some(1234.0)));
        assert_eq!(back.lookup_score(&b), Some(None));
        assert_eq!(back.lookup_feasible(&c), Some(true));
        assert_eq!(back.lookup_score(&c), None, "feasible-unscored misses");
        let front = back.frontier();
        assert_eq!(front.len(), 1);
        assert_eq!(front[0], a);
        assert_eq!(back.hits(), 3);
        assert_eq!(back.misses(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_or_mismatched_files_fall_back_cold() {
        let dir = tmp_dir("corrupt");
        let cache = WarmCache::open(&dir, 9);
        cache.record_score(&cfg_with_lines(256), Some(5.0));
        cache.flush().unwrap();
        let path = cache.path();
        let good = std::fs::read(&path).unwrap();

        // Truncated file.
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(WarmCache::open(&dir, 9).is_empty());

        // Flipped byte in the body breaks the checksum.
        let mut bad = good.clone();
        bad[MAGIC.len() + 20] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(WarmCache::open(&dir, 9).is_empty());

        // Wrong key: file content is valid but belongs elsewhere.
        std::fs::write(WarmCache::open(&dir, 11).path(), &good).unwrap();
        assert!(WarmCache::open(&dir, 11).is_empty());

        // Pristine bytes still load.
        std::fs::write(&path, &good).unwrap();
        assert_eq!(WarmCache::open(&dir, 9).len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flush_is_deterministic() {
        let dir = tmp_dir("determ");
        let cache = WarmCache::open(&dir, 3);
        for lines in [256usize, 512, 1024, 2048, 4096] {
            cache.record_score(&cfg_with_lines(lines), Some(lines as f64));
        }
        cache.flush().unwrap();
        let first = std::fs::read(cache.path()).unwrap();
        let again = WarmCache::open(&dir, 3);
        again.flush().unwrap();
        let second = std::fs::read(again.path()).unwrap();
        assert_eq!(first, second, "sorted serialization is reproducible");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_tmp_litter_is_swept_on_open() {
        let dir = tmp_dir("tmpsweep");
        std::fs::create_dir_all(&dir).unwrap();
        let litter = dir.join("warm_00000000000000aa.tmp");
        std::fs::write(&litter, b"half-written flush").unwrap();
        let unrelated = dir.join("keep.txt");
        std::fs::write(&unrelated, b"not ours").unwrap();
        let _cache = WarmCache::open(&dir, 5);
        assert!(!litter.exists(), "stale warm tmp must be swept");
        assert!(unrelated.exists(), "unrelated files must survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_flush_leaves_no_tmp_and_degrades_once() {
        let dir = tmp_dir("flushfault");
        let cache = WarmCache::open(&dir, 13);
        cache.record_score(&cfg_with_lines(256), Some(1.0));
        // Non-transient kind: retries must not mask it.
        let _g = fault::arm("warm.flush@1%1:notfound").unwrap();
        assert!(!cache.flush_or_degrade());
        assert!(cache.is_degraded());
        assert!(!cache.flush_or_degrade(), "still failing, but silent now");
        drop(_g);
        let tmps: Vec<_> = std::fs::read_dir(&dir)
            .map(|rd| {
                rd.flatten()
                    .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
                    .collect()
            })
            .unwrap_or_default();
        assert!(tmps.is_empty(), "failed flush must not leak .tmp files");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn transient_flush_fault_is_retried_to_identical_bytes() {
        let dir = tmp_dir("flushretry");
        let cache = WarmCache::open(&dir, 21);
        for lines in [256usize, 512, 1024] {
            cache.record_score(&cfg_with_lines(lines), Some(lines as f64));
        }
        cache.flush().unwrap();
        let oracle = std::fs::read(cache.path()).unwrap();
        std::fs::remove_file(cache.path()).unwrap();
        {
            let _g = fault::arm("warm.flush@1:interrupted").unwrap();
            cache.flush().unwrap();
        }
        assert!(!cache.is_degraded());
        let retried = std::fs::read(cache.path()).unwrap();
        assert_eq!(oracle, retried, "retried flush must be byte-identical");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_fault_degrades_to_cold_not_an_error() {
        let dir = tmp_dir("loadfault");
        let cache = WarmCache::open(&dir, 33);
        cache.record_score(&cfg_with_lines(512), Some(2.0));
        cache.flush().unwrap();
        let degraded = {
            let _g = fault::arm("warm.load@1%1:permissiondenied").unwrap();
            WarmCache::open(&dir, 33)
        };
        assert!(degraded.is_empty(), "load fault must start cold");
        assert!(degraded.is_degraded());
        // Disarmed, the same file still loads.
        assert_eq!(WarmCache::open(&dir, 33).len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
