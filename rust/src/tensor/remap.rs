//! Output-direction tensor remapping (paper §3, Algorithm 5 lines 3–6).
//!
//! Between modes, Approach 1 needs the COO list re-ordered so all
//! non-zeros with the same *next* output coordinate are consecutive.  The
//! paper does this with a table of per-coordinate memory address
//! pointers: each incoming element is stored at the next free slot of its
//! output coordinate's partition.  That is exactly a counting sort:
//! count pass -> prefix sum (the initial pointer table) -> scatter pass
//! (each write bumps its pointer).
//!
//! This module performs the *data* movement and reports the *pointer
//! traffic* the memory controller will be charged for (DESIGN.md D1): if
//! the pointer table exceeds the remapper's on-chip budget, every element
//! additionally costs a pointer load + store in external memory — the
//! §3 "Excessive memory address pointers" overhead.

use super::{SortOrder, SparseTensor};

/// Accounting of one remap pass, consumed by the trace generator / PMS.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RemapReport {
    /// Elements moved (= |T|): each is one streaming load + one
    /// element-wise store (paper: +2|T| accesses per mode).
    pub elements: usize,
    /// Pointer-table entries required (= I_out used range).
    pub pointers: usize,
    /// Entries that fit on-chip given the budget passed to [`remap`].
    pub pointers_on_chip: usize,
    /// Pointer loads+stores that spilled to external memory (0 when the
    /// table fits; 2 per element on the spilled fraction otherwise).
    pub spilled_pointer_accesses: usize,
}

impl RemapReport {
    /// Extra external-memory accesses caused by the remap, in *element
    /// records* for tensor data plus pointer words (paper counts 2|T|
    /// when the table fits on-chip).
    pub fn extra_accesses(&self) -> usize {
        2 * self.elements + self.spilled_pointer_accesses
    }
}

/// Remap `t` into `mode`-direction order (stable), returning traffic
/// accounting.  `on_chip_pointers` is the remapper's address-pointer
/// budget (§5.2.1 parameter 3): coordinates beyond it have their cursors
/// spilled to external memory.
///
/// On-chip cursors are allocated to the *densest* coordinates first —
/// the paper's ideal layout goal (1): maximize the fraction of pointer
/// traffic served on-chip.
pub fn remap(t: &mut SparseTensor, mode: usize, on_chip_pointers: usize) -> RemapReport {
    let nnz = t.nnz();
    let mode_len = t.dims()[mode];

    // Pass 1 (count): one streaming read of the mode column.
    let mut counts = vec![0usize; mode_len];
    for &c in t.mode_col(mode) {
        counts[c as usize] += 1;
    }
    let used: usize = counts.iter().filter(|&&c| c > 0).count();

    // Decide which coordinates get on-chip cursors: densest first.
    let spilled_fraction_elems: usize = if used > on_chip_pointers {
        let mut order: Vec<usize> = (0..mode_len).filter(|&c| counts[c] > 0).collect();
        order.sort_unstable_by(|&a, &b| counts[b].cmp(&counts[a]));
        order[on_chip_pointers..]
            .iter()
            .map(|&c| counts[c])
            .sum()
    } else {
        0
    };

    // Prefix sum -> initial pointer table.
    let mut cursors = vec![0usize; mode_len + 1];
    for c in 0..mode_len {
        cursors[c + 1] = cursors[c] + counts[c];
    }

    // Pass 2 (scatter): stream elements, store each at its cursor.
    let perm_inv = {
        let col = t.mode_col(mode);
        let mut dst = vec![0usize; nnz];
        let mut cur = cursors.clone();
        for (z, &c) in col.iter().enumerate() {
            dst[z] = cur[c as usize];
            cur[c as usize] += 1;
        }
        dst
    };
    // Convert destination map to gather permutation and apply.
    let mut perm = vec![0usize; nnz];
    for (z, &d) in perm_inv.iter().enumerate() {
        perm[d] = z;
    }
    t.apply_permutation(&perm);
    t.set_order(SortOrder::ByMode(mode));

    RemapReport {
        elements: nnz,
        pointers: used,
        pointers_on_chip: used.min(on_chip_pointers),
        spilled_pointer_accesses: 2 * spilled_fraction_elems,
    }
}

impl SparseTensor {
    /// Internal: remap() established this order by construction.
    pub(crate) fn set_order(&mut self, order: SortOrder) {
        // Debug-check the invariant before trusting it.
        if let SortOrder::ByMode(m) = order {
            debug_assert!(
                self.mode_col(m).windows(2).all(|w| w[0] <= w[1]),
                "set_order(ByMode({m})) on unsorted column"
            );
        }
        *self.order_mut() = order;
    }
}

/// The paper's closed-form communication-overhead ratio for one remap
/// (§3): `2|T| / (|T| + (N-1)|T|R + I_out R)`.
pub fn overhead_ratio(nnz: usize, n_modes: usize, rank: usize, i_out: usize) -> f64 {
    let t = nnz as f64;
    2.0 * t / (t + (n_modes as f64 - 1.0) * t * rank as f64 + (i_out * rank) as f64)
}

/// The paper's approximation `2 / (1 + (N-1) R)` of [`overhead_ratio`].
pub fn overhead_ratio_approx(n_modes: usize, rank: usize) -> f64 {
    2.0 / (1.0 + (n_modes as f64 - 1.0) * rank as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::testkit::forall;

    fn sample() -> SparseTensor {
        generate(&SynthConfig {
            dims: vec![60, 50, 40],
            nnz: 2_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 13,
        })
    }

    #[test]
    fn remap_sorts_by_requested_mode() {
        let mut t = sample();
        for mode in 0..3 {
            let r = remap(&mut t, mode, usize::MAX);
            assert_eq!(t.order(), SortOrder::ByMode(mode));
            assert!(t.mode_col(mode).windows(2).all(|w| w[0] <= w[1]));
            assert_eq!(r.elements, 2_000);
            assert_eq!(r.spilled_pointer_accesses, 0);
        }
    }

    #[test]
    fn remap_preserves_tensor_contents() {
        forall("remap_preserves_contents", 24, |rng| {
            let dims = vec![rng.range(2, 30), rng.range(2, 30), rng.range(2, 30)];
            let nnz = rng.range(1, 300).min(dims.iter().product::<usize>() / 2).max(1);
            let mut t = generate(&SynthConfig {
                dims,
                nnz,
                profile: Profile::Uniform,
                seed: rng.next_u64(),
            });
            let before = t.to_dense();
            let mode = rng.range(0, 3);
            remap(&mut t, mode, rng.range(1, 64));
            assert_eq!(t.to_dense(), before, "remap changed tensor contents");
        });
    }

    #[test]
    fn remap_is_stable_within_fibers() {
        // Two nnz with same mode-0 coord must keep relative order.
        let mut t = SparseTensor::new(
            vec![2, 3, 2],
            &[
                (vec![1, 0, 0], 1.0),
                (vec![0, 1, 1], 2.0),
                (vec![1, 2, 0], 3.0),
                (vec![0, 0, 0], 4.0),
            ],
        );
        remap(&mut t, 0, usize::MAX);
        assert_eq!(t.values(), &[2.0, 4.0, 1.0, 3.0]);
    }

    #[test]
    fn pointer_spill_accounting() {
        let mut t = sample();
        let full = remap(&mut t, 0, usize::MAX);
        assert_eq!(full.spilled_pointer_accesses, 0);
        assert_eq!(full.pointers_on_chip, full.pointers);

        // Re-shuffle and remap with a tiny budget: spills must appear and
        // be bounded by 2|T|.
        let mut t2 = sample();
        let tiny = remap(&mut t2, 0, 4);
        assert!(tiny.spilled_pointer_accesses > 0);
        assert!(tiny.spilled_pointer_accesses <= 2 * tiny.elements);
        assert_eq!(tiny.pointers_on_chip, 4);
        // Densest-first allocation: spilled elements < uniform share.
        let uniform_share =
            2 * tiny.elements * (tiny.pointers - 4) / tiny.pointers;
        assert!(
            tiny.spilled_pointer_accesses <= uniform_share,
            "densest-first should beat uniform: {} > {}",
            tiny.spilled_pointer_accesses,
            uniform_share
        );
    }

    #[test]
    fn extra_accesses_formula() {
        let r = RemapReport {
            elements: 100,
            pointers: 10,
            pointers_on_chip: 10,
            spilled_pointer_accesses: 6,
        };
        assert_eq!(r.extra_accesses(), 206);
    }

    #[test]
    fn overhead_matches_paper_claim_under_6_percent() {
        // Paper: for N = 3..5 and R = 16..64 overhead < 6 %.
        for n in 3..=5 {
            for &r in &[16usize, 32, 64] {
                let approx = overhead_ratio_approx(n, r);
                assert!(approx < 0.061, "N={n} R={r}: {approx}");
                // Exact ratio is smaller still (denominator has +I_out R).
                let exact = overhead_ratio(100_000, n, r, 10_000);
                assert!(exact < approx, "exact {exact} >= approx {approx}");
            }
        }
    }

    #[test]
    fn overhead_approx_close_to_exact_for_large_tensors() {
        let exact = overhead_ratio(1_000_000, 3, 16, 1_000);
        let approx = overhead_ratio_approx(3, 16);
        assert!((exact - approx).abs() / approx < 0.01);
    }
}
