//! FROSTT `.tns` reader / writer (Table 2's benchmark repository format).
//!
//! The format is whitespace-separated text: one non-zero per line,
//! `c_0 c_1 ... c_{N-1} value`, with **1-based** coordinates; `#` starts
//! a comment.  Mode lengths are not declared in the file — they are the
//! per-mode coordinate maxima unless the caller overrides them.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use super::{Coord, SparseTensor};
use crate::util::fault;

/// Errors from `.tns` parsing.
#[derive(Debug)]
pub enum TnsError {
    Io(std::io::Error),
    /// (line number, message)
    Parse(usize, String),
    Empty,
}

impl std::fmt::Display for TnsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TnsError::Io(e) => write!(f, "tns io error: {e}"),
            TnsError::Parse(line, msg) => write!(f, "tns parse error at line {line}: {msg}"),
            TnsError::Empty => write!(f, "tns file has no non-zero entries"),
        }
    }
}

impl std::error::Error for TnsError {}

impl From<std::io::Error> for TnsError {
    fn from(e: std::io::Error) -> Self {
        TnsError::Io(e)
    }
}

/// Default block granularity for streamed ingestion (nonzeros per
/// block): 1M entries ≈ 16 MB of COO columns for a 3-mode tensor —
/// large enough to amortize per-block overheads, small enough that a
/// pipeline holding two blocks stays far under any sane budget.
pub const DEFAULT_BLOCK_NNZ: usize = 1 << 20;

/// One bounded block of parsed COO entries (column-major, 0-based).
#[derive(Debug, Clone)]
pub struct TnsBlock {
    /// Per-mode coordinate columns, each `nnz()` long.
    pub cols: Vec<Vec<Coord>>,
    pub vals: Vec<f32>,
}

impl TnsBlock {
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }
}

/// Incremental `.tns` parser yielding fixed-size COO blocks, never a
/// whole-file `Vec` — the out-of-core ingestion primitive.  Parse
/// semantics (comment stripping, blank-line tolerance, 1-based
/// coordinates, arity locking to the first data line, exact `Parse`
/// line numbers) are identical to [`read_tns`], which is itself built
/// on this reader, so the two cannot drift.
///
/// Peak memory is one block (`block_nnz` entries) plus the per-mode
/// maxima — independent of file size.
pub struct TnsBlockReader<R: BufRead> {
    reader: R,
    block_nnz: usize,
    lineno: usize,
    n_modes: Option<usize>,
    maxima: Vec<Coord>,
    total_nnz: usize,
    /// Reused line buffer: one allocation for the whole file.
    line: String,
    eof: bool,
}

impl<R: BufRead> TnsBlockReader<R> {
    pub fn new(reader: R, block_nnz: usize) -> Self {
        assert!(block_nnz > 0, "block_nnz must be positive");
        TnsBlockReader {
            reader,
            block_nnz,
            lineno: 0,
            n_modes: None,
            maxima: Vec::new(),
            total_nnz: 0,
            line: String::new(),
            eof: false,
        }
    }

    /// Arity, once the first data line has fixed it.
    pub fn n_modes(&self) -> Option<usize> {
        self.n_modes
    }

    /// Nonzeros yielded so far.
    pub fn total_nnz(&self) -> usize {
        self.total_nnz
    }

    /// Mode lengths observed so far (per-mode coordinate maxima + 1).
    /// Final only after the last block has been consumed — the format
    /// stores no dims, so they cannot be known earlier.
    pub fn dims(&self) -> Vec<usize> {
        self.maxima.iter().map(|&m| m as usize + 1).collect()
    }

    /// Parse and append one line; `Ok(true)` if it carried a data entry.
    fn parse_line(
        &mut self,
        cols: &mut Vec<Vec<Coord>>,
        vals: &mut Vec<f32>,
    ) -> Result<bool, TnsError> {
        let lineno = self.lineno;
        let data = match self.line.find('#') {
            Some(pos) => &self.line[..pos],
            None => &self.line[..],
        };
        let fields: Vec<&str> = data.split_whitespace().collect();
        if fields.is_empty() {
            return Ok(false);
        }
        if fields.len() < 3 {
            return Err(TnsError::Parse(
                lineno,
                format!("expected >= 3 fields, got {}", fields.len()),
            ));
        }
        let arity = fields.len() - 1;
        match self.n_modes {
            None => {
                self.n_modes = Some(arity);
                self.maxima = vec![0; arity];
            }
            Some(n) if n != arity => {
                return Err(TnsError::Parse(
                    lineno,
                    format!("arity {arity} != first line's {n}"),
                ));
            }
            _ => {}
        }
        if cols.len() != arity {
            cols.resize_with(arity, Vec::new);
        }
        for (m, f) in fields[..arity].iter().enumerate() {
            let c: u64 = f
                .parse()
                .map_err(|e| TnsError::Parse(lineno, format!("bad coordinate {f:?}: {e}")))?;
            if c == 0 {
                return Err(TnsError::Parse(
                    lineno,
                    "coordinates are 1-based; got 0".into(),
                ));
            }
            let c0 = (c - 1) as Coord;
            self.maxima[m] = self.maxima[m].max(c0);
            cols[m].push(c0);
        }
        let v: f32 = fields[arity]
            .parse()
            .map_err(|e| TnsError::Parse(lineno, format!("bad value {:?}: {e}", fields[arity])))?;
        vals.push(v);
        Ok(true)
    }

    /// Parse the next block of at most `block_nnz` entries; `Ok(None)`
    /// at end of input.  Comments and blank lines may straddle block
    /// boundaries freely — they consume input lines, not block slots.
    pub fn next_block(&mut self) -> Result<Option<TnsBlock>, TnsError> {
        if self.eof {
            return Ok(None);
        }
        // Failpoint: one check per block keeps the per-line hot loop
        // untouched while still letting fault plans hit streamed
        // ingestion at any block boundary.
        fault::check_io(fault::FROSTT_READ_BLOCK)?;
        // Cap pre-allocation: callers may pass a huge block_nnz to mean
        // "one block"; grow on demand instead of reserving it all.
        let reserve = self.block_nnz.min(DEFAULT_BLOCK_NNZ);
        let mut cols: Vec<Vec<Coord>> = match self.n_modes {
            Some(n) => {
                let mut c = Vec::with_capacity(n);
                c.resize_with(n, || Vec::with_capacity(reserve));
                c
            }
            None => Vec::new(),
        };
        let mut vals: Vec<f32> = Vec::with_capacity(reserve);
        while vals.len() < self.block_nnz {
            self.line.clear();
            if self.reader.read_line(&mut self.line)? == 0 {
                self.eof = true;
                break;
            }
            self.lineno += 1;
            self.parse_line(&mut cols, &mut vals)?;
        }
        if vals.is_empty() {
            return Ok(None);
        }
        self.total_nnz += vals.len();
        Ok(Some(TnsBlock { cols, vals }))
    }
}

/// Open a `.tns` file as a block reader for streamed ingestion.
pub fn block_reader_file(
    path: &Path,
    block_nnz: usize,
) -> Result<TnsBlockReader<BufReader<std::fs::File>>, TnsError> {
    Ok(TnsBlockReader::new(
        BufReader::new(std::fs::File::open(path)?),
        block_nnz,
    ))
}

/// Parse a `.tns` stream.  All data lines must have the same arity.
///
/// Built on [`TnsBlockReader`] — the in-RAM tensor is the concatenation
/// of the streamed blocks, so the two paths are bit-identical by
/// construction (and pinned by `tests/streaming_props.rs`).
pub fn read_tns<R: Read>(reader: R) -> Result<SparseTensor, TnsError> {
    let mut blocks = TnsBlockReader::new(BufReader::new(reader), DEFAULT_BLOCK_NNZ);
    let mut cols: Vec<Vec<Coord>> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    while let Some(b) = blocks.next_block()? {
        if cols.is_empty() {
            cols = b.cols;
            vals = b.vals;
        } else {
            for (col, mut bc) in cols.iter_mut().zip(b.cols) {
                col.append(&mut bc);
            }
            vals.extend(b.vals);
        }
    }
    if vals.is_empty() {
        return Err(TnsError::Empty);
    }
    Ok(SparseTensor::from_columns(
        blocks.dims(),
        cols,
        vals,
        super::SortOrder::Unsorted,
    ))
}

/// Read a `.tns` file from disk.
pub fn read_tns_file(path: &Path) -> Result<SparseTensor, TnsError> {
    read_tns(std::fs::File::open(path)?)
}

/// Write a tensor in `.tns` format (1-based coordinates).
pub fn write_tns<W: Write>(t: &SparseTensor, mut w: W) -> std::io::Result<()> {
    for z in 0..t.nnz() {
        for m in 0..t.n_modes() {
            write!(w, "{} ", t.mode_col(m)[z] as u64 + 1)?;
        }
        writeln!(w, "{}", t.values()[z])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file_with_comments_and_blanks() {
        let text = "# a comment\n\n1 1 1 1.5\n2 3 1 -2.0 # trailing\n2 1 4 0.25\n";
        let t = read_tns(text.as_bytes()).unwrap();
        assert_eq!(t.n_modes(), 3);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.dims(), &[2, 3, 4]);
        assert_eq!(t.mode_col(0), &[0, 1, 1]);
        assert_eq!(t.values(), &[1.5, -2.0, 0.25]);
    }

    #[test]
    fn rejects_zero_based_coordinates() {
        let err = read_tns("0 1 1 1.0\n".as_bytes()).unwrap_err();
        assert!(matches!(err, TnsError::Parse(1, _)), "{err}");
    }

    #[test]
    fn rejects_mixed_arity() {
        let err = read_tns("1 1 1 1.0\n1 1 1 1 1.0\n".as_bytes()).unwrap_err();
        assert!(matches!(err, TnsError::Parse(2, _)), "{err}");
    }

    #[test]
    fn rejects_empty_file() {
        assert!(matches!(
            read_tns("# nothing\n".as_bytes()).unwrap_err(),
            TnsError::Empty
        ));
    }

    #[test]
    fn rejects_garbage_value() {
        let err = read_tns("1 1 1 abc\n".as_bytes()).unwrap_err();
        assert!(matches!(err, TnsError::Parse(1, _)), "{err}");
    }

    #[test]
    fn roundtrip_write_read() {
        let t = SparseTensor::new(
            vec![3, 2, 5],
            &[(vec![0, 1, 4], 1.25), (vec![2, 0, 0], -3.5)],
        );
        let mut buf = Vec::new();
        write_tns(&t, &mut buf).unwrap();
        let t2 = read_tns(&buf[..]).unwrap();
        assert_eq!(t2.nnz(), t.nnz());
        // Dims shrink to coordinate maxima (write does not store dims).
        assert_eq!(t2.dims(), &[3, 2, 5]);
        assert_eq!(t2.values(), t.values());
        for m in 0..3 {
            assert_eq!(t2.mode_col(m), t.mode_col(m));
        }
    }

    #[test]
    fn block_reader_yields_bounded_blocks_that_concatenate() {
        let text = "1 1 1 1.0\n2 2 2 2.0\n# noise\n3 3 3 3.0\n\n4 4 4 4.0\n5 5 5 5.0\n";
        let mut r = TnsBlockReader::new(text.as_bytes(), 2);
        let mut sizes = Vec::new();
        let mut all_vals = Vec::new();
        while let Some(b) = r.next_block().unwrap() {
            assert!(b.nnz() <= 2, "block overflowed: {}", b.nnz());
            assert_eq!(b.cols.len(), 3);
            sizes.push(b.nnz());
            all_vals.extend(b.vals);
        }
        assert_eq!(sizes, vec![2, 2, 1], "5 entries at block_nnz=2");
        assert_eq!(all_vals, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(r.total_nnz(), 5);
        assert_eq!(r.dims(), vec![5, 5, 5]);
        assert_eq!(r.n_modes(), Some(3));
    }

    #[test]
    fn block_reader_propagates_errors_with_exact_line_numbers() {
        // The bad line sits in the second block; the line number is
        // still the physical file line.
        let text = "1 1 1 1.0\n2 2 2 2.0\n0 1 1 9.0\n";
        let mut r = TnsBlockReader::new(text.as_bytes(), 2);
        assert_eq!(r.next_block().unwrap().unwrap().nnz(), 2);
        let err = r.next_block().unwrap_err();
        assert!(matches!(err, TnsError::Parse(3, _)), "{err}");
    }

    #[test]
    fn four_mode_file() {
        let t = read_tns("1 2 3 4 9.0\n4 3 2 1 8.0\n".as_bytes()).unwrap();
        assert_eq!(t.n_modes(), 4);
        assert_eq!(t.dims(), &[4, 3, 3, 4]);
    }
}
