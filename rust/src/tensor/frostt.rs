//! FROSTT `.tns` reader / writer (Table 2's benchmark repository format).
//!
//! The format is whitespace-separated text: one non-zero per line,
//! `c_0 c_1 ... c_{N-1} value`, with **1-based** coordinates; `#` starts
//! a comment.  Mode lengths are not declared in the file — they are the
//! per-mode coordinate maxima unless the caller overrides them.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

use super::{Coord, SparseTensor};

/// Errors from `.tns` parsing.
#[derive(Debug)]
pub enum TnsError {
    Io(std::io::Error),
    /// (line number, message)
    Parse(usize, String),
    Empty,
}

impl std::fmt::Display for TnsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TnsError::Io(e) => write!(f, "tns io error: {e}"),
            TnsError::Parse(line, msg) => write!(f, "tns parse error at line {line}: {msg}"),
            TnsError::Empty => write!(f, "tns file has no non-zero entries"),
        }
    }
}

impl std::error::Error for TnsError {}

impl From<std::io::Error> for TnsError {
    fn from(e: std::io::Error) -> Self {
        TnsError::Io(e)
    }
}

/// Parse a `.tns` stream.  All data lines must have the same arity.
pub fn read_tns<R: Read>(reader: R) -> Result<SparseTensor, TnsError> {
    let reader = BufReader::new(reader);
    let mut n_modes: Option<usize> = None;
    let mut cols: Vec<Vec<Coord>> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut maxima: Vec<Coord> = Vec::new();

    for (lineno, line) in reader.lines().enumerate() {
        let lineno = lineno + 1;
        let line = line?;
        let data = match line.find('#') {
            Some(pos) => &line[..pos],
            None => &line[..],
        };
        let fields: Vec<&str> = data.split_whitespace().collect();
        if fields.is_empty() {
            continue;
        }
        if fields.len() < 3 {
            return Err(TnsError::Parse(
                lineno,
                format!("expected >= 3 fields, got {}", fields.len()),
            ));
        }
        let arity = fields.len() - 1;
        match n_modes {
            None => {
                n_modes = Some(arity);
                cols = vec![Vec::new(); arity];
                maxima = vec![0; arity];
            }
            Some(n) if n != arity => {
                return Err(TnsError::Parse(
                    lineno,
                    format!("arity {arity} != first line's {n}"),
                ));
            }
            _ => {}
        }
        for (m, f) in fields[..arity].iter().enumerate() {
            let c: u64 = f
                .parse()
                .map_err(|e| TnsError::Parse(lineno, format!("bad coordinate {f:?}: {e}")))?;
            if c == 0 {
                return Err(TnsError::Parse(
                    lineno,
                    "coordinates are 1-based; got 0".into(),
                ));
            }
            let c0 = (c - 1) as Coord;
            maxima[m] = maxima[m].max(c0);
            cols[m].push(c0);
        }
        let v: f32 = fields[arity]
            .parse()
            .map_err(|e| TnsError::Parse(lineno, format!("bad value {:?}: {e}", fields[arity])))?;
        vals.push(v);
    }

    if vals.is_empty() {
        return Err(TnsError::Empty);
    }
    let dims: Vec<usize> = maxima.iter().map(|&m| m as usize + 1).collect();
    Ok(SparseTensor::from_columns(
        dims,
        cols,
        vals,
        super::SortOrder::Unsorted,
    ))
}

/// Read a `.tns` file from disk.
pub fn read_tns_file(path: &Path) -> Result<SparseTensor, TnsError> {
    read_tns(std::fs::File::open(path)?)
}

/// Write a tensor in `.tns` format (1-based coordinates).
pub fn write_tns<W: Write>(t: &SparseTensor, mut w: W) -> std::io::Result<()> {
    for z in 0..t.nnz() {
        for m in 0..t.n_modes() {
            write!(w, "{} ", t.mode_col(m)[z] as u64 + 1)?;
        }
        writeln!(w, "{}", t.values()[z])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file_with_comments_and_blanks() {
        let text = "# a comment\n\n1 1 1 1.5\n2 3 1 -2.0 # trailing\n2 1 4 0.25\n";
        let t = read_tns(text.as_bytes()).unwrap();
        assert_eq!(t.n_modes(), 3);
        assert_eq!(t.nnz(), 3);
        assert_eq!(t.dims(), &[2, 3, 4]);
        assert_eq!(t.mode_col(0), &[0, 1, 1]);
        assert_eq!(t.values(), &[1.5, -2.0, 0.25]);
    }

    #[test]
    fn rejects_zero_based_coordinates() {
        let err = read_tns("0 1 1 1.0\n".as_bytes()).unwrap_err();
        assert!(matches!(err, TnsError::Parse(1, _)), "{err}");
    }

    #[test]
    fn rejects_mixed_arity() {
        let err = read_tns("1 1 1 1.0\n1 1 1 1 1.0\n".as_bytes()).unwrap_err();
        assert!(matches!(err, TnsError::Parse(2, _)), "{err}");
    }

    #[test]
    fn rejects_empty_file() {
        assert!(matches!(
            read_tns("# nothing\n".as_bytes()).unwrap_err(),
            TnsError::Empty
        ));
    }

    #[test]
    fn rejects_garbage_value() {
        let err = read_tns("1 1 1 abc\n".as_bytes()).unwrap_err();
        assert!(matches!(err, TnsError::Parse(1, _)), "{err}");
    }

    #[test]
    fn roundtrip_write_read() {
        let t = SparseTensor::new(
            vec![3, 2, 5],
            &[(vec![0, 1, 4], 1.25), (vec![2, 0, 0], -3.5)],
        );
        let mut buf = Vec::new();
        write_tns(&t, &mut buf).unwrap();
        let t2 = read_tns(&buf[..]).unwrap();
        assert_eq!(t2.nnz(), t.nnz());
        // Dims shrink to coordinate maxima (write does not store dims).
        assert_eq!(t2.dims(), &[3, 2, 5]);
        assert_eq!(t2.values(), t.values());
        for m in 0..3 {
            assert_eq!(t2.mode_col(m), t.mode_col(m));
        }
    }

    #[test]
    fn four_mode_file() {
        let t = read_tns("1 2 3 4 9.0\n4 3 2 1 8.0\n".as_bytes()).unwrap();
        assert_eq!(t.n_modes(), 4);
        assert_eq!(t.dims(), &[4, 3, 3, 4]);
    }
}
