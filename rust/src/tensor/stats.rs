//! Access-pattern statistics (S1): the tensor-side quantities the paper's
//! analysis (§3, Table 1/2) and the PMS (§5.3) consume — fiber-length
//! distribution per mode (how many nnz share each output coordinate),
//! factor-row reuse, and Table-2-style summary characteristics.

use std::collections::HashMap;

use super::SparseTensor;

/// Per-mode fiber statistics: the distribution of non-zeros per output
/// coordinate in that mode.
#[derive(Debug, Clone)]
pub struct FiberStats {
    /// Number of distinct coordinates actually used (non-empty fibers).
    pub used_coords: usize,
    /// Mode length.
    pub mode_len: usize,
    /// Mean nnz per used coordinate.
    pub mean_len: f64,
    /// Max nnz in any fiber.
    pub max_len: usize,
    /// Gini-style skew in [0,1]: 0 = perfectly balanced fibers.
    pub skew: f64,
}

/// Compute fiber stats for `mode` (no sort required).
pub fn fiber_stats(t: &SparseTensor, mode: usize) -> FiberStats {
    let mut counts: HashMap<u32, usize> = HashMap::new();
    for &c in t.mode_col(mode) {
        *counts.entry(c).or_insert(0) += 1;
    }
    let used = counts.len().max(1);
    let mut lens: Vec<usize> = counts.into_values().collect();
    lens.sort_unstable();
    let total: usize = lens.iter().sum();
    let mean = total as f64 / used as f64;
    let max = lens.last().copied().unwrap_or(0);
    // Gini coefficient of fiber lengths.
    let mut cum = 0.0f64;
    let mut gini_num = 0.0f64;
    for (i, &l) in lens.iter().enumerate() {
        cum += l as f64;
        gini_num += (i as f64 + 1.0) * l as f64;
    }
    let skew = if total == 0 || used == 1 {
        0.0
    } else {
        ((2.0 * gini_num) / (used as f64 * cum) - (used as f64 + 1.0) / used as f64)
            .clamp(0.0, 1.0)
    };
    FiberStats {
        used_coords: used,
        mode_len: t.dims()[mode],
        mean_len: mean,
        max_len: max,
        skew,
    }
}

/// Average reuse distance proxy for factor-row accesses of `mode` when the
/// tensor is walked in its *current* order: number of *distinct* other
/// rows touched between consecutive touches of the same row, averaged.
/// This is the quantity that decides whether a cache of a given size can
/// exploit temporal locality (PMS cache model input).
pub fn mean_reuse_distance(t: &SparseTensor, mode: usize) -> f64 {
    let col = t.mode_col(mode);
    let mut last_seen: HashMap<u32, usize> = HashMap::new();
    // Approximate distinct-count with a position-difference proxy scaled
    // by the distinct/total ratio — exact stack distances are O(n^2) or
    // need a Fenwick-over-hash machinery; the proxy preserves ordering
    // between layouts, which is all the PMS needs.
    let mut sum = 0.0f64;
    let mut n_reuse = 0usize;
    for (pos, &c) in col.iter().enumerate() {
        if let Some(&prev) = last_seen.get(&c) {
            sum += (pos - prev) as f64;
            n_reuse += 1;
        }
        last_seen.insert(c, pos);
    }
    if n_reuse == 0 {
        return f64::INFINITY;
    }
    let distinct_ratio = last_seen.len() as f64 / col.len() as f64;
    (sum / n_reuse as f64) * distinct_ratio
}

/// Table-2-style characteristics row for a tensor.
#[derive(Debug, Clone)]
pub struct Characteristics {
    pub n_modes: usize,
    pub max_mode_len: usize,
    pub min_mode_len: usize,
    pub nnz: usize,
    pub density: f64,
    /// COO bytes (paper: "Tensor size ≤ 2.25 GB").
    pub tensor_bytes: usize,
    /// Largest factor-matrix bytes for the given rank (paper: "< 4.9 GB").
    pub max_factor_bytes: usize,
}

/// Compute the Table-2 row for rank `r`.
pub fn characteristics(t: &SparseTensor, r: usize) -> Characteristics {
    Characteristics {
        n_modes: t.n_modes(),
        max_mode_len: *t.dims().iter().max().unwrap(),
        min_mode_len: *t.dims().iter().min().unwrap(),
        nnz: t.nnz(),
        density: t.density(),
        tensor_bytes: t.bytes(),
        max_factor_bytes: t.dims().iter().max().unwrap() * r * 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::tensor::{Coord, SparseTensor};

    fn line_tensor() -> SparseTensor {
        // All nnz share coordinate 0 in mode 0; unique in mode 1.
        SparseTensor::new(
            vec![4, 8],
            &(0..8)
                .map(|j| (vec![0 as Coord, j as Coord], 1.0))
                .collect::<Vec<_>>(),
        )
    }

    #[test]
    fn fiber_stats_single_fiber() {
        let t = line_tensor();
        let s = fiber_stats(&t, 0);
        assert_eq!(s.used_coords, 1);
        assert_eq!(s.max_len, 8);
        assert!((s.mean_len - 8.0).abs() < 1e-12);
        assert_eq!(s.skew, 0.0);

        let s1 = fiber_stats(&t, 1);
        assert_eq!(s1.used_coords, 8);
        assert_eq!(s1.max_len, 1);
        assert!(s1.skew.abs() < 1e-9, "balanced fibers => 0 skew");
    }

    #[test]
    fn skew_orders_zipf_above_uniform() {
        let mk = |profile| {
            generate(&SynthConfig {
                dims: vec![500, 500, 500],
                nnz: 10_000,
                profile,
                seed: 2,
            })
        };
        let su = fiber_stats(&mk(Profile::Uniform), 0).skew;
        let sz = fiber_stats(&mk(Profile::Zipf { alpha_milli: 1300 }), 0).skew;
        assert!(sz > su + 0.1, "zipf skew {sz} <= uniform skew {su}");
    }

    #[test]
    fn reuse_distance_sorted_is_smaller_than_shuffled() {
        let mut t = generate(&SynthConfig {
            dims: vec![200, 200, 200],
            nnz: 5_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 4,
        });
        let shuffled = mean_reuse_distance(&t, 1);
        t.sort_by_mode(1);
        let sorted = mean_reuse_distance(&t, 1);
        assert!(
            sorted < shuffled * 0.2,
            "sorted {sorted} vs shuffled {shuffled}"
        );
    }

    #[test]
    fn reuse_distance_no_reuse_is_infinite() {
        // Every coordinate unique in mode 1.
        let t = line_tensor();
        assert!(mean_reuse_distance(&t, 1).is_infinite());
    }

    #[test]
    fn characteristics_matches_hand_computation() {
        let t = line_tensor();
        let c = characteristics(&t, 16);
        assert_eq!(c.n_modes, 2);
        assert_eq!(c.nnz, 8);
        assert_eq!(c.max_mode_len, 8);
        assert_eq!(c.tensor_bytes, 8 * (2 * 4 + 4));
        assert_eq!(c.max_factor_bytes, 8 * 16 * 4);
    }
}
