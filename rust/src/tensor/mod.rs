//! Sparse tensor substrate (S1): COO storage, mode ordering, FROSTT IO,
//! synthetic workload generators, and access-pattern statistics.
//!
//! The paper (§3) computes spMTTKRP over tensors stored in coordinate
//! (COO) format in FPGA external memory, sorted in the direction of the
//! current output mode.  [`SparseTensor`] is that representation;
//! [`remap`] implements the §3/Alg. 5 output-direction remapping.

mod coo;
pub mod frostt;
pub mod remap;
pub mod stats;
pub mod synth;

pub use coo::{SortOrder, SparseTensor};

/// Element index type for mode coordinates.  Real FROSTT tensors have
/// mode lengths up to ~39M (Table 2), well within u32; we use u32 to
/// halve index traffic exactly like a 32-bit FPGA address pointer.
pub type Coord = u32;
