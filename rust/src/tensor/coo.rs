//! COO sparse tensor: the paper's in-memory tensor format (§2.1, Alg. 2).

use super::Coord;

/// How the non-zero list is currently ordered.  The paper's Approach 1
/// requires the tensor sorted in the *output-mode* direction; Approach 2
/// sorts by an *input* mode.  Tracking the order lets engines assert
/// their precondition and lets the remapper skip no-op remaps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SortOrder {
    /// Sorted by the coordinates of the given mode (stable w.r.t.
    /// insertion order within equal coordinates).
    ByMode(usize),
    /// No ordering guarantee.
    Unsorted,
}

/// A sparse tensor in coordinate format.
///
/// Indices are stored mode-major (`indices[m][z]` = coordinate of nnz `z`
/// in mode `m`) rather than nnz-major: every engine walks one mode's
/// coordinate column linearly, and the FPGA layout the paper assumes
/// (tensor elements streamed as records) is reproduced by the trace
/// generators, not by this host layout.
#[derive(Debug, Clone)]
pub struct SparseTensor {
    /// Mode lengths `I_0 .. I_{N-1}`.
    dims: Vec<usize>,
    /// Coordinate columns, one per mode; all of length `nnz`.
    indices: Vec<Vec<Coord>>,
    /// Non-zero values.
    values: Vec<f32>,
    /// Current ordering of the nnz list.
    order: SortOrder,
}

impl SparseTensor {
    /// Build a tensor from nnz-major triples. Panics on inconsistent
    /// lengths or out-of-range coordinates (these are programmer errors
    /// in generators/readers, not recoverable conditions).
    pub fn new(dims: Vec<usize>, entries: &[(Vec<Coord>, f32)]) -> Self {
        let n = dims.len();
        assert!(n >= 2, "tensor needs >= 2 modes");
        let mut indices = vec![Vec::with_capacity(entries.len()); n];
        let mut values = Vec::with_capacity(entries.len());
        for (coords, v) in entries {
            assert_eq!(coords.len(), n, "coordinate arity mismatch");
            for (m, &c) in coords.iter().enumerate() {
                assert!(
                    (c as usize) < dims[m],
                    "coordinate {c} out of range for mode {m} (len {})",
                    dims[m]
                );
                indices[m].push(c);
            }
            values.push(*v);
        }
        SparseTensor {
            dims,
            indices,
            values,
            order: SortOrder::Unsorted,
        }
    }

    /// Build directly from columns (no copy). `indices[m].len()` must all
    /// equal `values.len()`.
    pub fn from_columns(
        dims: Vec<usize>,
        indices: Vec<Vec<Coord>>,
        values: Vec<f32>,
        order: SortOrder,
    ) -> Self {
        assert_eq!(indices.len(), dims.len());
        for col in &indices {
            assert_eq!(col.len(), values.len());
        }
        SparseTensor {
            dims,
            indices,
            values,
            order,
        }
    }

    /// Number of modes N.
    pub fn n_modes(&self) -> usize {
        self.dims.len()
    }

    /// Mode lengths.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of non-zero elements |T|.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Coordinate column of `mode`.
    pub fn mode_col(&self, mode: usize) -> &[Coord] {
        &self.indices[mode]
    }

    /// Non-zero values.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Current sort order.
    pub fn order(&self) -> SortOrder {
        self.order
    }

    /// Internal mutable access for modules (remap) that establish an
    /// ordering by construction.
    pub(crate) fn order_mut(&mut self) -> &mut SortOrder {
        &mut self.order
    }

    /// Coordinates of nnz `z` as a small vec.
    pub fn coords_of(&self, z: usize) -> Vec<Coord> {
        self.indices.iter().map(|col| col[z]).collect()
    }

    /// Density `|T| / prod(dims)` (useful for stats; real tensors ~1e-7).
    pub fn density(&self) -> f64 {
        let cells: f64 = self.dims.iter().map(|&d| d as f64).product();
        self.nnz() as f64 / cells
    }

    /// Bytes of one COO record: N u32 coordinates + one f32 value.  This
    /// is the "width of a tensor element" remapper parameter (§5.2.1).
    pub fn record_bytes(&self) -> usize {
        self.n_modes() * 4 + 4
    }

    /// Total tensor bytes in external memory (|T| records).
    pub fn bytes(&self) -> usize {
        self.nnz() * self.record_bytes()
    }

    /// Sort (stably) in the direction of `mode` — the layout Approach 1
    /// needs for that output mode.  Counting sort over the mode column:
    /// O(nnz + I_mode), mirroring the remapper's pointer-table pass.
    pub fn sort_by_mode(&mut self, mode: usize) {
        assert!(mode < self.n_modes());
        if self.order == SortOrder::ByMode(mode) {
            return;
        }
        let perm = sort_permutation(&self.indices[mode], self.dims[mode]);
        self.apply_permutation(&perm);
        self.order = SortOrder::ByMode(mode);
    }

    /// Apply a gather permutation: `new[z] = old[perm[z]]`.
    pub fn apply_permutation(&mut self, perm: &[usize]) {
        assert_eq!(perm.len(), self.nnz());
        for col in &mut self.indices {
            let old = std::mem::take(col);
            *col = perm.iter().map(|&p| old[p]).collect();
        }
        let old_vals = std::mem::take(&mut self.values);
        self.values = perm.iter().map(|&p| old_vals[p]).collect();
        self.order = SortOrder::Unsorted;
    }

    /// Iterate runs of equal coordinates in `mode` (requires sorted by
    /// that mode): yields `(coord, start, end)` half-open nnz ranges —
    /// the "all non-zeros with the same output coordinate" groups of
    /// Alg. 3 line 5.
    pub fn fiber_ranges(&self, mode: usize) -> FiberRanges<'_> {
        assert_eq!(
            self.order,
            SortOrder::ByMode(mode),
            "fiber_ranges requires tensor sorted by mode {mode}"
        );
        FiberRanges {
            col: &self.indices[mode],
            pos: 0,
        }
    }

    /// Dense reconstruction (tests only; tiny tensors).
    pub fn to_dense(&self) -> Vec<f32> {
        let total: usize = self.dims.iter().product();
        let mut out = vec![0.0f32; total];
        for z in 0..self.nnz() {
            let mut off = 0usize;
            for m in 0..self.n_modes() {
                off = off * self.dims[m] + self.indices[m][z] as usize;
            }
            out[off] += self.values[z];
        }
        let _ = total;
        out
    }
}

/// Stable counting-sort permutation of `col` with key range `key_len`.
/// Returned `perm` satisfies: `col[perm[z]]` is non-decreasing in `z`.
pub fn sort_permutation(col: &[Coord], key_len: usize) -> Vec<usize> {
    let mut counts = vec![0usize; key_len + 1];
    for &c in col {
        counts[c as usize + 1] += 1;
    }
    for i in 1..counts.len() {
        counts[i] += counts[i - 1];
    }
    let mut perm = vec![0usize; col.len()];
    for (z, &c) in col.iter().enumerate() {
        perm[counts[c as usize]] = z;
        counts[c as usize] += 1;
    }
    perm
}

/// Iterator over equal-coordinate runs of a sorted mode column.
pub struct FiberRanges<'a> {
    col: &'a [Coord],
    pos: usize,
}

impl Iterator for FiberRanges<'_> {
    /// `(coordinate, start_nnz, end_nnz)` half-open range.
    type Item = (Coord, usize, usize);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.col.len() {
            return None;
        }
        let start = self.pos;
        let c = self.col[start];
        let mut end = start + 1;
        while end < self.col.len() && self.col[end] == c {
            end += 1;
        }
        self.pos = end;
        Some((c, start, end))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Rng};

    fn small() -> SparseTensor {
        SparseTensor::new(
            vec![3, 4, 2],
            &[
                (vec![2, 0, 1], 1.0),
                (vec![0, 3, 0], 2.0),
                (vec![2, 1, 1], 3.0),
                (vec![1, 2, 0], 4.0),
                (vec![0, 0, 1], 5.0),
            ],
        )
    }

    #[test]
    fn basic_accessors() {
        let t = small();
        assert_eq!(t.n_modes(), 3);
        assert_eq!(t.nnz(), 5);
        assert_eq!(t.dims(), &[3, 4, 2]);
        assert_eq!(t.record_bytes(), 16);
        assert_eq!(t.bytes(), 80);
        assert_eq!(t.order(), SortOrder::Unsorted);
        assert_eq!(t.coords_of(3), vec![1, 2, 0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range_coordinate() {
        SparseTensor::new(vec![2, 2], &[(vec![2, 0], 1.0)]);
    }

    #[test]
    fn sort_by_mode_orders_column_and_is_stable() {
        let mut t = small();
        t.sort_by_mode(0);
        assert_eq!(t.order(), SortOrder::ByMode(0));
        assert_eq!(t.mode_col(0), &[0, 0, 1, 2, 2]);
        // Stability: the two i0=0 entries keep insertion order (2.0, 5.0).
        assert_eq!(&t.values()[..2], &[2.0, 5.0]);
        // The two i0=2 entries keep order (1.0, 3.0).
        assert_eq!(&t.values()[3..], &[1.0, 3.0]);
    }

    #[test]
    fn sort_is_idempotent() {
        let mut t = small();
        t.sort_by_mode(1);
        let vals = t.values().to_vec();
        t.sort_by_mode(1); // should early-out
        assert_eq!(t.values(), &vals[..]);
    }

    #[test]
    fn fiber_ranges_cover_all_nnz_without_overlap() {
        let mut t = small();
        t.sort_by_mode(0);
        let ranges: Vec<_> = t.fiber_ranges(0).collect();
        assert_eq!(ranges, vec![(0, 0, 2), (1, 2, 3), (2, 3, 5)]);
    }

    #[test]
    #[should_panic(expected = "requires tensor sorted")]
    fn fiber_ranges_requires_sorted() {
        let t = small();
        let _ = t.fiber_ranges(0).count();
    }

    #[test]
    fn sort_preserves_multiset_property() {
        forall("sort_preserves_multiset", 32, |rng: &mut Rng| {
            let dims = vec![rng.range(1, 20), rng.range(1, 20), rng.range(1, 20)];
            let nnz = rng.range(0, 200);
            let entries: Vec<(Vec<Coord>, f32)> = (0..nnz)
                .map(|_| {
                    (
                        dims.iter().map(|&d| rng.below(d as u64) as Coord).collect(),
                        rng.f32(),
                    )
                })
                .collect();
            let mut t = SparseTensor::new(dims.clone(), &entries);
            let mode = rng.range(0, 3);
            let dense_before = t.to_dense();
            t.sort_by_mode(mode);
            // Sorted column is non-decreasing.
            let col = t.mode_col(mode);
            assert!(col.windows(2).all(|w| w[0] <= w[1]));
            // Tensor contents unchanged.
            assert_eq!(t.to_dense(), dense_before);
        });
    }

    #[test]
    fn sort_permutation_matches_std_stable_sort() {
        forall("counting_sort_vs_std", 32, |rng: &mut Rng| {
            let key_len = rng.range(1, 50);
            let n = rng.range(0, 300);
            let col: Vec<Coord> = (0..n).map(|_| rng.below(key_len as u64) as Coord).collect();
            let perm = sort_permutation(&col, key_len);
            let mut want: Vec<usize> = (0..n).collect();
            want.sort_by_key(|&z| col[z]); // std stable sort
            assert_eq!(perm, want);
        });
    }

    #[test]
    fn density_of_known_tensor() {
        let t = small();
        assert!((t.density() - 5.0 / 24.0).abs() < 1e-12);
    }
}
