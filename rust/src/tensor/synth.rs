//! Synthetic sparse-tensor generators (hardware substitution, DESIGN.md §2).
//!
//! FROSTT tensors (Table 2: 3–5 modes, mode lengths to 39 M, 3–144 M nnz)
//! are too large for this testbed and not redistributable here, so we
//! generate scaled-down tensors that preserve the properties the memory
//! controller is sensitive to: fiber-length *skew* (how many non-zeros
//! share an output coordinate — drives remap locality and output-store
//! streaming), coordinate *clustering* (drives cache-line spatial
//! locality on factor rows), and density.

use std::collections::HashSet;

use super::{Coord, SparseTensor};
use crate::testkit::Rng;

/// Statistical profile of a generated tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// Coordinates i.i.d. uniform per mode — the least-locality baseline.
    Uniform,
    /// Per-mode coordinates Zipf-distributed (exponent ~1.1–1.4): a few
    /// "hub" fibers hold most non-zeros, like NELL / Amazon review
    /// tensors.  This is the realistic FROSTT-like profile.
    Zipf {
        /// Skew exponent; larger = more skewed. Typical 1.05..1.5.
        alpha_milli: u32,
    },
    /// Non-zeros drawn uniformly inside randomly-placed dense blocks,
    /// like timestamped interaction tensors; high spatial locality.
    Clustered {
        /// Edge length of each dense block per mode.
        block: usize,
        /// Number of blocks.
        blocks: usize,
    },
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Mode lengths.
    pub dims: Vec<usize>,
    /// Target non-zero count (exact; duplicates are re-drawn).
    pub nnz: usize,
    pub profile: Profile,
    pub seed: u64,
}

impl SynthConfig {
    /// A small FROSTT-like default: 3 modes, Zipf skew.
    pub fn small_default(seed: u64) -> Self {
        SynthConfig {
            dims: vec![2000, 1500, 1000],
            nnz: 50_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed,
        }
    }
}

/// Profile-specific coordinate drawing, shared by the deduplicating
/// [`generate`] and the bounded-memory [`generate_streamed`].  Both
/// construct it after seeding the RNG and draw tuples in the same
/// order, so the two generators consume the identical random sequence
/// per accepted draw.
struct CoordSampler<'a> {
    cfg: &'a SynthConfig,
    /// Cluster anchors for [`Profile::Clustered`].
    anchors: Vec<Vec<Coord>>,
    /// Per-mode random permutations for the Zipf profile so the "hub"
    /// coordinates are scattered across the index range rather than
    /// all being small numbers (which would fake spatial locality).
    scatter: Vec<Vec<Coord>>,
}

impl<'a> CoordSampler<'a> {
    fn new(cfg: &'a SynthConfig, rng: &mut Rng) -> Self {
        let anchors: Vec<Vec<Coord>> = match cfg.profile {
            Profile::Clustered { block, blocks } => (0..blocks)
                .map(|_| {
                    cfg.dims
                        .iter()
                        .map(|&d| {
                            let hi = d.saturating_sub(block).max(1);
                            rng.below(hi as u64) as Coord
                        })
                        .collect()
                })
                .collect(),
            _ => Vec::new(),
        };
        let scatter: Vec<Vec<Coord>> = match cfg.profile {
            Profile::Zipf { .. } => cfg
                .dims
                .iter()
                .map(|&d| {
                    let mut p: Vec<Coord> = (0..d as Coord).collect();
                    rng.shuffle(&mut p);
                    p
                })
                .collect(),
            _ => Vec::new(),
        };
        CoordSampler {
            cfg,
            anchors,
            scatter,
        }
    }

    /// Draw one coordinate tuple into `out` (cleared first).
    fn draw(&self, rng: &mut Rng, out: &mut Vec<Coord>) {
        out.clear();
        match self.cfg.profile {
            Profile::Uniform => {
                out.extend(self.cfg.dims.iter().map(|&d| rng.below(d as u64) as Coord))
            }
            Profile::Zipf { alpha_milli } => {
                let alpha = alpha_milli as f64 / 1000.0;
                out.extend(
                    self.cfg
                        .dims
                        .iter()
                        .enumerate()
                        .map(|(m, &d)| self.scatter[m][rng.zipf(d as u64, alpha) as usize]),
                )
            }
            Profile::Clustered { block, .. } => {
                let a = &self.anchors[rng.range(0, self.anchors.len())];
                out.extend(self.cfg.dims.iter().enumerate().map(|(m, &d)| {
                    let c = a[m] as usize + rng.range(0, block);
                    c.min(d - 1) as Coord
                }))
            }
        }
    }
}

/// Values in (-1, 1), excluding exact zero.
fn draw_value(rng: &mut Rng) -> f32 {
    let v = rng.f32() * 2.0 - 1.0;
    if v == 0.0 {
        0.5
    } else {
        v
    }
}

/// Generate a tensor with *unique* coordinates and values in `(-1, 1)`.
///
/// Panics if `nnz` exceeds 50% of the coordinate space (the rejection
/// loop would crawl); scaled workloads are far sparser than that.
pub fn generate(cfg: &SynthConfig) -> SparseTensor {
    let space: f64 = cfg.dims.iter().map(|&d| d as f64).product();
    assert!(
        (cfg.nnz as f64) <= 0.5 * space,
        "nnz {} too dense for dims {:?}",
        cfg.nnz,
        cfg.dims
    );
    let mut rng = Rng::new(cfg.seed);
    let sampler = CoordSampler::new(cfg, &mut rng);
    let mut seen: HashSet<Vec<Coord>> = HashSet::with_capacity(cfg.nnz * 2);
    let mut cols: Vec<Vec<Coord>> = vec![Vec::with_capacity(cfg.nnz); cfg.dims.len()];
    let mut vals = Vec::with_capacity(cfg.nnz);
    let mut coords: Vec<Coord> = Vec::with_capacity(cfg.dims.len());

    while vals.len() < cfg.nnz {
        sampler.draw(&mut rng, &mut coords);
        if seen.insert(coords.clone()) {
            for (m, &c) in coords.iter().enumerate() {
                cols[m].push(c);
            }
            vals.push(draw_value(&mut rng));
        }
    }

    SparseTensor::from_columns(cfg.dims.clone(), cols, vals, super::SortOrder::Unsorted)
}

/// [`generate`] without the coordinate-dedup set (S24): draws exactly
/// `nnz` tuples and keeps every one.  The dedup `HashSet` holds an
/// owned coordinate tuple per non-zero — at 100M nnz that is several
/// gigabytes on top of the tensor itself — so the out-of-core path
/// cannot afford it.  Duplicate coordinates may occur with probability
/// ~`nnz² / (2·space)`; for the huge, hyper-sparse tensors this path
/// exists for that is vanishingly rare, and the simulation pipeline
/// (remap, Approach-1, replay) treats a duplicate as two co-located
/// non-zeros, which is harmless for timing studies.  Peak memory is
/// the COO columns + values and nothing else.
///
/// When no draw collides, the result is bit-identical to [`generate`]
/// with the same config (both consume the same RNG sequence per
/// accepted draw).
pub fn generate_streamed(cfg: &SynthConfig) -> SparseTensor {
    let mut rng = Rng::new(cfg.seed);
    let sampler = CoordSampler::new(cfg, &mut rng);
    let mut cols: Vec<Vec<Coord>> = vec![Vec::with_capacity(cfg.nnz); cfg.dims.len()];
    let mut vals = Vec::with_capacity(cfg.nnz);
    let mut coords: Vec<Coord> = Vec::with_capacity(cfg.dims.len());

    for _ in 0..cfg.nnz {
        sampler.draw(&mut rng, &mut coords);
        for (m, &c) in coords.iter().enumerate() {
            cols[m].push(c);
        }
        vals.push(draw_value(&mut rng));
    }

    SparseTensor::from_columns(cfg.dims.clone(), cols, vals, super::SortOrder::Unsorted)
}

/// Generate a tensor that *is* (noisily) low-rank: every cell of a
/// rank-`rank` CP model over small `dims` is enumerated, plus i.i.d.
/// Gaussian noise of standard deviation `noise`.  Use for recovery demos
/// and ALS convergence tests — COO zeros-are-zero semantics would break
/// the rank structure if cells were subsampled instead.
pub fn low_rank(dims: &[usize], rank: usize, noise: f32, seed: u64) -> SparseTensor {
    let mut rng = Rng::new(seed);
    // Ground-truth factors ~ N(0,1).
    let factors: Vec<Vec<f32>> = dims
        .iter()
        .map(|&d| (0..d * rank).map(|_| rng.normal() as f32).collect())
        .collect();
    let total: usize = dims.iter().product();
    let mut cols: Vec<Vec<Coord>> = vec![Vec::with_capacity(total); dims.len()];
    let mut vals = Vec::with_capacity(total);
    for lin in 0..total {
        let mut rem = lin;
        let mut coords = vec![0usize; dims.len()];
        for m in (0..dims.len()).rev() {
            coords[m] = rem % dims[m];
            rem /= dims[m];
        }
        let mut v = 0.0f32;
        for rr in 0..rank {
            let mut p = 1.0f32;
            for (m, &c) in coords.iter().enumerate() {
                p *= factors[m][c * rank + rr];
            }
            v += p;
        }
        if noise > 0.0 {
            v += noise * rng.normal() as f32;
        }
        for (m, &c) in coords.iter().enumerate() {
            cols[m].push(c as Coord);
        }
        vals.push(v);
    }
    SparseTensor::from_columns(dims.to_vec(), cols, vals, super::SortOrder::Unsorted)
}

/// The scaled FROSTT-like benchmark suite used across the benches: one
/// tensor per (domain-profile, mode-count) cell, chosen to reproduce the
/// *ranges* of Table 2 at ~1/1000 scale.
pub fn frostt_suite(seed: u64) -> Vec<(&'static str, SynthConfig)> {
    vec![
        (
            "uniform-3",
            SynthConfig {
                dims: vec![17_000, 10_000, 8_000],
                nnz: 120_000,
                profile: Profile::Uniform,
                seed,
            },
        ),
        (
            "zipf-3 (nell-like)",
            SynthConfig {
                // 140k x 16 B = 2.24 MB -> 2.24 GB at x1000 scale, inside
                // Table 2's "tensor size <= 2.25 GB".
                dims: vec![39_000, 20_000, 12_000],
                nnz: 140_000,
                profile: Profile::Zipf { alpha_milli: 1300 },
                seed: seed ^ 1,
            },
        ),
        (
            "zipf-4 (amazon-like)",
            SynthConfig {
                dims: vec![18_000, 12_000, 9_000, 400],
                nnz: 100_000,
                profile: Profile::Zipf { alpha_milli: 1150 },
                seed: seed ^ 2,
            },
        ),
        (
            "clustered-3 (timestamped)",
            SynthConfig {
                dims: vec![20_000, 15_000, 5_000],
                nnz: 90_000,
                profile: Profile::Clustered {
                    block: 64,
                    blocks: 400,
                },
                seed: seed ^ 3,
            },
        ),
        (
            "zipf-5 (vast-like)",
            SynthConfig {
                dims: vec![8_000, 6_000, 4_000, 300, 50],
                nnz: 60_000,
                profile: Profile::Zipf { alpha_milli: 1100 },
                seed: seed ^ 4,
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_exact_nnz_with_unique_coords() {
        let cfg = SynthConfig {
            dims: vec![50, 40, 30],
            nnz: 500,
            profile: Profile::Uniform,
            seed: 1,
        };
        let t = generate(&cfg);
        assert_eq!(t.nnz(), 500);
        let mut seen = HashSet::new();
        for z in 0..t.nnz() {
            assert!(seen.insert(t.coords_of(z)), "duplicate coordinate");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SynthConfig::small_default(9);
        let a = generate(&SynthConfig {
            nnz: 2_000,
            ..cfg.clone()
        });
        let b = generate(&SynthConfig { nnz: 2_000, ..cfg });
        assert_eq!(a.values(), b.values());
        assert_eq!(a.mode_col(0), b.mode_col(0));
    }

    #[test]
    fn streamed_matches_generate_when_sparse_enough() {
        // Space 1e12, nnz 2000: the dedup path accepts every draw, so
        // both generators walk the identical RNG sequence and must
        // produce the identical tensor (deterministic per seed).
        for profile in [
            Profile::Uniform,
            Profile::Zipf { alpha_milli: 1200 },
            Profile::Clustered {
                block: 16,
                blocks: 40,
            },
        ] {
            let cfg = SynthConfig {
                dims: vec![10_000, 10_000, 10_000],
                nnz: 2_000,
                profile,
                seed: 11,
            };
            let a = generate(&cfg);
            let b = generate_streamed(&cfg);
            assert_eq!(a.nnz(), b.nnz(), "{profile:?}");
            assert_eq!(a.values(), b.values(), "{profile:?}");
            for m in 0..3 {
                assert_eq!(a.mode_col(m), b.mode_col(m), "{profile:?} mode {m}");
            }
        }
    }

    #[test]
    fn streamed_is_deterministic_and_exact_nnz() {
        let cfg = SynthConfig {
            dims: vec![300, 200, 100],
            nnz: 5_000,
            profile: Profile::Zipf { alpha_milli: 1100 },
            seed: 3,
        };
        let a = generate_streamed(&cfg);
        let b = generate_streamed(&cfg);
        assert_eq!(a.nnz(), 5_000);
        assert_eq!(a.values(), b.values());
        for m in 0..3 {
            assert_eq!(a.mode_col(m), b.mode_col(m));
            let &max = a.mode_col(m).iter().max().unwrap();
            assert!((max as usize) < cfg.dims[m]);
        }
    }

    #[test]
    fn zipf_profile_is_more_skewed_than_uniform() {
        let dims = vec![1000, 1000, 1000];
        let mk = |profile, seed| {
            generate(&SynthConfig {
                dims: dims.clone(),
                nnz: 20_000,
                profile,
                seed,
            })
        };
        let top_fiber_share = |t: &SparseTensor| {
            let mut counts = vec![0usize; 1000];
            for &c in t.mode_col(0) {
                counts[c as usize] += 1;
            }
            counts.sort_unstable_by(|a, b| b.cmp(a));
            counts[..10].iter().sum::<usize>() as f64 / t.nnz() as f64
        };
        let u = mk(Profile::Uniform, 3);
        let z = mk(Profile::Zipf { alpha_milli: 1300 }, 3);
        assert!(
            top_fiber_share(&z) > 3.0 * top_fiber_share(&u),
            "zipf {} vs uniform {}",
            top_fiber_share(&z),
            top_fiber_share(&u)
        );
    }

    #[test]
    fn clustered_profile_stays_within_dims() {
        let t = generate(&SynthConfig {
            dims: vec![100, 80, 60],
            nnz: 1_000,
            profile: Profile::Clustered {
                block: 16,
                blocks: 10,
            },
            seed: 5,
        });
        for m in 0..3 {
            let max = *t.mode_col(m).iter().max().unwrap() as usize;
            assert!(max < t.dims()[m]);
        }
    }

    #[test]
    #[should_panic(expected = "too dense")]
    fn rejects_overdense_request() {
        generate(&SynthConfig {
            dims: vec![4, 4],
            nnz: 12,
            profile: Profile::Uniform,
            seed: 0,
        });
    }

    #[test]
    fn low_rank_tensor_has_expected_shape_and_determinism() {
        let a = low_rank(&[6, 5, 4], 2, 0.0, 3);
        assert_eq!(a.nnz(), 120);
        let b = low_rank(&[6, 5, 4], 2, 0.0, 3);
        assert_eq!(a.values(), b.values());
        // Noise changes values but not coordinates.
        let c = low_rank(&[6, 5, 4], 2, 0.1, 3);
        assert_eq!(a.mode_col(0), c.mode_col(0));
        assert_ne!(a.values(), c.values());
    }

    #[test]
    fn frostt_suite_covers_mode_counts_3_to_5() {
        let suite = frostt_suite(0);
        let modes: HashSet<usize> = suite.iter().map(|(_, c)| c.dims.len()).collect();
        assert!(modes.contains(&3) && modes.contains(&4) && modes.contains(&5));
    }
}
