//! Shared workload construction for the CLI, examples, and benches:
//! either load a FROSTT `.tns` file (`--input`) or generate a synthetic
//! tensor (`--synth uniform|zipf|clustered`, `--dims`, `--nnz`, `--seed`).

use super::{Args, CliError};
use crate::tensor::synth::{generate, generate_streamed, Profile, SynthConfig};
use crate::tensor::{frostt, SparseTensor};

/// Option names consumed by [`tensor_from_args`]; include them in the
/// caller's `known_opts`.
pub const WORKLOAD_OPTS: &[&str] = &["input", "synth", "dims", "nnz", "seed", "alpha"];

/// Parse `--dims 100x200x300`.
pub fn parse_dims(s: &str) -> Result<Vec<usize>, CliError> {
    s.split(['x', ','])
        .map(|p| {
            p.trim()
                .parse::<usize>()
                .map_err(|_| CliError(format!("bad --dims component {p:?}")))
        })
        .collect()
}

/// Build the tensor a subcommand should operate on.
pub fn tensor_from_args(args: &Args) -> Result<SparseTensor, Box<dyn std::error::Error>> {
    tensor_from_args_budgeted(args, None)
}

/// [`tensor_from_args`] with an optional memory budget (S24): under a
/// budget, synthetic tensors are drawn through the dedup-free
/// [`generate_streamed`] (the dedup set alone would dwarf a bounded
/// budget at 100M nnz).  FROSTT `--input` files always go through the
/// block-streamed parser ([`frostt::read_tns_file`]), budget or not.
pub fn tensor_from_args_budgeted(
    args: &Args,
    budget: Option<u64>,
) -> Result<SparseTensor, Box<dyn std::error::Error>> {
    if let Some(path) = args.get("input") {
        return Ok(frostt::read_tns_file(std::path::Path::new(path))?);
    }
    let dims = parse_dims(args.str_or("dims", "2000x1500x1000"))?;
    let nnz = args.usize_or("nnz", 50_000)?;
    let seed = args.u64_or("seed", 42)?;
    let alpha = args.f64_or("alpha", 1.2)?;
    let profile = match args.str_or("synth", "zipf") {
        "uniform" => Profile::Uniform,
        "zipf" => Profile::Zipf {
            alpha_milli: (alpha * 1000.0) as u32,
        },
        "clustered" => Profile::Clustered {
            block: 64,
            blocks: (nnz / 256).max(1),
        },
        other => return Err(Box::new(CliError(format!("unknown --synth {other:?}")))),
    };
    let cfg = SynthConfig {
        dims,
        nnz,
        profile,
        seed,
    };
    Ok(if budget.is_some() {
        generate_streamed(&cfg)
    } else {
        generate(&cfg)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn dims_parse_both_separators() {
        assert_eq!(parse_dims("10x20x30").unwrap(), vec![10, 20, 30]);
        assert_eq!(parse_dims("10,20").unwrap(), vec![10, 20]);
        assert!(parse_dims("10xzebra").is_err());
    }

    #[test]
    fn synth_tensor_from_args() {
        let a = Args::parse(
            &sv(&["x", "--synth", "uniform", "--dims", "50x40x30", "--nnz", "100"]),
            WORKLOAD_OPTS,
            &[],
        )
        .unwrap();
        let t = tensor_from_args(&a).unwrap();
        assert_eq!(t.dims(), &[50, 40, 30]);
        assert_eq!(t.nnz(), 100);
    }

    #[test]
    fn unknown_profile_is_error() {
        let a = Args::parse(&sv(&["x", "--synth", "weird"]), WORKLOAD_OPTS, &[]).unwrap();
        assert!(tensor_from_args(&a).is_err());
    }
}
