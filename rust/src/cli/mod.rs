//! Hand-rolled CLI argument parsing (S14; no clap in the offline build).
//!
//! Grammar: `ptmc <subcommand> [--flag] [--key value]...`.  Flags are
//! order-independent; unknown keys are an error so typos fail loudly.

pub mod workload;

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

/// CLI error.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw args (without argv[0]).  `known_opts` take a value;
    /// `known_flags` do not.
    pub fn parse(
        raw: &[String],
        known_opts: &[&str],
        known_flags: &[&str],
    ) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if known_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else if known_opts.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError(format!("--{name} requires a value")))?;
                    args.options.insert(name.to_string(), v.clone());
                } else {
                    return Err(CliError(format!("unknown option --{name}")));
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok.clone());
            } else {
                return Err(CliError(format!("unexpected positional argument {tok:?}")));
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got {v:?}"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        Ok(self.usize_or(name, default as usize)? as u64)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected float, got {v:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            &sv(&["decompose", "--rank", "32", "--verbose", "--input", "x.tns"]),
            &["rank", "input"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("decompose"));
        assert_eq!(a.usize_or("rank", 16).unwrap(), 32);
        assert_eq!(a.get("input"), Some("x.tns"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn unknown_option_is_an_error() {
        let e = Args::parse(&sv(&["x", "--bogus", "1"]), &["rank"], &[]).unwrap_err();
        assert!(e.0.contains("--bogus"));
    }

    #[test]
    fn missing_value_is_an_error() {
        let e = Args::parse(&sv(&["x", "--rank"]), &["rank"], &[]).unwrap_err();
        assert!(e.0.contains("requires a value"));
    }

    #[test]
    fn bad_int_is_an_error() {
        let a = Args::parse(&sv(&["x", "--rank", "abc"]), &["rank"], &[]).unwrap();
        assert!(a.usize_or("rank", 1).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["x"]), &["rank"], &[]).unwrap();
        assert_eq!(a.usize_or("rank", 16).unwrap(), 16);
        assert_eq!(a.str_or("backend", "native"), "native");
        assert_eq!(a.f64_or("tol", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn double_positional_rejected() {
        assert!(Args::parse(&sv(&["a", "b"]), &[], &[]).is_err());
    }
}
