//! Run configuration (S14): a TOML-subset parser and the typed run
//! config it feeds.  The offline build has no serde, so this implements
//! exactly the subset the tool needs: `[section]` headers, `key = value`
//! pairs with integer / float / string / boolean values, `#` comments.
//!
//! Example (`ptmc.toml`):
//! ```toml
//! [run]
//! rank = 16
//! iters = 10
//! backend = "pjrt"
//!
//! [cache]
//! line_bytes = 64
//! num_lines = 4096
//! assoc = 4
//!
//! [dma]
//! num_dmas = 2
//! buffers_per_dma = 2
//! buffer_bytes = 4096
//!
//! [remapper]
//! max_pointers = 65536
//!
//! [memory]
//! tech = "ddr4"      # ddr4 | hbm2 | osram
//!
//! [dram]
//! channels = 4
//!
//! [dse]
//! search = "joint"   # coordinate | joint | beam
//! top_k = 5
//!
//! [serve]
//! listen = "127.0.0.1:7421"
//! workers = 8
//! tenant_budget = 100
//! memo_spill = ".ptmc-warm"
//! ```
//!
//! The `[dse]` section configures the explore subcommand's search
//! layer (overridden by `--search` / `--top-k` on the command line);
//! `[serve]` configures the DSE service the same way (overridden by
//! `--listen` / `--serve-workers` / `--tenant-budget` /
//! `--memo-spill`).
//!
//! The parser is strict, mirroring the CLI's unknown-option handling:
//! sections and keys outside the known schema are a [`ParseError`]
//! naming the offending line, so typos fail loudly instead of running
//! the experiment with silent defaults.

use std::collections::HashMap;

use crate::controller::ControllerConfig;
use crate::cpd::AlsConfig;
use crate::mem::MemTech;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
}

impl Value {
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Known sections and their keys.  `Config::parse` rejects anything
/// outside this table so a typo (`[dram] bank = 8`) fails loudly at
/// parse time instead of silently running with defaults — mirroring the
/// CLI's strict unknown-option handling.
const SCHEMA: &[(&str, &[&str])] = &[
    ("run", &["rank", "iters", "tol", "ridge", "seed", "backend", "verbose"]),
    ("cache", &["line_bytes", "num_lines", "assoc", "hit_latency"]),
    ("dma", &["num_dmas", "buffers_per_dma", "buffer_bytes"]),
    ("remapper", &["max_pointers", "buffer_bytes"]),
    ("memory", &["tech"]),
    ("dram", &["channels", "banks", "row_policy"]),
    ("dse", &["search", "top_k", "warm_cache", "checkpoint_every"]),
    ("serve", &["listen", "workers", "tenant_budget", "memo_spill"]),
];

fn schema_keys(section: &str) -> Option<&'static [&'static str]> {
    SCHEMA
        .iter()
        .find(|(s, _)| *s == section)
        .map(|(_, keys)| *keys)
}

/// Parsed config: section -> key -> value.
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: HashMap<String, HashMap<String, Value>>,
    /// Source line of each (section, key) pair, for post-parse
    /// validation errors that must name the offending line.
    key_lines: HashMap<(String, String), usize>,
}

/// Parse error with line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

fn parse_value(raw: &str, line: usize) -> Result<Value, ParseError> {
    let raw = raw.trim();
    if raw.starts_with('"') && raw.ends_with('"') && raw.len() >= 2 {
        return Ok(Value::Str(raw[1..raw.len() - 1].to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError {
        line,
        message: format!("cannot parse value {raw:?}"),
    })
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (idx, raw_line) in text.lines().enumerate() {
            let line_no = idx + 1;
            let line = match raw_line.find('#') {
                Some(p) => &raw_line[..p],
                None => raw_line,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') || line.len() < 3 {
                    return Err(ParseError {
                        line: line_no,
                        message: format!("malformed section header {line:?}"),
                    });
                }
                section = line[1..line.len() - 1].trim().to_string();
                if schema_keys(&section).is_none() {
                    let known: Vec<&str> = SCHEMA.iter().map(|(s, _)| *s).collect();
                    return Err(ParseError {
                        line: line_no,
                        message: format!(
                            "unknown section [{section}]; expected one of [{}]",
                            known.join("], [")
                        ),
                    });
                }
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ParseError {
                line: line_no,
                message: format!("expected key = value, got {line:?}"),
            })?;
            let key = k.trim().to_string();
            match schema_keys(&section) {
                None => {
                    return Err(ParseError {
                        line: line_no,
                        message: format!("key {key:?} before any [section] header"),
                    });
                }
                Some(keys) if !keys.contains(&key.as_str()) => {
                    return Err(ParseError {
                        line: line_no,
                        message: format!(
                            "unknown key {key:?} in [{section}]; expected one of {}",
                            keys.join(", ")
                        ),
                    });
                }
                Some(_) => {}
            }
            let value = parse_value(v, line_no)?;
            cfg.key_lines.insert((section.clone(), key.clone()), line_no);
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(cfg)
    }

    /// Source line of a parsed key (1-based), for validation errors.
    fn line_of(&self, section: &str, key: &str) -> usize {
        self.key_lines
            .get(&(section.to_string(), key.to_string()))
            .copied()
            .unwrap_or(0)
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<Self, Box<dyn std::error::Error>> {
        Ok(Self::parse(&std::fs::read_to_string(path)?)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(Value::as_usize)
            .unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key)
            .and_then(Value::as_f64)
            .unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
    }

    /// Build a [`ControllerConfig`] from the `[cache]`, `[dma]`,
    /// `[remapper]`, `[memory]` and `[dram]` sections, defaulting
    /// unset keys.  `[memory] tech = "ddr4" | "hbm2" | "osram"`
    /// selects the external-memory technology (default DDR4, at each
    /// technology's default knob set).  Misconfiguration is an error,
    /// never a silent default: unknown `tech` / `row_policy` strings
    /// are rejected, and `[dram]` keys combined with a non-DDR4
    /// technology fail exactly like the equivalent `--dram-*` CLI
    /// flags with a non-DDR4 `--memory-tech`.
    pub fn controller(&self, elem_bytes: usize) -> Result<ControllerConfig, ParseError> {
        let mut c = ControllerConfig::default_for(elem_bytes);
        c.cache.line_bytes = self.usize_or("cache", "line_bytes", c.cache.line_bytes);
        c.cache.num_lines = self.usize_or("cache", "num_lines", c.cache.num_lines);
        c.cache.assoc = self.usize_or("cache", "assoc", c.cache.assoc);
        c.cache.hit_latency =
            self.usize_or("cache", "hit_latency", c.cache.hit_latency as usize) as u64;
        c.dma.num_dmas = self.usize_or("dma", "num_dmas", c.dma.num_dmas);
        c.dma.buffers_per_dma = self.usize_or("dma", "buffers_per_dma", c.dma.buffers_per_dma);
        c.dma.buffer_bytes = self.usize_or("dma", "buffer_bytes", c.dma.buffer_bytes);
        c.remapper.max_pointers =
            self.usize_or("remapper", "max_pointers", c.remapper.max_pointers);
        c.remapper.buffer_bytes =
            self.usize_or("remapper", "buffer_bytes", c.remapper.buffer_bytes);
        if let Some(v) = self.get("memory", "tech") {
            let raw = v.as_str().ok_or_else(|| ParseError {
                line: self.line_of("memory", "tech"),
                message: "memory tech must be a string: \"ddr4\" | \"hbm2\" | \"osram\""
                    .to_string(),
            })?;
            let tech = raw.parse::<MemTech>().map_err(|_| ParseError {
                line: self.line_of("memory", "tech"),
                message: format!("unknown memory tech {raw:?}; expected ddr4 | hbm2 | osram"),
            })?;
            c.mem = tech.default_config();
        }
        if c.mem.tech() == MemTech::Ddr4 {
            let dram = c.mem.ddr4_mut();
            dram.channels = self.usize_or("dram", "channels", dram.channels);
            dram.banks = self.usize_or("dram", "banks", dram.banks);
            if let Some(v) = self.get("dram", "row_policy") {
                let raw = v.as_str().ok_or_else(|| ParseError {
                    line: self.line_of("dram", "row_policy"),
                    message: "row_policy must be a string: \"open\" | \"closed\"".to_string(),
                })?;
                dram.row_policy = raw.parse().map_err(|_| ParseError {
                    line: self.line_of("dram", "row_policy"),
                    message: format!("unknown row_policy {raw:?}; expected open | closed"),
                })?;
            }
        } else if let Some(keys) = self.sections.get("dram") {
            // Same contract as the CLI (PR 6): a `--dram-*` flag under a
            // non-DDR4 tech is an error, so a `[dram]` key must be too —
            // not silently dropped.
            if let Some(key) = keys
                .keys()
                .min_by_key(|k| self.line_of("dram", k))
                .cloned()
            {
                return Err(ParseError {
                    line: self.line_of("dram", &key),
                    message: format!(
                        "[dram] {key} shapes the DDR4 configuration, but the memory tech \
                         is {}; drop the key or set [memory] tech = \"ddr4\"",
                        c.mem.tech()
                    ),
                });
            }
        }
        Ok(c)
    }

    /// Build an [`AlsConfig`] from the `[run]` section.
    pub fn als(&self) -> AlsConfig {
        let d = AlsConfig::default();
        AlsConfig {
            rank: self.usize_or("run", "rank", d.rank),
            max_iters: self.usize_or("run", "iters", d.max_iters),
            tol: self.f64_or("run", "tol", d.tol),
            ridge: self.f64_or("run", "ridge", d.ridge as f64) as f32,
            seed: self.usize_or("run", "seed", d.seed as usize) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# comment
[run]
rank = 32
backend = "pjrt"
tol = 1e-4
verbose = true

[cache]
num_lines = 4096   # inline comment
line_bytes = 128
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("run", "rank"), Some(&Value::Int(32)));
        assert_eq!(c.get("run", "backend"), Some(&Value::Str("pjrt".into())));
        assert_eq!(c.get("run", "tol"), Some(&Value::Float(1e-4)));
        assert_eq!(c.get("run", "verbose"), Some(&Value::Bool(true)));
        assert_eq!(c.get("cache", "num_lines"), Some(&Value::Int(4096)));
    }

    #[test]
    fn defaults_fill_missing_keys() {
        let c = Config::parse(SAMPLE).unwrap();
        let ctl = c.controller(16).unwrap();
        assert_eq!(ctl.cache.num_lines, 4096);
        assert_eq!(ctl.cache.line_bytes, 128);
        assert_eq!(ctl.cache.assoc, 4); // default
        let als = c.als();
        assert_eq!(als.rank, 32);
        assert_eq!(als.max_iters, 20); // default
        assert!((als.tol - 1e-4).abs() < 1e-12);
    }

    #[test]
    fn dram_row_policy_key_parses() {
        let c = Config::parse("[dram]\nrow_policy = \"closed\"\nbanks = 8\n").unwrap();
        let ctl = c.controller(16).unwrap();
        let dram = ctl.mem.ddr4().expect("default tech is DDR4");
        assert_eq!(dram.row_policy, crate::dram::RowPolicy::Closed);
        assert_eq!(dram.banks, 8);
        // Unknown policy strings are an error naming the line — not a
        // silent fall-back to the default (a typo'd policy used to run
        // the whole sweep under open-page without a word).
        let err = Config::parse("[dram]\nrow_policy = \"adaptive\"\n")
            .unwrap()
            .controller(16)
            .unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("adaptive"), "{}", err.message);
        assert!(err.message.contains("open | closed"), "{}", err.message);
    }

    #[test]
    fn memory_tech_key_selects_technology() {
        let c = Config::parse("[memory]\ntech = \"hbm2\"\n").unwrap();
        assert_eq!(c.controller(16).unwrap().mem.tech(), MemTech::Hbm2);
        let c = Config::parse("[memory]\ntech = \"ddr4\"\n[dram]\nchannels = 4\n").unwrap();
        assert_eq!(c.controller(16).unwrap().mem.ddr4().unwrap().channels, 4);
        // Unknown tech strings are an error naming the line, not a
        // silent fall-back to DDR4.
        let err = Config::parse("[memory]\ntech = \"mram\"\n")
            .unwrap()
            .controller(16)
            .unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("mram"), "{}", err.message);
        assert!(err.message.contains("ddr4 | hbm2 | osram"), "{}", err.message);
        // No [memory] section at all: the legacy DDR4 path, untouched.
        let c = Config::parse("[dram]\nchannels = 2\n").unwrap();
        let ctl = c.controller(16).unwrap();
        assert_eq!(ctl.mem.tech(), MemTech::Ddr4);
        assert_eq!(ctl.mem.ddr4().unwrap().channels, 2);
    }

    #[test]
    fn dram_keys_under_non_ddr4_tech_error_like_the_cli() {
        // PR 6 made `--dram-* --memory-tech osram` a CLI error; the
        // config path used to drop the same keys silently.  Both now
        // fail, with the config error naming the offending line.
        let err = Config::parse("[memory]\ntech = \"osram\"\n[dram]\nchannels = 4\n")
            .unwrap()
            .controller(16)
            .unwrap_err();
        assert_eq!(err.line, 4);
        assert!(err.message.contains("channels"), "{}", err.message);
        assert!(err.message.contains("osram"), "{}", err.message);
        assert!(
            err.message.contains("shapes the DDR4 configuration"),
            "{}",
            err.message
        );
        // hbm2 too, and the earliest [dram] key is the one named.
        let err = Config::parse("[memory]\ntech = \"hbm2\"\n[dram]\nbanks = 8\nchannels = 2\n")
            .unwrap()
            .controller(16)
            .unwrap_err();
        assert_eq!(err.line, 4);
        assert!(err.message.contains("banks"), "{}", err.message);
    }

    #[test]
    fn unknown_sections_and_keys_are_rejected_with_line_numbers() {
        // The motivating typo: [dram] bank (no `s`) used to run the
        // whole experiment with the default geometry, silently.
        let err = Config::parse("[dram]\nbank = 8\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("bank"), "{}", err.message);
        assert!(err.message.contains("[dram]"), "{}", err.message);
        // Unknown section names fail at the header line.
        let err = Config::parse("\n[dramm]\nchannels = 4\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.message.contains("dramm"), "{}", err.message);
        // Keys before any section header fail too.
        let err = Config::parse("channels = 4\n").unwrap_err();
        assert_eq!(err.line, 1);
        assert!(err.message.contains("before any"), "{}", err.message);
    }

    #[test]
    fn dse_search_section_parses() {
        // The explore subcommand reads these exact keys; keep the
        // accessor contract pinned here.
        let c = Config::parse("[dse]\nsearch = \"joint\"\ntop_k = 5\n").unwrap();
        assert_eq!(c.str_or("dse", "search", "coordinate"), "joint");
        assert_eq!(c.usize_or("dse", "top_k", 1), 5);
        // Unset keys fall back to the coordinate/top-1 defaults.
        let c = Config::parse("[cache]\nnum_lines = 64\n").unwrap();
        assert_eq!(c.str_or("dse", "search", "coordinate"), "coordinate");
        assert_eq!(c.usize_or("dse", "top_k", 1), 1);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("[unclosed\n").is_err());
        assert!(Config::parse("keyvalue\n").is_err());
        assert!(Config::parse("k = @@@\n").is_err());
        let err = Config::parse("\n\nk = @@@\n").unwrap_err();
        assert_eq!(err.line, 3);
    }

    #[test]
    fn underscored_ints_parse() {
        let c = Config::parse("[cache]\nnum_lines = 1_000_000\n").unwrap();
        assert_eq!(c.usize_or("cache", "num_lines", 0), 1_000_000);
    }

    #[test]
    fn accessor_defaults() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("x", "y", 7), 7);
        assert_eq!(c.str_or("x", "y", "dflt"), "dflt");
        assert_eq!(c.f64_or("x", "y", 2.5), 2.5);
    }
}
