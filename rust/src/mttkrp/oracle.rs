//! Sequential COO spMTTKRP — paper Algorithm 2, the numeric ground truth
//! every engine (and the PJRT path) is checked against.

use crate::cpd::linalg::Mat;
use crate::tensor::SparseTensor;

/// Compute mode-`mode` MTTKRP of `t` with the given factor matrices
/// (`factors[m]` must have `t.dims()[m]` rows; all the same rank).
/// Works in any storage order.
pub fn mttkrp(t: &SparseTensor, factors: &[Mat], mode: usize) -> Mat {
    assert_eq!(factors.len(), t.n_modes());
    let r = factors[0].cols();
    for (m, f) in factors.iter().enumerate() {
        assert_eq!(f.rows(), t.dims()[m], "factor {m} row count");
        assert_eq!(f.cols(), r, "factor {m} rank");
    }
    let mut out = Mat::zeros(t.dims()[mode], r);
    accumulate_into(t, factors, mode, 0..t.nnz(), 0, &mut out);
    out
}

/// The Alg.-2 inner kernel: accumulate the contributions of the nnz
/// indices yielded by `zs` into `out`, where nnz `z` lands in row
/// `mode_col[z] - row_base`.  Shared with the sharded workers
/// ([`crate::shard`]) — a single copy of the loop is what makes the
/// sharded result *bit-identical* to this oracle, not merely close.
pub fn accumulate_into(
    t: &SparseTensor,
    factors: &[Mat],
    mode: usize,
    zs: impl Iterator<Item = usize>,
    row_base: usize,
    out: &mut Mat,
) {
    let n = t.n_modes();
    let r = factors[0].cols();
    let mut prod = vec![0.0f32; r];
    let vals = t.values();
    let col = t.mode_col(mode);
    for z in zs {
        // prod = val * hadamard of the other modes' rows (Alg. 2 line 6).
        prod.iter_mut().for_each(|p| *p = vals[z]);
        for m in 0..n {
            if m == mode {
                continue;
            }
            let row = factors[m].row(t.mode_col(m)[z] as usize);
            for (p, &x) in prod.iter_mut().zip(row) {
                *p *= x;
            }
        }
        let dst = out.row_mut(col[z] as usize - row_base);
        for (d, &p) in dst.iter_mut().zip(&prod) {
            *d += p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Coord, SparseTensor};
    use crate::testkit::assert_allclose;

    /// Dense 3-way MTTKRP by definition: A~(i,r) = sum_{j,k} X(i,j,k) B(j,r) C(k,r).
    fn dense_mttkrp_mode0(dense: &[f32], dims: &[usize], b: &Mat, c: &Mat) -> Mat {
        let (i0, i1, i2) = (dims[0], dims[1], dims[2]);
        let r = b.cols();
        let mut out = Mat::zeros(i0, r);
        for i in 0..i0 {
            for j in 0..i1 {
                for k in 0..i2 {
                    let x = dense[(i * i1 + j) * i2 + k];
                    if x == 0.0 {
                        continue;
                    }
                    for rr in 0..r {
                        let v = out.get(i, rr) + x * b.get(j, rr) * c.get(k, rr);
                        out.set(i, rr, v);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn matches_dense_definition_mode0() {
        let dims = vec![4usize, 5, 3];
        let t = crate::tensor::synth::generate(&crate::tensor::synth::SynthConfig {
            dims: dims.clone(),
            nnz: 20,
            profile: crate::tensor::synth::Profile::Uniform,
            seed: 17,
        });
        let b = Mat::randn(5, 6, 2);
        let c = Mat::randn(3, 6, 3);
        let a = Mat::zeros(4, 6); // unused by mode-0 MTTKRP
        let got = mttkrp(&t, &[a, b.clone(), c.clone()], 0);
        let want = dense_mttkrp_mode0(&t.to_dense(), &dims, &b, &c);
        assert_allclose(got.data(), want.data(), 1e-4, 1e-5);
    }

    #[test]
    fn single_nnz_hand_case() {
        // X(1,2,0) = 2.0; A~(1,r) = 2 * B(2,r) * C(0,r).
        let t = SparseTensor::new(vec![2, 3, 2], &[(vec![1 as Coord, 2, 0], 2.0)]);
        let b = Mat::from_rows(&[&[1.0, 1.0], &[2.0, 2.0], &[3.0, -1.0]]);
        let c = Mat::from_rows(&[&[10.0, 4.0], &[0.0, 0.0]]);
        let a = Mat::zeros(2, 2);
        let out = mttkrp(&t, &[a, b, c], 0);
        assert_eq!(out.row(0), &[0.0, 0.0]);
        assert_eq!(out.row(1), &[60.0, -8.0]);
    }

    #[test]
    fn order_invariant() {
        let mut t = crate::tensor::synth::generate(&crate::tensor::synth::SynthConfig {
            dims: vec![10, 12, 8],
            nnz: 100,
            profile: crate::tensor::synth::Profile::Uniform,
            seed: 5,
        });
        let factors: Vec<Mat> = t
            .dims()
            .iter()
            .enumerate()
            .map(|(m, &d)| Mat::randn(d, 4, m as u64))
            .collect();
        let before = mttkrp(&t, &factors, 1);
        t.sort_by_mode(2);
        let after = mttkrp(&t, &factors, 1);
        assert_allclose(after.data(), before.data(), 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "factor 1 row count")]
    fn rejects_mismatched_factors() {
        let t = SparseTensor::new(vec![2, 3], &[(vec![0, 0], 1.0)]);
        let a = Mat::zeros(2, 4);
        let b = Mat::zeros(999, 4);
        mttkrp(&t, &[a, b], 0);
    }
}
