//! Approach 1 with remapping — paper Algorithm 5: between modes, the
//! Tensor Remapper re-orders the COO list into the next output mode's
//! direction (lines 3–6), then Approach 1 runs without partial sums
//! (lines 7–15).  This is the paper's chosen full-decomposition scheme:
//! one tensor copy ping-pongs between two external-memory regions instead
//! of keeping N sorted copies.

use crate::controller::{MemLayout, MemoryController};
use crate::cpd::linalg::Mat;
use crate::engine::EngineKind;
use crate::tensor::{remap, SortOrder, SparseTensor};

use super::{approach1, EngineRun, Tracing};

/// Timing/traffic breakdown of one remapped-mode execution.
#[derive(Debug, Clone)]
pub struct RemappedRun {
    pub engine: EngineRun,
    /// Cycles spent in the Tensor Remapper pass (0 if no remap needed).
    pub remap_cycles: u64,
    /// Cycles spent in the Approach-1 compute trace replay.
    pub compute_cycles: u64,
    /// Remap data-movement accounting (None if no remap was needed).
    pub remap_report: Option<remap::RemapReport>,
}

impl RemappedRun {
    pub fn total_cycles(&self) -> u64 {
        self.remap_cycles + self.compute_cycles
    }

    /// Measured communication overhead of the remap: extra accesses over
    /// the Approach-1 baseline accesses (the §3 ratio).
    pub fn overhead_ratio(&self) -> f64 {
        match &self.remap_report {
            None => 0.0,
            Some(rep) => {
                rep.extra_accesses() as f64 / self.engine.counts.total_accesses() as f64
            }
        }
    }
}

/// Execute mode `mode` with remap-if-needed through the memory
/// controller `ctl` (advances its clock), updating `t` in place.
///
/// `src` is the ping-pong slot currently holding the tensor; on remap the
/// data moves to `1 - src` (the caller flips its slot tracking).
pub fn run(
    t: &mut SparseTensor,
    factors: &[Mat],
    mode: usize,
    layout: &MemLayout,
    ctl: &mut MemoryController,
    src: usize,
) -> RemappedRun {
    run_with_engine(t, factors, mode, layout, ctl, src, EngineKind::Lockstep)
}

/// [`run`] with an explicit replay core ([`crate::engine`]) for the
/// compute-trace replay: `Lockstep` replays the raw access list,
/// `Event` delta-encodes it and drives the batched kernels.  Both are
/// bit-identical in cycles and statistics.
pub fn run_with_engine(
    t: &mut SparseTensor,
    factors: &[Mat],
    mode: usize,
    layout: &MemLayout,
    ctl: &mut MemoryController,
    src: usize,
    replay_engine: EngineKind,
) -> RemappedRun {
    let t_start = ctl.now();

    // Remap pass (skipped when the tensor is already in direction).
    let (remap_cycles, remap_report) = if t.order() == SortOrder::ByMode(mode) {
        (0, None)
    } else {
        let done = ctl.remap_pass(t.mode_col(mode), t.dims()[mode], layout, src, 1 - src);
        let report = remap::remap(t, mode, ctl.config().remapper.max_pointers);
        (done - t_start, Some(report))
    };

    // Approach-1 compute with trace replay.
    let engine = approach1::run(t, factors, mode, layout, Tracing::On);
    let t_mid = ctl.now();
    let compute_cycles = replay_engine.replay_raw(ctl, &engine.trace) - t_mid;

    let mut engine = engine;
    if let Some(rep) = &remap_report {
        engine.counts.remap_accesses = rep.extra_accesses() as u64;
    }

    RemappedRun {
        engine,
        remap_cycles,
        compute_cycles,
        remap_report,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ControllerConfig;
    use crate::mttkrp::oracle;
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::testkit::assert_allclose;

    fn setup(seed: u64) -> (SparseTensor, Vec<Mat>, MemLayout, MemoryController) {
        let t = generate(&SynthConfig {
            dims: vec![60, 45, 35],
            nnz: 1_500,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed,
        });
        let factors: Vec<Mat> = t
            .dims()
            .iter()
            .enumerate()
            .map(|(m, &d)| Mat::randn(d, 16, seed ^ (m as u64) << 4))
            .collect();
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 16);
        let ctl = MemoryController::new(ControllerConfig::default_for(t.record_bytes()));
        (t, factors, layout, ctl)
    }

    #[test]
    fn produces_oracle_result_after_remap() {
        let (mut t, factors, layout, mut ctl) = setup(51);
        let want = oracle::mttkrp(&t, &factors, 1);
        let run = run(&mut t, &factors, 1, &layout, &mut ctl, 0);
        assert_allclose(run.engine.output.data(), want.data(), 1e-4, 1e-5);
        assert!(run.remap_cycles > 0, "unsorted tensor must pay a remap");
        assert!(run.compute_cycles > 0);
    }

    #[test]
    fn skips_remap_when_already_sorted() {
        let (mut t, factors, layout, mut ctl) = setup(52);
        t.sort_by_mode(2);
        let run = run(&mut t, &factors, 2, &layout, &mut ctl, 0);
        assert_eq!(run.remap_cycles, 0);
        assert!(run.remap_report.is_none());
        assert_eq!(run.overhead_ratio(), 0.0);
    }

    #[test]
    fn all_modes_in_sequence_like_cp_als() {
        // The Alg.-5 usage pattern: modes 0,1,2 back-to-back with
        // ping-pong slots; every mode's result must match the oracle.
        let (mut t, factors, layout, mut ctl) = setup(53);
        let mut src = 0;
        for mode in 0..3 {
            let want = oracle::mttkrp(&t, &factors, mode);
            let r = run(&mut t, &factors, mode, &layout, &mut ctl, src);
            if r.remap_report.is_some() {
                src = 1 - src;
            }
            assert_allclose(r.engine.output.data(), want.data(), 1e-4, 1e-5);
        }
    }

    #[test]
    fn measured_overhead_close_to_paper_formula() {
        let (mut t, factors, layout, mut ctl) = setup(54);
        let run = run(&mut t, &factors, 0, &layout, &mut ctl, 0);
        let measured = run.overhead_ratio();
        let approx = crate::tensor::remap::overhead_ratio_approx(3, 16);
        // Measured uses actual I_out stores, so it differs a little from
        // the closed form — but must be the same magnitude and < 6%.
        assert!(measured > 0.0 && measured < 0.09, "measured {measured}");
        assert!(
            (measured - approx).abs() / approx < 0.6,
            "measured {measured} vs approx {approx}"
        );
    }

    #[test]
    fn remap_cycles_scale_with_nnz() {
        let mk = |nnz| {
            let t = generate(&SynthConfig {
                dims: vec![60, 45, 35],
                nnz,
                profile: Profile::Uniform,
                seed: 7,
            });
            let factors: Vec<Mat> = t
                .dims()
                .iter()
                .map(|&d| Mat::randn(d, 8, 1))
                .collect();
            let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
            let mut ctl =
                MemoryController::new(ControllerConfig::default_for(t.record_bytes()));
            let mut t = t;
            run(&mut t, &factors, 1, &layout, &mut ctl, 0).remap_cycles
        };
        let small = mk(500);
        let big = mk(4_000);
        assert!(big > 4 * small, "remap cycles: {big} vs {small}");
    }
}
