//! Approach 1 — output-mode-direction spMTTKRP (paper Algorithm 3).
//!
//! Precondition: the tensor is sorted by the output mode, so all
//! non-zeros sharing an output coordinate arrive consecutively and the
//! output row accumulates entirely on-chip — no partial sums touch
//! external memory (the Table-1 advantage).
//!
//! Memory behaviour compiled into the trace (§4 pattern taxonomy):
//! 1. tensor elements  -> streaming loads (chunked by fiber run),
//! 2. input factor rows -> cached random loads,
//! 3. output rows       -> streaming stores.

use crate::controller::{Access, MemLayout};
use crate::cpd::linalg::Mat;
use crate::tensor::{SortOrder, SparseTensor};

use super::{counts::OpCounts, EngineRun, Tracing, STREAM_CHUNK_ELEMS};

/// Run Approach 1 for `mode`.  Panics if the tensor is not sorted by
/// `mode` (use [`crate::mttkrp::remap_exec`] to remap first).
pub fn run(
    t: &SparseTensor,
    factors: &[Mat],
    mode: usize,
    layout: &MemLayout,
    tracing: Tracing,
) -> EngineRun {
    assert_eq!(
        t.order(),
        SortOrder::ByMode(mode),
        "Approach 1 requires the tensor sorted in the output-mode direction"
    );
    let n = t.n_modes();
    let r = factors[0].cols();
    let eb = t.record_bytes();
    let row_bytes = r * 4;
    let tensor_base = layout.tensor_base[0];

    let mut output = Mat::zeros(t.dims()[mode], r);
    let mut trace = Vec::new();
    if tracing == Tracing::On {
        // §Perf: presize — (N-1) cached loads per nnz plus ~2 streams
        // per fiber; avoids repeated realloc on 100k+ nnz traces.
        trace.reserve(t.nnz() * n + t.dims()[mode]);
    }
    let mut counts = OpCounts::default();
    let mut acc = vec![0.0f32; r];
    let mut prod = vec![0.0f32; r];
    let vals = t.values();

    for (coord, start, end) in t.fiber_ranges(mode) {
        // Output row accumulator lives on-chip for the whole fiber.
        acc.iter_mut().for_each(|a| *a = 0.0);

        // Stream the fiber's tensor records (they are consecutive).
        if tracing == Tracing::On {
            let mut z = start;
            while z < end {
                let n_chunk = (end - z).min(STREAM_CHUNK_ELEMS);
                trace.push(Access::Stream {
                    addr: tensor_base + (z * eb) as u64,
                    bytes: n_chunk * eb,
                });
                z += n_chunk;
            }
        }
        counts.tensor_loads += (end - start) as u64;

        for z in start..end {
            // Gather input factor rows through the cache.
            for m in 0..n {
                if m == mode {
                    continue;
                }
                let row_idx = t.mode_col(m)[z];
                if tracing == Tracing::On {
                    trace.push(Access::Cached {
                        addr: layout.factor_row_addr(m, row_idx),
                        bytes: row_bytes,
                    });
                }
                counts.factor_loads += r as u64;
            }
            // Compute: acc += val * hadamard(other rows) — row-slice
            // form (§Perf: avoids per-scalar bounds-checked get()).
            let v = vals[z];
            prod.iter_mut().for_each(|p| *p = v);
            for m in 0..n {
                if m == mode {
                    continue;
                }
                let row = factors[m].row(t.mode_col(m)[z] as usize);
                for (p, &x) in prod.iter_mut().zip(row) {
                    *p *= x;
                }
            }
            for (a, &p) in acc.iter_mut().zip(&prod) {
                *a += p;
            }
            counts.compute_ops += (n * r) as u64;
        }

        // Store the finished output row (streaming store, Alg. 3 line 11).
        output.row_mut(coord as usize).copy_from_slice(&acc);
        if tracing == Tracing::On {
            trace.push(Access::Stream {
                addr: layout.factor_row_addr(mode, coord),
                bytes: row_bytes,
            });
        }
        counts.output_stores += r as u64;
    }

    EngineRun {
        output,
        trace,
        counts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::counts::approach1_expected;
    use crate::mttkrp::oracle;
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::testkit::assert_allclose;

    fn setup(seed: u64) -> (SparseTensor, Vec<Mat>, MemLayout) {
        let t = generate(&SynthConfig {
            dims: vec![40, 50, 30],
            nnz: 600,
            profile: Profile::Zipf { alpha_milli: 1100 },
            seed,
        });
        let factors: Vec<Mat> = t
            .dims()
            .iter()
            .enumerate()
            .map(|(m, &d)| Mat::randn(d, 8, seed ^ m as u64))
            .collect();
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
        (t, factors, layout)
    }

    #[test]
    fn matches_oracle_every_mode() {
        for mode in 0..3 {
            let (mut t, factors, layout) = setup(31);
            t.sort_by_mode(mode);
            let run = run(&t, &factors, mode, &layout, Tracing::Off);
            let want = oracle::mttkrp(&t, &factors, mode);
            assert_allclose(run.output.data(), want.data(), 1e-4, 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "requires the tensor sorted")]
    fn panics_on_unsorted_tensor() {
        let (t, factors, layout) = setup(32);
        run(&t, &factors, 0, &layout, Tracing::Off);
    }

    #[test]
    fn counts_match_closed_form() {
        let (mut t, factors, layout) = setup(33);
        t.sort_by_mode(0);
        let used_coords = crate::tensor::stats::fiber_stats(&t, 0).used_coords;
        let run = run(&t, &factors, 0, &layout, Tracing::Off);
        // Closed form charges I_out rows; the engine only writes fibers
        // that exist (used coords) — identical when every coord is used,
        // otherwise strictly fewer stores.
        let expect = approach1_expected(t.nnz() as u64, 3, 8, used_coords as u64);
        assert_eq!(run.counts.compute_ops, expect.compute_ops);
        assert_eq!(run.counts.tensor_loads, expect.tensor_loads);
        assert_eq!(run.counts.factor_loads, expect.factor_loads);
        assert_eq!(run.counts.output_stores, expect.output_stores);
        assert_eq!(run.counts.partial_stores, 0);
    }

    #[test]
    fn trace_has_no_element_accesses_and_covers_all_bytes() {
        let (mut t, factors, layout) = setup(34);
        t.sort_by_mode(1);
        let run = run(&t, &factors, 1, &layout, Tracing::On);
        let mut stream_bytes = 0usize;
        let mut cached_loads = 0u64;
        for a in &run.trace {
            match a {
                Access::Stream { bytes, .. } => stream_bytes += bytes,
                Access::Cached { .. } => cached_loads += 1,
                Access::Element { .. } | Access::CachedStore { .. } => {
                    panic!("Approach 1 must not issue element/cached-store accesses")
                }
            }
        }
        // Streams = tensor records + output rows.
        let used = crate::tensor::stats::fiber_stats(&t, 1).used_coords;
        assert_eq!(
            stream_bytes,
            t.nnz() * t.record_bytes() + used * 8 * 4
        );
        // One cached row load per (nnz, other-mode) pair.
        assert_eq!(cached_loads, (t.nnz() * 2) as u64);
    }

    #[test]
    fn tracing_off_skips_trace_but_not_counts() {
        let (mut t, factors, layout) = setup(35);
        t.sort_by_mode(0);
        let off = run(&t, &factors, 0, &layout, Tracing::Off);
        assert!(off.trace.is_empty());
        assert!(off.counts.total_accesses() > 0);
    }
}
