//! Table-1 cost model (paper §3): closed-form totals for computations,
//! external memory accesses, and partial-sum storage of the two
//! approaches, plus the measured-count accumulator the engines fill in.
//!
//! Units follow the paper: computations in scalar multiply/add
//! operations, memory accesses in *elements* (one tensor record, one
//! factor-matrix scalar, or one partial scalar each count as one), and
//! partial-sum size in scalars.

/// Measured operation counts from an engine run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Scalar multiply+add operations (the paper's "total computations").
    pub compute_ops: u64,
    /// Tensor-element loads (records).
    pub tensor_loads: u64,
    /// Factor-matrix scalars loaded.
    pub factor_loads: u64,
    /// Output factor scalars stored.
    pub output_stores: u64,
    /// Partial-sum scalars stored (Approach 2 only).
    pub partial_stores: u64,
    /// Partial-sum scalars loaded back (Approach 2 only).
    pub partial_loads: u64,
    /// Remap element loads+stores (Alg. 5 lines 4/6; in records).
    pub remap_accesses: u64,
}

impl OpCounts {
    /// Total external memory accesses in elements — the paper's Table-1
    /// second column.
    pub fn total_accesses(&self) -> u64 {
        self.tensor_loads
            + self.factor_loads
            + self.output_stores
            + self.partial_stores
            + self.partial_loads
            + self.remap_accesses
    }
}

/// Closed-form Table-1 row for Approach 1: computations `N*|T|*R`,
/// accesses `|T| + (N-1)*|T|*R + I_out*R`, partial sums `0`.
pub fn approach1_expected(nnz: u64, n_modes: u64, rank: u64, i_out: u64) -> OpCounts {
    OpCounts {
        compute_ops: n_modes * nnz * rank,
        tensor_loads: nnz,
        factor_loads: (n_modes - 1) * nnz * rank,
        output_stores: i_out * rank,
        ..Default::default()
    }
}

/// Closed-form Table-1 row for Approach 2: computations `N*|T|*R`,
/// accesses `|T| + N*|T|*R + I_in*R`, partial sums `|T|*R`.
///
/// The paper's accounting charges `(N-1)*|T|*R` factor transfers plus
/// the additional `|T|*R` partial-sum *stores* — it does not charge the
/// accumulate phase's partial re-loads (Alg. 4 line 15), so the paper
/// row is a **lower bound**; the measured engine counts include them
/// (see `approach2::run`), which only widens Approach 1's advantage.
pub fn approach2_expected(nnz: u64, n_modes: u64, rank: u64, i_in: u64) -> OpCounts {
    OpCounts {
        compute_ops: n_modes * nnz * rank,
        tensor_loads: nnz,
        factor_loads: (n_modes - 1) * nnz * rank,
        output_stores: i_in * rank,
        partial_stores: nnz * rank,
        partial_loads: 0, // paper's Table-1 row omits these
        ..Default::default()
    }
}

/// Paper Table 1 "Total external memory accesses" for Approach 1.
pub fn table1_accesses_a1(nnz: u64, n_modes: u64, rank: u64, i_out: u64) -> u64 {
    nnz + (n_modes - 1) * nnz * rank + i_out * rank
}

/// Paper Table 1 "Total external memory accesses" for Approach 2.
pub fn table1_accesses_a2(nnz: u64, n_modes: u64, rank: u64, i_in: u64) -> u64 {
    nnz + n_modes * nnz * rank + i_in * rank
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approach1_closed_form() {
        let c = approach1_expected(1000, 3, 16, 50);
        assert_eq!(c.compute_ops, 3 * 1000 * 16);
        assert_eq!(c.total_accesses(), 1000 + 2 * 1000 * 16 + 50 * 16);
        assert_eq!(c.partial_stores, 0);
    }

    #[test]
    fn approach2_has_partial_traffic() {
        let c = approach2_expected(1000, 3, 16, 40);
        assert_eq!(c.compute_ops, 3 * 1000 * 16);
        assert_eq!(c.partial_stores, 16_000);
        // Total matches the paper row |T| + N|T|R + I_in R.
        assert_eq!(c.total_accesses(), table1_accesses_a2(1000, 3, 16, 40));
    }

    #[test]
    fn approach1_always_fewer_accesses_for_realistic_shapes() {
        // Paper's Table-1 message: Approach 1 wins whenever I_out R and
        // I_in R are small next to |T| R (always true for sparse tensors
        // with nnz >> dims).
        for &(nnz, n, r, i) in &[
            (100_000u64, 3u64, 16u64, 10_000u64),
            (1_000_000, 4, 32, 39_000),
            (50_000, 5, 8, 5_000),
        ] {
            assert!(table1_accesses_a1(nnz, n, r, i) < table1_accesses_a2(nnz, n, r, i));
        }
    }

    #[test]
    fn a2_minus_a1_equals_partial_sum_traffic_when_modes_match() {
        // With I_out == I_in the entire gap is the |T|*R partial traffic.
        let (nnz, n, r, i) = (10_000, 3, 16, 1_000);
        assert_eq!(
            table1_accesses_a2(nnz, n, r, i) - table1_accesses_a1(nnz, n, r, i),
            nnz * r
        );
    }
}
