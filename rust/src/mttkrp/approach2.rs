//! Approach 2 — input-mode-direction spMTTKRP (paper Algorithm 4).
//!
//! The tensor is ordered by an *input* mode: the input factor row is
//! loaded once per fiber, but every non-zero produces a partial output
//! row that must be stored to — and later accumulated from — external
//! memory (`|T| x R` scalars, the Table-1 "size of total partial sums").
//! The accumulation phase then walks the partials in *output* order,
//! which is a random element-wise pattern: this is exactly why the paper
//! rules Approach 2 impractical on FPGA (§3.1).

use crate::controller::{Access, MemLayout};
use crate::cpd::linalg::Mat;
use crate::tensor::{SortOrder, SparseTensor};

use super::{counts::OpCounts, EngineRun, Tracing, STREAM_CHUNK_ELEMS};

/// Run Approach 2 computing the MTTKRP of `out_mode`, with the tensor
/// sorted by `in_mode` (any mode other than `out_mode`).
pub fn run(
    t: &SparseTensor,
    factors: &[Mat],
    out_mode: usize,
    in_mode: usize,
    layout: &MemLayout,
    tracing: Tracing,
) -> EngineRun {
    assert_ne!(out_mode, in_mode, "input mode must differ from output");
    assert_eq!(
        t.order(),
        SortOrder::ByMode(in_mode),
        "Approach 2 requires the tensor sorted by the input mode"
    );
    let n = t.n_modes();
    let r = factors[0].cols();
    let eb = t.record_bytes();
    let row_bytes = r * 4;
    let tensor_base = layout.tensor_base[0];
    let vals = t.values();

    let mut trace = Vec::new();
    let mut counts = OpCounts::default();

    // ---- Phase 1 (Alg. 4 lines 3-10): compute + store partials --------
    // partials[z] = val_z * prod of all input-mode rows; kept in host
    // memory standing in for the FPGA's external partial region.
    let mut partials = vec![0.0f32; t.nnz() * r];
    for (in_coord, start, end) in t.fiber_ranges(in_mode) {
        // Load the input-mode factor row once per fiber (line 4).
        if tracing == Tracing::On {
            trace.push(Access::Cached {
                addr: layout.factor_row_addr(in_mode, in_coord),
                bytes: row_bytes,
            });
            let mut z = start;
            while z < end {
                let n_chunk = (end - z).min(STREAM_CHUNK_ELEMS);
                trace.push(Access::Stream {
                    addr: tensor_base + (z * eb) as u64,
                    bytes: n_chunk * eb,
                });
                z += n_chunk;
            }
        }
        counts.factor_loads += r as u64;
        counts.tensor_loads += (end - start) as u64;

        for z in start..end {
            for m in 0..n {
                if m == out_mode || m == in_mode {
                    continue;
                }
                if tracing == Tracing::On {
                    trace.push(Access::Cached {
                        addr: layout.factor_row_addr(m, t.mode_col(m)[z]),
                        bytes: row_bytes,
                    });
                }
                counts.factor_loads += r as u64;
            }
            let p = &mut partials[z * r..(z + 1) * r];
            for (rr, slot) in p.iter_mut().enumerate() {
                let mut v = vals[z];
                for m in 0..n {
                    if m == out_mode {
                        continue;
                    }
                    v *= factors[m].get(t.mode_col(m)[z] as usize, rr);
                }
                *slot = v;
            }
            // (N-1) multiplies per scalar; the accumulate add is phase 2.
            counts.compute_ops += ((n - 1) * r) as u64;
            // Element-wise partial store (line 10) — no locality.
            if tracing == Tracing::On {
                trace.push(Access::Element {
                    addr: layout.partial_base + (z * row_bytes) as u64,
                    bytes: row_bytes,
                });
            }
            counts.partial_stores += r as u64;
        }
    }

    // ---- Phase 2 (Alg. 4 lines 11-17): accumulate by output coord -----
    // Bucket nnz indices by output coordinate (the FPGA would re-walk the
    // partial region; the bucket list reproduces its access order).
    let i_out = t.dims()[out_mode];
    let mut heads = vec![usize::MAX; i_out];
    let mut next = vec![usize::MAX; t.nnz()];
    for z in (0..t.nnz()).rev() {
        let c = t.mode_col(out_mode)[z] as usize;
        next[z] = heads[c];
        heads[c] = z;
    }

    let mut output = Mat::zeros(i_out, r);
    for c in 0..i_out {
        let mut z = heads[c];
        if z == usize::MAX {
            continue;
        }
        let row = output.row_mut(c);
        while z != usize::MAX {
            // Element-wise partial load (line 15) — random order.
            if tracing == Tracing::On {
                trace.push(Access::Element {
                    addr: layout.partial_base + (z * row_bytes) as u64,
                    bytes: row_bytes,
                });
            }
            counts.partial_loads += r as u64;
            for (d, &p) in row.iter_mut().zip(&partials[z * r..(z + 1) * r]) {
                *d += p;
            }
            counts.compute_ops += r as u64;
            z = next[z];
        }
        // Store the finished output row (line 17).
        if tracing == Tracing::On {
            trace.push(Access::Stream {
                addr: layout.factor_row_addr(out_mode, c as u32),
                bytes: row_bytes,
            });
        }
        counts.output_stores += r as u64;
    }

    EngineRun {
        output,
        trace,
        counts,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::{approach1, oracle};
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::testkit::assert_allclose;

    fn setup(seed: u64) -> (SparseTensor, Vec<Mat>, MemLayout) {
        let t = generate(&SynthConfig {
            dims: vec![30, 40, 25],
            nnz: 500,
            profile: Profile::Zipf { alpha_milli: 1100 },
            seed,
        });
        let factors: Vec<Mat> = t
            .dims()
            .iter()
            .enumerate()
            .map(|(m, &d)| Mat::randn(d, 8, seed ^ (m as u64) << 8))
            .collect();
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 8);
        (t, factors, layout)
    }

    #[test]
    fn matches_oracle_for_all_mode_pairs() {
        for out_mode in 0..3 {
            for in_mode in 0..3 {
                if in_mode == out_mode {
                    continue;
                }
                let (mut t, factors, layout) = setup(41);
                t.sort_by_mode(in_mode);
                let run = run(&t, &factors, out_mode, in_mode, &layout, Tracing::Off);
                let want = oracle::mttkrp(&t, &factors, out_mode);
                assert_allclose(run.output.data(), want.data(), 1e-4, 1e-5);
            }
        }
    }

    #[test]
    fn agrees_with_approach1() {
        let (mut t, factors, layout) = setup(42);
        t.sort_by_mode(1);
        let a2 = run(&t, &factors, 0, 1, &layout, Tracing::Off);
        t.sort_by_mode(0);
        let a1 = approach1::run(&t, &factors, 0, &layout, Tracing::Off);
        assert_allclose(a2.output.data(), a1.output.data(), 1e-4, 1e-5);
    }

    #[test]
    fn partial_sum_traffic_matches_table1() {
        let (mut t, factors, layout) = setup(43);
        t.sort_by_mode(2);
        let run = run(&t, &factors, 0, 2, &layout, Tracing::Off);
        let nnz_r = (t.nnz() * 8) as u64;
        assert_eq!(run.counts.partial_stores, nnz_r);
        assert_eq!(run.counts.partial_loads, nnz_r);
        // Total compute matches the paper: N * |T| * R.
        assert_eq!(run.counts.compute_ops, 3 * nnz_r);
    }

    #[test]
    fn trace_contains_element_accesses_for_partials() {
        let (mut t, factors, layout) = setup(44);
        t.sort_by_mode(1);
        let run = run(&t, &factors, 0, 1, &layout, Tracing::On);
        let elements = run
            .trace
            .iter()
            .filter(|a| matches!(a, Access::Element { .. }))
            .count();
        // One element store + one element load per nnz.
        assert_eq!(elements, 2 * t.nnz());
    }

    #[test]
    fn more_total_accesses_than_approach1() {
        let (mut t, factors, layout) = setup(45);
        t.sort_by_mode(1);
        let a2 = run(&t, &factors, 0, 1, &layout, Tracing::Off);
        t.sort_by_mode(0);
        let a1 = approach1::run(&t, &factors, 0, &layout, Tracing::Off);
        assert!(
            a2.counts.total_accesses() > a1.counts.total_accesses(),
            "A2 {} must exceed A1 {}",
            a2.counts.total_accesses(),
            a1.counts.total_accesses()
        );
    }

    #[test]
    #[should_panic(expected = "sorted by the input mode")]
    fn panics_on_wrong_sort() {
        let (mut t, factors, layout) = setup(46);
        t.sort_by_mode(0);
        run(&t, &factors, 0, 1, &layout, Tracing::Off);
    }
}
