//! spMTTKRP compute engines (S8): the paper's two compute patterns (§3)
//! plus Approach 1 with remapping (Alg. 5), each producing both the
//! numeric result and the memory-access trace its FPGA execution would
//! issue to the memory controller.
//!
//! * [`oracle`] — sequential COO spMTTKRP (paper Alg. 2), the numeric
//!   ground truth.
//! * [`approach1`] — output-mode-direction computation (Alg. 3): no
//!   partial sums; requires the tensor sorted by the output mode.
//! * [`approach2`] — input-mode-direction computation (Alg. 4): streams
//!   an input mode, stores |T| partial rows in external memory, then
//!   accumulates them.
//! * [`remap_exec`] — Alg. 5: Tensor-Remapper pass (re-sorting the tensor
//!   in the output direction) followed by Approach 1.
//! * [`counts`] — the closed-form Table-1 cost model.

pub mod approach1;
pub mod approach2;
pub mod counts;
pub mod oracle;
pub mod remap_exec;

pub use counts::OpCounts;

use crate::controller::Access;
use crate::cpd::linalg::Mat;

/// Coalesce at most this many consecutive tensor records into one
/// streaming load (a DMA buffer's worth at 16 B/record).  Shared by the
/// sequential engines and the sharded executor ([`crate::shard`]) so
/// their DMA chunking models stay comparable.
pub const STREAM_CHUNK_ELEMS: usize = 1024;

/// Result of one MTTKRP engine run: the updated (un-normalized) output
/// factor matrix, the memory trace (empty when tracing is disabled), and
/// the operation counts for the Table-1 comparison.
#[derive(Debug, Clone)]
pub struct EngineRun {
    pub output: Mat,
    pub trace: Vec<Access>,
    pub counts: OpCounts,
}

/// Whether an engine should also produce its memory trace (tracing a
/// 100k-nnz tensor allocates a few MB; numeric-only runs skip it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tracing {
    On,
    Off,
}
