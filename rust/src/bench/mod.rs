//! Bench harness (S16; no criterion in the offline build): warmed-up
//! wall-clock timing with min/mean/max, aligned table printing, and CSV
//! emission for the per-table/figure bench binaries under `rust/benches/`.

use std::time::{Duration, Instant};

/// True when `PTMC_BENCH_SMOKE` is set: benches shrink their workloads
/// to seconds-scale "does it still run" checks (the CI bench-smoke job)
/// and skip statistical shape assertions that need full-size workloads.
/// Compile bit-rot and panics still fail the run.
pub fn smoke() -> bool {
    std::env::var_os("PTMC_BENCH_SMOKE").is_some()
}

/// `full` normally, `small` under [`smoke`] — the one-liner benches use
/// to scale nnz counts and iteration counts.
pub fn sized(full: usize, small: usize) -> usize {
    if smoke() {
        small
    } else {
        full
    }
}

/// Result of timing one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub iters: u32,
    pub min: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl Timing {
    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Time `f` with `warmup` unrecorded runs then `iters` recorded runs.
/// `f` must return something opaque to keep the optimizer honest; its
/// result is black-boxed.
pub fn time<T>(warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> Timing {
    assert!(iters >= 1);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut min = Duration::MAX;
    let mut max = Duration::ZERO;
    let mut total = Duration::ZERO;
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        let dt = t0.elapsed();
        min = min.min(dt);
        max = max.max(dt);
        total += dt;
    }
    Timing {
        iters,
        min,
        mean: total / iters,
        max,
    }
}

/// Optimization barrier (std::hint::black_box wrapper, kept local so the
/// bench binaries don't import std::hint everywhere).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// A simple fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (no quoting needed for our numeric tables).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Print to stdout and optionally write a CSV next to the bench.
    pub fn emit(&self, title: &str, csv_path: Option<&std::path::Path>) {
        println!("\n== {title} ==");
        println!("{}", self.render());
        if let Some(p) = csv_path {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(p, self.to_csv()) {
                eprintln!("warning: failed to write {}: {e}", p.display());
            } else {
                println!("[csv written to {}]", p.display());
            }
        }
    }
}

/// Extract the value of a top-level `"key": <value>` member from a
/// JSON object text, by balanced-brace scan (no JSON parser in the
/// offline build).  Returns the raw value text (object, array, string,
/// or scalar).  Used by the bench binaries that share one trajectory
/// file (`BENCH_dse.json`) so each can preserve the sections the
/// others own.
pub fn json_section(text: &str, key: &str) -> Option<String> {
    let trimmed = text.trim();
    if !trimmed.starts_with('{') {
        return None;
    }
    top_level_member(trimmed, key).map(|(s, e)| trimmed[s..e].to_string())
}

/// Insert or replace the top-level `"key": <value>` member of a JSON
/// object text, preserving every other member verbatim.  A missing or
/// non-object `text` produces a fresh one-member object.
pub fn upsert_json_section(text: &str, key: &str, value: &str) -> String {
    let trimmed = text.trim();
    if trimmed.is_empty() || !trimmed.starts_with('{') {
        return format!("{{\n  \"{key}\": {value}\n}}\n");
    }
    if let Some((vstart, vend)) = top_level_member(trimmed, key) {
        return format!("{}{}{}\n", &trimmed[..vstart], value, &trimmed[vend..]);
    }
    let close = match trimmed.rfind('}') {
        Some(c) => c,
        None => return format!("{{\n  \"{key}\": {value}\n}}\n"),
    };
    let body = trimmed[..close].trim_end();
    let comma = if body.ends_with('{') { "" } else { "," };
    format!("{body}{comma}\n  \"{key}\": {value}\n}}\n")
}

/// A held sibling lockfile; removing it on drop releases the lock even
/// when the critical section errors out.
struct SectionLock {
    path: std::path::PathBuf,
}

impl Drop for SectionLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// How long a waiter spins on someone else's `.lock` before declaring
/// it stale (a crashed holder) and breaking it.  Upserts are
/// millisecond-scale, so seconds of waiting means the holder is gone.
const LOCK_STALE: Duration = Duration::from_secs(10);

/// Acquire the exclusive sibling `<path minus extension>.lock` file.
/// `create_new` is the atomic claim: exactly one process wins; losers
/// sleep and retry until the holder releases (or crashed and the lock
/// goes stale).
fn lock_sibling(path: &std::path::Path) -> std::io::Result<SectionLock> {
    let lock_path = path.with_extension("lock");
    let deadline = Instant::now() + LOCK_STALE;
    loop {
        match std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&lock_path)
        {
            Ok(_) => {
                return Ok(SectionLock {
                    path: lock_path.clone(),
                })
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                if Instant::now() >= deadline {
                    // The holder has been gone for the whole window:
                    // break its lock and race create_new again (only
                    // one breaker wins the recreate).
                    let _ = std::fs::remove_file(&lock_path);
                } else {
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            Err(e) => return Err(e),
        }
    }
}

/// Read-modify-write a `"key": <section>` member into the JSON object
/// file at `path`, atomically (tmp + rename, so a crash mid-write
/// leaves the previous file intact) and behind the `bench.upsert`
/// failpoint.  Transient IO errors are retried.  The read-merge-write
/// runs under an exclusive sibling `.lock` file, so concurrent bench
/// binaries upserting *different* sections serialize instead of
/// reading the same base text and silently dropping each other's
/// sections on the final rename.
pub fn upsert_json_file(
    path: &std::path::Path,
    key: &str,
    section: &str,
) -> std::io::Result<()> {
    let _lock = lock_sibling(path)?;
    crate::util::fault::retry_transient(3, || {
        crate::util::fault::check_io(crate::util::fault::BENCH_UPSERT)?;
        let old = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => String::new(),
            Err(e) => return Err(e),
        };
        let merged = upsert_json_section(&old, key, section);
        crate::util::write_atomic(path, merged.as_bytes())
    })
}

/// Byte index one past the closing quote of the string starting at
/// `start` (which must index a `"`), honoring backslash escapes.
fn skip_string(b: &[u8], start: usize) -> usize {
    let mut i = start + 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    b.len()
}

/// Byte range `(start, end)` of the value of the top-level member
/// named `key`, or None.
fn top_level_member(text: &str, key: &str) -> Option<(usize, usize)> {
    let b = text.as_bytes();
    let mut i = 0usize;
    let mut depth = 0i32;
    while i < b.len() {
        match b[i] {
            b'"' => {
                let end = skip_string(b, i);
                if depth == 1 && &text[i + 1..end - 1] == key {
                    let mut j = end;
                    while j < b.len() && b[j].is_ascii_whitespace() {
                        j += 1;
                    }
                    if j < b.len() && b[j] == b':' {
                        let mut k = j + 1;
                        while k < b.len() && b[k].is_ascii_whitespace() {
                            k += 1;
                        }
                        return Some((k, value_end(b, k)));
                    }
                }
                i = end;
            }
            b'{' | b'[' => {
                depth += 1;
                i += 1;
            }
            b'}' | b']' => {
                depth -= 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    None
}

/// Byte index one past the value starting at `start`: a balanced
/// object/array, a string, or a scalar running to the next top-level
/// comma / closing brace.
fn value_end(b: &[u8], start: usize) -> usize {
    match b.get(start) {
        Some(b'{') | Some(b'[') => {
            let mut depth = 0i32;
            let mut i = start;
            while i < b.len() {
                match b[i] {
                    b'"' => {
                        i = skip_string(b, i);
                        continue;
                    }
                    b'{' | b'[' => depth += 1,
                    b'}' | b']' => {
                        depth -= 1;
                        if depth == 0 {
                            return i + 1;
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
            b.len()
        }
        Some(b'"') => skip_string(b, start),
        _ => {
            let mut i = start;
            while i < b.len() && b[i] != b',' && b[i] != b'}' && b[i] != b'\n' {
                i += 1;
            }
            while i > start && b[i - 1].is_ascii_whitespace() {
                i -= 1;
            }
            i
        }
    }
}

/// Format a cycle count with thousands separators.
pub fn fmt_cycles(c: u64) -> String {
    let s = c.to_string();
    let mut out = String::new();
    for (i, ch) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push('_');
        }
        out.push(ch);
    }
    out
}

/// Format a ratio as `x.xx×`.
pub fn fmt_speedup(r: f64) -> String {
    format!("{r:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_consistent_stats() {
        let t = time(1, 5, || {
            std::thread::sleep(Duration::from_micros(200));
            42
        });
        assert_eq!(t.iters, 5);
        assert!(t.min <= t.mean && t.mean <= t.max);
        assert!(t.min >= Duration::from_micros(150));
    }

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut tb = Table::new(&["name", "cycles"]);
        tb.row(&["a".into(), "100".into()]);
        tb.row(&["longer-name".into(), "2".into()]);
        let r = tb.render();
        assert!(r.contains("longer-name"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        let csv = tb.to_csv();
        assert_eq!(csv, "name,cycles\na,100\nlonger-name,2\n");
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_rejects_ragged_rows() {
        let mut tb = Table::new(&["a", "b"]);
        tb.row(&["only-one".into()]);
    }

    #[test]
    fn cycle_formatting() {
        assert_eq!(fmt_cycles(1234567), "1_234_567");
        assert_eq!(fmt_cycles(42), "42");
        assert_eq!(fmt_speedup(2.5), "2.50x");
    }

    #[test]
    fn upsert_creates_object_from_nothing() {
        let out = upsert_json_section("", "streaming", "{\n    \"nnz\": 5\n  }");
        assert_eq!(json_section(&out, "streaming"), Some("{\n    \"nnz\": 5\n  }".into()));
    }

    #[test]
    fn upsert_appends_to_existing_object_preserving_members() {
        let base = "{\n  \"bench\": \"dse_engines\",\n  \"nested\": {\n    \"a\": [1, 2]\n  }\n}\n";
        let out = upsert_json_section(base, "streaming", "{ \"nnz_per_s\": 1.5e6 }");
        assert_eq!(json_section(&out, "bench"), Some("\"dse_engines\"".into()));
        assert_eq!(
            json_section(&out, "nested"),
            Some("{\n    \"a\": [1, 2]\n  }".into())
        );
        assert_eq!(
            json_section(&out, "streaming"),
            Some("{ \"nnz_per_s\": 1.5e6 }".into())
        );
    }

    #[test]
    fn upsert_replaces_existing_section_in_place() {
        let base = "{\n  \"streaming\": { \"old\": true },\n  \"keep\": 42\n}\n";
        let out = upsert_json_section(base, "streaming", "{ \"new\": 1 }");
        assert_eq!(json_section(&out, "streaming"), Some("{ \"new\": 1 }".into()));
        assert_eq!(json_section(&out, "keep"), Some("42".into()));
        assert!(!out.contains("old"), "stale section must be gone");
    }

    #[test]
    fn concurrent_file_upserts_keep_every_section() {
        // Regression: two binaries racing read-modify-write on the
        // shared trajectory file used to drop whichever section lost
        // the final rename.  With the sibling lock, all writers'
        // sections must survive.
        let dir = std::env::temp_dir().join(format!(
            "ptmc_bench_upsert_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("traj.json");
        let _ = std::fs::remove_file(&path);
        let n = 8;
        std::thread::scope(|scope| {
            for w in 0..n {
                let path = &path;
                scope.spawn(move || {
                    for round in 0..5 {
                        upsert_json_file(
                            path,
                            &format!("section_{w}"),
                            &format!("{{ \"round\": {round} }}"),
                        )
                        .unwrap();
                    }
                });
            }
        });
        let text = std::fs::read_to_string(&path).unwrap();
        for w in 0..n {
            assert_eq!(
                json_section(&text, &format!("section_{w}")),
                Some("{ \"round\": 4 }".to_string()),
                "section_{w} lost in {text}"
            );
        }
        assert!(
            !path.with_extension("lock").exists(),
            "lockfile must be released"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn section_lookup_ignores_nested_keys_and_brace_strings() {
        let text = "{\n  \"outer\": { \"target\": \"inner{]\" },\n  \"target\": [1, {\"x\": 2}]\n}";
        assert_eq!(json_section(text, "target"), Some("[1, {\"x\": 2}]".into()));
        assert_eq!(json_section(text, "missing"), None);
    }
}
