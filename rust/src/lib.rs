//! # ptmc — Programmable Tensor Memory Controller
//!
//! A full-stack reproduction of *"Towards Programmable Memory Controller
//! for Tensor Decomposition"* (Wijeratne, Wang, Kannan, Prasanna, 2022):
//! sparse-MTTKRP-centric CP-ALS tensor decomposition built around a
//! cycle-approximate model of the paper's programmable FPGA memory
//! controller (Cache Engine + DMA Engine + Tensor Remapper), its
//! Performance Model Simulator (PMS), and a design-space explorer.
//!
//! Architecture (DESIGN.md §6): a three-layer Rust + JAX + Pallas stack.
//! Layer 3 (this crate) owns the event loop, the memory-controller
//! simulation, CP-ALS orchestration, metrics, and CLI.  Layers 2/1 (JAX
//! graph + Pallas kernel) are AOT-compiled to HLO-text artifacts at build
//! time and executed from Rust via the PJRT C API ([`runtime`]); Python
//! never runs on the request path.
//!
//! Module map (system inventory in DESIGN.md §4):
//! * [`tensor`] — COO sparse tensors, FROSTT IO, synthetic generators,
//!   mode sort / remap, access-pattern statistics. (S1)
//! * [`dram`] — bank / row-buffer DRAM timing model. (S2)
//! * [`engine`] — lockstep vs event-driven simulation cores behind one
//!   [`engine::SimEngine`] trait, the delta-encoded
//!   [`engine::CompressedTrace`] both replay (S19), the one-pass cache
//!   grid classifier [`engine::grid`] (S20), and the vectorized
//!   multi-candidate DRAM/DMA timing core [`engine::timing`] (S21)
//! * [`controller`] — Cache Engine, DMA Engine, Tensor Remapper, and the
//!   memory-controller top that routes the paper's three transfer types.
//!   (S3–S6)
//! * [`mem`] — the [`mem::MemoryDevice`] trait and [`mem::MemDevice`]
//!   dispatcher behind which the DDR4, HBM2, and optical-SRAM external
//!   memory models live; memory technology as a DSE axis. (S24)
//! * [`fpga`] — BRAM/URAM resource accounting and device catalog. (S7)
//! * [`mttkrp`] — Approach 1 / Approach 2 / Approach-1-with-remap compute
//!   engines and their memory-trace generators. (S8)
//! * [`cpd`] — CP-ALS with from-scratch dense linear algebra. (S9)
//! * [`pms`] — analytic Performance Model Simulator. (S10)
//! * [`dse`] — module-by-module exhaustive design-space search. (S11)
//! * [`runtime`] — PJRT artifact loading and execution. (S12)
//! * [`serve`] — persistent multi-tenant DSE service: length-prefixed
//!   socket protocol, fixed worker pool, and the cross-query memo
//!   layer ([`dse::MemoStore`]) that lets concurrent explorations of
//!   the same tensor share classification and simulation work. (S32)
//! * [`coordinator`] — block batching leader + worker pool. (S13)
//! * [`shard`] — output-disjoint nnz sharding + the multi-threaded
//!   [`shard::ParallelBackend`] (one worker and one simulated memory
//!   controller per shard). (S17)
//! * [`cli`], [`config`] — hand-rolled CLI and config (offline build:
//!   no clap/serde available). (S14)
//! * [`testkit`] — PRNG + mini property-test harness (no proptest). (S15)
//! * [`bench`] — timing harness + table emitters (no criterion). (S16)
//! * [`error`] — vendored minimal error type (no anyhow). (S18)
//! * [`util`] — shared scoped-thread fan-out helper. (S22)

pub mod bench;
pub mod cli;
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod cpd;
pub mod dram;
pub mod dse;
pub mod engine;
pub mod error;
pub mod fpga;
pub mod mem;
pub mod mttkrp;
pub mod pms;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod tensor;
pub mod testkit;
pub mod util;
