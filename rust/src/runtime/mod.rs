//! PJRT runtime (S12): load the AOT-compiled HLO-text artifacts emitted
//! by `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Interchange is **HLO text** — jax >= 0.5 serializes HloModuleProto
//! with 64-bit instruction ids that the crate's bundled xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py and
//! /opt/xla-example/README.md).  Python is never invoked here: the
//! artifacts directory is the only contract between the layers.
//!
//! Execution requires the `pjrt` cargo feature (which in turn needs the
//! xla_extension bindings baked into the offline image).  Without it the
//! runtime still opens artifact directories and serves manifest metadata
//! — so manifest tooling and the coordinator's packing paths stay
//! testable on a bare toolchain — but every dispatch returns a clean
//! "built without pjrt" error.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::{bail, err};

/// Parsed `manifest.txt` entry describing one artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// "mttkrp" or "rowsolve".
    pub kind: String,
    /// Extra key=value fields (modes, seg, blk, s, r, tile ...).
    pub fields: HashMap<String, String>,
}

impl ArtifactMeta {
    /// Integer field accessor (`blk`, `s`, `r`, `modes`, `tile`).
    pub fn int(&self, key: &str) -> Option<usize> {
        self.fields.get(key)?.parse().ok()
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }
}

/// Parse a manifest file's contents.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactMeta>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut fields = HashMap::new();
        for kv in line.split_whitespace() {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| err!("manifest line {}: bad field {kv:?}", lineno + 1))?;
            fields.insert(k.to_string(), v.to_string());
        }
        let name = fields
            .remove("name")
            .ok_or_else(|| err!("manifest line {}: missing name", lineno + 1))?;
        let file = fields
            .remove("file")
            .ok_or_else(|| err!("manifest line {}: missing file", lineno + 1))?;
        let kind = fields
            .remove("kind")
            .ok_or_else(|| err!("manifest line {}: missing kind", lineno + 1))?;
        out.push(ArtifactMeta {
            name,
            file,
            kind,
            fields,
        });
    }
    Ok(out)
}

/// A compiled, ready-to-execute artifact.
#[cfg(feature = "pjrt")]
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with the given input literals; returns the tuple-unwrapped
    /// first output literal (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| err!("executing {}: {e}", self.meta.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetching result of {}: {e}", self.meta.name))?;
        lit.to_tuple1()
            .map_err(|e| err!("unwrapping result of {}: {e}", self.meta.name))
    }
}

/// The PJRT runtime: one CPU client plus compiled executables, loaded
/// lazily from an artifacts directory and cached by name.
pub struct Runtime {
    dir: PathBuf,
    manifest: Vec<ArtifactMeta>,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Open `dir` (must contain `manifest.txt`).  Compilation happens on
    /// first use of each artifact.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = parse_manifest(&text)?;
        if manifest.is_empty() {
            bail!("empty manifest at {}", manifest_path.display());
        }
        Ok(Runtime {
            dir: dir.to_path_buf(),
            manifest,
            #[cfg(feature = "pjrt")]
            client: xla::PjRtClient::cpu().map_err(|e| err!("PJRT cpu client: {e}"))?,
            #[cfg(feature = "pjrt")]
            cache: HashMap::new(),
        })
    }

    /// Artifact directory default used by the CLI/examples: `./artifacts`.
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new("artifacts"))
    }

    /// The artifacts directory this runtime was opened on.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &[ArtifactMeta] {
        &self.manifest
    }

    /// Find the block-MTTKRP artifact for a tensor with `modes` modes,
    /// rank `r`, and segment encoding `seg` ("onehot"/"segids"/"refseg").
    ///
    /// Block-size policy (measured in the §Perf pass): the one-hot form
    /// does `S x BLK x R` MACs per block — work grows ~quadratically in
    /// block size, so the *smallest* block wins on compute-bound
    /// backends.  The segment forms are linear in BLK, so the *largest*
    /// block wins (fewer dispatches at the same total work).
    pub fn find_mttkrp(&self, modes: usize, r: usize, seg: &str) -> Option<&ArtifactMeta> {
        let candidates = self.manifest.iter().filter(|m| {
            m.kind == "mttkrp"
                && m.int("modes") == Some(modes)
                && m.int("r") == Some(r)
                && m.str("seg") == Some(seg)
        });
        if seg == "onehot" {
            candidates.min_by_key(|m| m.int("blk").unwrap_or(usize::MAX))
        } else {
            candidates.max_by_key(|m| m.int("blk").unwrap_or(0))
        }
    }

    /// Find the ALS row-solve artifact for rank `r`.
    pub fn find_rowsolve(&self, r: usize) -> Option<&ArtifactMeta> {
        self.manifest
            .iter()
            .find(|m| m.kind == "rowsolve" && m.int("r") == Some(r))
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Get (compiling on first use) the executable named `name`.
    pub fn executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .iter()
                .find(|m| m.name == name)
                .ok_or_else(|| err!("artifact {name:?} not in manifest"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
            )
            .map_err(|e| err!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err!("compiling {name}: {e}"))?;
            self.cache.insert(name.to_string(), Executable { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute one MTTKRP block through the `onehot` artifact.
    ///
    /// * `seg_onehot` — row-major `[s, blk]` scatter matrix.
    /// * `vals` — `[blk]`.
    /// * `rows` — `modes-1` row-major `[blk, r]` gathered factor blocks.
    ///
    /// Returns the row-major `[s, r]` partial output.
    pub fn mttkrp_block_onehot(
        &mut self,
        name: &str,
        seg_onehot: &[f32],
        vals: &[f32],
        rows: &[&[f32]],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let (blk, s, r) = (
            exe.meta.int("blk").context("blk")?,
            exe.meta.int("s").context("s")?,
            exe.meta.int("r").context("r")?,
        );
        crate::ensure!(seg_onehot.len() == s * blk, "seg_onehot shape");
        crate::ensure!(vals.len() == blk, "vals shape");
        let mut inputs = Vec::with_capacity(rows.len() + 2);
        inputs.push(
            xla::Literal::vec1(seg_onehot)
                .reshape(&[s as i64, blk as i64])
                .map_err(|e| err!("reshaping seg_onehot: {e}"))?,
        );
        inputs.push(xla::Literal::vec1(vals));
        for row in rows {
            crate::ensure!(row.len() == blk * r, "row block shape");
            inputs.push(
                xla::Literal::vec1(row)
                    .reshape(&[blk as i64, r as i64])
                    .map_err(|e| err!("reshaping row block: {e}"))?,
            );
        }
        let out = self.cache[name].run(&inputs)?;
        out.to_vec::<f32>().map_err(|e| err!("reading output: {e}"))
    }

    /// Execute one MTTKRP block through a `segids`/`refseg` artifact
    /// (int32 segment ids instead of the one-hot matrix).
    pub fn mttkrp_block_segids(
        &mut self,
        name: &str,
        seg_ids: &[i32],
        vals: &[f32],
        rows: &[&[f32]],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let (blk, r) = (
            exe.meta.int("blk").context("blk")?,
            exe.meta.int("r").context("r")?,
        );
        crate::ensure!(seg_ids.len() == blk, "seg_ids shape");
        crate::ensure!(vals.len() == blk, "vals shape");
        let mut inputs = Vec::with_capacity(rows.len() + 2);
        inputs.push(xla::Literal::vec1(seg_ids));
        inputs.push(xla::Literal::vec1(vals));
        for row in rows {
            crate::ensure!(row.len() == blk * r, "row block shape");
            inputs.push(
                xla::Literal::vec1(row)
                    .reshape(&[blk as i64, r as i64])
                    .map_err(|e| err!("reshaping row block: {e}"))?,
            );
        }
        let out = self.cache[name].run(&inputs)?;
        out.to_vec::<f32>().map_err(|e| err!("reading output: {e}"))
    }

    /// Execute one ALS row-solve tile: `m_tile [tile, r] @ hinv [r, r]`.
    pub fn rowsolve(&mut self, name: &str, m_tile: &[f32], hinv: &[f32]) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let (tile, r) = (
            exe.meta.int("tile").context("tile")?,
            exe.meta.int("r").context("r")?,
        );
        crate::ensure!(m_tile.len() == tile * r, "m_tile shape");
        crate::ensure!(hinv.len() == r * r, "hinv shape");
        let inputs = [
            xla::Literal::vec1(m_tile)
                .reshape(&[tile as i64, r as i64])
                .map_err(|e| err!("reshaping m_tile: {e}"))?,
            xla::Literal::vec1(hinv)
                .reshape(&[r as i64, r as i64])
                .map_err(|e| err!("reshaping hinv: {e}"))?,
        ];
        let out = self.cache[name].run(&inputs)?;
        out.to_vec::<f32>().map_err(|e| err!("reading output: {e}"))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    fn no_pjrt(&self) -> crate::error::Error {
        err!(
            "ptmc was built without the `pjrt` feature; add the xla \
             path dependency (see the [features] notes in rust/Cargo.toml) \
             and rebuild with `--features pjrt` to execute artifacts \
             from {}",
            self.dir.display()
        )
    }

    /// Stub: execution needs the `pjrt` feature.
    pub fn mttkrp_block_onehot(
        &mut self,
        _name: &str,
        _seg_onehot: &[f32],
        _vals: &[f32],
        _rows: &[&[f32]],
    ) -> Result<Vec<f32>> {
        Err(self.no_pjrt())
    }

    /// Stub: execution needs the `pjrt` feature.
    pub fn mttkrp_block_segids(
        &mut self,
        _name: &str,
        _seg_ids: &[i32],
        _vals: &[f32],
        _rows: &[&[f32]],
    ) -> Result<Vec<f32>> {
        Err(self.no_pjrt())
    }

    /// Stub: execution needs the `pjrt` feature.
    pub fn rowsolve(&mut self, _name: &str, _m_tile: &[f32], _hinv: &[f32]) -> Result<Vec<f32>> {
        Err(self.no_pjrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_key_values() {
        let text = "name=a file=a.hlo.txt kind=mttkrp modes=3 seg=onehot blk=256 s=64 r=16\n\
                    # comment\n\
                    name=b file=b.hlo.txt kind=rowsolve tile=256 r=16\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].name, "a");
        assert_eq!(m[0].int("blk"), Some(256));
        assert_eq!(m[0].str("seg"), Some("onehot"));
        assert_eq!(m[1].kind, "rowsolve");
        assert_eq!(m[1].int("tile"), Some(256));
    }

    #[test]
    fn manifest_rejects_missing_name() {
        assert!(parse_manifest("file=x kind=y\n").is_err());
        assert!(parse_manifest("name=x kind=y\n").is_err());
        assert!(parse_manifest("garbage\n").is_err());
    }

    #[test]
    fn open_fails_cleanly_without_artifacts() {
        let err = match Runtime::open(Path::new("/nonexistent-dir")) {
            Ok(_) => panic!("open of missing dir must fail"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn dispatch_without_pjrt_is_a_clean_error() {
        // Build a manifest-only runtime in a temp dir and check the
        // execution stubs refuse with a pointer at the feature flag.
        let dir = std::env::temp_dir().join("ptmc_runtime_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "name=a file=a.hlo.txt kind=mttkrp modes=3 seg=segids blk=4 s=2 r=2\n",
        )
        .unwrap();
        let mut rt = Runtime::open(&dir).unwrap();
        assert_eq!(rt.manifest().len(), 1);
        assert!(rt.find_mttkrp(3, 2, "segids").is_some());
        let e = rt
            .mttkrp_block_segids("a", &[0; 4], &[0.0; 4], &[])
            .unwrap_err();
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
