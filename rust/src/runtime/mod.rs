//! PJRT runtime (S12): load the AOT-compiled HLO-text artifacts emitted
//! by `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! Interchange is **HLO text** — jax >= 0.5 serializes HloModuleProto
//! with 64-bit instruction ids that the crate's bundled xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py and
//! /opt/xla-example/README.md).  Python is never invoked here: the
//! artifacts directory is the only contract between the layers.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Parsed `manifest.txt` entry describing one artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// "mttkrp" or "rowsolve".
    pub kind: String,
    /// Extra key=value fields (modes, seg, blk, s, r, tile ...).
    pub fields: HashMap<String, String>,
}

impl ArtifactMeta {
    /// Integer field accessor (`blk`, `s`, `r`, `modes`, `tile`).
    pub fn int(&self, key: &str) -> Option<usize> {
        self.fields.get(key)?.parse().ok()
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }
}

/// Parse a manifest file's contents.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactMeta>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut fields = HashMap::new();
        for kv in line.split_whitespace() {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("manifest line {}: bad field {kv:?}", lineno + 1))?;
            fields.insert(k.to_string(), v.to_string());
        }
        let name = fields
            .remove("name")
            .ok_or_else(|| anyhow!("manifest line {}: missing name", lineno + 1))?;
        let file = fields
            .remove("file")
            .ok_or_else(|| anyhow!("manifest line {}: missing file", lineno + 1))?;
        let kind = fields
            .remove("kind")
            .ok_or_else(|| anyhow!("manifest line {}: missing kind", lineno + 1))?;
        out.push(ArtifactMeta {
            name,
            file,
            kind,
            fields,
        });
    }
    Ok(out)
}

/// A compiled, ready-to-execute artifact.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with the given input literals; returns the tuple-unwrapped
    /// first output literal (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.meta.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.meta.name))?;
        Ok(lit.to_tuple1()?)
    }
}

/// The PJRT runtime: one CPU client plus compiled executables, loaded
/// lazily from an artifacts directory and cached by name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Vec<ArtifactMeta>,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Open `dir` (must contain `manifest.txt`).  Compilation happens on
    /// first use of each artifact.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = parse_manifest(&text)?;
        if manifest.is_empty() {
            bail!("empty manifest at {}", manifest_path.display());
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: HashMap::new(),
        })
    }

    /// Artifact directory default used by the CLI/examples: `./artifacts`.
    pub fn open_default() -> Result<Self> {
        Self::open(Path::new("artifacts"))
    }

    pub fn manifest(&self) -> &[ArtifactMeta] {
        &self.manifest
    }

    /// Find the block-MTTKRP artifact for a tensor with `modes` modes,
    /// rank `r`, and segment encoding `seg` ("onehot"/"segids"/"refseg").
    ///
    /// Block-size policy (measured in the §Perf pass): the one-hot form
    /// does `S x BLK x R` MACs per block — work grows ~quadratically in
    /// block size, so the *smallest* block wins on compute-bound
    /// backends.  The segment forms are linear in BLK, so the *largest*
    /// block wins (fewer dispatches at the same total work).
    pub fn find_mttkrp(&self, modes: usize, r: usize, seg: &str) -> Option<&ArtifactMeta> {
        let candidates = self.manifest.iter().filter(|m| {
            m.kind == "mttkrp"
                && m.int("modes") == Some(modes)
                && m.int("r") == Some(r)
                && m.str("seg") == Some(seg)
        });
        if seg == "onehot" {
            candidates.min_by_key(|m| m.int("blk").unwrap_or(usize::MAX))
        } else {
            candidates.max_by_key(|m| m.int("blk").unwrap_or(0))
        }
    }

    /// Find the ALS row-solve artifact for rank `r`.
    pub fn find_rowsolve(&self, r: usize) -> Option<&ArtifactMeta> {
        self.manifest
            .iter()
            .find(|m| m.kind == "rowsolve" && m.int("r") == Some(r))
    }

    /// Get (compiling on first use) the executable named `name`.
    pub fn executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let meta = self
                .manifest
                .iter()
                .find(|m| m.name == name)
                .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
                .clone();
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            self.cache.insert(name.to_string(), Executable { meta, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute one MTTKRP block through the `onehot` artifact.
    ///
    /// * `seg_onehot` — row-major `[s, blk]` scatter matrix.
    /// * `vals` — `[blk]`.
    /// * `rows` — `modes-1` row-major `[blk, r]` gathered factor blocks.
    ///
    /// Returns the row-major `[s, r]` partial output.
    pub fn mttkrp_block_onehot(
        &mut self,
        name: &str,
        seg_onehot: &[f32],
        vals: &[f32],
        rows: &[&[f32]],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let (blk, s, r) = (
            exe.meta.int("blk").context("blk")?,
            exe.meta.int("s").context("s")?,
            exe.meta.int("r").context("r")?,
        );
        anyhow::ensure!(seg_onehot.len() == s * blk, "seg_onehot shape");
        anyhow::ensure!(vals.len() == blk, "vals shape");
        let mut inputs = Vec::with_capacity(rows.len() + 2);
        inputs.push(xla::Literal::vec1(seg_onehot).reshape(&[s as i64, blk as i64])?);
        inputs.push(xla::Literal::vec1(vals));
        for row in rows {
            anyhow::ensure!(row.len() == blk * r, "row block shape");
            inputs.push(xla::Literal::vec1(row).reshape(&[blk as i64, r as i64])?);
        }
        let out = self.cache[name].run(&inputs)?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute one MTTKRP block through a `segids`/`refseg` artifact
    /// (int32 segment ids instead of the one-hot matrix).
    pub fn mttkrp_block_segids(
        &mut self,
        name: &str,
        seg_ids: &[i32],
        vals: &[f32],
        rows: &[&[f32]],
    ) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let (blk, r) = (
            exe.meta.int("blk").context("blk")?,
            exe.meta.int("r").context("r")?,
        );
        anyhow::ensure!(seg_ids.len() == blk, "seg_ids shape");
        anyhow::ensure!(vals.len() == blk, "vals shape");
        let mut inputs = Vec::with_capacity(rows.len() + 2);
        inputs.push(xla::Literal::vec1(seg_ids));
        inputs.push(xla::Literal::vec1(vals));
        for row in rows {
            anyhow::ensure!(row.len() == blk * r, "row block shape");
            inputs.push(xla::Literal::vec1(row).reshape(&[blk as i64, r as i64])?);
        }
        let out = self.cache[name].run(&inputs)?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute one ALS row-solve tile: `m_tile [tile, r] @ hinv [r, r]`.
    pub fn rowsolve(&mut self, name: &str, m_tile: &[f32], hinv: &[f32]) -> Result<Vec<f32>> {
        let exe = self.executable(name)?;
        let (tile, r) = (
            exe.meta.int("tile").context("tile")?,
            exe.meta.int("r").context("r")?,
        );
        anyhow::ensure!(m_tile.len() == tile * r, "m_tile shape");
        anyhow::ensure!(hinv.len() == r * r, "hinv shape");
        let inputs = [
            xla::Literal::vec1(m_tile).reshape(&[tile as i64, r as i64])?,
            xla::Literal::vec1(hinv).reshape(&[r as i64, r as i64])?,
        ];
        let out = self.cache[name].run(&inputs)?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_key_values() {
        let text = "name=a file=a.hlo.txt kind=mttkrp modes=3 seg=onehot blk=256 s=64 r=16\n\
                    # comment\n\
                    name=b file=b.hlo.txt kind=rowsolve tile=256 r=16\n";
        let m = parse_manifest(text).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].name, "a");
        assert_eq!(m[0].int("blk"), Some(256));
        assert_eq!(m[0].str("seg"), Some("onehot"));
        assert_eq!(m[1].kind, "rowsolve");
        assert_eq!(m[1].int("tile"), Some(256));
    }

    #[test]
    fn manifest_rejects_missing_name() {
        assert!(parse_manifest("file=x kind=y\n").is_err());
        assert!(parse_manifest("name=x kind=y\n").is_err());
        assert!(parse_manifest("garbage\n").is_err());
    }

    #[test]
    fn open_fails_cleanly_without_artifacts() {
        let err = match Runtime::open(Path::new("/nonexistent-dir")) {
            Ok(_) => panic!("open of missing dir must fail"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
