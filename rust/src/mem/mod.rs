//! Memory-technology abstraction (S24): external memory behind one
//! [`MemoryDevice`] trait so the memory *technology* — not just the
//! timing knobs of one DDR4-shaped device — is a programmable
//! controller parameter and a first-class DSE axis.
//!
//! Three implementations live behind the [`MemDevice`] dispatcher:
//!
//! * **DDR4** — the existing bank/row-buffer model
//!   ([`crate::dram::Dram`]), unchanged; the trait instance is
//!   bit-identical to the pre-refactor direct path (enforced by
//!   `tests/memtech_props.rs` and the differential suites).
//! * **HBM2** — a multi-stack model (stacks × channels ×
//!   pseudo-channels) with shorter rows and narrower bursts.  Each
//!   pseudo-channel owns independent bank state, which is exactly the
//!   flat `(channel, bank)` state the DRAM engine already keeps — so
//!   HBM2 composes over [`Dram`] driven by a derived flat
//!   [`DramConfig`] ([`Hbm2Config::flat_dram`]).
//! * **Optical-SRAM-class scratchpad** — flat low access latency, no
//!   row-buffer dynamics at all (activate/precharge are never charged,
//!   so [`DramStats::activations`] stays 0), bandwidth-limited by
//!   per-port word occupancy ([`OpticalSram`]); cf. "Performance
//!   Modeling Sparse MTTKRP Using Optical SRAM on FPGA" (PAPERS.md).
//!
//! All three share [`DramStats`] as the universal device-statistics
//! type so per-shard aggregation ([`DramStats::merge`]) and every
//! report keep working unchanged; technologies without row buffers
//! simply never touch the row counters.
//!
//! The configuration side is [`MemTechConfig`], a closed enum carrying
//! each technology's knob set.  It is `Hash`/`Eq` so it can key the
//! remap-pass memo ([`crate::util::remap_memo::RemapKey`]) and dedup
//! DSE candidates, and it carries the analytic PMS counterparts
//! ([`MemTechConfig::stream_bytes_per_cycle`],
//! [`MemTechConfig::random_access_cycles`]) plus an FPGA power proxy
//! ([`MemTechConfig::power_proxy_mw`]) so `Exploration::pareto` can
//! report cross-technology frontiers.

use std::fmt;
use std::str::FromStr;

use crate::dram::{Dram, DramConfig, DramStats, RowPolicy};

/// External-memory device model: the one interface every simulation
/// core drives.  Implementations MUST be deterministic — the DSE
/// layers memoize and differentially compare their outputs.
pub trait MemoryDevice {
    /// Access `len` bytes at `addr` starting no earlier than `start`;
    /// returns the completion cycle.
    fn access(&mut self, addr: u64, len: usize, start: u64) -> u64;

    /// Aggregate device statistics since the last reset.
    fn stats(&self) -> &DramStats;

    /// Reset device state and statistics (fresh epoch).
    fn reset(&mut self);

    /// Max completion cycle across the device's parallel units.
    fn makespan(&self) -> u64;
}

/// Memory technology selector (CLI `--memory-tech`, config
/// `[memory] tech = ...`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum MemTech {
    /// Board-attached DDR4 DIMMs (the paper's reference platform).
    #[default]
    Ddr4,
    /// On-package HBM2 stacks (Alveo U280-class).
    Hbm2,
    /// Optical-SRAM-class external scratchpad.
    Osram,
}

impl MemTech {
    /// Default knob set for this technology.
    pub fn default_config(self) -> MemTechConfig {
        match self {
            MemTech::Ddr4 => MemTechConfig::Ddr4(DramConfig::default_ddr4()),
            MemTech::Hbm2 => MemTechConfig::Hbm2(Hbm2Config::default_u280()),
            MemTech::Osram => MemTechConfig::Osram(OsramConfig::default_16p()),
        }
    }
}

impl FromStr for MemTech {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "ddr4" => Ok(MemTech::Ddr4),
            "hbm2" => Ok(MemTech::Hbm2),
            "osram" => Ok(MemTech::Osram),
            other => Err(format!(
                "unknown memory tech {other:?} (ddr4|hbm2|osram)"
            )),
        }
    }
}

impl fmt::Display for MemTech {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            MemTech::Ddr4 => "ddr4",
            MemTech::Hbm2 => "hbm2",
            MemTech::Osram => "osram",
        })
    }
}

/// HBM2 geometry/timing knobs.  The stack hierarchy flattens into the
/// DRAM engine's channel dimension ([`Self::flat_dram`]): every
/// pseudo-channel is an independent half-width bus with its own bank
/// state, which is the semantics the flat `(channel, bank)` vectors
/// already model.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Hbm2Config {
    /// HBM stacks on the package.
    pub stacks: usize,
    /// Channels per stack.
    pub channels_per_stack: usize,
    /// Pseudo-channels per channel (HBM2 splits each 128-bit channel
    /// into two independent 64-bit pseudo-channels).
    pub pseudo_channels: usize,
    /// Banks per pseudo-channel.
    pub banks: usize,
    /// Row-buffer size in bytes — much shorter than DDR4 pages.
    pub row_bytes: usize,
    /// Bytes per burst on one pseudo-channel (half-width bus).
    pub burst_bytes: usize,
    pub t_rcd: u64,
    pub t_rp: u64,
    pub t_cl: u64,
    pub t_burst: u64,
    pub row_policy: RowPolicy,
}

impl Hbm2Config {
    /// Alveo U280-like dual-stack HBM2: 2 stacks x 8 channels x 2
    /// pseudo-channels = 32 independent pseudo-channels, 1 KiB rows,
    /// 32 B bursts, slightly longer bank timings than DDR4 at the
    /// controller clock.
    pub fn default_u280() -> Self {
        Hbm2Config {
            stacks: 2,
            channels_per_stack: 8,
            pseudo_channels: 2,
            banks: 8,
            row_bytes: 1024,
            burst_bytes: 32,
            t_rcd: 7,
            t_rp: 7,
            t_cl: 7,
            t_burst: 2,
            row_policy: RowPolicy::Open,
        }
    }

    /// Total independent pseudo-channels across the package.
    pub fn total_pseudo_channels(&self) -> usize {
        self.stacks * self.channels_per_stack * self.pseudo_channels
    }

    /// The equivalent flat DRAM geometry driving the shared engine:
    /// one engine channel per pseudo-channel, per-pseudo-channel bank
    /// state, HBM row/burst/timing knobs.
    pub fn flat_dram(&self) -> DramConfig {
        DramConfig {
            channels: self.total_pseudo_channels().max(1),
            banks: self.banks,
            row_bytes: self.row_bytes,
            burst_bytes: self.burst_bytes,
            t_rcd: self.t_rcd,
            t_rp: self.t_rp,
            t_cl: self.t_cl,
            t_burst: self.t_burst,
            row_policy: self.row_policy,
        }
    }
}

/// Optical-SRAM-class scratchpad knobs: no rows, no activate or
/// precharge — a flat access latency plus per-port word occupancy.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct OsramConfig {
    /// Independent ports (banks); each serializes its own words.
    pub banks: usize,
    /// Transfer granularity per port in bytes.
    pub word_bytes: usize,
    /// Flat access latency in cycles (pipelined across words).
    pub t_access: u64,
    /// Port occupancy per word in cycles (bounds sustained bandwidth
    /// at `banks * word_bytes / t_word`).
    pub t_word: u64,
}

impl OsramConfig {
    /// 16-port scratchpad, 64 B words, 2-cycle flat latency, one word
    /// per port per cycle — 1 KiB/cycle peak.
    pub fn default_16p() -> Self {
        OsramConfig {
            banks: 16,
            word_bytes: 64,
            t_access: 2,
            t_word: 1,
        }
    }
}

/// Per-technology configuration: the swept DSE dimension.  `Hash`/`Eq`
/// so it can key memo tables and dedup candidates.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum MemTechConfig {
    Ddr4(DramConfig),
    Hbm2(Hbm2Config),
    Osram(OsramConfig),
}

impl MemTechConfig {
    /// The default DDR4 instance (the pre-refactor controller default).
    pub fn default_ddr4() -> Self {
        MemTechConfig::Ddr4(DramConfig::default_ddr4())
    }

    /// Which technology this knob set belongs to.
    pub fn tech(&self) -> MemTech {
        match self {
            MemTechConfig::Ddr4(_) => MemTech::Ddr4,
            MemTechConfig::Hbm2(_) => MemTech::Hbm2,
            MemTechConfig::Osram(_) => MemTech::Osram,
        }
    }

    /// The DDR4 knob set, if this is the DDR4 technology.
    pub fn ddr4(&self) -> Option<&DramConfig> {
        match self {
            MemTechConfig::Ddr4(c) => Some(c),
            _ => None,
        }
    }

    /// Mutable DDR4 knob set; panics on other technologies.  For call
    /// sites (CLI `--dram-*` overrides, tests, benches) that are
    /// DDR4-specific by construction.
    pub fn ddr4_mut(&mut self) -> &mut DramConfig {
        match self {
            MemTechConfig::Ddr4(c) => c,
            other => panic!(
                "DDR4 knob applied to {} memory technology",
                other.tech()
            ),
        }
    }

    /// Independent parallel units the device exposes: DDR4 channels,
    /// HBM2 pseudo-channels, oSRAM ports.  Bounds device feasibility
    /// and the sharded per-worker split.
    pub fn parallel_units(&self) -> usize {
        match self {
            MemTechConfig::Ddr4(c) => c.channels,
            MemTechConfig::Hbm2(h) => h.total_pseudo_channels(),
            MemTechConfig::Osram(o) => o.banks,
        }
    }

    /// Peak bandwidth in bytes/cycle (all units streaming).
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        match self {
            MemTechConfig::Ddr4(c) => c.peak_bytes_per_cycle(),
            MemTechConfig::Hbm2(h) => h.flat_dram().peak_bytes_per_cycle(),
            MemTechConfig::Osram(o) => {
                o.banks as f64 * o.word_bytes as f64 / o.t_word.max(1) as f64
            }
        }
    }

    /// Analytic PMS counterpart: effective *streaming* bandwidth in
    /// bytes/cycle.  Row-buffer technologies amortize one activation
    /// per row (open page) or pay one per burst spread over the banks
    /// (closed page); the scratchpad streams at its port-limited peak.
    pub fn stream_bytes_per_cycle(&self) -> f64 {
        match self {
            MemTechConfig::Ddr4(c) => dram_stream_bytes_per_cycle(c),
            MemTechConfig::Hbm2(h) => dram_stream_bytes_per_cycle(&h.flat_dram()),
            MemTechConfig::Osram(_) => self.peak_bytes_per_cycle(),
        }
    }

    /// Analytic PMS counterpart: cycles for one isolated random
    /// element access (no locality).
    pub fn random_access_cycles(&self) -> f64 {
        match self {
            MemTechConfig::Ddr4(c) => dram_random_access_cycles(c),
            MemTechConfig::Hbm2(h) => dram_random_access_cycles(&h.flat_dram()),
            MemTechConfig::Osram(o) => (o.t_access + o.t_word) as f64,
        }
    }

    /// Analytic PMS counterpart: bus/port occupancy of one burst —
    /// the back-to-back service time a store pays once its row (if any)
    /// is open.
    pub fn burst_occupancy_cycles(&self) -> f64 {
        match self {
            MemTechConfig::Ddr4(c) => c.t_burst as f64,
            MemTechConfig::Hbm2(h) => h.t_burst as f64,
            MemTechConfig::Osram(o) => o.t_word as f64,
        }
    }

    /// Device power proxy in mW for the Pareto frontier's third axis:
    /// a static PHY/background term plus a per-unit I/O term.  These
    /// are coarse proxies for relative cross-technology comparison
    /// (DDR4 DIMM interfaces burn the most energy per unit, HBM2's
    /// short in-package traces much less per pseudo-channel, optical
    /// SRAM the least) — not calibrated absolute numbers.
    pub fn power_proxy_mw(&self) -> u64 {
        match self {
            MemTechConfig::Ddr4(c) => 150 + 170 * c.channels as u64,
            MemTechConfig::Hbm2(h) => 400 + 28 * h.total_pseudo_channels() as u64,
            MemTechConfig::Osram(o) => 60 + 6 * o.banks as u64,
        }
    }

    /// Per-worker slice of this technology's parallel units for the
    /// sharded backend: each of `k` concurrent controllers gets
    /// `units / k` floored to a power of two (at least one), mirroring
    /// the pre-refactor DDR4 channel split.
    pub fn split_for_workers(&self, k: usize) -> Self {
        let share = split_units(self.parallel_units(), k);
        match self {
            MemTechConfig::Ddr4(c) => {
                let mut c = c.clone();
                c.channels = share;
                MemTechConfig::Ddr4(c)
            }
            MemTechConfig::Hbm2(h) => {
                // Slice the stack hierarchy by collapsing it: one
                // worker sees `share` pseudo-channels as 1 stack x
                // `share` channels x 1 pseudo-channel of identical
                // timing — the flat engine geometry is what matters.
                let mut h = h.clone();
                h.stacks = 1;
                h.channels_per_stack = share;
                h.pseudo_channels = 1;
                MemTechConfig::Hbm2(h)
            }
            MemTechConfig::Osram(o) => {
                let mut o = o.clone();
                o.banks = share;
                MemTechConfig::Osram(o)
            }
        }
    }
}

impl Default for MemTechConfig {
    fn default() -> Self {
        MemTechConfig::default_ddr4()
    }
}

impl fmt::Display for MemTechConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MemTechConfig::Ddr4(c) => {
                write!(f, "ddr4 {}ch x{} {}", c.channels, c.banks, c.row_policy)
            }
            MemTechConfig::Hbm2(h) => write!(
                f,
                "hbm2 {}x{}x{}pc x{} {}",
                h.stacks, h.channels_per_stack, h.pseudo_channels, h.banks, h.row_policy
            ),
            MemTechConfig::Osram(o) => {
                write!(f, "osram {}p x{}B", o.banks, o.word_bytes)
            }
        }
    }
}

/// `units / k` floored to a power of two, at least 1 — the per-worker
/// resource split shared by all technologies.
fn split_units(units: usize, k: usize) -> usize {
    let share = (units / k.max(1)).max(1);
    let mut p = 1usize;
    while p * 2 <= share {
        p *= 2;
    }
    p
}

/// Effective streaming bandwidth of a row-buffer device in bytes/cycle:
/// peak derated by the row-policy cost.  Open page pays one activation
/// per row; closed page re-activates every burst but overlaps the
/// activates across banks, so its per-burst time is the activate
/// latency divided by the bank-level parallelism, floored at the bus
/// occupancy.  (Formulas unchanged from the pre-refactor PMS — the
/// DDR4 analytic path stays bit-identical.)
fn dram_stream_bytes_per_cycle(c: &DramConfig) -> f64 {
    let hit_time = c.t_burst as f64;
    let avg = match c.row_policy {
        RowPolicy::Open => {
            let bursts_per_row = (c.row_bytes / c.burst_bytes) as f64;
            let miss_time = (c.t_rp + c.t_rcd + c.t_cl + c.t_burst) as f64;
            (miss_time + (bursts_per_row - 1.0) * hit_time) / bursts_per_row
        }
        RowPolicy::Closed => {
            let act_time = (c.t_rcd + c.t_cl + c.t_burst) as f64;
            hit_time.max(act_time / (c.banks as f64).max(1.0))
        }
    };
    c.channels as f64 * c.burst_bytes as f64 / avg
}

/// Latency of one isolated random access on a row-buffer device: open
/// page assumes a row conflict (precharge on the critical path); closed
/// page auto-precharged behind the previous burst, so only the activate
/// remains.  (Formulas unchanged from the pre-refactor PMS.)
fn dram_random_access_cycles(c: &DramConfig) -> f64 {
    match c.row_policy {
        RowPolicy::Open => (c.t_rp + c.t_rcd + c.t_cl + c.t_burst) as f64,
        RowPolicy::Closed => (c.t_rcd + c.t_cl + c.t_burst) as f64,
    }
}

/// HBM2 device: the shared DRAM engine over the flattened
/// pseudo-channel geometry, so every pseudo-channel keeps independent
/// per-bank row state and an independent data bus.
#[derive(Debug, Clone)]
pub struct Hbm2 {
    cfg: Hbm2Config,
    inner: Dram,
}

impl Hbm2 {
    pub fn new(cfg: Hbm2Config) -> Self {
        let inner = Dram::new(cfg.flat_dram());
        Hbm2 { cfg, inner }
    }

    pub fn config(&self) -> &Hbm2Config {
        &self.cfg
    }
}

impl MemoryDevice for Hbm2 {
    fn access(&mut self, addr: u64, len: usize, start: u64) -> u64 {
        self.inner.access(addr, len, start)
    }

    fn stats(&self) -> &DramStats {
        self.inner.stats()
    }

    fn reset(&mut self) {
        self.inner.reset();
    }

    fn makespan(&self) -> u64 {
        self.inner.makespan()
    }
}

/// Optical-SRAM-class scratchpad device: words route to ports by
/// address interleave, each port serializes its words at `t_word`
/// occupancy, and every word completes a flat `t_access` later — no
/// row state, so the row counters in [`DramStats`] stay 0 forever.
#[derive(Debug, Clone)]
pub struct OpticalSram {
    cfg: OsramConfig,
    /// Cycle at which each port can accept its next word.
    port_free: Vec<u64>,
    /// Max completion cycle seen (ports pipeline, so completion can
    /// trail port availability by `t_access`).
    horizon: u64,
    stats: DramStats,
}

impl OpticalSram {
    pub fn new(cfg: OsramConfig) -> Self {
        assert!(cfg.banks > 0, "osram needs at least one port");
        assert!(cfg.word_bytes > 0, "osram needs a positive word size");
        OpticalSram {
            port_free: vec![0; cfg.banks],
            horizon: 0,
            cfg,
            stats: DramStats::default(),
        }
    }

    pub fn config(&self) -> &OsramConfig {
        &self.cfg
    }
}

impl MemoryDevice for OpticalSram {
    fn access(&mut self, addr: u64, len: usize, start: u64) -> u64 {
        assert!(len > 0, "zero-length memory access");
        let wb = self.cfg.word_bytes as u64;
        let first = addr / wb;
        let last = (addr + len as u64 - 1) / wb;
        let mut done = start;
        for word in first..=last {
            let port = (word % self.cfg.banks as u64) as usize;
            let issue = start.max(self.port_free[port]);
            self.port_free[port] = issue + self.cfg.t_word;
            let word_done = issue + self.cfg.t_access + self.cfg.t_word;
            done = done.max(word_done);
            self.stats.bursts += 1;
            self.stats.bytes += wb;
        }
        self.horizon = self.horizon.max(done);
        done
    }

    fn stats(&self) -> &DramStats {
        &self.stats
    }

    fn reset(&mut self) {
        self.port_free.iter_mut().for_each(|t| *t = 0);
        self.horizon = 0;
        self.stats = DramStats::default();
    }

    fn makespan(&self) -> u64 {
        self.horizon
    }
}

impl MemoryDevice for Dram {
    fn access(&mut self, addr: u64, len: usize, start: u64) -> u64 {
        Dram::access(self, addr, len, start)
    }

    fn stats(&self) -> &DramStats {
        Dram::stats(self)
    }

    fn reset(&mut self) {
        Dram::reset(self);
    }

    fn makespan(&self) -> u64 {
        Dram::makespan(self)
    }
}

/// The concrete device dispatcher every simulation core holds.  An
/// enum (not a trait object) so devices stay `Clone`-able flat state —
/// the vectorized timing core keeps arrays of per-candidate devices —
/// and so dispatch is a match, not a vtable, on the burst-level hot
/// path.
#[derive(Debug, Clone)]
pub enum MemDevice {
    Ddr4(Dram),
    Hbm2(Hbm2),
    Osram(OpticalSram),
}

impl MemDevice {
    /// Instantiate the device a technology config describes.
    pub fn new(cfg: &MemTechConfig) -> Self {
        match cfg {
            MemTechConfig::Ddr4(c) => MemDevice::Ddr4(Dram::new(c.clone())),
            MemTechConfig::Hbm2(h) => MemDevice::Hbm2(Hbm2::new(h.clone())),
            MemTechConfig::Osram(o) => MemDevice::Osram(OpticalSram::new(o.clone())),
        }
    }

    /// Access `len` bytes at `addr` starting no earlier than `start`;
    /// returns the completion cycle (inherent mirror of the trait so
    /// hot paths need no trait import).
    pub fn access(&mut self, addr: u64, len: usize, start: u64) -> u64 {
        match self {
            MemDevice::Ddr4(d) => d.access(addr, len, start),
            MemDevice::Hbm2(h) => MemoryDevice::access(h, addr, len, start),
            MemDevice::Osram(o) => MemoryDevice::access(o, addr, len, start),
        }
    }

    pub fn stats(&self) -> &DramStats {
        match self {
            MemDevice::Ddr4(d) => d.stats(),
            MemDevice::Hbm2(h) => MemoryDevice::stats(h),
            MemDevice::Osram(o) => MemoryDevice::stats(o),
        }
    }

    pub fn reset(&mut self) {
        match self {
            MemDevice::Ddr4(d) => d.reset(),
            MemDevice::Hbm2(h) => MemoryDevice::reset(h),
            MemDevice::Osram(o) => MemoryDevice::reset(o),
        }
    }

    pub fn makespan(&self) -> u64 {
        match self {
            MemDevice::Ddr4(d) => d.makespan(),
            MemDevice::Hbm2(h) => MemoryDevice::makespan(h),
            MemDevice::Osram(o) => MemoryDevice::makespan(o),
        }
    }
}

impl MemoryDevice for MemDevice {
    fn access(&mut self, addr: u64, len: usize, start: u64) -> u64 {
        MemDevice::access(self, addr, len, start)
    }

    fn stats(&self) -> &DramStats {
        MemDevice::stats(self)
    }

    fn reset(&mut self) {
        MemDevice::reset(self);
    }

    fn makespan(&self) -> u64 {
        MemDevice::makespan(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tech_parses_and_displays() {
        assert_eq!("ddr4".parse::<MemTech>().unwrap(), MemTech::Ddr4);
        assert_eq!("hbm2".parse::<MemTech>().unwrap(), MemTech::Hbm2);
        assert_eq!("osram".parse::<MemTech>().unwrap(), MemTech::Osram);
        assert!("sram".parse::<MemTech>().is_err());
        assert_eq!(MemTech::Ddr4.to_string(), "ddr4");
        assert_eq!(MemTech::Hbm2.to_string(), "hbm2");
        assert_eq!(MemTech::Osram.to_string(), "osram");
        assert_eq!(MemTech::default(), MemTech::Ddr4);
    }

    #[test]
    fn ddr4_device_matches_raw_dram_exactly() {
        let cfg = DramConfig::default_ddr4();
        let mut raw = Dram::new(cfg.clone());
        let mut dev = MemDevice::new(&MemTechConfig::Ddr4(cfg));
        let mut rng = crate::testkit::Rng::new(9);
        let (mut ta, mut tb) = (0u64, 0u64);
        for _ in 0..2_000 {
            let addr = rng.below(1 << 26);
            let len = 1 + rng.below(512) as usize;
            ta = raw.access(addr, len, ta);
            tb = dev.access(addr, len, tb);
            assert_eq!(ta, tb);
        }
        assert_eq!(raw.stats(), dev.stats());
        assert_eq!(Dram::makespan(&raw), dev.makespan());
    }

    #[test]
    fn hbm2_flattens_to_pseudo_channel_geometry() {
        let h = Hbm2Config::default_u280();
        assert_eq!(h.total_pseudo_channels(), 32);
        let flat = h.flat_dram();
        assert_eq!(flat.channels, 32);
        assert_eq!(flat.banks, h.banks);
        assert_eq!(flat.row_bytes, 1024);
    }

    #[test]
    fn hbm2_streams_faster_than_ddr4() {
        let ddr = MemTechConfig::default_ddr4();
        let hbm = MemTechConfig::Hbm2(Hbm2Config::default_u280());
        assert!(hbm.peak_bytes_per_cycle() > ddr.peak_bytes_per_cycle());
        assert!(hbm.stream_bytes_per_cycle() > ddr.stream_bytes_per_cycle());

        // And the cycle model agrees on an actual 1 MiB stream.
        let run = |cfg: &MemTechConfig| {
            let mut dev = MemDevice::new(cfg);
            let mut t = 0;
            for off in (0u64..1 << 20).step_by(64) {
                t = dev.access(off, 64, t);
            }
            dev.makespan()
        };
        assert!(run(&hbm) < run(&ddr));
    }

    #[test]
    fn osram_never_touches_row_counters() {
        let mut dev = MemDevice::new(&MemTechConfig::Osram(OsramConfig::default_16p()));
        let mut rng = crate::testkit::Rng::new(3);
        let mut t = 0;
        for _ in 0..4_000 {
            t = dev.access(rng.below(1 << 26), 1 + rng.below(300) as usize, t);
        }
        let s = dev.stats();
        assert!(s.bursts > 0 && s.bytes > 0);
        assert_eq!(s.activations(), 0);
        assert_eq!(s.row_hits, 0);
        assert_eq!(s.row_misses, 0);
        assert_eq!(s.row_conflicts, 0);
        assert_eq!(s.hit_rate(), 0.0);
    }

    #[test]
    fn osram_random_equals_stream_per_word() {
        // No row dynamics: a random word costs the same as a
        // sequential word, unlike any row-buffer device.
        let cfg = MemTechConfig::Osram(OsramConfig::default_16p());
        let mut seq = MemDevice::new(&cfg);
        let mut t = 0;
        for i in 0u64..1_000 {
            t = seq.access(i * 64, 64, t);
        }
        let mut rnd = MemDevice::new(&cfg);
        let mut rng = crate::testkit::Rng::new(11);
        let mut t = 0;
        for _ in 0..1_000 {
            t = rnd.access(rng.below(1 << 24) / 64 * 64, 64, t);
        }
        // FIFO chaining serializes both identically; the port spread
        // differs only by interleave, so the totals stay close.
        let (a, b) = (seq.makespan(), rnd.makespan());
        assert!(a.abs_diff(b) <= a / 2, "seq {a} vs random {b}");
    }

    #[test]
    fn osram_reset_restores_fresh_state() {
        let mut dev = OpticalSram::new(OsramConfig::default_16p());
        MemoryDevice::access(&mut dev, 0, 4096, 0);
        MemoryDevice::reset(&mut dev);
        assert_eq!(MemoryDevice::stats(&dev), &DramStats::default());
        assert_eq!(MemoryDevice::makespan(&dev), 0);
    }

    #[test]
    fn split_for_workers_matches_legacy_channel_split() {
        let mut quad = DramConfig::default_ddr4();
        quad.channels = 4;
        let cfg = MemTechConfig::Ddr4(quad);
        assert_eq!(cfg.split_for_workers(1).parallel_units(), 4);
        assert_eq!(cfg.split_for_workers(2).parallel_units(), 2);
        assert_eq!(cfg.split_for_workers(3).parallel_units(), 1);
        assert_eq!(cfg.split_for_workers(8).parallel_units(), 1);

        let hbm = MemTechConfig::Hbm2(Hbm2Config::default_u280());
        assert_eq!(hbm.split_for_workers(4).parallel_units(), 8);
        let os = MemTechConfig::Osram(OsramConfig::default_16p());
        assert_eq!(os.split_for_workers(4).parallel_units(), 4);
    }

    #[test]
    fn power_proxy_orders_technologies_sensibly() {
        let ddr = MemTechConfig::default_ddr4();
        let hbm = MemTechConfig::Hbm2(Hbm2Config::default_u280());
        let os = MemTechConfig::Osram(OsramConfig::default_16p());
        // Per unit of peak bandwidth, DDR4 pays the most and the
        // scratchpad the least.
        let per_bw = |c: &MemTechConfig| c.power_proxy_mw() as f64 / c.peak_bytes_per_cycle();
        assert!(per_bw(&ddr) > per_bw(&hbm));
        assert!(per_bw(&hbm) > per_bw(&os));
        assert!(os.power_proxy_mw() < ddr.power_proxy_mw());
    }

    #[test]
    fn display_summaries_name_the_tech() {
        assert!(MemTechConfig::default_ddr4().to_string().starts_with("ddr4"));
        assert!(MemTech::Hbm2
            .default_config()
            .to_string()
            .starts_with("hbm2"));
        assert!(MemTech::Osram
            .default_config()
            .to_string()
            .starts_with("osram"));
    }
}
