//! Minimal error substrate for the fallible subsystems ([`crate::runtime`],
//! [`crate::coordinator`]).  The offline build has no `anyhow`; this
//! vendors the small slice of its API the crate uses: a string-message
//! [`Error`] with an optional source, a [`Result`] alias, a [`Context`]
//! extension trait for `Result`/`Option`, and the [`err!`](crate::err),
//! [`bail!`](crate::bail), [`ensure!`](crate::ensure) macros.

use std::error::Error as StdError;
use std::fmt;

/// Coarse failure taxonomy carried by [`Error`] so user-facing
/// frontends (the `ptmc` binary) can map each failure to a distinct
/// nonzero exit code and a one-line stderr message instead of
/// panicking (S31).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// A bug or unclassified internal failure.
    Internal,
    /// Bad command line / configuration from the user.
    Usage,
    /// Malformed input data (tensor files, cache files).
    Parse,
    /// An IO failure that survived retry/degradation.
    Io,
    /// A memory-budget violation.
    Budget,
    /// A shard worker died (panic or persistent IO fault).
    Worker,
}

impl ErrorClass {
    /// The process exit code for this class (`Internal` keeps the
    /// generic `1`; everything user-diagnosable gets its own code).
    pub fn exit_code(self) -> u8 {
        match self {
            ErrorClass::Internal => 1,
            ErrorClass::Usage => 2,
            ErrorClass::Parse => 3,
            ErrorClass::Io => 4,
            ErrorClass::Budget => 5,
            ErrorClass::Worker => 6,
        }
    }
}

/// A message-carrying error, optionally wrapping a source error.
/// `Display` renders the full context chain (`outer: inner: ...`) so a
/// bare `eprintln!("{e}")` tells the whole story.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
    class: ErrorClass,
}

/// Crate-wide result alias (defaults the error type to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// An error from a plain message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error {
            msg: msg.to_string(),
            source: None,
            class: ErrorClass::Internal,
        }
    }

    /// An error wrapping `source` with a context message.
    pub fn with_source(
        msg: impl fmt::Display,
        source: impl StdError + Send + Sync + 'static,
    ) -> Self {
        Error {
            msg: msg.to_string(),
            source: Some(Box::new(source)),
            class: ErrorClass::Internal,
        }
    }

    /// Tag this error with a failure class (builder style).
    pub fn classify(mut self, class: ErrorClass) -> Self {
        self.class = class;
        self
    }

    /// The failure class (defaults to [`ErrorClass::Internal`]).
    pub fn class(&self) -> ErrorClass {
        self.class
    }

    /// A supervised shard worker died: `cause` is either the panic
    /// payload rendered to text or a persistent IO error.  Replaces
    /// the poisoned-join panic of the unsupervised executor.
    pub fn worker_failed(shard: usize, cause: impl fmt::Display) -> Self {
        Error::msg(format!("shard worker {shard} failed: {cause}")).classify(ErrorClass::Worker)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(outer) = &self.source {
            write!(f, ": {outer}")?;
            let mut src = outer.source();
            while let Some(inner) = src {
                write!(f, ": {inner}")?;
                src = inner.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl StdError for Error {
    // Display already renders the chain; exposing the source again here
    // would make chain-walking printers duplicate it.
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        None
    }
}

/// `.context()` / `.with_context()` on `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::with_source(ctx, e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::with_source(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string: `err!("bad {thing}")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_renders_context_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("reading manifest"), "{s}");
        assert!(s.contains("gone"), "{s}");
    }

    #[test]
    fn option_context_and_macros() {
        let e = None::<u32>.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");

        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
    }

    #[test]
    fn converts_into_boxed_std_error() {
        fn run() -> std::result::Result<(), Box<dyn StdError>> {
            Err(err!("boom"))?;
            Ok(())
        }
        assert_eq!(run().unwrap_err().to_string(), "boom");
    }

    #[test]
    fn classes_carry_distinct_exit_codes() {
        assert_eq!(err!("plain").class(), ErrorClass::Internal);
        let e = err!("over budget").classify(ErrorClass::Budget);
        assert_eq!(e.class(), ErrorClass::Budget);
        assert_eq!(e.class().exit_code(), 5);
        let w = Error::worker_failed(3, "injected panic");
        assert_eq!(w.class(), ErrorClass::Worker);
        assert!(w.to_string().contains("shard worker 3"), "{w}");
        // Context-wrapping resets to Internal by design; the frontier
        // that cares about class must classify last.
        let codes: Vec<u8> = [
            ErrorClass::Internal,
            ErrorClass::Usage,
            ErrorClass::Parse,
            ErrorClass::Io,
            ErrorClass::Budget,
            ErrorClass::Worker,
        ]
        .iter()
        .map(|c| c.exit_code())
        .collect();
        let mut uniq = codes.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), codes.len(), "exit codes must be distinct");
    }

    #[test]
    fn with_context_is_lazy() {
        let mut called = false;
        let ok: Result<u32, std::io::Error> = Ok(5);
        let v = ok
            .with_context(|| {
                called = true;
                "never"
            })
            .unwrap();
        assert_eq!(v, 5);
        assert!(!called, "context closure must not run on Ok");
    }
}
