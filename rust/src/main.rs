//! `ptmc` — leader entrypoint for the Programmable Tensor Memory
//! Controller stack.
//!
//! Subcommands:
//! * `decompose` — run CP-ALS on a tensor (native / sim / parallel /
//!   pjrt backend; `--workers N` sets the parallel shard count).
//! * `simulate`  — one full MTTKRP sweep through the memory-controller
//!   cycle simulator, with per-module statistics.
//! * `shard`     — report the output-disjoint shard plan (per-shard
//!   coordinate ranges, nnz shares, load imbalance) for `--workers K`.
//! * `pms`       — analytic PMS estimate for a (tensor, config) pair.
//! * `explore`   — design-space search (paper §5.3): coordinate descent
//!   (the default), exhaustive joint cross-product search, or beam
//!   search (`--search coordinate|joint|beam`), optionally across
//!   memory technologies (`--mem-techs all`), reporting the winner,
//!   the top-k points (`--top-k`), and the Pareto frontier of cycles
//!   vs on-chip blocks vs memory-device power.
//! * `stats`     — Table-2-style characteristics of a tensor.
//! * `serve`     — persistent multi-tenant DSE service: a socket server
//!   running explorations on a fixed worker pool behind the
//!   cross-query memo, so concurrent and repeat queries of the same
//!   tensor share classification and simulation work.
//! * `batch`     — pipeline a batch of exploration jobs to a running
//!   `serve` instance and report results + memo economics.
//!
//! Workload selection (all subcommands): `--input file.tns` or
//! `--synth zipf|uniform|clustered --dims AxBxC --nnz N --seed S`.
//! Controller parameters come from `--config ptmc.toml` plus overrides
//! (`--cache-lines`, `--dma-buffers`, `--memory-tech ddr4|hbm2|osram`,
//! `--channels`, `--dram-banks`, `--row-policy`, ...; the `--dram-*`
//! flags shape the DDR4 configuration and are rejected under another
//! `--memory-tech`).  `--engine lockstep|event|grid` picks the
//! trace-replay core for `simulate` and `explore` (bit-identical
//! results; `event` is the batched fast path, `grid` additionally
//! scores whole cache-module grids in one classification pass and
//! DRAM/DMA module sweeps in one vectorized op-queue walk on
//! `explore`).

use std::path::Path;
use std::process::ExitCode;

use ptmc::cli::{workload, Args, CliError};
use ptmc::config::Config;
use ptmc::controller::{ControllerConfig, MemLayout, MemoryController};
use ptmc::coordinator::{PjrtCoordinator, SegMode};
use ptmc::cpd::{cp_als, linalg::Mat, AlsConfig, NativeBackend, SimBackend};
use ptmc::dse::{
    explore_with, tensor_fingerprint, EvaluatorBuilder, Grids, KeyBuilder, SearchOptions,
    SearchStrategy, WarmCache,
};
use ptmc::engine::EngineKind;
use ptmc::fpga::Device;
use ptmc::mem::MemTech;
use ptmc::pms::{self, TensorProfile};
use ptmc::runtime::Runtime;
use ptmc::serve::proto::{EvalKind, GridPreset, JobSpec};
use ptmc::serve::{client, ServeConfig, Server};
use ptmc::shard::{ParallelBackend, ShardPlan, ShardedSweep};
use ptmc::tensor::{stats, SparseTensor};

const OPTS: &[&str] = &[
    "input", "synth", "dims", "nnz", "seed", "alpha", // workload
    "config", "rank", "iters", "tol", "backend", "device", "evaluator", "seg",
    "workers", "mode", "engine", // sharded execution + replay core
    "search", "top-k", "warm-cache", // DSE search strategy + report depth + score cache
    "checkpoint-every", // periodic frontier/verdict flush for resumable explore
    "cache-lines", "cache-line-bytes", "cache-assoc", "dma-buffers", "dma-num",
    "dma-buffer-bytes", "max-pointers", "memory-tech", "channels", "dram-banks",
    "row-policy", "mem-techs", "artifacts", "memory-budget",
    "listen", "serve-workers", "tenant-budget", "memo-spill", // serve
    "addr", "tenant", "repeat", "grid", // batch
];
const FLAGS: &[&str] = &["help", "verbose", "csv", "shutdown", "server-stats"];

fn main() -> ExitCode {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    match run(&raw) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::from(exit_code_for(e.as_ref()))
        }
    }
}

/// One distinct nonzero exit code per failure class (S31), so scripts
/// and the CI fault-smoke job can tell a usage mistake (2) from a
/// corrupt input (3), an IO failure (4), a blown memory budget (5), or
/// a dead shard worker (6) without scraping stderr.
fn exit_code_for(e: &(dyn std::error::Error + 'static)) -> u8 {
    use ptmc::error::ErrorClass;
    use ptmc::tensor::frostt::TnsError;
    if let Some(err) = e.downcast_ref::<ptmc::error::Error>() {
        return err.class().exit_code();
    }
    if e.downcast_ref::<CliError>().is_some() {
        return ErrorClass::Usage.exit_code();
    }
    if let Some(t) = e.downcast_ref::<TnsError>() {
        return match t {
            TnsError::Io(_) => ErrorClass::Io.exit_code(),
            TnsError::Parse(..) | TnsError::Empty => ErrorClass::Parse.exit_code(),
        };
    }
    if e.downcast_ref::<std::io::Error>().is_some() {
        return ErrorClass::Io.exit_code();
    }
    ErrorClass::Internal.exit_code()
}

fn usage() {
    println!(
        "ptmc — programmable tensor memory controller (paper reproduction)\n\
         \n\
         USAGE: ptmc <decompose|simulate|shard|pms|explore|stats|serve|batch> [options]\n\
         \n\
         workload:  --input x.tns | --synth zipf|uniform|clustered\n\
         \x20          --dims 2000x1500x1000 --nnz 50000 --seed 42 --alpha 1.2\n\
         run:       --rank 16 --iters 10 --tol 1e-5\n\
         \x20          --backend native|sim|parallel|pjrt --workers 4\n\
         \x20          --seg onehot|segids|refseg --artifacts DIR\n\
         shard:     --workers 4 [--mode M]  (plan report; default: all modes)\n\
         controller:--config ptmc.toml --cache-lines N --cache-line-bytes B\n\
         \x20          --cache-assoc A --dma-num N --dma-buffers K\n\
         \x20          --dma-buffer-bytes B --max-pointers P\n\
         \x20          --memory-tech ddr4|hbm2|osram ([memory] tech in the\n\
         \x20          config file; DDR4-only knobs: --channels C\n\
         \x20          --dram-banks B --row-policy open|closed — rejected\n\
         \x20          under another --memory-tech)\n\
         dse:       --device u250|u280|vu9p --evaluator pms|sim|sharded|grid\n\
         \x20          --search coordinate|joint|beam --top-k N\n\
         \x20          --mem-techs all|ddr4,hbm2,osram (memory technologies\n\
         \x20          in the sweep; default: the base config's tech)\n\
         \x20          (coordinate sweeps cache, DMA, memory — technology x\n\
         \x20          channels x banks x row policy — then remapper grids,\n\
         \x20          one module at a time; joint scores the full cross\n\
         \x20          product through the hierarchical sweep core; beam\n\
         \x20          keeps the top-k incumbents between module sweeps.\n\
         \x20          Every search also reports the top-k points and the\n\
         \x20          Pareto frontier of cycles vs on-chip blocks vs\n\
         \x20          memory-device power.  Config-file equivalents:\n\
         \x20          [dse] search / top_k / warm_cache)\n\
         \x20          --warm-cache DIR persists scored points + Pareto\n\
         \x20          frontier per (tensor fingerprint, evaluator, device)\n\
         \x20          context; repeat/adjacent explores re-score only\n\
         \x20          unseen candidates and beam searches resume from\n\
         \x20          the stored frontier ([dse] warm_cache)\n\
         \x20          --checkpoint-every N (with --warm-cache): flush the\n\
         \x20          frontier + scored verdicts every N visited points,\n\
         \x20          so a killed explore resumes from its last checkpoint\n\
         \x20          ([dse] checkpoint_every; 0 disables)\n\
         sim core:  --engine lockstep|event|grid (bit-identical; default\n\
         \x20          event on explore for sweep throughput, lockstep on\n\
         \x20          simulate; grid scores whole cache-module grids in\n\
         \x20          one classification pass and DRAM/DMA module sweeps\n\
         \x20          in one vectorized walk of the shared op queue)\n\
         memory:    --memory-budget 4g (decompose/explore: bound host\n\
         \x20          peak RSS — dedup-free streamed synthesis, spilled\n\
         \x20          remap columns, compressed-only traces; results are\n\
         \x20          bit-identical; peak RSS is reported and enforced\n\
         \x20          at exit)\n\
         serve:     --listen 127.0.0.1:7421 --serve-workers 4\n\
         \x20          --tenant-budget N (0 = unmetered) --memo-spill DIR\n\
         \x20          --device u250  (config: [serve] listen / workers /\n\
         \x20          tenant_budget / memo_spill.  Jobs from all clients\n\
         \x20          run on one worker pool and score through the shared\n\
         \x20          cross-query memo; repeat queries of the same tensor\n\
         \x20          skip simulation entirely.  Shut down via\n\
         \x20          `batch --shutdown`)\n\
         batch:     --addr 127.0.0.1:7421 --tenant NAME --repeat N\n\
         \x20          (submit the workload N times; ids 1..N) plus the\n\
         \x20          workload/dse knobs: --synth/--dims/--nnz/--seed,\n\
         \x20          --rank, --evaluator pms|sim, --engine, --search,\n\
         \x20          --top-k, --grid default|smoke.  --server-stats\n\
         \x20          prints the server's lifetime counters;\n\
         \x20          --shutdown drains and stops the server\n"
    );
}

fn run(raw: &[String]) -> Result<(), Box<dyn std::error::Error>> {
    // Arm any requested fault plan eagerly so a malformed
    // PTMC_FAULT_PLAN fails the run instead of silently executing
    // fault-free (lazy library arming would only warn).
    ptmc::util::fault::init_env()
        .map_err(|e| CliError(format!("invalid PTMC_FAULT_PLAN: {e}")))?;
    let args = Args::parse(raw, OPTS, FLAGS)?;
    if args.flag("help") || args.subcommand.is_none() {
        usage();
        return Ok(());
    }
    match args.subcommand.as_deref().unwrap() {
        "decompose" => cmd_decompose(&args),
        "simulate" => cmd_simulate(&args),
        "shard" => cmd_shard(&args),
        "pms" => cmd_pms(&args),
        "explore" => cmd_explore(&args),
        "stats" => cmd_stats(&args),
        "serve" => cmd_serve(&args),
        "batch" => cmd_batch(&args),
        other => Err(Box::new(CliError(format!(
            "unknown subcommand {other:?} (try --help)"
        )))),
    }
}

/// Controller config from `--config` file plus CLI overrides.
fn controller_config(
    args: &Args,
    elem_bytes: usize,
) -> Result<ControllerConfig, Box<dyn std::error::Error>> {
    let file_cfg = match args.get("config") {
        Some(path) => Some(Config::load(Path::new(path))?),
        None => None,
    };
    controller_config_with(args, elem_bytes, file_cfg.as_ref())
}

/// [`controller_config`] with an already-loaded `--config` file, so
/// callers that need other sections of the same file (explore's
/// `[dse]` keys) parse it exactly once.
fn controller_config_with(
    args: &Args,
    elem_bytes: usize,
    file_cfg: Option<&Config>,
) -> Result<ControllerConfig, Box<dyn std::error::Error>> {
    let mut cfg = match file_cfg {
        Some(c) => c.controller(elem_bytes)?,
        None => ControllerConfig::default_for(elem_bytes),
    };
    cfg.cache.num_lines = args.usize_or("cache-lines", cfg.cache.num_lines)?;
    cfg.cache.line_bytes = args.usize_or("cache-line-bytes", cfg.cache.line_bytes)?;
    cfg.cache.assoc = args.usize_or("cache-assoc", cfg.cache.assoc)?;
    cfg.dma.num_dmas = args.usize_or("dma-num", cfg.dma.num_dmas)?;
    cfg.dma.buffers_per_dma = args.usize_or("dma-buffers", cfg.dma.buffers_per_dma)?;
    cfg.dma.buffer_bytes = args.usize_or("dma-buffer-bytes", cfg.dma.buffer_bytes)?;
    cfg.remapper.max_pointers = args.usize_or("max-pointers", cfg.remapper.max_pointers)?;
    // Memory technology first (CLI wins over the config file), then
    // the DDR4-shaped knobs — which only make sense on DDR4, so a
    // non-DDR4 tech combined with any of them is a hard error rather
    // than a silently ignored flag.
    if let Some(raw) = args.get("memory-tech") {
        let tech: MemTech = raw
            .parse()
            .map_err(|e| CliError(format!("--memory-tech: {e}")))?;
        if tech != cfg.mem.tech() {
            cfg.mem = tech.default_config();
        }
    }
    let ddr4_flags: Vec<&str> = ["channels", "dram-banks", "row-policy"]
        .into_iter()
        .filter(|f| args.get(f).is_some())
        .collect();
    if cfg.mem.tech() == MemTech::Ddr4 {
        let dram = cfg.mem.ddr4_mut();
        dram.channels = args.usize_or("channels", dram.channels)?;
        dram.banks = args.usize_or("dram-banks", dram.banks)?;
        if let Some(p) = args.get("row-policy") {
            dram.row_policy = p
                .parse()
                .map_err(|e| CliError(format!("--row-policy: {e}")))?;
        }
    } else if !ddr4_flags.is_empty() {
        return Err(Box::new(CliError(format!(
            "--{} shapes the DDR4 configuration, but the memory tech is {}; \
             drop the flag or use --memory-tech ddr4",
            ddr4_flags[0],
            cfg.mem.tech()
        ))));
    }
    Ok(cfg)
}

fn als_config(args: &Args) -> Result<AlsConfig, Box<dyn std::error::Error>> {
    let base = match args.get("config") {
        Some(path) => Config::load(Path::new(path))?.als(),
        None => AlsConfig::default(),
    };
    Ok(AlsConfig {
        rank: args.usize_or("rank", base.rank)?,
        max_iters: args.usize_or("iters", base.max_iters)?,
        tol: args.f64_or("tol", base.tol)?,
        ridge: base.ridge,
        seed: args.u64_or("seed", base.seed)?,
    })
}

/// Replay core from `--engine`.  The default is per command:
/// `explore` replays the same prepared traces across a whole candidate
/// grid, where the event engine's batching amortizes (`event`);
/// `simulate` compiles and replays each trace exactly once, where
/// compression would not pay for itself (`lockstep`).
fn engine_kind(args: &Args, default: EngineKind) -> Result<EngineKind, CliError> {
    match args.get("engine") {
        None => Ok(default),
        Some(v) => v
            .parse::<EngineKind>()
            .map_err(|e| CliError(format!("--engine: {e}"))),
    }
}

fn device(args: &Args) -> Result<Device, CliError> {
    match args.str_or("device", "u250") {
        "u250" => Ok(Device::alveo_u250()),
        "u280" => Ok(Device::alveo_u280()),
        "vu9p" => Ok(Device::vu9p()),
        other => Err(CliError(format!("unknown --device {other:?}"))),
    }
}

/// `--memory-budget 4g` parsed to bytes (None when absent).
fn memory_budget(args: &Args) -> Result<Option<u64>, Box<dyn std::error::Error>> {
    match args.get("memory-budget") {
        None => Ok(None),
        Some(raw) => ptmc::util::parse_size(raw)
            .map(Some)
            .map_err(|e| Box::new(CliError(format!("--memory-budget: {e}"))) as _),
    }
}

/// Report the process's peak RSS and, when a budget was requested,
/// fail the run if the peak exceeded it — the out-of-core contract is
/// observable, not advisory.
fn enforce_budget(budget: Option<u64>) -> Result<(), Box<dyn std::error::Error>> {
    let Some(peak) = ptmc::util::peak_rss_bytes() else {
        if budget.is_some() {
            println!("peak RSS: unavailable on this platform (budget not checked)");
        }
        return Ok(());
    };
    match budget {
        None => {}
        Some(b) if peak <= b => println!(
            "peak RSS: {} (within budget {})",
            ptmc::util::format_size(peak),
            ptmc::util::format_size(b)
        ),
        Some(b) => {
            return Err(Box::new(
                ptmc::error::Error::msg(format!(
                    "peak RSS {} exceeded --memory-budget {}",
                    ptmc::util::format_size(peak),
                    ptmc::util::format_size(b)
                ))
                .classify(ptmc::error::ErrorClass::Budget),
            ))
        }
    }
    Ok(())
}

fn cmd_decompose(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let budget = memory_budget(args)?;
    let mut t = workload::tensor_from_args_budgeted(args, budget)?;
    let als = als_config(args)?;
    let backend_name = args.str_or("backend", "native");
    println!(
        "decompose: {} modes, dims {:?}, nnz {}, rank {}, backend {}",
        t.n_modes(),
        t.dims(),
        t.nnz(),
        als.rank,
        backend_name
    );
    let t0 = std::time::Instant::now();
    let model = match backend_name {
        "native" => cp_als(&mut t, &als, &mut NativeBackend),
        "sim" => {
            let cfg = controller_config(args, t.record_bytes())?;
            let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), als.rank);
            let mut b = SimBackend::new(MemoryController::new(cfg), layout);
            cp_als(&mut t, &als, &mut b)
        }
        "parallel" => {
            let workers = args.usize_or("workers", 4)?.max(1);
            let cfg = controller_config(args, t.record_bytes())?;
            let mut b = ParallelBackend::with_controller(workers, cfg);
            // The backend trait is infallible, so a supervised worker
            // failure leaves the ALS loop as a panic with the typed
            // error stashed in the backend; recover it here so the CLI
            // reports one line and the Worker exit code, not a
            // backtrace.
            let model = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                cp_als(&mut t, &als, &mut b)
            })) {
                Ok(model) => model,
                Err(payload) => match b.take_failure() {
                    Some(e) => return Err(Box::new(e)),
                    None => std::panic::resume_unwind(payload),
                },
            };
            let s = b.stats();
            println!(
                "parallel: {} workers, {} controller instances, cache {:.1}% hits, \
                 {} dram bursts, imbalance {:.2}",
                b.workers(),
                s.controllers,
                100.0 * s.cache.hit_rate(),
                s.dram.bursts,
                b.last_plan().map_or(1.0, |p| p.imbalance()),
            );
            model
        }
        "pjrt" => {
            let rt = Runtime::open(Path::new(args.str_or("artifacts", "artifacts")))?;
            let seg = match args.str_or("seg", "onehot") {
                "onehot" => SegMode::Onehot,
                "segids" => SegMode::SegIds,
                "refseg" => SegMode::RefSeg,
                other => return Err(Box::new(CliError(format!("unknown --seg {other:?}")))),
            };
            let mut b = PjrtCoordinator::new(rt, seg);
            let model = cp_als(&mut t, &als, &mut b);
            println!("coordinator: {}", b.metrics().summary());
            model
        }
        other => {
            return Err(Box::new(CliError(format!(
                "unknown --backend {other:?} (native|sim|parallel|pjrt)"
            ))))
        }
    };
    let wall = t0.elapsed();
    println!("iters: {}", model.iters);
    for (i, f) in model.fit_history.iter().enumerate() {
        println!("  iter {:>3}: fit {f:.6}", i + 1);
    }
    println!("final fit: {:.6}", model.final_fit());
    if model.cycles > 0 {
        println!("simulated memory cycles: {}", model.cycles);
    }
    println!("wall time: {wall:?}");
    enforce_budget(budget)
}

fn cmd_simulate(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let mut t = workload::tensor_from_args(args)?;
    let rank = args.usize_or("rank", 16)?;
    let engine = engine_kind(args, EngineKind::Lockstep)?;
    let cfg = controller_config(args, t.record_bytes())?;
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, rank, m as u64))
        .collect();
    let mem_tech = cfg.mem.tech();
    let mem_power = cfg.mem.power_proxy_mw();
    let mut ctl = MemoryController::new(cfg);

    println!("simulate: dims {:?}, nnz {}, rank {rank}", t.dims(), t.nnz());
    println!("engine: {engine}");
    println!("memory: {mem_tech} ({mem_power} mW proxy)");
    let mut total = 0u64;
    for mode in 0..t.n_modes() {
        let run = ptmc::mttkrp::remap_exec::run_with_engine(
            &mut t, &factors, mode, &layout, &mut ctl, 0, engine,
        );
        println!(
            "  mode {mode}: remap {} + compute {} cycles (overhead {:.2}%)",
            run.remap_cycles,
            run.compute_cycles,
            100.0 * run.overhead_ratio()
        );
        total = ctl.now();
    }
    println!("total cycles: {total}");
    let cs = ctl.cache_stats();
    println!(
        "cache: {} accesses, {:.1}% hits | dram: {} bursts, {:.1}% row hits | remapper: {} spilled",
        cs.accesses,
        100.0 * cs.hit_rate(),
        ctl.dram_stats().bursts,
        100.0 * ctl.dram_stats().hit_rate(),
        ctl.remapper_stats().spilled_cursor_elems,
    );
    Ok(())
}

fn cmd_shard(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let t = workload::tensor_from_args(args)?;
    let workers = args.usize_or("workers", 4)?.max(1);
    let modes: Vec<usize> = match args.get("mode") {
        Some(_) => vec![args.usize_or("mode", 0)?],
        None => (0..t.n_modes()).collect(),
    };
    println!(
        "shard plan: dims {:?}, nnz {}, {workers} workers",
        t.dims(),
        t.nnz()
    );
    for mode in modes {
        if mode >= t.n_modes() {
            return Err(Box::new(CliError(format!(
                "--mode {mode} out of range for a {}-mode tensor",
                t.n_modes()
            ))));
        }
        let plan = ShardPlan::balance(&t, mode, workers);
        println!("mode {mode}: imbalance {:.3}", plan.imbalance());
        for (sid, s) in plan.shards.iter().enumerate() {
            println!(
                "  shard {sid}: coords [{}, {}) ({} rows), {} nnz ({:.1}%)",
                s.coord_lo,
                s.coord_hi,
                s.rows(),
                s.nnz,
                100.0 * s.nnz as f64 / t.nnz().max(1) as f64
            );
        }
    }
    Ok(())
}

fn cmd_pms(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let t = workload::tensor_from_args(args)?;
    let rank = args.usize_or("rank", 16)?;
    let cfg = controller_config(args, t.record_bytes())?;
    let dev = device(args)?;
    let profile = TensorProfile::measure(&t);
    let est = pms::estimate_with_rank(&profile, &cfg, &dev, rank);
    println!("pms: dims {:?}, nnz {}, rank {rank}, device {}", t.dims(), t.nnz(), dev.name);
    for (m, e) in est.per_mode.iter().enumerate() {
        println!(
            "  mode {m}: remap {:.0} + tensor {:.0} + factors {:.0} + output {:.0} = {:.0} cycles",
            e.remap_cycles,
            e.tensor_stream_cycles,
            e.factor_access_cycles,
            e.output_store_cycles,
            e.total()
        );
    }
    println!("total estimate: {:.0} cycles", est.total_cycles());
    println!(
        "resources: {} BRAM36 + {} URAM ({}, {:.1}% of device)",
        est.resources.bram36_used,
        est.resources.uram_used,
        if est.resources.fits { "fits" } else { "DOES NOT FIT" },
        100.0 * est.resources.utilization(&dev)
    );
    Ok(())
}

/// One-line knob summary of a configuration for the explore report.
fn cfg_summary(cfg: &ControllerConfig) -> String {
    format!(
        "cache {}x{}B {}-way | dma {}x{}x{}B | {} | ptr {}",
        cfg.cache.num_lines,
        cfg.cache.line_bytes,
        cfg.cache.assoc,
        cfg.dma.num_dmas,
        cfg.dma.buffers_per_dma,
        cfg.dma.buffer_bytes,
        cfg.mem,
        cfg.remapper.max_pointers
    )
}

fn cmd_explore(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let budget = memory_budget(args)?;
    let t = workload::tensor_from_args_budgeted(args, budget)?;
    let rank = args.usize_or("rank", 16)?;
    let evaluator = args.str_or("evaluator", "pms");
    // Search layer: --search / --top-k override the config file's
    // `[dse]` section; the default is the legacy coordinate descent
    // with a single winner.
    let file_cfg = match args.get("config") {
        Some(path) => Some(Config::load(Path::new(path))?),
        None => None,
    };
    let search_default = file_cfg
        .as_ref()
        .map(|c| c.str_or("dse", "search", "coordinate").to_string())
        .unwrap_or_else(|| "coordinate".to_string());
    let top_k_default = file_cfg
        .as_ref()
        .map_or(1, |c| c.usize_or("dse", "top_k", 1));
    let top_k = args.usize_or("top-k", top_k_default)?.max(1);
    let search = args.str_or("search", &search_default);
    let strategy = match search {
        "coordinate" => SearchStrategy::Coordinate,
        "joint" => SearchStrategy::Joint,
        // The beam keeps as many incumbents as the report shows (at
        // least 2 — width 1 would just be coordinate descent again).
        "beam" => SearchStrategy::Beam {
            width: top_k.max(2),
        },
        other => {
            return Err(Box::new(CliError(format!(
                "unknown --search {other:?} (coordinate|joint|beam)"
            ))))
        }
    };
    // Warm-start score cache (S28): --warm-cache overrides the config
    // file's `[dse] warm_cache`.  When active, beam searches also
    // resume from the persisted Pareto frontier.
    let warm_dir: Option<String> = args
        .get("warm-cache")
        .map(|s| s.to_string())
        .or_else(|| {
            file_cfg
                .as_ref()
                .and_then(|c| c.get("dse", "warm_cache"))
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
        });
    let checkpoint_default = file_cfg
        .as_ref()
        .map_or(0, |c| c.usize_or("dse", "checkpoint_every", 0));
    let checkpoint_every = args.usize_or("checkpoint-every", checkpoint_default)?;
    if checkpoint_every > 0 && warm_dir.is_none() {
        eprintln!(
            "warning: --checkpoint-every {checkpoint_every} has no effect without --warm-cache \
             (checkpoints persist through the warm cache)"
        );
    }
    let opts = SearchOptions {
        strategy,
        top_k,
        resume: warm_dir.is_some(),
        checkpoint_every,
    };
    // `--evaluator grid` is shorthand for the cycle evaluator pinned to
    // the grid batch core; a conflicting explicit --engine would
    // silently lose, so reject it and default the header to grid.
    let mut engine = engine_kind(args, EngineKind::Event)?;
    if evaluator == "grid" {
        if engine != EngineKind::Grid && args.get("engine").is_some() {
            return Err(Box::new(CliError(format!(
                "--evaluator grid pins --engine grid (got --engine {engine})"
            ))));
        }
        engine = EngineKind::Grid;
    }
    let base = controller_config_with(args, t.record_bytes(), file_cfg.as_ref())?;
    let dev = device(args)?;
    // An infeasible base configuration would panic deep inside the
    // search ("base configuration must fit the device"); reject it up
    // front as a usage error with the resource numbers.
    let base_est = ptmc::fpga::estimate(&base, &dev);
    if !base_est.fits || !dev.supports(&base.mem) {
        return Err(Box::new(CliError(format!(
            "base configuration does not fit {} ({} BRAM36 + {} URAM, or unsupported memory \
             tech {}); shrink --cache-lines/--max-pointers or pick a larger --device",
            dev.name,
            base_est.bram36_used,
            base_est.uram_used,
            base.mem.tech()
        ))));
    }
    let profile = TensorProfile::measure(&t);
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .map(|&d| Mat::randn(d, rank, 3))
        .collect();
    println!("engine: {engine}");
    let workers = args.usize_or("workers", 4)?.max(1);
    // The warm cache is keyed by the full scoring context: changing
    // the tensor, evaluator, engine, rank, worker count, device, or
    // factors lands on a different (cold) cache file.
    let warm = warm_dir.as_ref().map(|dir| {
        let key = KeyBuilder::new(tensor_fingerprint(&t))
            .evaluator(evaluator)
            .engine(engine)
            .rank(rank)
            .workers(if evaluator == "sharded" { workers } else { 0 })
            .device(&dev)
            .factors(&factors)
            .finish();
        std::sync::Arc::new(WarmCache::open(dir, key))
    });
    if let Some(w) = &warm {
        println!(
            "warm cache: {} ({} cached verdicts)",
            w.path().display(),
            w.len()
        );
    }
    let builder = EvaluatorBuilder::new()
        .engine(engine)
        .rank(rank)
        .memory_budget(budget)
        .warm_cache(warm.clone());
    let sweep;
    let eval = match evaluator {
        "pms" => builder.pms(&profile),
        "sim" => builder.cycle_sim(&t, &factors),
        // The cache-module sweep is classified in one trace pass
        // (stack-distance classifier + miss-only replay) instead of
        // replaying the trace once per candidate.
        "grid" => {
            println!("grid evaluator: one-pass cache-module scoring");
            builder.cycle_sim(&t, &factors)
        }
        "sharded" => {
            println!("sharded evaluator: {workers} concurrent controller instances");
            sweep = ShardedSweep::prepare_with_engine(&t, rank, workers, engine);
            builder.sharded(&sweep)
        }
        other => {
            return Err(Box::new(CliError(format!(
                "unknown --evaluator {other:?} (pms|sim|sharded|grid)"
            ))))
        }
    };
    // The memory-technology axis of the sweep: default to the base
    // configuration's technology (a pure-DDR4 grid reproduces the
    // legacy search exactly), `--mem-techs all` or a comma list opens
    // the cross-technology space.
    let grids = Grids {
        mem_techs: match args.get("mem-techs") {
            None => vec![base.mem.tech()],
            Some("all") => vec![MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram],
            Some(list) => list
                .split(',')
                .map(|s| s.trim().parse::<MemTech>())
                .collect::<Result<Vec<_>, _>>()
                .map_err(|e| CliError(format!("--mem-techs: {e}")))?,
        },
        ..Grids::default()
    };
    println!("search: {search} (top-k {top_k})");
    let ex = explore_with(&base, &grids, &dev, &eval, &opts);
    if let Some(w) = &warm {
        println!(
            "warm cache: hits={} misses={} entries={}",
            w.hits(),
            w.misses(),
            w.len()
        );
    }
    println!(
        "explored {} feasible configs ({} rejected as not fitting {})",
        ex.visited.len(),
        ex.rejected,
        dev.name
    );
    let b = &ex.best;
    println!("best: {:.3e} cycles", b.cycles);
    println!(
        "  cache: {} lines x {}B, {}-way | dma: {} x {} x {}B | pointers: {}",
        b.cfg.cache.num_lines,
        b.cfg.cache.line_bytes,
        b.cfg.cache.assoc,
        b.cfg.dma.num_dmas,
        b.cfg.dma.buffers_per_dma,
        b.cfg.dma.buffer_bytes,
        b.cfg.remapper.max_pointers
    );
    println!("  memory: {} ({} mW proxy)", b.cfg.mem, b.power_mw());
    println!("  resources: {} BRAM36 + {} URAM", b.bram36, b.uram);
    if ex.top.len() > 1 {
        println!("top-{} points:", ex.top.len());
        for (i, p) in ex.top.iter().enumerate() {
            println!(
                "  {}: {:.3e} cycles | {} | {} blocks",
                i + 1,
                p.cycles,
                cfg_summary(&p.cfg),
                p.blocks()
            );
        }
    }
    println!(
        "pareto frontier (cycles vs on-chip blocks vs memory power): {} points",
        ex.pareto.len()
    );
    for p in ex.pareto.iter().take(8) {
        println!(
            "  {:.3e} cycles @ {} blocks, {} mW | {}",
            p.cycles,
            p.blocks(),
            p.power_mw(),
            cfg_summary(&p.cfg)
        );
    }
    if ex.pareto.len() > 8 {
        println!("  ... {} more on the frontier", ex.pareto.len() - 8);
    }
    enforce_budget(budget)
}

fn cmd_stats(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let t: SparseTensor = workload::tensor_from_args(args)?;
    let rank = args.usize_or("rank", 16)?;
    let c = stats::characteristics(&t, rank);
    println!("tensor characteristics (cf. paper Table 2):");
    println!("  modes:             {}", c.n_modes);
    println!("  mode lengths:      {:?} (max {})", t.dims(), c.max_mode_len);
    println!("  non-zeros:         {}", c.nnz);
    println!("  density:           {:.3e}", c.density);
    println!("  tensor size:       {} bytes", c.tensor_bytes);
    println!("  max factor matrix: {} bytes (R = {rank})", c.max_factor_bytes);
    for m in 0..t.n_modes() {
        let f = stats::fiber_stats(&t, m);
        println!(
            "  mode {m}: {} used coords, mean fiber {:.2}, max fiber {}, skew {:.3}",
            f.used_coords, f.mean_len, f.max_len, f.skew
        );
    }
    Ok(())
}

/// `ptmc serve`: run the persistent DSE service until a client sends
/// shutdown.  CLI flags override the config file's `[serve]` section.
fn cmd_serve(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let file_cfg = match args.get("config") {
        Some(path) => Some(Config::load(Path::new(path))?),
        None => None,
    };
    let listen_default = file_cfg
        .as_ref()
        .map(|c| c.str_or("serve", "listen", "127.0.0.1:7421").to_string())
        .unwrap_or_else(|| "127.0.0.1:7421".to_string());
    let listen = args.str_or("listen", &listen_default);
    let workers_default = file_cfg
        .as_ref()
        .map_or(4, |c| c.usize_or("serve", "workers", 4));
    let workers = args.usize_or("serve-workers", workers_default)?.max(1);
    let budget_default = file_cfg
        .as_ref()
        .map_or(0, |c| c.usize_or("serve", "tenant_budget", 0));
    let tenant_budget = args.usize_or("tenant-budget", budget_default)?;
    let spill: Option<String> = args
        .get("memo-spill")
        .map(|s| s.to_string())
        .or_else(|| {
            file_cfg
                .as_ref()
                .and_then(|c| c.get("serve", "memo_spill"))
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
        });
    let cfg = ServeConfig {
        workers,
        tenant_budget: if tenant_budget > 0 {
            Some(tenant_budget as u64)
        } else {
            None
        },
        spill: spill.map(std::path::PathBuf::from),
        device: device(args)?,
    };
    if let Some(dir) = &cfg.spill {
        println!("serve: memo spill tier at {}", dir.display());
    }
    let server = Server::bind(listen, cfg)?;
    server.run()?;
    Ok(())
}

/// The job template `ptmc batch` submits: the synthetic-workload and
/// DSE knobs of `explore`, minus anything that is a server-side
/// resource decision.
fn batch_spec(args: &Args) -> Result<JobSpec, Box<dyn std::error::Error>> {
    if args.get("input").is_some() {
        return Err(Box::new(CliError(
            "batch serves synthetic workloads only (the server regenerates the tensor \
             from --synth/--dims/--nnz/--seed; --input is not supported)"
            .to_string(),
        )));
    }
    let dims = workload::parse_dims(args.str_or("dims", "2000x1500x1000"))?;
    let nnz = args.usize_or("nnz", 50_000)?;
    let seed = args.u64_or("seed", 42)?;
    let alpha = args.f64_or("alpha", 1.2)?;
    // Mirrors `workload::tensor_from_args` exactly, so a served job
    // and a local `explore` of the same flags describe one tensor.
    let profile = match args.str_or("synth", "zipf") {
        "uniform" => ptmc::tensor::synth::Profile::Uniform,
        "zipf" => ptmc::tensor::synth::Profile::Zipf {
            alpha_milli: (alpha * 1000.0) as u32,
        },
        "clustered" => ptmc::tensor::synth::Profile::Clustered {
            block: 64,
            blocks: (nnz / 256).max(1),
        },
        other => return Err(Box::new(CliError(format!("unknown --synth {other:?}")))),
    };
    let rank = args.usize_or("rank", 16)?;
    let evaluator = match args.str_or("evaluator", "pms") {
        "pms" => EvalKind::Pms,
        "sim" => EvalKind::Sim,
        other => {
            return Err(Box::new(CliError(format!(
                "unknown --evaluator {other:?} for batch (pms|sim)"
            ))))
        }
    };
    let top_k = args.usize_or("top-k", 1)?.max(1);
    let strategy = match args.str_or("search", "coordinate") {
        "coordinate" => SearchStrategy::Coordinate,
        "joint" => SearchStrategy::Joint,
        "beam" => SearchStrategy::Beam {
            width: top_k.max(2),
        },
        other => {
            return Err(Box::new(CliError(format!(
                "unknown --search {other:?} (coordinate|joint|beam)"
            ))))
        }
    };
    let grid = match args.str_or("grid", "default") {
        "default" => GridPreset::Default,
        "smoke" => GridPreset::Smoke,
        other => {
            return Err(Box::new(CliError(format!(
                "unknown --grid {other:?} (default|smoke)"
            ))))
        }
    };
    Ok(JobSpec {
        id: 0, // assigned per submission
        tenant: args.str_or("tenant", "default").to_string(),
        dims,
        nnz,
        seed,
        profile,
        rank,
        evaluator,
        engine: engine_kind(args, EngineKind::Event)?,
        strategy,
        top_k,
        grid,
    })
}

/// `ptmc batch`: pipeline `--repeat` copies of the job to a running
/// server, print results and memo economics, then optionally fetch
/// stats and/or shut the server down.
fn cmd_batch(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let addr = args.str_or("addr", "127.0.0.1:7421").to_string();
    if args.flag("server-stats") {
        let st = client::stats(&addr)?;
        println!(
            "server stats: jobs done={} failed={} | memo entries={} hits={} misses={} | \
             {} workers",
            st.jobs_done, st.jobs_failed, st.memo_entries, st.memo_hits, st.memo_misses,
            st.workers
        );
        if args.flag("shutdown") {
            client::shutdown(&addr)?;
            println!("server shut down");
        }
        return Ok(());
    }
    let template = batch_spec(args)?;
    let repeat = args.usize_or("repeat", 1)?.max(1);
    let jobs: Vec<JobSpec> = (0..repeat)
        .map(|i| JobSpec {
            id: i as u64 + 1,
            ..template.clone()
        })
        .collect();
    println!(
        "batch: {} job(s) to {} (tenant {:?}, dims {:?}, nnz {}, rank {})",
        jobs.len(),
        addr,
        template.tenant,
        template.dims,
        template.nnz,
        template.rank
    );
    let report = client::submit_batch(&addr, &jobs)?;
    for r in &report.results {
        println!(
            "job {}: {:.3e} cycles | pareto {} points | {} visited, {} rejected | \
             memo hits={} misses={}",
            r.id,
            r.best.cycles(),
            r.pareto.len(),
            r.visited,
            r.rejected,
            r.memo_hits,
            r.memo_misses
        );
    }
    for e in &report.errors {
        eprintln!("job {}: {:?}: {}", e.id, e.class, e.msg);
    }
    let (hits, misses) = (report.memo_hits(), report.memo_misses());
    let total = hits + misses;
    println!(
        "batch memo: hits={} misses={} ({:.1}% hit rate)",
        hits,
        misses,
        if total > 0 {
            hits as f64 * 100.0 / total as f64
        } else {
            0.0
        }
    );
    if args.flag("shutdown") {
        client::shutdown(&addr)?;
        println!("server shut down");
    }
    if let Some(class) = report.first_error_class() {
        return Err(Box::new(
            ptmc::error::Error::msg(format!(
                "{} of {} jobs failed (first: job {}: {})",
                report.errors.len(),
                jobs.len(),
                report.errors[0].id,
                report.errors[0].msg
            ))
            .classify(class),
        ));
    }
    Ok(())
}
