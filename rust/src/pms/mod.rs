//! Performance Model Simulator (S10, paper §5.3 and §6): a *fast
//! analytic* estimator of total spMTTKRP memory-access time and FPGA
//! on-chip memory for a given (dataset, memory-controller configuration)
//! pair — the tool the paper says it is developing because "synthesizing
//! a FPGA can take a long time".
//!
//! Inputs mirror §5.3 exactly: (1) FPGA resources via
//! [`crate::fpga::Device`], (2) data-structure sizes (record width, rank),
//! (3) controller parameters ([`crate::controller::ControllerConfig`]).
//! The dataset enters through cheap summary statistics
//! ([`TensorProfile`]) so one profile can stand for a whole application
//! domain (the paper's `t_avg` use-case).
//!
//! The model is closed-form per §4 access class; it is validated against
//! the cycle-level simulator in the `pms_validation` bench (E7) — single
//! digit percentage error across the DSE grid is the target, which is
//! ample to rank configurations.

use crate::controller::ControllerConfig;
use crate::fpga::{self, Device, Usage};
use crate::tensor::{stats, SparseTensor};

/// Summary statistics of a tensor, per mode — everything the analytic
/// model needs to know about the dataset.
#[derive(Debug, Clone)]
pub struct TensorProfile {
    pub n_modes: usize,
    pub nnz: usize,
    pub record_bytes: usize,
    /// Mode lengths.
    pub dims: Vec<usize>,
    /// Non-empty fiber count per mode (output-store row count).
    pub used_coords: Vec<usize>,
    /// Mean reuse distance proxy per mode when walked in another mode's
    /// order (drives the cache-hit model); `f64::INFINITY` = no reuse.
    pub reuse_distance: Vec<f64>,
    /// Per mode: fraction of nnz covered by the top-k densest
    /// coordinates, at k = 4^0, 4^1, ... (drives the densest-first
    /// pointer-spill model).  Monotone non-decreasing, ends at 1.0.
    pub coverage: Vec<Vec<(usize, f64)>>,
}

/// Coverage of the top-k densest coordinates for one mode column.
fn coverage_curve(col: &[u32], mode_len: usize) -> Vec<(usize, f64)> {
    let mut counts = vec![0u32; mode_len];
    for &c in col {
        counts[c as usize] += 1;
    }
    let mut lens: Vec<u32> = counts.into_iter().filter(|&c| c > 0).collect();
    lens.sort_unstable_by(|a, b| b.cmp(a));
    let total: u64 = lens.iter().map(|&l| l as u64).sum();
    let mut curve = Vec::new();
    let mut k = 1usize;
    let mut cum = 0u64;
    let mut idx = 0usize;
    while idx < lens.len() {
        let next = k.min(lens.len());
        while idx < next {
            cum += lens[idx] as u64;
            idx += 1;
        }
        curve.push((next, cum as f64 / total.max(1) as f64));
        if next == lens.len() {
            break;
        }
        k *= 4;
    }
    curve
}

/// Interpolate a coverage curve at pointer budget `k` (log-linear).
fn coverage_at(curve: &[(usize, f64)], k: usize) -> f64 {
    // Hand-built or averaged profiles can carry a zero first knot; the
    // linear ramp below would then divide by zero and the NaN silently
    // poisons every PMS score downstream.  Skip past zero-k knots (a
    // zero pointer budget covers nothing) before interpolating.
    let curve = match curve.iter().position(|&(k0, _)| k0 > 0) {
        Some(i) => &curve[i..],
        None => return 1.0, // empty or all-zero knots: degenerate curve
    };
    if k >= curve.last().unwrap().0 {
        return 1.0;
    }
    if k <= curve[0].0 {
        return curve[0].1 * (k as f64 / curve[0].0 as f64);
    }
    for w in curve.windows(2) {
        let (k0, c0) = w[0];
        let (k1, c1) = w[1];
        if k >= k0 && k <= k1 {
            let f = ((k as f64).ln() - (k0 as f64).ln()) / ((k1 as f64).ln() - (k0 as f64).ln());
            return c0 + f * (c1 - c0);
        }
    }
    1.0
}

impl TensorProfile {
    /// Measure a tensor (one pass per mode).
    pub fn measure(t: &SparseTensor) -> Self {
        let n = t.n_modes();
        TensorProfile {
            n_modes: n,
            nnz: t.nnz(),
            record_bytes: t.record_bytes(),
            dims: t.dims().to_vec(),
            used_coords: (0..n).map(|m| stats::fiber_stats(t, m).used_coords).collect(),
            reuse_distance: (0..n).map(|m| stats::mean_reuse_distance(t, m)).collect(),
            coverage: (0..n)
                .map(|m| coverage_curve(t.mode_col(m), t.dims()[m]))
                .collect(),
        }
    }

    /// Average several tensors from one application domain (the paper's
    /// `t_avg` input: "use with multiple datasets from the same domain").
    pub fn average(profiles: &[TensorProfile]) -> Self {
        assert!(!profiles.is_empty());
        let n = profiles[0].n_modes;
        assert!(profiles.iter().all(|p| p.n_modes == n));
        let k = profiles.len() as f64;
        let avg_usize = |f: &dyn Fn(&TensorProfile) -> usize| {
            (profiles.iter().map(f).sum::<usize>() as f64 / k) as usize
        };
        TensorProfile {
            n_modes: n,
            nnz: avg_usize(&|p| p.nnz),
            record_bytes: profiles[0].record_bytes,
            dims: (0..n)
                .map(|m| (profiles.iter().map(|p| p.dims[m]).sum::<usize>() as f64 / k) as usize)
                .collect(),
            used_coords: (0..n)
                .map(|m| {
                    (profiles.iter().map(|p| p.used_coords[m]).sum::<usize>() as f64 / k) as usize
                })
                .collect(),
            reuse_distance: (0..n)
                .map(|m| profiles.iter().map(|p| p.reuse_distance[m]).sum::<f64>() / k)
                .collect(),
            // Averaging curves point-wise would need re-sampling; take
            // the first profile's (domain-mates have similar skew).
            coverage: profiles[0].coverage.clone(),
        }
    }
}

/// Per-mode estimate breakdown (cycles).
#[derive(Debug, Clone, Copy, Default)]
pub struct ModeEstimate {
    pub remap_cycles: f64,
    pub tensor_stream_cycles: f64,
    pub factor_access_cycles: f64,
    pub output_store_cycles: f64,
}

impl ModeEstimate {
    pub fn total(&self) -> f64 {
        self.remap_cycles
            + self.tensor_stream_cycles
            + self.factor_access_cycles
            + self.output_store_cycles
    }
}

/// Full PMS output.
#[derive(Debug, Clone)]
pub struct Estimate {
    pub per_mode: Vec<ModeEstimate>,
    pub resources: Usage,
}

impl Estimate {
    /// Total cycles across all modes (one full MTTKRP sweep — the paper's
    /// unit of optimization).
    pub fn total_cycles(&self) -> f64 {
        self.per_mode.iter().map(|m| m.total()).sum()
    }
}

// ---- The model -----------------------------------------------------------
//
// The external-memory service-time primitives (streaming bandwidth,
// random-access latency, burst occupancy) live on
// [`crate::mem::MemTechConfig`] as the analytic counterparts of each
// device model — DDR4 keeps the exact pre-refactor formulas, HBM2
// applies them to its flattened pseudo-channel geometry, and the
// optical-SRAM scratchpad has no row dynamics at all.

/// Estimate one full MTTKRP sweep (all modes, Approach 1 with remapping)
/// for `profile` under `cfg` on `dev` with factor rank 16 (the FROSTT
/// "typical" value, Table 2).  Use [`estimate_with_rank`] otherwise.
pub fn estimate(profile: &TensorProfile, cfg: &ControllerConfig, dev: &Device) -> Estimate {
    estimate_with_rank(profile, cfg, dev, 16)
}

/// Estimate one full MTTKRP sweep for an explicit factor rank `rank`
/// (the factor-row width R*4 drives cache behaviour and output volume).
pub fn estimate_with_rank(
    profile: &TensorProfile,
    cfg: &ControllerConfig,
    dev: &Device,
    rank: usize,
) -> Estimate {
    let sbw = cfg.mem.stream_bytes_per_cycle();
    let rand_lat = cfg.mem.random_access_cycles();
    let row_bytes = cfg.remapper.elem_bytes; // record width
    let nnz = profile.nnz as f64;

    let mut per_mode = Vec::with_capacity(profile.n_modes);
    for mode in 0..profile.n_modes {
        // --- Remap pass (every mode but the first in steady state; we
        // charge it for every mode, matching the simulator's behaviour
        // when the previous mode left the tensor in its own order).
        let stream_in = nnz * row_bytes as f64 / sbw;
        // Element-wise stores: per-request setup plus a mostly-conflict
        // DRAM access (the interleaved stream loads keep closing rows).
        let store_each = cfg.remapper.store_setup_cycles as f64
            + 0.9 * rand_lat
            + 0.1 * cfg.mem.burst_occupancy_cycles();
        // Pointer spill: densest-first allocation means the spilled
        // *element* fraction is 1 - coverage(top max_pointers coords).
        let spill_frac = 1.0 - coverage_at(&profile.coverage[mode], cfg.remapper.max_pointers);
        let ptr_cycles = spill_frac * nnz * 2.0 * rand_lat;
        // Every mode pays a remap in the simulator's protocol (the
        // tensor arrives in no particular order before mode 0 too).
        let remap_cycles = stream_in + nnz * store_each + ptr_cycles;

        // --- Compute phase ---
        let tensor_stream_cycles = nnz * row_bytes as f64 / sbw;

        // Factor-row loads through the cache: hit probability from the
        // reuse distance vs cache reach (lines that survive between
        // reuses ≈ num_lines / lines-per-row).
        let rank_bytes = (rank * 4) as f64;
        let lines_per_row = (rank_bytes / cfg.cache.line_bytes as f64).max(1.0);
        let cache_rows = cfg.cache.num_lines as f64 / lines_per_row;
        // The cache is shared by the (N-1) input factor matrices.
        let rows_per_matrix = (cache_rows / (profile.n_modes as f64 - 1.0)).max(1.0);
        let mut factor_access_cycles = 0.0;
        for m in 0..profile.n_modes {
            if m == mode {
                continue;
            }
            // LRU-under-skew approximation: the top-W hottest rows stay
            // resident, so the hit rate is their access coverage (the
            // same curve that drives the pointer-spill model).
            let p_hit = coverage_at(&profile.coverage[m], rows_per_matrix as usize);
            // Associativity correction: low associativity suffers
            // conflict misses; fold in a simple penalty.
            let assoc_pen = match cfg.cache.assoc {
                1 => 0.75,
                2 => 0.9,
                4 => 0.97,
                _ => 1.0,
            };
            let p_hit = p_hit * assoc_pen;
            let hit_c = cfg.cache.hit_latency as f64;
            let miss_c = rand_lat * lines_per_row + hit_c;
            factor_access_cycles += nnz * (p_hit * hit_c + (1.0 - p_hit) * miss_c);
        }

        // --- Output stores: streaming, one row per used coordinate.
        let output_store_cycles = profile.used_coords[mode] as f64 * rank_bytes / sbw;

        per_mode.push(ModeEstimate {
            remap_cycles,
            tensor_stream_cycles,
            factor_access_cycles,
            output_store_cycles,
        });
    }

    Estimate {
        per_mode,
        resources: fpga::estimate(cfg, dev),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{CacheConfig, ControllerConfig};
    use crate::tensor::synth::{generate, Profile, SynthConfig};

    fn profile() -> TensorProfile {
        let t = generate(&SynthConfig {
            dims: vec![800, 600, 400],
            nnz: 30_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 5,
        });
        TensorProfile::measure(&t)
    }

    fn base_cfg() -> ControllerConfig {
        ControllerConfig::default_for(16)
    }

    #[test]
    fn estimate_is_positive_and_every_mode_pays_remap() {
        let e = estimate(&profile(), &base_cfg(), &Device::alveo_u250());
        assert!(e.total_cycles() > 0.0);
        for m in &e.per_mode {
            assert!(m.remap_cycles > 0.0);
        }
    }

    #[test]
    fn row_policy_moves_the_estimate() {
        // The PMS must see the row-policy knob the DSE now sweeps:
        // closed page trades streaming bandwidth for cheaper random
        // access, so the two estimates cannot coincide.
        let p = profile();
        let open = estimate(&p, &base_cfg(), &Device::alveo_u250());
        let mut cfg = base_cfg();
        cfg.mem.ddr4_mut().row_policy = crate::dram::RowPolicy::Closed;
        let closed = estimate(&p, &cfg, &Device::alveo_u250());
        assert_ne!(open.total_cycles(), closed.total_cycles());
        // Closed page never pays a precharge on the random path.
        assert!(cfg.mem.random_access_cycles() < base_cfg().mem.random_access_cycles());
    }

    #[test]
    fn memory_technology_moves_the_estimate() {
        // Each technology's analytic primitives differ, so swapping the
        // device under an otherwise identical controller must move the
        // estimate — memory tech is a real PMS input, not a label.
        use crate::mem::MemTech;
        let p = profile();
        let dev = Device::alveo_u250();
        let per_tech: Vec<f64> = [MemTech::Ddr4, MemTech::Hbm2, MemTech::Osram]
            .iter()
            .map(|&tech| {
                let mut cfg = base_cfg();
                cfg.mem = tech.default_config();
                estimate(&p, &cfg, &dev).total_cycles()
            })
            .collect();
        assert_ne!(per_tech[0], per_tech[1]);
        assert_ne!(per_tech[0], per_tech[2]);
        assert_ne!(per_tech[1], per_tech[2]);
        // The scratchpad has no row-conflict path, so its random-access
        // latency — the factor-miss driver — beats both DRAM techs.
        let os = crate::mem::MemTech::Osram.default_config();
        assert!(os.random_access_cycles() < base_cfg().mem.random_access_cycles());
    }

    #[test]
    fn coverage_curve_and_interpolation() {
        // 4 coords with counts 8, 4, 2, 1.
        let col: Vec<u32> = [vec![0u32; 8], vec![1; 4], vec![2; 2], vec![3; 1]].concat();
        let curve = coverage_curve(&col, 10);
        assert_eq!(curve[0], (1, 8.0 / 15.0));
        assert_eq!(curve.last().unwrap().0, 4);
        assert!((curve.last().unwrap().1 - 1.0).abs() < 1e-12);
        assert_eq!(coverage_at(&curve, 100), 1.0);
        let mid = coverage_at(&curve, 2);
        assert!(mid > 8.0 / 15.0 && mid < 1.0);
    }

    #[test]
    fn zero_first_knot_never_yields_nan() {
        // Regression: a zero first knot used to divide by zero in the
        // `k <= curve[0].0` ramp and leak NaN into PMS scores.
        let curve = vec![(0usize, 0.0f64), (4, 0.5), (16, 1.0)];
        for k in [0usize, 1, 2, 4, 8, 16, 100] {
            let c = coverage_at(&curve, k);
            assert!(c.is_finite(), "coverage_at(k={k}) = {c} must be finite");
            assert!((0.0..=1.0).contains(&c), "coverage_at(k={k}) = {c}");
        }
        // Degenerate all-zero curves fall back to full coverage rather
        // than NaN (matches the empty-curve convention).
        assert_eq!(coverage_at(&[(0, 0.3)], 5), 1.0);
        assert_eq!(coverage_at(&[], 5), 1.0);
        // A zero pointer budget covers nothing on a well-formed curve.
        assert_eq!(coverage_at(&[(1, 0.4), (4, 1.0)], 0), 0.0);
    }

    #[test]
    fn bigger_cache_never_slower() {
        let p = profile();
        let dev = Device::alveo_u250();
        let mut small = base_cfg();
        small.cache = CacheConfig {
            line_bytes: 64,
            num_lines: 64,
            assoc: 4,
            hit_latency: 2,
        };
        let mut big = small.clone();
        big.cache.num_lines = 8192;
        let es = estimate(&p, &small, &dev).total_cycles();
        let eb = estimate(&p, &big, &dev).total_cycles();
        assert!(eb <= es, "big cache {eb} vs small {es}");
    }

    #[test]
    fn pointer_spill_adds_remap_cost() {
        let p = profile();
        let dev = Device::alveo_u250();
        let fits = base_cfg();
        let mut spills = base_cfg();
        spills.remapper.max_pointers = 16;
        let a = estimate(&p, &fits, &dev).total_cycles();
        let b = estimate(&p, &spills, &dev).total_cycles();
        assert!(b > a * 1.05, "spill {b} should cost >5% over {a}");
    }

    #[test]
    fn stream_bandwidth_between_half_and_full_peak() {
        let cfg = crate::mem::MemTechConfig::default_ddr4();
        let s = cfg.stream_bytes_per_cycle();
        assert!(s > 0.5 * cfg.peak_bytes_per_cycle());
        assert!(s <= cfg.peak_bytes_per_cycle());
    }

    #[test]
    fn average_profile_blends_domains() {
        let p1 = profile();
        let t2 = generate(&SynthConfig {
            dims: vec![800, 600, 400],
            nnz: 10_000,
            profile: Profile::Uniform,
            seed: 9,
        });
        let p2 = TensorProfile::measure(&t2);
        let avg = TensorProfile::average(&[p1.clone(), p2.clone()]);
        assert_eq!(avg.nnz, (p1.nnz + p2.nnz) / 2);
        assert!(avg.reuse_distance[0] > 0.0);
    }

    #[test]
    fn rank_scales_output_traffic() {
        let p = profile();
        let dev = Device::alveo_u250();
        let lo = estimate_with_rank(&p, &base_cfg(), &dev, 8);
        let hi = estimate_with_rank(&p, &base_cfg(), &dev, 64);
        assert!(
            hi.per_mode[0].output_store_cycles > 4.0 * lo.per_mode[0].output_store_cycles
        );
    }
}
