//! Simulation engines (S19): how a compiled access trace is driven
//! through the [`MemoryController`].
//!
//! Two engines implement the same [`SimEngine`] trait over the same
//! [`PreparedTrace`], so they are differentially comparable:
//!
//! * [`LockstepEngine`] — the legacy core: replays the raw
//!   [`Access`](crate::controller::Access) list one request at a time
//!   ([`MemoryController::replay`]).  Exact, simple, slow.
//! * [`EventEngine`] — the event-driven, epoch-batched core: walks the
//!   delta-encoded [`CompressedTrace`] run by run, dispatching each
//!   run to a batched kernel ([`MemoryController::replay_events`])
//!   that processes the whole run without per-access dispatch, and
//!   folds controller-level statistics in per epoch rather than per
//!   request.
//!
//! The two engines are **bit-identical** in completion cycles and in
//! every statistics counter (cache hits/misses, DRAM bursts and row
//! activations, DMA chunks, controller totals); the event engine is
//! strictly an execution-strategy change, not a model change.  The
//! differential harness in `tests/differential.rs` enforces this on a
//! randomized corpus; pick `Event` for sweep throughput (DSE scoring,
//! shard replays) and `Lockstep` when debugging the model or when a
//! third-party trace is replayed once and compression would not pay
//! for itself.
//!
//! A third core, the **grid core** ([`grid`], selected as
//! [`EngineKind::Grid`]), targets batch DSE scoring: one stack-distance
//! classification pass over a trace yields exact hit/miss outcomes for
//! an entire `(num_lines, assoc)` cache grid at once (Mattson
//! inclusion), and each candidate is then timed from its miss stream
//! alone — also bit-identical to the other cores, also enforced by the
//! differential harness.
//!
//! Its timing-dimension sibling is the **vectorized timing core**
//! ([`timing`], engaged by the same [`EngineKind::Grid`] selection on
//! DRAM/DMA module sweeps): one cache classification pass feeds an
//! extracted miss/stream op queue, and a single walk of that queue
//! advances an array of per-candidate DRAM/DMA lanes in
//! structure-of-arrays form — every DRAM and DMA candidate timed
//! simultaneously, bit-identically to per-candidate replay.
//!
//! The two one-pass cores compose hierarchically in the **joint sweep
//! core** ([`sweep`], also engaged by [`EngineKind::Grid`]): a whole
//! `line_bytes × (num_lines, assoc) × DRAM × DMA` cross product is
//! scored in one structured traversal — classify per line width,
//! extract per cache candidate, walk each cache's DRAM/DMA lane set
//! once — so a *joint* DSE search pays for distinct `(cache, lane)`
//! cells instead of full replays per candidate, still bit-identically.

pub mod grid;
pub mod stream;
pub mod sweep;
pub mod timing;
pub mod trace;

pub use grid::{ClassifyKernel, GridClassification, GridRun};
pub use stream::{replay_events_source, ChunkedWindows, CoalescedWindows, OneWindow, WindowSource};
pub use sweep::JointIndex;
pub use timing::{TimingCandidate, TimingOps, TimingRun};
pub use trace::CompressedTrace;

use std::fmt;
use std::str::FromStr;

use crate::controller::{Access, MemoryController};

/// Which simulation core replays traces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Legacy per-access lockstep replay.
    Lockstep,
    /// Event-driven batched replay of the compressed trace.
    #[default]
    Event,
    /// Grid core ([`grid`]): batch DSE scoring via the single-pass
    /// stack-distance classifier + miss-only timing replay.  Selecting
    /// it tells batch scorers ([`crate::dse::Evaluator::score_batch`],
    /// [`crate::shard::ShardedSweep`]) to classify a whole cache-module
    /// grid in one trace pass; a *single-trace* replay under this kind
    /// is served by the event core — the grid core is bit-identical to
    /// it (enforced by `tests/differential.rs`), so there is nothing to
    /// gain from classifying a trace that is scored exactly once.
    Grid,
}

impl EngineKind {
    /// The engine implementation behind this kind.
    pub fn engine(self) -> &'static dyn SimEngine {
        match self {
            EngineKind::Lockstep => &LockstepEngine,
            EngineKind::Event => &EventEngine,
            EngineKind::Grid => &GridEngine,
        }
    }

    /// Replay `trace` on `ctl` (continuing from `ctl.now()`) with this
    /// kind's engine; returns the completion cycle.
    pub fn replay(self, ctl: &mut MemoryController, trace: &PreparedTrace) -> u64 {
        self.engine().replay(ctl, trace)
    }

    /// Replay a raw, single-use access list under this kind's engine:
    /// lockstep replays it directly; the event engine delta-encodes it
    /// on the fly and drives the batched kernels.  The one shared
    /// entry point for callers that compile a fresh trace per call
    /// (CycleSim scoring, remapped execution, shard workers) — keep
    /// the engine dispatch here so the paths cannot diverge.
    pub fn replay_raw(self, ctl: &mut MemoryController, trace: &[Access]) -> u64 {
        match self {
            EngineKind::Lockstep => ctl.replay(trace),
            EngineKind::Event | EngineKind::Grid => {
                ctl.replay_events(&CompressedTrace::compress(trace))
            }
        }
    }

    /// Stable one-byte wire tag (the serve protocol,
    /// [`crate::serve::proto`]).  Round-trips through
    /// [`Self::from_tag`]; values are append-only.
    pub fn tag(self) -> u8 {
        match self {
            EngineKind::Lockstep => 0,
            EngineKind::Event => 1,
            EngineKind::Grid => 2,
        }
    }

    /// Decode a [`Self::tag`] byte; `None` on an unknown value.
    pub fn from_tag(tag: u8) -> Option<EngineKind> {
        match tag {
            0 => Some(EngineKind::Lockstep),
            1 => Some(EngineKind::Event),
            2 => Some(EngineKind::Grid),
            _ => None,
        }
    }
}

impl FromStr for EngineKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "lockstep" => Ok(EngineKind::Lockstep),
            "event" => Ok(EngineKind::Event),
            "grid" => Ok(EngineKind::Grid),
            other => Err(format!("unknown engine {other:?} (lockstep|event|grid)")),
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EngineKind::Lockstep => "lockstep",
            EngineKind::Event => "event",
            EngineKind::Grid => "grid",
        })
    }
}

/// A trace compiled once and replayable by either engine: the raw
/// access list (lockstep's input) plus its delta-encoded form (the
/// event engine's input).  Building one costs a single linear pass;
/// both views describe exactly the same request sequence.
#[derive(Debug, Clone)]
pub struct PreparedTrace {
    raw: Vec<Access>,
    compressed: CompressedTrace,
}

impl PreparedTrace {
    /// Prepare a raw trace for replay under any engine.
    pub fn new(raw: Vec<Access>) -> Self {
        let compressed = CompressedTrace::compress(&raw);
        PreparedTrace { raw, compressed }
    }

    /// Prepare from an already-compressed trace, dropping the raw view
    /// — the bounded-memory variant (S24): under a `--memory-budget`,
    /// per-mode traces keep only the delta-encoded form (typically an
    /// order of magnitude smaller on MTTKRP traffic).  [`Self::raw`]
    /// returns an empty slice, so such a trace must be replayed by the
    /// Event or Grid core; the budget plumbing in [`crate::dse`]
    /// enforces that before building one.
    pub fn from_compressed(compressed: CompressedTrace) -> Self {
        PreparedTrace {
            raw: Vec::new(),
            compressed,
        }
    }

    /// The raw access list (empty for a compressed-only trace, see
    /// [`Self::from_compressed`]).
    pub fn raw(&self) -> &[Access] {
        &self.raw
    }

    /// The delta-encoded form.
    pub fn compressed(&self) -> &CompressedTrace {
        &self.compressed
    }

    /// True when the raw view was dropped to save memory.
    pub fn raw_dropped(&self) -> bool {
        self.raw.is_empty() && !self.compressed.is_empty()
    }

    /// Number of accesses.
    pub fn len(&self) -> usize {
        self.compressed.len()
    }

    /// True when the trace has no accesses.
    pub fn is_empty(&self) -> bool {
        self.compressed.is_empty()
    }
}

/// A simulation core: replays a prepared trace through a controller.
/// Implementations MUST be bit-identical to one another in both the
/// returned completion cycle and every statistics counter — engines
/// differ only in how fast they get there.
pub trait SimEngine: Sync {
    /// Engine name for reports and CLI selection.
    fn name(&self) -> &'static str;

    /// Replay `trace` on `ctl`, continuing from `ctl.now()`; returns
    /// the completion cycle (== `ctl.now()` afterwards).
    fn replay(&self, ctl: &mut MemoryController, trace: &PreparedTrace) -> u64;
}

/// Legacy per-access lockstep replay core.
pub struct LockstepEngine;

impl SimEngine for LockstepEngine {
    fn name(&self) -> &'static str {
        "lockstep"
    }

    fn replay(&self, ctl: &mut MemoryController, trace: &PreparedTrace) -> u64 {
        ctl.replay(trace.raw())
    }
}

/// Event-driven batched replay core over the compressed trace.
pub struct EventEngine;

impl SimEngine for EventEngine {
    fn name(&self) -> &'static str {
        "event"
    }

    fn replay(&self, ctl: &mut MemoryController, trace: &PreparedTrace) -> u64 {
        ctl.replay_events(trace.compressed())
    }
}

/// Grid batch-scoring core ([`grid`]).  A single-trace replay has no
/// grid to amortize over, so it is served by the (bit-identical) event
/// kernels; the classifier + miss-only replay engage on the batch
/// scoring paths ([`crate::dse::Evaluator::score_batch`],
/// [`crate::shard::ShardedSweep::makespans_for_cache_grid`]).
pub struct GridEngine;

impl SimEngine for GridEngine {
    fn name(&self) -> &'static str {
        "grid"
    }

    fn replay(&self, ctl: &mut MemoryController, trace: &PreparedTrace) -> u64 {
        ctl.replay_events(trace.compressed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{Access, ControllerConfig};
    use crate::testkit::Rng;

    fn random_trace(seed: u64, n: usize) -> Vec<Access> {
        let mut rng = Rng::new(seed);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            match rng.below(4) {
                0 => trace.push(Access::Stream {
                    addr: i * 4096,
                    bytes: 2048 + rng.below(2048) as usize,
                }),
                1 => trace.push(Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 14) * 64,
                    bytes: 64,
                }),
                2 => trace.push(Access::Element {
                    addr: (1 << 28) + rng.below(1 << 20) * 16,
                    bytes: 16,
                }),
                _ => trace.push(Access::CachedStore {
                    addr: (2 << 28) + rng.below(1 << 14) * 16,
                    bytes: 16,
                }),
            }
        }
        trace
    }

    #[test]
    fn kind_parses_and_displays() {
        assert_eq!("lockstep".parse::<EngineKind>().unwrap(), EngineKind::Lockstep);
        assert_eq!("event".parse::<EngineKind>().unwrap(), EngineKind::Event);
        assert_eq!("grid".parse::<EngineKind>().unwrap(), EngineKind::Grid);
        assert!("bogus".parse::<EngineKind>().is_err());
        assert_eq!(EngineKind::Event.to_string(), "event");
        assert_eq!(EngineKind::Lockstep.to_string(), "lockstep");
        assert_eq!(EngineKind::Grid.to_string(), "grid");
        assert_eq!(EngineKind::default(), EngineKind::Event);
        assert_eq!(EngineKind::Event.engine().name(), "event");
        assert_eq!(EngineKind::Lockstep.engine().name(), "lockstep");
        assert_eq!(EngineKind::Grid.engine().name(), "grid");
    }

    #[test]
    fn grid_kind_single_replay_matches_other_cores() {
        let prepared = PreparedTrace::new(random_trace(31, 1_000));
        let mut a = MemoryController::new(ControllerConfig::default_for(16));
        let mut b = MemoryController::new(ControllerConfig::default_for(16));
        let ta = EngineKind::Lockstep.replay(&mut a, &prepared);
        let tb = EngineKind::Grid.replay(&mut b, &prepared);
        assert_eq!(ta, tb);
        assert_eq!(a.stats(), b.stats());
        assert_eq!(a.dram_stats(), b.dram_stats());
    }

    #[test]
    fn engines_are_bit_identical_on_random_traces() {
        for seed in [3u64, 7, 11] {
            let prepared = PreparedTrace::new(random_trace(seed, 2_000));
            let mut a = MemoryController::new(ControllerConfig::default_for(16));
            let mut b = MemoryController::new(ControllerConfig::default_for(16));
            let ta = EngineKind::Lockstep.replay(&mut a, &prepared);
            let tb = EngineKind::Event.replay(&mut b, &prepared);
            assert_eq!(ta, tb, "completion cycles diverged (seed {seed})");
            assert_eq!(a.now(), b.now());
            assert_eq!(a.stats(), b.stats());
            assert_eq!(a.cache_stats(), b.cache_stats());
            assert_eq!(a.dma_stats(), b.dma_stats());
            assert_eq!(a.dram_stats(), b.dram_stats());
        }
    }

    #[test]
    fn event_replay_continues_from_now_like_lockstep() {
        // Two back-to-back replays must thread the clock identically.
        let p1 = PreparedTrace::new(random_trace(21, 500));
        let p2 = PreparedTrace::new(random_trace(22, 500));
        let mut a = MemoryController::new(ControllerConfig::default_for(16));
        let mut b = MemoryController::new(ControllerConfig::default_for(16));
        EngineKind::Lockstep.replay(&mut a, &p1);
        EngineKind::Lockstep.replay(&mut a, &p2);
        EngineKind::Event.replay(&mut b, &p1);
        EngineKind::Event.replay(&mut b, &p2);
        assert_eq!(a.now(), b.now());
        assert_eq!(a.dram_stats(), b.dram_stats());
    }

    #[test]
    fn prepared_trace_views_agree() {
        let raw = random_trace(5, 300);
        let p = PreparedTrace::new(raw.clone());
        assert_eq!(p.len(), 300);
        assert!(!p.is_empty());
        assert_eq!(p.raw(), &raw[..]);
        assert_eq!(p.compressed().expand(), raw);
    }
}
