//! Hierarchical joint cross-product sweep core (S24): one structured
//! traversal of a trace scores the **entire** `line_bytes ×
//! (num_lines, assoc) × DRAM × DMA` joint space — the composition the
//! cache grid core ([`super::grid`]) and the vectorized timing core
//! ([`super::timing`]) were built for, finally driven as one tree
//! instead of module-by-module.
//!
//! The share-one-level-up principle: every level of the joint space
//! reuses the most expensive artifact of the level above it.
//!
//! * The **trace** is shared by everything (and, one level higher
//!   still, the host remap that produced it is shared across the whole
//!   sweep — the callers' [`RemapMemo`](crate::util::RemapMemo) keys
//!   the remap-*pass* cycles per (mode, DRAM, remapper)).
//! * Per distinct **`line_bytes`**, one stack-distance classification
//!   pass serves every `(num_lines, assoc)` candidate of that width
//!   ([`GridClassification::classify`] already groups passes by width,
//!   so handing it the deduplicated cache list *is* this level).
//! * Per distinct **cache candidate**, one op-queue extraction
//!   ([`TimingOps::extract`]) folds the hit-dominated cache loop away.
//! * Per cache candidate's **DRAM × DMA lane set**, one walk of that op
//!   queue advances all lanes simultaneously
//!   ([`TimingOps::time_grid`]).
//!
//! A joint point is a `(cache, DRAM×DMA lane)` **cell**; candidates
//! that collapse to the same cell (e.g. remapper-only variants, or
//! channel counts with the same per-worker split) are timed once and
//! fanned back out.  Every candidate's cycle count is **bit-identical**
//! to a fresh per-candidate lockstep/event replay of the same trace:
//! a candidate's classification does not depend on which other
//! candidates share its pass, its extracted op queue does not depend
//! on which candidates shared the classification (the grid/timing
//! cores' "company independence" properties), and lanes are walked by
//! the exact scalar [`MemDevice`](crate::mem::MemDevice) /
//! [`DmaEngine`](crate::controller::DmaEngine) state machines — as
//! enforced on a randomized corpus by `tests/sweep_props.rs` and the
//! joint-grid column of `tests/differential.rs`.

use super::grid::GridClassification;
use super::timing::{TimingCandidate, TimingOps};
use super::CompressedTrace;
use crate::controller::CacheConfig;
use crate::util::parallel_indexed;

/// A deduplicated joint candidate list: the distinct cache candidates,
/// each with the distinct DRAM×DMA lanes it must be timed against, plus
/// the map from every input candidate to its `(cache, lane)` cell.
/// Build once per candidate list with [`JointIndex::build`], then score
/// any number of traces with [`JointIndex::sweep`].
#[derive(Debug, Clone)]
pub struct JointIndex {
    caches: Vec<CacheConfig>,
    lane_sets: Vec<Vec<TimingCandidate>>,
    /// Per input candidate: (index into `caches`, index into that
    /// cache's lane set).
    cell_of: Vec<(usize, usize)>,
}

impl JointIndex {
    /// Index a joint candidate list given as `(cache, timing)` pairs —
    /// one pair per candidate, in scoring order.  Duplicate caches
    /// share a classification + extraction; duplicate `(cache, lane)`
    /// cells share the timing walk entirely.
    pub fn build(pairs: &[(CacheConfig, TimingCandidate)]) -> JointIndex {
        let mut caches: Vec<CacheConfig> = Vec::new();
        let mut lane_sets: Vec<Vec<TimingCandidate>> = Vec::new();
        let mut cell_of = Vec::with_capacity(pairs.len());
        for (cc, lane) in pairs {
            let ci = match caches.iter().position(|c| c == cc) {
                Some(i) => i,
                None => {
                    caches.push(*cc);
                    lane_sets.push(Vec::new());
                    caches.len() - 1
                }
            };
            let li = match lane_sets[ci].iter().position(|l| l == lane) {
                Some(i) => i,
                None => {
                    lane_sets[ci].push(lane.clone());
                    lane_sets[ci].len() - 1
                }
            };
            cell_of.push((ci, li));
        }
        JointIndex {
            caches,
            lane_sets,
            cell_of,
        }
    }

    /// Number of input candidates.
    pub fn len(&self) -> usize {
        self.cell_of.len()
    }

    /// True when the index holds no candidates.
    pub fn is_empty(&self) -> bool {
        self.cell_of.is_empty()
    }

    /// The distinct cache candidates (classification targets).
    pub fn caches(&self) -> &[CacheConfig] {
        &self.caches
    }

    /// Number of distinct `(cache, lane)` cells actually simulated —
    /// the sweep's real work, `<= len()`.
    pub fn cells(&self) -> usize {
        self.lane_sets.iter().map(Vec::len).sum()
    }

    /// Completion cycles of every candidate over `trace`, in input
    /// order: one classification pass per distinct `line_bytes`, one
    /// op-queue extraction per distinct cache, one multi-lane walk per
    /// cache's lane set — each bit-identical to a fresh per-candidate
    /// lockstep/event replay.
    pub fn sweep(&self, trace: &CompressedTrace) -> Vec<u64> {
        self.run(trace, false)
    }

    /// [`JointIndex::sweep`] with the per-cache extraction + walk
    /// fanned out across host threads (cells are independent, so the
    /// result is identical).
    pub fn sweep_parallel(&self, trace: &CompressedTrace) -> Vec<u64> {
        self.run(trace, true)
    }

    /// Sweep several traces (e.g. one per shard) with one flattened
    /// `(trace × cache)` fan-out: classifications run concurrently per
    /// trace, then every (trace, cache) row extracts and walks on its
    /// own thread slot — saturating the host even when either
    /// dimension alone is smaller than the core count.  Returns one
    /// per-candidate cycle vector per trace, each identical to
    /// [`JointIndex::sweep`] of that trace.
    pub fn sweep_many(&self, traces: &[&CompressedTrace]) -> Vec<Vec<u64>> {
        if self.caches.is_empty() || traces.is_empty() {
            return traces.iter().map(|_| Vec::new()).collect();
        }
        let classifications: Vec<GridClassification> = parallel_indexed(traces.len(), |ti| {
            GridClassification::classify(traces[ti], &self.caches)
        });
        let nc = self.caches.len();
        let rows: Vec<Vec<u64>> = parallel_indexed(traces.len() * nc, |k| {
            self.cell_cycles(&classifications[k / nc], k % nc, traces[k / nc])
        });
        (0..traces.len())
            .map(|ti| {
                self.cell_of
                    .iter()
                    .map(|&(ci, li)| rows[ti * nc + ci][li])
                    .collect()
            })
            .collect()
    }

    fn run(&self, trace: &CompressedTrace, parallel: bool) -> Vec<u64> {
        if self.caches.is_empty() {
            return Vec::new();
        }
        let cls = GridClassification::classify(trace, &self.caches);
        let cells: Vec<Vec<u64>> = if parallel && self.caches.len() > 1 {
            parallel_indexed(self.caches.len(), |ci| self.cell_cycles(&cls, ci, trace))
        } else if parallel {
            // One cache: the lanes themselves are the only parallelism.
            let ops = TimingOps::extract(&cls, 0, trace);
            vec![ops
                .time_grid_parallel(&self.lane_sets[0])
                .into_iter()
                .map(|r| r.cycles)
                .collect()]
        } else {
            (0..self.caches.len())
                .map(|ci| self.cell_cycles(&cls, ci, trace))
                .collect()
        };
        self.cell_of.iter().map(|&(ci, li)| cells[ci][li]).collect()
    }

    /// One cache candidate's row of cells: extract its op queue, walk
    /// its lane set once.
    fn cell_cycles(
        &self,
        cls: &GridClassification,
        ci: usize,
        trace: &CompressedTrace,
    ) -> Vec<u64> {
        let ops = TimingOps::extract(cls, ci, trace);
        ops.time_grid(&self.lane_sets[ci])
            .into_iter()
            .map(|r| r.cycles)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{Access, ControllerConfig, MemoryController};
    use crate::dram::RowPolicy;
    use crate::engine::{EngineKind, PreparedTrace};
    use crate::testkit::Rng;

    fn mixed_trace(seed: u64, n: usize) -> Vec<Access> {
        let mut rng = Rng::new(seed);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            match rng.below(6) {
                0 => trace.push(Access::Stream {
                    addr: i * 4096,
                    bytes: 1024 + rng.below(4096) as usize,
                }),
                1 => trace.push(Access::Element {
                    addr: (1 << 30) + rng.below(1 << 20) * 16,
                    bytes: 16,
                }),
                2 => trace.push(Access::CachedStore {
                    addr: (2 << 28) + rng.below(1 << 12) * 16,
                    bytes: 16,
                }),
                _ => trace.push(Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 12) * 64,
                    bytes: 64,
                }),
            }
        }
        trace
    }

    /// A small joint cross product: 2 line widths x 2 geometries x
    /// 3 DRAM timings x 2 DMA shapes, plus full configurations to
    /// verify against.
    fn joint_grid(base: &ControllerConfig) -> Vec<ControllerConfig> {
        let mut cfgs = Vec::new();
        for &(line_bytes, num_lines, assoc) in
            &[(32usize, 256usize, 2usize), (64, 256, 2), (64, 1024, 4)]
        {
            for &(channels, policy) in &[
                (1usize, RowPolicy::Open),
                (2, RowPolicy::Closed),
                (4, RowPolicy::Open),
            ] {
                for &(num_dmas, buffer_bytes) in &[(1usize, 1024usize), (2, 4096)] {
                    let mut cfg = base.clone();
                    cfg.cache.line_bytes = line_bytes;
                    cfg.cache.num_lines = num_lines;
                    cfg.cache.assoc = assoc;
                    cfg.mem.ddr4_mut().channels = channels;
                    cfg.mem.ddr4_mut().row_policy = policy;
                    cfg.dma.num_dmas = num_dmas;
                    cfg.dma.buffer_bytes = buffer_bytes;
                    cfgs.push(cfg);
                }
            }
        }
        cfgs
    }

    #[test]
    fn joint_sweep_matches_fresh_event_replay_for_every_candidate() {
        let prepared = PreparedTrace::new(mixed_trace(41, 2_000));
        let base = ControllerConfig::default_for(16);
        let cfgs = joint_grid(&base);
        let pairs: Vec<(crate::controller::CacheConfig, TimingCandidate)> = cfgs
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        assert_eq!(index.len(), cfgs.len());
        let got = index.sweep(prepared.compressed());
        for (cfg, &cycles) in cfgs.iter().zip(&got) {
            let mut ctl = MemoryController::new(cfg.clone());
            let want = EngineKind::Event.replay(&mut ctl, &prepared);
            assert_eq!(cycles, want, "joint sweep diverged for {cfg:?}");
        }
    }

    #[test]
    fn parallel_sweep_is_identical_to_sequential() {
        let prepared = PreparedTrace::new(mixed_trace(43, 1_500));
        let base = ControllerConfig::default_for(16);
        let cfgs = joint_grid(&base);
        let pairs: Vec<_> = cfgs
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        assert_eq!(
            index.sweep(prepared.compressed()),
            index.sweep_parallel(prepared.compressed())
        );
    }

    #[test]
    fn duplicate_candidates_share_cells() {
        let base = ControllerConfig::default_for(16);
        let mut other = base.clone();
        other.mem.ddr4_mut().channels = 4;
        let mut remapper_only = base.clone();
        remapper_only.remapper.max_pointers = 4;
        let pairs: Vec<_> = [&base, &other, &base, &remapper_only]
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        assert_eq!(index.len(), 4);
        // One cache, two distinct lanes: the base cell serves the
        // duplicate AND the remapper-only variant.
        assert_eq!(index.caches().len(), 1);
        assert_eq!(index.cells(), 2);
        let prepared = PreparedTrace::new(mixed_trace(45, 400));
        let got = index.sweep(prepared.compressed());
        assert_eq!(got[0], got[2]);
        assert_eq!(got[0], got[3]);
        assert_ne!(got[0], got[1], "4-channel lane must time differently");
    }

    #[test]
    fn single_cache_parallel_path_matches() {
        let base = ControllerConfig::default_for(16);
        let mut cfgs = Vec::new();
        for &channels in &[1usize, 2, 4] {
            for &num_dmas in &[1usize, 2, 4] {
                let mut cfg = base.clone();
                cfg.mem.ddr4_mut().channels = channels;
                cfg.dma.num_dmas = num_dmas;
                cfgs.push(cfg);
            }
        }
        let pairs: Vec<_> = cfgs
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        assert_eq!(index.caches().len(), 1);
        let prepared = PreparedTrace::new(mixed_trace(47, 1_200));
        assert_eq!(
            index.sweep(prepared.compressed()),
            index.sweep_parallel(prepared.compressed())
        );
    }

    #[test]
    fn sweep_many_matches_per_trace_sweeps() {
        let base = ControllerConfig::default_for(16);
        let cfgs = joint_grid(&base);
        let pairs: Vec<_> = cfgs
            .iter()
            .map(|c| (c.cache, TimingCandidate::of(c)))
            .collect();
        let index = JointIndex::build(&pairs);
        let prepared: Vec<PreparedTrace> = [(49u64, 800usize), (51, 1), (53, 1_200)]
            .iter()
            .map(|&(seed, n)| PreparedTrace::new(mixed_trace(seed, n)))
            .collect();
        let traces: Vec<_> = prepared.iter().map(|p| p.compressed()).collect();
        let many = index.sweep_many(&traces);
        assert_eq!(many.len(), traces.len());
        for (trace, got) in traces.iter().zip(&many) {
            assert_eq!(*got, index.sweep(trace));
        }
        assert!(index.sweep_many(&[]).is_empty());
    }

    #[test]
    fn empty_index_sweeps_to_nothing() {
        let index = JointIndex::build(&[]);
        assert!(index.is_empty());
        assert_eq!(index.cells(), 0);
        let prepared = PreparedTrace::new(Vec::new());
        assert!(index.sweep(prepared.compressed()).is_empty());
    }
}
