//! Vectorized multi-configuration DRAM/DMA timing core (S21): one walk
//! of a trace's miss/stream op queue times **every** DRAM and DMA
//! candidate simultaneously — the DSE timing-module sweep's fast path,
//! completing the one-pass story the cache grid core
//! ([`super::grid`]) started.
//!
//! The insight mirrors the grid core's: for a *fixed* cache candidate,
//! which requests reach memory — the miss stream, the dirty-victim
//! writebacks, the DMA stream/element runs, and the folded hit runs —
//! is entirely **timing-independent**.  DRAM and DMA knobs (channels,
//! banks, row policy, DMA count/depth/buffer size) change *when* those
//! requests complete, never *which* requests occur.  So the trace's
//! run-queue is walked **once**: a single cache classification pass
//! ([`GridClassification`]) feeds an op-queue extraction
//! ([`TimingOps::extract`]) that folds every hit run to a closed-form
//! clock advance and keeps only the timing-relevant events.  Timing a
//! candidate then never touches the trace again.
//!
//! [`TimingOps::time_grid`] walks that op queue once with an array of
//! per-candidate **lanes** in structure-of-arrays form: each lane owns
//! its own flat-state memory device ([`MemDevice`]: DDR4 bank/row-open
//! vectors, HBM2 pseudo-channel state, or oSRAM port clocks) plus flat
//! DMA queue-depth slots ([`DmaEngine`]) and a FIFO clock.  Every op
//! applies to each lane through the *same* [`MemDevice::access`] /
//! [`DmaEngine::stream`] state machines the scalar
//! engines use, so completion cycles and every statistics counter are
//! **bit-identical** to a fresh per-candidate lockstep/event replay
//! (enforced on a randomized corpus by `tests/timing_props.rs` and the
//! timing-grid column of `tests/differential.rs`).

use super::grid::GridClassification;
use super::stream::{OneWindow, WindowSource};
use super::trace::Run;
use super::CompressedTrace;
use crate::controller::{
    Access, CacheStats, ControllerConfig, ControllerStats, DmaConfig, DmaEngine, DmaStats,
    LineGeom,
};
use crate::dram::DramStats;
use crate::mem::{MemDevice, MemTechConfig};
use crate::util::parallel_indexed;

/// One timing-relevant event of the extracted op queue.  Addresses and
/// byte counts are cache-classified facts; how long each op takes is
/// the per-lane question the timing walk answers.
#[derive(Debug, Clone, Copy)]
enum TimingOp {
    /// `count` contiguous DMA stream requests: request `i` covers
    /// `chunk` bytes at `base + i*chunk`, the last covers `tail`
    /// (chunking *within* each request is a DMA-candidate property,
    /// applied per lane at timing time).
    StreamRun {
        base: u64,
        chunk: u32,
        count: u32,
        tail: u32,
    },
    /// A single (verbatim-encoded) stream request.
    Stream { addr: u64, bytes: usize },
    /// An element-wise DMA request.
    Element { addr: u64, bytes: usize },
    /// `count` consecutive cache hits: the clock advances
    /// `count * hit_latency`; no memory traffic.
    Hits { count: u64 },
    /// Dirty-victim writeback preceding a fill: one full-line DRAM
    /// access at `line * line_bytes`.
    Writeback { line: u64 },
    /// Miss fill: one full-line DRAM access, then the hit-latency
    /// service of the missing request.
    Fill { line: u64 },
}

/// Result of timing one candidate lane: completion cycle plus the full
/// statistics bundle a fresh [`MemoryController`] replay of the same
/// trace under the same configuration would report.
///
/// [`MemoryController`]: crate::controller::MemoryController
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimingRun {
    pub cycles: u64,
    pub stats: ControllerStats,
    pub cache: CacheStats,
    pub dma: DmaStats,
    pub dram: DramStats,
}

/// One memory-device/DMA candidate of a timing-module sweep: the two
/// knob sets that change request *timing* without changing the request
/// sequence.  The memory side is a full [`MemTechConfig`], so a timing
/// grid can mix DDR4, HBM2, and oSRAM lanes in one walk.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TimingCandidate {
    pub mem: MemTechConfig,
    pub dma: DmaConfig,
}

impl TimingCandidate {
    /// The timing knobs of a full controller configuration.
    pub fn of(cfg: &ControllerConfig) -> Self {
        TimingCandidate {
            mem: cfg.mem.clone(),
            dma: cfg.dma,
        }
    }

    /// Deduplicate a candidate list: returns the distinct lanes plus,
    /// per input candidate, the index of its lane.  Candidates that
    /// share every timing knob (e.g. a remapper-only sweep, or channel
    /// counts that collapse to the same per-worker split) would walk
    /// identical lanes — time each distinct lane once and fan the
    /// results back out instead.
    pub fn dedup(cands: Vec<TimingCandidate>) -> (Vec<TimingCandidate>, Vec<usize>) {
        let mut uniq: Vec<TimingCandidate> = Vec::new();
        let lane_of = cands
            .into_iter()
            .map(|c| match uniq.iter().position(|u| *u == c) {
                Some(i) => i,
                None => {
                    uniq.push(c);
                    uniq.len() - 1
                }
            })
            .collect();
        (uniq, lane_of)
    }
}

/// One candidate's live state during the op walk: its own flat-state
/// memory device (per-bank open rows + ready clocks and per-channel bus
/// clocks for DRAM-class devices, port clocks for oSRAM), flat DMA
/// queue slots, and the FIFO clock.
struct Lane {
    dram: MemDevice,
    dma: DmaEngine,
    now: u64,
}

impl Lane {
    fn new(cand: &TimingCandidate) -> Self {
        Lane {
            dram: MemDevice::new(&cand.mem),
            dma: DmaEngine::new(cand.dma),
            now: 0,
        }
    }

    /// Apply one op, advancing this lane's clock exactly as the scalar
    /// replay would (`lb` = line bytes, `hl` = hit latency of the
    /// classified cache candidate).
    fn apply(&mut self, op: &TimingOp, lb: usize, hl: u64) {
        match *op {
            TimingOp::StreamRun {
                base,
                chunk,
                count,
                tail,
            } => {
                self.now = self.dma.stream_run(
                    &mut self.dram,
                    base,
                    chunk as usize,
                    count,
                    tail as usize,
                    self.now,
                );
            }
            TimingOp::Stream { addr, bytes } => {
                self.now = self.dma.stream(&mut self.dram, addr, bytes, self.now);
            }
            TimingOp::Element { addr, bytes } => {
                self.now = self.dma.element(&mut self.dram, addr, bytes, self.now);
            }
            TimingOp::Hits { count } => {
                self.now += count * hl;
            }
            TimingOp::Writeback { line } => {
                self.now = self.dram.access(line * lb as u64, lb, self.now);
            }
            TimingOp::Fill { line } => {
                self.now = self.dram.access(line * lb as u64, lb, self.now) + hl;
            }
        }
    }
}

/// Builds the op queue from one candidate's miss stream, mirroring the
/// grid core's replay cursor ([`super::grid`]) but emitting ops instead
/// of driving a device.
struct OpBuilder<'a> {
    ops: Vec<TimingOp>,
    recs: &'a [super::grid::MissRec],
    i: usize,
    /// Hits of `recs[i].hits_before` already consumed.
    taken: u64,
}

impl OpBuilder<'_> {
    /// Emit `n` hits, coalescing with a directly preceding hit run (hit
    /// folding is purely additive, so merging across run boundaries
    /// cannot change any lane's clock).
    fn hits(&mut self, n: u64) {
        if n == 0 {
            return;
        }
        if let Some(TimingOp::Hits { count }) = self.ops.last_mut() {
            *count += n;
            return;
        }
        self.ops.push(TimingOp::Hits { count: n });
    }

    /// Consume `lines` cache-class line accesses: whole hit runs fold
    /// to one `Hits` op; each miss emits its writeback (if dirty) and
    /// fill ops in the exact order the scalar Cache Engine performs
    /// them.
    fn consume(&mut self, mut lines: u64) {
        while lines > 0 {
            match self.recs.get(self.i) {
                None => {
                    // Everything after the last miss hits.
                    self.hits(lines);
                    lines = 0;
                }
                Some(r) => {
                    let avail = r.hits_before - self.taken;
                    if avail >= lines {
                        self.hits(lines);
                        self.taken += lines;
                        lines = 0;
                    } else {
                        self.hits(avail);
                        lines -= avail + 1;
                        self.taken = 0;
                        if r.writeback {
                            self.ops.push(TimingOp::Writeback {
                                line: r.victim_line,
                            });
                        }
                        self.ops.push(TimingOp::Fill { line: r.line });
                        self.i += 1;
                    }
                }
            }
        }
    }
}

/// The extracted, cache-classified op queue of one trace under one
/// cache candidate: everything the timing walk needs, with the
/// hit-dominated cache loop already folded away.  Build once per
/// (trace, cache candidate) with [`TimingOps::extract`], then time any
/// number of DRAM/DMA candidates with [`TimingOps::time_grid`].
pub struct TimingOps {
    ops: Vec<TimingOp>,
    line_bytes: usize,
    hit_latency: u64,
    requests: u64,
    total_bytes: u64,
    cache: CacheStats,
}

impl TimingOps {
    /// Extract the op queue of candidate `idx` of `cls` over `trace`
    /// (the trace that was classified).  One linear walk of the
    /// compressed run-queue; after it, timing never touches the trace.
    pub fn extract(cls: &GridClassification, idx: usize, trace: &CompressedTrace) -> TimingOps {
        Self::extract_source(cls, idx, &mut OneWindow(trace))
    }

    /// Windowed extraction (S24): identical op queue to
    /// [`Self::extract`] — the miss cursor persists across windows and
    /// run-line counts are consumed by global run index, while hit
    /// coalescing across window boundaries is additive and cannot
    /// change any lane's clock.  `src` must yield the exact window
    /// sequence that was classified.  The op queue itself stays in RAM
    /// (it is miss-bounded, like the miss streams), but the trace never
    /// is.
    pub fn extract_source(
        cls: &GridClassification,
        idx: usize,
        src: &mut dyn WindowSource,
    ) -> TimingOps {
        let pass = cls.pass_info(idx);
        let line_bytes = pass.line_bytes;
        let geom = LineGeom::new(line_bytes, 1);
        let mut b = OpBuilder {
            ops: Vec::new(),
            recs: cls.miss_stream(idx),
            i: 0,
            taken: 0,
        };
        // Run index, global across windows: `pass.run_lines` is flat
        // over every window's runs in classification order.
        let mut ri = 0usize;
        let mut requests = 0u64;
        let mut total_bytes = 0u64;
        src.for_each_window(&mut |trace| {
            requests += trace.requests();
            total_bytes += trace.total_bytes();
            for run in trace.runs() {
                match *run {
                    Run::Stream {
                        base,
                        chunk,
                        count,
                        tail,
                    } => {
                        b.ops.push(TimingOp::StreamRun {
                            base,
                            chunk,
                            count,
                            tail,
                        });
                    }
                    Run::Cached { .. } => {
                        b.consume(pass.run_lines[ri]);
                    }
                    Run::Verbatim { off, count } => {
                        for &a in trace.raw_at(off, count) {
                            match a {
                                Access::Stream { addr, bytes } => {
                                    b.ops.push(TimingOp::Stream { addr, bytes });
                                }
                                Access::Element { addr, bytes } => {
                                    b.ops.push(TimingOp::Element { addr, bytes });
                                }
                                Access::Cached { addr, bytes }
                                | Access::CachedStore { addr, bytes } => {
                                    b.consume(geom.line_count(addr, bytes));
                                }
                            }
                        }
                    }
                }
                ri += 1;
            }
        });
        debug_assert_eq!(
            b.i,
            b.recs.len(),
            "extraction must consume the whole miss stream"
        );
        debug_assert_eq!(
            ri,
            pass.run_lines.len(),
            "extraction must walk the exact classified run sequence"
        );
        TimingOps {
            ops: b.ops,
            line_bytes,
            hit_latency: cls.configs()[idx].hit_latency,
            requests,
            total_bytes,
            cache: cls.cache_stats(idx),
        }
    }

    /// Number of ops in the queue (after hit folding).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// True when the queue is empty (an empty trace).
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// The classified cache candidate's counters every lane reports
    /// (cache behaviour is shared across the whole timing grid).
    pub fn cache_stats(&self) -> &CacheStats {
        &self.cache
    }

    /// Time every candidate in one walk of the op queue: op-outer,
    /// lane-inner, so the queue is decoded once while each lane's flat
    /// state advances.  Returns one [`TimingRun`] per candidate in
    /// input order — each bit-identical to a fresh per-candidate
    /// lockstep/event replay of the classified trace.
    pub fn time_grid(&self, cands: &[TimingCandidate]) -> Vec<TimingRun> {
        let mut lanes: Vec<Lane> = cands.iter().map(Lane::new).collect();
        for op in &self.ops {
            for lane in lanes.iter_mut() {
                lane.apply(op, self.line_bytes, self.hit_latency);
            }
        }
        lanes
            .into_iter()
            .map(|l| TimingRun {
                cycles: l.now,
                stats: ControllerStats {
                    requests: self.requests,
                    total_bytes: self.total_bytes,
                },
                cache: self.cache.clone(),
                dma: l.dma.stats().clone(),
                dram: l.dram.stats().clone(),
            })
            .collect()
    }

    /// [`TimingOps::time_grid`] with the lanes chunked across host
    /// threads: each thread performs its own op walk over a contiguous
    /// lane subset (lanes are independent, so the result is identical).
    pub fn time_grid_parallel(&self, cands: &[TimingCandidate]) -> Vec<TimingRun> {
        /// Lanes per thread-chunk: small enough to spread a typical
        /// module grid over the host, large enough to amortize the op
        /// walk per thread.
        const LANES_PER_CHUNK: usize = 4;
        if cands.len() <= LANES_PER_CHUNK {
            return self.time_grid(cands);
        }
        let n_chunks = cands.len().div_ceil(LANES_PER_CHUNK);
        let per_chunk: Vec<Vec<TimingRun>> = parallel_indexed(n_chunks, |ci| {
            let lo = ci * LANES_PER_CHUNK;
            let hi = (lo + LANES_PER_CHUNK).min(cands.len());
            self.time_grid(&cands[lo..hi])
        });
        per_chunk.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{CacheConfig, ControllerConfig, MemoryController};
    use crate::dram::RowPolicy;
    use crate::engine::{EngineKind, PreparedTrace};
    use crate::testkit::Rng;

    fn mixed_trace(seed: u64, n: usize) -> Vec<Access> {
        let mut rng = Rng::new(seed);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            match rng.below(6) {
                0 => trace.push(Access::Stream {
                    addr: i * 4096,
                    bytes: 1024 + rng.below(4096) as usize,
                }),
                1 => trace.push(Access::Element {
                    addr: (1 << 30) + rng.below(1 << 20) * 16,
                    bytes: 16,
                }),
                2 => trace.push(Access::CachedStore {
                    addr: (2 << 28) + rng.below(1 << 12) * 16,
                    bytes: 16,
                }),
                _ => trace.push(Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 12) * 64,
                    bytes: 64,
                }),
            }
        }
        trace
    }

    fn dram_dma_grid(base: &ControllerConfig) -> Vec<TimingCandidate> {
        let mut cands = Vec::new();
        for &(channels, banks, policy) in &[
            (1usize, 16usize, RowPolicy::Open),
            (2, 8, RowPolicy::Open),
            (4, 16, RowPolicy::Closed),
        ] {
            for &(num_dmas, buffer_bytes) in &[(1usize, 1024usize), (2, 4096), (4, 16384)] {
                let mut mem = base.mem.clone();
                {
                    let dram = mem.ddr4_mut();
                    dram.channels = channels;
                    dram.banks = banks;
                    dram.row_policy = policy;
                }
                let mut dma = base.dma;
                dma.num_dmas = num_dmas;
                dma.buffer_bytes = buffer_bytes;
                cands.push(TimingCandidate { mem, dma });
            }
        }
        cands
    }

    #[test]
    fn timing_grid_matches_fresh_event_replay_for_every_candidate() {
        let prepared = PreparedTrace::new(mixed_trace(5, 2_000));
        let base = ControllerConfig::default_for(16);
        let cls = GridClassification::classify(prepared.compressed(), &[base.cache]);
        let ops = TimingOps::extract(&cls, 0, prepared.compressed());
        let cands = dram_dma_grid(&base);
        let runs = ops.time_grid(&cands);
        assert_eq!(runs.len(), cands.len());
        for (cand, run) in cands.iter().zip(&runs) {
            let mut cfg = base.clone();
            cfg.mem = cand.mem.clone();
            cfg.dma = cand.dma;
            let mut ctl = MemoryController::new(cfg);
            let want = EngineKind::Event.replay(&mut ctl, &prepared);
            assert_eq!(run.cycles, want, "cycles diverged for {cand:?}");
            assert_eq!(run.stats, *ctl.stats(), "{cand:?}");
            assert_eq!(run.cache, *ctl.cache_stats(), "{cand:?}");
            assert_eq!(run.dma, *ctl.dma_stats(), "{cand:?}");
            assert_eq!(run.dram, *ctl.dram_stats(), "{cand:?}");
        }
    }

    #[test]
    fn parallel_walk_is_identical_to_single_walk() {
        let prepared = PreparedTrace::new(mixed_trace(7, 1_500));
        let base = ControllerConfig::default_for(16);
        let cls = GridClassification::classify(prepared.compressed(), &[base.cache]);
        let ops = TimingOps::extract(&cls, 0, prepared.compressed());
        let cands = dram_dma_grid(&base);
        assert_eq!(ops.time_grid(&cands), ops.time_grid_parallel(&cands));
    }

    #[test]
    fn extraction_is_independent_of_classification_company() {
        // The op queue of a cache candidate must not depend on which
        // other cache candidates shared the classification pass.
        let prepared = PreparedTrace::new(mixed_trace(9, 1_200));
        let base = ControllerConfig::default_for(16);
        let mut other = base.cache;
        other.num_lines = 64;
        other.assoc = 1;
        let both = GridClassification::classify(prepared.compressed(), &[base.cache, other]);
        let alone = GridClassification::classify(prepared.compressed(), &[base.cache]);
        let cands = dram_dma_grid(&base);
        let a = TimingOps::extract(&both, 0, prepared.compressed());
        let b = TimingOps::extract(&alone, 0, prepared.compressed());
        assert_eq!(a.len(), b.len());
        assert_eq!(a.time_grid(&cands), b.time_grid(&cands));
    }

    #[test]
    fn windowed_extraction_times_identically_to_monolithic() {
        use crate::engine::stream::ChunkedWindows;
        let raw = mixed_trace(21, 2_000);
        let prepared = PreparedTrace::new(raw.clone());
        let base = ControllerConfig::default_for(16);
        let cands = dram_dma_grid(&base);
        let mono_cls = GridClassification::classify(prepared.compressed(), &[base.cache]);
        let mono = TimingOps::extract(&mono_cls, 0, prepared.compressed());
        for window in [1usize, 173, 5_000] {
            let cls = GridClassification::classify_source(
                &mut ChunkedWindows::new(&raw, window),
                &[base.cache],
            );
            let ops = TimingOps::extract_source(&cls, 0, &mut ChunkedWindows::new(&raw, window));
            assert_eq!(
                mono.time_grid(&cands),
                ops.time_grid(&cands),
                "window {window}"
            );
        }
    }

    #[test]
    fn dedup_collapses_identical_lanes() {
        let base = ControllerConfig::default_for(16);
        let mut other = base.clone();
        other.mem.ddr4_mut().channels = 4;
        let cands = vec![
            TimingCandidate::of(&base),
            TimingCandidate::of(&other),
            TimingCandidate::of(&base),
        ];
        let (uniq, lane_of) = TimingCandidate::dedup(cands);
        assert_eq!(uniq.len(), 2);
        assert_eq!(lane_of, vec![0, 1, 0]);
    }

    #[test]
    fn empty_trace_times_to_zero() {
        let prepared = PreparedTrace::new(Vec::new());
        let cc = CacheConfig::default_64k();
        let cls = GridClassification::classify(prepared.compressed(), &[cc]);
        let ops = TimingOps::extract(&cls, 0, prepared.compressed());
        assert!(ops.is_empty());
        let base = ControllerConfig::default_for(16);
        let runs = ops.time_grid(&[TimingCandidate::of(&base)]);
        assert_eq!(runs[0].cycles, 0);
        assert_eq!(runs[0].stats.requests, 0);
    }

    #[test]
    fn hit_folding_compresses_the_op_queue() {
        // A hot single-line loop: one fill plus one folded hit run.
        let trace: Vec<Access> = (0..500)
            .map(|_| Access::Cached {
                addr: 8 << 20,
                bytes: 16,
            })
            .collect();
        let prepared = PreparedTrace::new(trace);
        let cc = CacheConfig::default_64k();
        let cls = GridClassification::classify(prepared.compressed(), &[cc]);
        let ops = TimingOps::extract(&cls, 0, prepared.compressed());
        assert!(
            ops.len() <= 2,
            "1 fill + 1 folded hit run expected, got {} ops",
            ops.len()
        );
        assert_eq!(ops.cache_stats().hits, 499);
        assert_eq!(ops.cache_stats().misses, 1);
    }
}
