//! Grid simulation core (S20): single-pass multi-configuration cache
//! classification + miss-only timing replay — the DSE cache-module
//! sweep's fast path.
//!
//! The insight: for a set-associative cache with true-LRU replacement,
//! whether an access hits is **timing-independent** and obeys Mattson's
//! inclusion property — the content of an A-way set is exactly the A
//! most-recently-used distinct lines mapping to that set.  So one pass
//! over the trace's cache-class accesses, maintaining a per-set LRU
//! *stack* (recency-ordered distinct lines), classifies every
//! `(num_lines, assoc)` candidate **simultaneously**: a candidate with
//! `S = num_lines / assoc` sets and associativity `A` hits exactly when
//! the accessed line sits at stack depth `< A` in its `S`-set stack.
//! One pass is needed per distinct `line_bytes` value (the line-index
//! sequence changes), and candidates sharing a set count share a stack.
//!
//! The pass records, per candidate, only the **miss stream**: for each
//! miss, how many hits preceded it, the line to fill, and — because the
//! stack entry at depth `A-1` is precisely the A-way set's LRU victim —
//! whether the miss evicts and whether the victim is dirty (writeback).
//! Dirty state is tracked per candidate as a bitmask on each stack
//! entry, so `CachedStore` write-allocate/write-back traffic classifies
//! exactly too.
//!
//! [`GridClassification::replay`] then reproduces a candidate's full
//! controller timing by driving **only** that miss stream (plus the
//! cache-independent DMA runs) through the real memory-device
//! ([`MemDevice`]) and [`DmaEngine`] models, folding every run of `n`
//! hits into
//! `n * hit_latency` in closed form.  The replay performs the identical
//! DRAM access sequence the lockstep core would — same misses, same
//! writeback-before-fill ordering, same FIFO clock threading — so its
//! cycle count and every statistics counter are **bit-identical** to
//! [`MemoryController::replay`](crate::controller::MemoryController)
//! (enforced on a randomized corpus by `tests/differential.rs` and
//! `tests/grid_props.rs`).

use super::stream::{OneWindow, WindowSource};
use super::trace::Run;
use super::CompressedTrace;

/// Which inner loop the classification pass runs (S28).  Both kernels
/// produce **bit-identical** miss streams, counters, and replay cycles
/// — enforced by `tests/classify_props.rs` across the full default DSE
/// grid — so the choice is purely a throughput knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ClassifyKernel {
    /// The original per-access stack walk: data-dependent `position()`
    /// search, a per-candidate hit/miss loop on **every** access, and
    /// `copy_within` rotation.  Kept as the executable oracle the SoA
    /// kernel is proven against.
    Scalar,
    /// Branch-light structure-of-arrays kernel (the default): stacks
    /// are fixed-width (`cap` lanes, empty lanes hold a sentinel tag),
    /// the depth search and LRU rotation are mask-selects over
    /// contiguous lanes rustc can autovectorize, hits are accounted in
    /// closed form from a pass-global line counter (per-candidate work
    /// happens only on misses), and `Run::Cached` delta words are
    /// expanded into a line buffer consumed in batches per set group.
    #[default]
    Soa,
}

use crate::controller::{
    Access, CacheConfig, CacheStats, ControllerConfig, ControllerStats, DmaEngine, DmaStats,
    LineGeom,
};
use crate::dram::DramStats;
use crate::mem::MemDevice;

/// Sentinel tag marking an empty SoA stack lane.  Real tags are line
/// addresses shifted right by the set bits, so this value is
/// unreachable for any address that is not within one cache line of
/// `u64::MAX` (debug-asserted in the kernel).
const TAG_EMPTY: u64 = u64::MAX;

/// Lines buffered per SoA batch before the set groups consume them.
const SOA_BATCH: usize = 4096;

/// One recorded miss of one candidate configuration: the `hits_before`
/// cache-class line accesses since the previous miss all hit (and cost
/// `hit_latency` each); this access misses on `line`, evicting the
/// candidate set's LRU victim (`victim_line`) if the set was full, with
/// a dirty-victim writeback preceding the fill when `writeback` is set.
#[derive(Debug, Clone, Copy)]
pub(crate) struct MissRec {
    pub(crate) hits_before: u64,
    pub(crate) line: u64,
    pub(crate) victim_line: u64,
    pub(crate) evicted: bool,
    pub(crate) writeback: bool,
}

/// One candidate's classification result: its miss stream plus the
/// counters a full replay would have accumulated.
#[derive(Debug, Clone, Default)]
struct MissStream {
    recs: Vec<MissRec>,
    /// Hits since the last recorded miss (classification scratch; the
    /// replay derives trailing hits from the pass's total line count).
    open_hits: u64,
    evictions: u64,
    writebacks: u64,
}

/// One classification pass: everything that depends only on
/// `line_bytes`, shared by all candidates with that line width.
#[derive(Debug, Clone)]
pub(crate) struct PassInfo {
    pub(crate) line_bytes: usize,
    /// Per compressed-trace run index: cache-class line accesses inside
    /// that run (meaningful for `Run::Cached`; verbatim runs are walked
    /// per access at replay time).
    pub(crate) run_lines: Vec<u64>,
    /// Total cache-class line accesses in the trace.
    pub(crate) total_lines: u64,
}

/// All candidates sharing one `(line_bytes, num_sets)` pair: one LRU
/// stack array serves every associativity at this set count.  Stacks
/// are truncated to the largest candidate associativity (`cap`) —
/// deeper entries are misses for every candidate by inclusion.
struct SetGroup {
    geom: LineGeom,
    cap: usize,
    /// `(assoc, global candidate index, dirty-mask bit)` per candidate.
    cands: Vec<(usize, usize, u32)>,
    /// Per stack depth `d`: dirty-mask bits of candidates with
    /// `assoc > d` (the candidates that *hit* at depth `d`).
    gt_mask: Vec<u32>,
    all_mask: u32,
    /// Flattened per-set stacks: `tags[set * cap + depth]`.
    tags: Vec<u64>,
    /// Per-entry dirty bitmask, one bit per candidate in this group.
    dirty: Vec<u32>,
    /// Current stack depth per set (scalar kernel only: the SoA kernel
    /// derives fullness from the sentinel tag in the last lane).
    lens: Vec<u32>,
    /// SoA kernel only: per candidate slot, the pass-global line index
    /// one past the candidate's last miss — `lineno - last_line[slot]`
    /// is the hit-run length preceding the current miss, so hits cost
    /// no per-candidate work at all.
    last_line: Vec<u64>,
}

impl SetGroup {
    fn new(line_bytes: usize, num_sets: usize, assocs: &[(usize, usize)]) -> Self {
        assert!(
            assocs.len() <= 32,
            "at most 32 candidates may share one (line_bytes, num_sets) group"
        );
        let cap = assocs.iter().map(|&(a, _)| a).max().expect("non-empty");
        let cands: Vec<(usize, usize, u32)> = assocs
            .iter()
            .enumerate()
            .map(|(bit, &(assoc, ci))| (assoc, ci, 1u32 << bit))
            .collect();
        // One extra entry at depth `cap` (always 0): the SoA kernel
        // indexes `gt_mask[found]` with `found == cap` meaning "miss
        // for every candidate", collapsing the hit/miss split into one
        // unconditional mask load.
        let gt_mask: Vec<u32> = (0..=cap)
            .map(|d| {
                cands
                    .iter()
                    .filter(|&&(a, _, _)| a > d)
                    .map(|&(_, _, bit)| bit)
                    .fold(0u32, |m, b| m | b)
            })
            .collect();
        let all_mask = cands.iter().map(|&(_, _, bit)| bit).fold(0u32, |m, b| m | b);
        let n_cands = cands.len();
        SetGroup {
            geom: LineGeom::new(line_bytes, num_sets),
            cap,
            cands,
            gt_mask,
            all_mask,
            tags: vec![TAG_EMPTY; num_sets * cap],
            dirty: vec![0; num_sets * cap],
            lens: vec![0; num_sets],
            last_line: vec![0; n_cands],
        }
    }

    /// Classify one cache-class line access for every candidate in the
    /// group, recording miss events, then update the LRU stack.
    fn access(&mut self, line: u64, write: bool, streams: &mut [MissStream]) {
        let set = self.geom.set(line);
        let tag = self.geom.tag(line);
        let base = set * self.cap;
        let len = self.lens[set] as usize;
        let found = self.tags[base..base + len].iter().position(|&t| t == tag);

        for &(assoc, ci, bit) in &self.cands {
            if let Some(d) = found {
                if d < assoc {
                    streams[ci].open_hits += 1;
                    continue;
                }
            }
            // Miss for this candidate.  The A-way set's LRU victim is
            // the stack entry at depth A-1; the set is full (a real
            // eviction) exactly when the stack already holds >= A
            // distinct lines.
            let evicted = len >= assoc;
            let (victim_line, writeback) = if evicted {
                let vt = self.tags[base + assoc - 1];
                let wb = self.dirty[base + assoc - 1] & bit != 0;
                (self.geom.line_of(set, vt), wb)
            } else {
                (0, false)
            };
            let s = &mut streams[ci];
            s.recs.push(MissRec {
                hits_before: s.open_hits,
                line,
                victim_line,
                evicted,
                writeback,
            });
            s.open_hits = 0;
            if evicted {
                s.evictions += 1;
            }
            if writeback {
                s.writebacks += 1;
            }
        }

        // LRU stack update: accessed line moves to the front.  Dirty
        // bits: candidates that hit (assoc > depth) keep the line's
        // dirty state (|= write); candidates that missed refill it with
        // dirty = write — for a store both collapse to "all dirty".
        match found {
            Some(d) => {
                let old_dirty = self.dirty[base + d];
                self.tags.copy_within(base..base + d, base + 1);
                self.dirty.copy_within(base..base + d, base + 1);
                self.tags[base] = tag;
                self.dirty[base] = if write {
                    self.all_mask
                } else {
                    old_dirty & self.gt_mask[d]
                };
            }
            None => {
                let new_len = (len + 1).min(self.cap);
                self.tags.copy_within(base..base + new_len - 1, base + 1);
                self.dirty.copy_within(base..base + new_len - 1, base + 1);
                self.tags[base] = tag;
                self.dirty[base] = if write { self.all_mask } else { 0 };
                self.lens[set] = new_len as u32;
            }
        }
    }

    /// Branch-light SoA classification of one line access (see
    /// [`ClassifyKernel::Soa`]).  `lineno` is the pass-global index of
    /// this cache-class line access; per-candidate hit runs are
    /// reconstructed from it at miss time, so the hit path (the
    /// overwhelmingly common case) does no per-candidate work.
    ///
    /// Invariant: each set's lanes are a prefix of live tags followed
    /// by `TAG_EMPTY` sentinels, so "the A-way set is full" is exactly
    /// "lane A-1 is live", and the full-width rotation below preserves
    /// the prefix shape.
    fn access_soa(&mut self, line: u64, write: bool, lineno: u64, streams: &mut [MissStream]) {
        let set = self.geom.set(line);
        let tag = self.geom.tag(line);
        debug_assert_ne!(tag, TAG_EMPTY, "tag collides with the empty-lane sentinel");
        let cap = self.cap;
        let base = set * cap;
        // Depth search over the fixed-width stack: live lanes hold
        // distinct tags and empty lanes the sentinel, so at most one
        // lane matches and the masked subtraction selects its depth
        // (`found == cap` = present in no lane = miss everywhere).
        let mut found = cap;
        for (d, &t) in self.tags[base..base + cap].iter().enumerate() {
            found -= (t == tag) as usize * (cap - d);
        }
        let hit_mask = self.gt_mask[found];
        // Per-candidate work happens only on misses.
        let mut m = self.all_mask & !hit_mask;
        while m != 0 {
            let slot = m.trailing_zeros() as usize;
            m &= m - 1;
            let (assoc, ci, bit) = self.cands[slot];
            let vt = self.tags[base + assoc - 1];
            let evicted = vt != TAG_EMPTY;
            let writeback = evicted && self.dirty[base + assoc - 1] & bit != 0;
            let victim_line = if evicted { self.geom.line_of(set, vt) } else { 0 };
            let s = &mut streams[ci];
            s.recs.push(MissRec {
                hits_before: lineno - self.last_line[slot],
                line,
                victim_line,
                evicted,
                writeback,
            });
            s.evictions += evicted as u64;
            s.writebacks += writeback as u64;
            self.last_line[slot] = lineno + 1;
        }
        // Mask-select LRU rotation: lanes 1..=found shift down one (a
        // miss has `found == cap`, rotating the whole stack and
        // dropping the LRU tail), deeper lanes keep their entry.  The
        // dirty word the accessed line carries to the front is read
        // before the shift; on a miss the retained mask is 0, so the
        // clamped stale read is harmless.
        let old_dirty = self.dirty[base + found.min(cap - 1)];
        for d in (1..cap).rev() {
            let take = d <= found;
            let t_shift = self.tags[base + d - 1];
            let t_keep = self.tags[base + d];
            self.tags[base + d] = if take { t_shift } else { t_keep };
            let y_shift = self.dirty[base + d - 1];
            let y_keep = self.dirty[base + d];
            self.dirty[base + d] = if take { y_shift } else { y_keep };
        }
        self.tags[base] = tag;
        self.dirty[base] = if write { self.all_mask } else { old_dirty & hit_mask };
    }
}

/// Result of replaying one candidate's miss stream: completion cycle
/// and the full statistics bundle a [`MemoryController`] replay of the
/// same trace under the same configuration would report.
///
/// [`MemoryController`]: crate::controller::MemoryController
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GridRun {
    pub cycles: u64,
    pub stats: ControllerStats,
    pub cache: CacheStats,
    pub dma: DmaStats,
    pub dram: DramStats,
}

/// The single-pass classification of one trace against a whole cache
/// grid (see module docs).  Build with [`GridClassification::classify`],
/// then score any candidate with [`GridClassification::replay`] — each
/// replay touches only the candidate's miss stream and the trace's DMA
/// runs, never the hit-dominated cache loop.
pub struct GridClassification {
    configs: Vec<CacheConfig>,
    streams: Vec<MissStream>,
    passes: Vec<PassInfo>,
    /// Candidate index -> index into `passes`.
    pass_of: Vec<usize>,
}

impl GridClassification {
    /// Classify `trace` for every cache candidate in `configs`: one
    /// trace pass per distinct `line_bytes` value, all `(num_lines,
    /// assoc)` candidates of that width classified simultaneously.
    pub fn classify(trace: &CompressedTrace, configs: &[CacheConfig]) -> Self {
        Self::classify_source(&mut OneWindow(trace), configs)
    }

    /// [`Self::classify`] with an explicit kernel choice (S28).  The
    /// default entry points run [`ClassifyKernel::Soa`]; passing
    /// [`ClassifyKernel::Scalar`] selects the oracle inner loop the SoA
    /// kernel is proven bit-identical against.
    pub fn classify_with(
        trace: &CompressedTrace,
        configs: &[CacheConfig],
        kernel: ClassifyKernel,
    ) -> Self {
        Self::classify_source_with(&mut OneWindow(trace), configs, kernel)
    }

    /// Windowed classification (S24): one walk of the source classifies
    /// every candidate — each window is fed to every width's pass state
    /// in order, so peak memory is one window plus the per-set LRU
    /// stacks, independent of total trace length.  Per-candidate
    /// results are identical to the monolithic [`Self::classify`]
    /// (which now delegates here): a candidate's miss stream depends
    /// only on its own width's line-access sequence, and every width
    /// sees the same ordered accesses either way.
    pub fn classify_source(src: &mut dyn WindowSource, configs: &[CacheConfig]) -> Self {
        Self::classify_source_with(src, configs, ClassifyKernel::default())
    }

    /// [`Self::classify_source`] with an explicit kernel choice (S28).
    pub fn classify_source_with(
        src: &mut dyn WindowSource,
        configs: &[CacheConfig],
        kernel: ClassifyKernel,
    ) -> Self {
        assert!(!configs.is_empty(), "need at least one cache candidate");
        for c in configs {
            c.validate();
        }
        let mut streams = vec![MissStream::default(); configs.len()];
        let mut pass_of = vec![0usize; configs.len()];

        // Group candidates by line width, preserving first-seen order.
        let mut widths: Vec<usize> = Vec::new();
        for c in configs {
            if !widths.contains(&c.line_bytes) {
                widths.push(c.line_bytes);
            }
        }
        let mut states: Vec<PassState> = Vec::with_capacity(widths.len());
        for lb in widths {
            let idxs: Vec<usize> = (0..configs.len())
                .filter(|&i| configs[i].line_bytes == lb)
                .collect();
            for &i in &idxs {
                pass_of[i] = states.len();
            }
            states.push(PassState::new(lb, &idxs, configs, kernel));
        }
        src.for_each_window(&mut |w| {
            for st in states.iter_mut() {
                st.feed(w, &mut streams);
            }
        });
        GridClassification {
            configs: configs.to_vec(),
            streams,
            passes: states.into_iter().map(PassState::finish).collect(),
            pass_of,
        }
    }

    /// Number of classified candidates.
    pub fn len(&self) -> usize {
        self.configs.len()
    }

    /// True when no candidates were classified (never: `classify`
    /// rejects an empty grid).
    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// The classified candidate configurations, in input order.
    pub fn configs(&self) -> &[CacheConfig] {
        &self.configs
    }

    /// Candidate `idx`'s recorded miss stream (crate-internal: the
    /// vectorized timing core's extraction input,
    /// [`crate::engine::timing`]).
    pub(crate) fn miss_stream(&self, idx: usize) -> &[MissRec] {
        &self.streams[idx].recs
    }

    /// Candidate `idx`'s classification-pass info (crate-internal, see
    /// [`GridClassification::miss_stream`]).
    pub(crate) fn pass_info(&self, idx: usize) -> &PassInfo {
        &self.passes[self.pass_of[idx]]
    }

    /// Cache-class line accesses candidate `idx` serves (equals the
    /// replayed `CacheStats::accesses`).
    pub fn accesses(&self, idx: usize) -> u64 {
        self.passes[self.pass_of[idx]].total_lines
    }

    /// Misses of candidate `idx`.
    pub fn misses(&self, idx: usize) -> u64 {
        self.streams[idx].recs.len() as u64
    }

    /// Hits of candidate `idx`.
    pub fn hits(&self, idx: usize) -> u64 {
        self.accesses(idx) - self.misses(idx)
    }

    /// The full Cache Engine counter set candidate `idx` would report
    /// after a real replay of the classified trace.
    pub fn cache_stats(&self, idx: usize) -> CacheStats {
        let s = &self.streams[idx];
        CacheStats {
            accesses: self.accesses(idx),
            hits: self.hits(idx),
            misses: self.misses(idx),
            evictions: s.evictions,
            writebacks: s.writebacks,
        }
    }

    /// Miss-only timing replay of candidate `idx` under the full
    /// controller configuration `cfg` (whose `cache` must equal the
    /// classified candidate): hit runs fold to `n * hit_latency`; only
    /// misses, writebacks, and DMA-class runs drive the [`MemDevice`] /
    /// [`DmaEngine`] models.  `trace` must be the trace that was
    /// classified.  Returns the completion cycle (from 0, i.e. a fresh
    /// controller) plus every statistics counter — bit-identical to a
    /// lockstep or event replay of the same trace.
    pub fn replay(&self, idx: usize, trace: &CompressedTrace, cfg: &ControllerConfig) -> GridRun {
        self.replay_source(idx, &mut OneWindow(trace), cfg)
    }

    /// Windowed miss-only replay (S24): identical timing to
    /// [`Self::replay`] — the miss cursor, device/DMA models, and clock
    /// persist across windows, and run-line counts are consumed by
    /// global run index — but only one window is resident at a time.
    /// `src` must yield the exact window sequence that was classified
    /// (same accesses, same boundaries), or the run indices go out of
    /// step.
    pub fn replay_source(
        &self,
        idx: usize,
        src: &mut dyn WindowSource,
        cfg: &ControllerConfig,
    ) -> GridRun {
        assert_eq!(
            cfg.cache, self.configs[idx],
            "cfg.cache must be the classified candidate"
        );
        let pass = &self.passes[self.pass_of[idx]];
        let geom = LineGeom::new(pass.line_bytes, 1);
        let lb = pass.line_bytes;
        let hl = cfg.cache.hit_latency;
        let mut dram = MemDevice::new(&cfg.mem);
        let mut dma = DmaEngine::new(cfg.dma);
        let mut cur = Cursor {
            recs: &self.streams[idx].recs,
            i: 0,
            taken: 0,
        };
        let mut now = 0u64;
        // Run index, global across windows: `pass.run_lines` is flat
        // over every window's runs in classification order.
        let mut ri = 0usize;
        let mut requests = 0u64;
        let mut total_bytes = 0u64;
        src.for_each_window(&mut |trace| {
            requests += trace.requests();
            total_bytes += trace.total_bytes();
            for run in trace.runs() {
                match *run {
                    Run::Stream {
                        base,
                        chunk,
                        count,
                        tail,
                    } => {
                        now = dma.stream_run(
                            &mut dram,
                            base,
                            chunk as usize,
                            count,
                            tail as usize,
                            now,
                        );
                    }
                    Run::Cached { .. } => {
                        now = cur.consume(pass.run_lines[ri], &mut dram, lb, hl, now);
                    }
                    Run::Verbatim { off, count } => {
                        for &a in trace.raw_at(off, count) {
                            match a {
                                Access::Stream { addr, bytes } => {
                                    now = dma.stream(&mut dram, addr, bytes, now);
                                }
                                Access::Element { addr, bytes } => {
                                    now = dma.element(&mut dram, addr, bytes, now);
                                }
                                Access::Cached { addr, bytes }
                                | Access::CachedStore { addr, bytes } => {
                                    let n = geom.line_count(addr, bytes);
                                    now = cur.consume(n, &mut dram, lb, hl, now);
                                }
                            }
                        }
                    }
                }
                ri += 1;
            }
        });
        debug_assert_eq!(
            cur.i,
            cur.recs.len(),
            "replay must consume the whole miss stream"
        );
        debug_assert_eq!(
            ri,
            pass.run_lines.len(),
            "replay must walk the exact classified run sequence"
        );
        GridRun {
            cycles: now,
            stats: ControllerStats {
                requests,
                total_bytes,
            },
            cache: self.cache_stats(idx),
            dma: dma.stats().clone(),
            dram: dram.stats().clone(),
        }
    }
}

/// Replay cursor over one candidate's miss stream.
struct Cursor<'a> {
    recs: &'a [MissRec],
    i: usize,
    /// Hits of `recs[i].hits_before` already consumed.
    taken: u64,
}

impl Cursor<'_> {
    /// Advance the clock over `lines` cache-class line accesses: whole
    /// hit runs fold to `n * hit_latency`; each miss performs exactly
    /// the DRAM sequence the real Cache Engine would (dirty-victim
    /// writeback, then line fill, then the hit-latency service).
    fn consume(
        &mut self,
        mut lines: u64,
        dram: &mut MemDevice,
        lb: usize,
        hl: u64,
        mut now: u64,
    ) -> u64 {
        while lines > 0 {
            match self.recs.get(self.i) {
                None => {
                    // Everything after the last miss hits.
                    now += lines * hl;
                    lines = 0;
                }
                Some(r) => {
                    let avail = r.hits_before - self.taken;
                    if avail >= lines {
                        now += lines * hl;
                        self.taken += lines;
                        lines = 0;
                    } else {
                        now += avail * hl;
                        lines -= avail + 1;
                        self.taken = 0;
                        if r.writeback {
                            now = dram.access(r.victim_line * lb as u64, lb, now);
                        }
                        now = dram.access(r.line * lb as u64, lb, now) + hl;
                        self.i += 1;
                    }
                }
            }
        }
        now
    }
}

/// Classification state for one line width `lb`, persistent across
/// windows: the per-set LRU stack groups plus the per-run line counts
/// accumulated so far.  [`PassState::feed`] appends one window's runs;
/// [`PassState::finish`] freezes the result into a [`PassInfo`].
struct PassState {
    lb: usize,
    geom: LineGeom,
    /// This width's candidates grouped by set count: one LRU stack
    /// array per distinct num_sets, every associativity sharing it.
    groups: Vec<SetGroup>,
    run_lines: Vec<u64>,
    total: u64,
    kernel: ClassifyKernel,
    /// Pass-global cache-class line counter (the SoA kernel's hit
    /// accounting clock; equals `total` at run boundaries but ticks per
    /// line so batched and per-line paths stay in step).
    lineno: u64,
    /// Reused SoA batch buffer of expanded line indices.
    buf: Vec<u64>,
}

impl PassState {
    fn new(lb: usize, idxs: &[usize], configs: &[CacheConfig], kernel: ClassifyKernel) -> Self {
        let mut groups: Vec<SetGroup> = Vec::new();
        let mut set_counts: Vec<usize> = Vec::new();
        for &i in idxs {
            let s = configs[i].num_sets();
            if !set_counts.contains(&s) {
                set_counts.push(s);
            }
        }
        for s in set_counts {
            let assocs: Vec<(usize, usize)> = idxs
                .iter()
                .filter(|&&i| configs[i].num_sets() == s)
                .map(|&i| (configs[i].assoc, i))
                .collect();
            groups.push(SetGroup::new(lb, s, &assocs));
        }
        PassState {
            lb,
            geom: LineGeom::new(lb, 1),
            groups,
            run_lines: Vec::new(),
            total: 0,
            kernel,
            lineno: 0,
            buf: Vec::new(),
        }
    }

    /// Classify one cache-class access (every line it touches) for
    /// every candidate at this width; returns the line count.
    fn serve(&mut self, addr: u64, bytes: usize, write: bool, streams: &mut [MissStream]) -> u64 {
        let first = self.geom.first_line(addr);
        let last = self.geom.last_line(addr, bytes);
        let mut line = first;
        loop {
            match self.kernel {
                ClassifyKernel::Scalar => {
                    for g in self.groups.iter_mut() {
                        g.access(line, write, streams);
                    }
                }
                ClassifyKernel::Soa => {
                    let ln = self.lineno;
                    for g in self.groups.iter_mut() {
                        g.access_soa(line, write, ln, streams);
                    }
                }
            }
            self.lineno += 1;
            if line == last {
                break;
            }
            line += 1;
        }
        last - first + 1
    }

    /// SoA batched consumption of one `Run::Cached` delta-word run:
    /// expand words into a contiguous line buffer in chunks, then let
    /// each set group sweep the whole chunk before the next group runs
    /// — the group's stacks stay hot and the inner loop is the
    /// branch-light [`SetGroup::access_soa`] over contiguous lanes.
    /// Group-major order is bit-identical to line-major: set groups
    /// share no state, and each sees the same line/lineno sequence.
    fn feed_cached_soa(
        &mut self,
        words: &[u32],
        base: u64,
        bytes: usize,
        streams: &mut [MissStream],
    ) -> u64 {
        let mut lines = 0u64;
        let mut i = 0usize;
        while i < words.len() {
            let mut buf = std::mem::take(&mut self.buf);
            buf.clear();
            while i < words.len() && buf.len() < SOA_BATCH {
                let addr = base + 4 * words[i] as u64;
                let first = self.geom.first_line(addr);
                let last = self.geom.last_line(addr, bytes);
                buf.extend(first..=last);
                i += 1;
            }
            let base_ln = self.lineno;
            for g in self.groups.iter_mut() {
                let mut ln = base_ln;
                for &l in buf.iter() {
                    g.access_soa(l, false, ln, streams);
                    ln += 1;
                }
            }
            self.lineno += buf.len() as u64;
            lines += buf.len() as u64;
            self.buf = buf;
        }
        lines
    }

    /// Classify one window's runs, continuing from the stack state the
    /// previous windows left behind.
    fn feed(&mut self, trace: &CompressedTrace, streams: &mut [MissStream]) {
        self.run_lines.reserve(trace.runs().len());
        for run in trace.runs() {
            let mut lines = 0u64;
            match *run {
                Run::Stream { .. } => {}
                Run::Cached {
                    base,
                    bytes,
                    off,
                    count,
                } => match self.kernel {
                    ClassifyKernel::Scalar => {
                        for &w in trace.words_at(off, count) {
                            lines +=
                                self.serve(base + 4 * w as u64, bytes as usize, false, streams);
                        }
                    }
                    ClassifyKernel::Soa => {
                        lines += self.feed_cached_soa(
                            trace.words_at(off, count),
                            base,
                            bytes as usize,
                            streams,
                        );
                    }
                },
                Run::Verbatim { off, count } => {
                    for &a in trace.raw_at(off, count) {
                        match a {
                            Access::Cached { addr, bytes } => {
                                lines += self.serve(addr, bytes, false, streams);
                            }
                            Access::CachedStore { addr, bytes } => {
                                lines += self.serve(addr, bytes, true, streams);
                            }
                            Access::Stream { .. } | Access::Element { .. } => {}
                        }
                    }
                }
            }
            self.run_lines.push(lines);
            self.total += lines;
        }
    }

    fn finish(self) -> PassInfo {
        PassInfo {
            line_bytes: self.lb,
            run_lines: self.run_lines,
            total_lines: self.total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::{ControllerConfig, MemoryController};
    use crate::engine::PreparedTrace;
    use crate::testkit::Rng;

    fn cache_heavy_trace(seed: u64, n: usize) -> Vec<Access> {
        let mut rng = Rng::new(seed);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            match rng.below(6) {
                0 => trace.push(Access::Stream {
                    addr: i * 4096,
                    bytes: 1024 + rng.below(4096) as usize,
                }),
                1 => trace.push(Access::Element {
                    addr: (1 << 30) + rng.below(1 << 20) * 16,
                    bytes: 16,
                }),
                2 => trace.push(Access::CachedStore {
                    addr: (2 << 28) + rng.below(1 << 12) * 16,
                    bytes: 16,
                }),
                _ => trace.push(Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 12) * 64,
                    bytes: 64,
                }),
            }
        }
        trace
    }

    fn small_grid() -> Vec<CacheConfig> {
        let mut grid = Vec::new();
        for &line_bytes in &[32usize, 64, 128] {
            for &num_lines in &[64usize, 256, 1024] {
                for &assoc in &[1usize, 2, 4] {
                    grid.push(CacheConfig {
                        line_bytes,
                        num_lines,
                        assoc,
                        hit_latency: 2,
                    });
                }
            }
        }
        grid
    }

    #[test]
    fn grid_replay_matches_lockstep_for_every_candidate() {
        let raw = cache_heavy_trace(9, 3_000);
        let prepared = PreparedTrace::new(raw);
        let grid = small_grid();
        let cls = GridClassification::classify(prepared.compressed(), &grid);
        assert_eq!(cls.len(), grid.len());
        for (i, cc) in grid.iter().enumerate() {
            let mut cfg = ControllerConfig::default_for(16);
            cfg.cache = *cc;
            let mut ctl = MemoryController::new(cfg.clone());
            let want = ctl.replay(prepared.raw());
            let run = cls.replay(i, prepared.compressed(), &cfg);
            assert_eq!(run.cycles, want, "cycles diverged for {cc:?}");
            assert_eq!(run.stats, *ctl.stats(), "{cc:?}");
            assert_eq!(run.cache, *ctl.cache_stats(), "{cc:?}");
            assert_eq!(run.dma, *ctl.dma_stats(), "{cc:?}");
            assert_eq!(run.dram, *ctl.dram_stats(), "{cc:?}");
        }
    }

    #[test]
    fn classification_is_independent_of_grid_company() {
        // A candidate's miss stream must not depend on which other
        // candidates share the classification pass.
        let raw = cache_heavy_trace(11, 2_000);
        let prepared = PreparedTrace::new(raw);
        let grid = small_grid();
        let all = GridClassification::classify(prepared.compressed(), &grid);
        for (i, cc) in grid.iter().enumerate() {
            let alone = GridClassification::classify(prepared.compressed(), &[*cc]);
            assert_eq!(all.cache_stats(i), alone.cache_stats(0), "{cc:?}");
        }
    }

    #[test]
    fn hit_miss_counts_are_monotone_in_capacity() {
        // Mattson inclusion: at fixed line width and set count, more
        // ways can only add hits.
        let raw = cache_heavy_trace(13, 4_000);
        let prepared = PreparedTrace::new(raw);
        let grid: Vec<CacheConfig> = [1usize, 2, 4, 8]
            .iter()
            .map(|&assoc| CacheConfig {
                line_bytes: 64,
                num_lines: 128 * assoc,
                assoc,
                hit_latency: 2,
            })
            .collect();
        let cls = GridClassification::classify(prepared.compressed(), &grid);
        for w in 1..grid.len() {
            assert!(
                cls.hits(w) >= cls.hits(w - 1),
                "hits must be monotone in associativity at fixed sets"
            );
        }
    }

    #[test]
    fn empty_cache_class_trace_scores_hit_free() {
        let raw = vec![
            Access::Stream {
                addr: 0,
                bytes: 8192,
            },
            Access::Element {
                addr: 1 << 20,
                bytes: 16,
            },
        ];
        let prepared = PreparedTrace::new(raw);
        let cc = CacheConfig::default_64k();
        let cls = GridClassification::classify(prepared.compressed(), &[cc]);
        assert_eq!(cls.accesses(0), 0);
        let mut cfg = ControllerConfig::default_for(16);
        cfg.cache = cc;
        let mut ctl = MemoryController::new(cfg.clone());
        let want = ctl.replay(prepared.raw());
        let run = cls.replay(0, prepared.compressed(), &cfg);
        assert_eq!(run.cycles, want);
        assert_eq!(run.cache, *ctl.cache_stats());
    }

    #[test]
    fn windowed_classify_and_replay_match_monolithic() {
        use crate::engine::stream::ChunkedWindows;
        let raw = cache_heavy_trace(17, 3_000);
        let prepared = PreparedTrace::new(raw.clone());
        let grid = small_grid();
        let mono = GridClassification::classify(prepared.compressed(), &grid);
        for window in [1usize, 251, 4_096] {
            let cls =
                GridClassification::classify_source(&mut ChunkedWindows::new(&raw, window), &grid);
            for (i, cc) in grid.iter().enumerate() {
                let mut cfg = ControllerConfig::default_for(16);
                cfg.cache = *cc;
                let want = mono.replay(i, prepared.compressed(), &cfg);
                let got =
                    cls.replay_source(i, &mut ChunkedWindows::new(&raw, window), &cfg);
                assert_eq!(got, want, "{cc:?} window {window}");
            }
        }
    }

    #[test]
    fn soa_kernel_is_bit_identical_to_scalar_oracle() {
        let raw = cache_heavy_trace(21, 4_000);
        let prepared = PreparedTrace::new(raw);
        let grid = small_grid();
        let scalar =
            GridClassification::classify_with(prepared.compressed(), &grid, ClassifyKernel::Scalar);
        let soa =
            GridClassification::classify_with(prepared.compressed(), &grid, ClassifyKernel::Soa);
        for (i, cc) in grid.iter().enumerate() {
            assert_eq!(scalar.cache_stats(i), soa.cache_stats(i), "{cc:?}");
            let mut cfg = ControllerConfig::default_for(16);
            cfg.cache = *cc;
            assert_eq!(
                scalar.replay(i, prepared.compressed(), &cfg),
                soa.replay(i, prepared.compressed(), &cfg),
                "{cc:?}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "classified candidate")]
    fn replay_rejects_mismatched_config() {
        let prepared = PreparedTrace::new(cache_heavy_trace(3, 50));
        let cls =
            GridClassification::classify(prepared.compressed(), &[CacheConfig::default_64k()]);
        let mut cfg = ControllerConfig::default_for(16);
        cfg.cache.num_lines = 512;
        cls.replay(0, prepared.compressed(), &cfg);
    }
}
