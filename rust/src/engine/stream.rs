//! Bounded-window trace streaming (S24): the out-of-core replay
//! substrate.  A full compressed trace for a 100M-nnz mode is tens of
//! gigabytes of access records — far past any laptop-class budget — yet
//! every simulation core only ever walks its trace *in order*.  This
//! module turns that walk into a pull of bounded windows:
//!
//! * [`WindowSource`] — a re-iterable producer of [`CompressedTrace`]
//!   windows.  Each call to [`WindowSource::for_each_window`] must
//!   yield the identical window sequence from the start; sources
//!   regenerate deterministically (from the tensor, from a file, or
//!   from a borrowed in-RAM trace), so the cores that need several
//!   passes (grid classify + per-candidate replay, timing extraction)
//!   simply walk the source again.
//! * [`replay_events_source`] — the event core over windows: each
//!   window drives [`MemoryController::replay_events`], which threads
//!   the FIFO clock through `ctl.now()` and accumulates statistics, so
//!   back-to-back windowed replay is **bit-identical to one monolithic
//!   replay by construction** (the continuation property pinned by
//!   `engine::tests::event_replay_continues_from_now_like_lockstep`,
//!   and end-to-end by `tests/streaming_props.rs`).
//! * The grid/timing cores gain `_source` variants
//!   ([`super::grid::GridClassification::classify_source`],
//!   [`super::grid::GridClassification::replay_source`],
//!   [`super::timing::TimingOps::extract_source`]) that thread their
//!   per-set LRU stacks, miss cursors, and lane clocks across windows —
//!   the monolithic entry points are now the single-window special case
//!   of the same code, so the two paths cannot diverge.
//!
//! Peak replay memory drops from O(trace) to O(window): the window in
//! flight, the per-set stacks, and the miss streams (O(misses), which
//! the grid core already required).

use super::trace::CompressedTrace;
use crate::controller::{Access, MemoryController};

/// A re-iterable producer of bounded trace windows.
///
/// Contract: every call to [`Self::for_each_window`] restarts from the
/// beginning and yields the **identical** window sequence — same
/// accesses, same window boundaries.  The grid core relies on this:
/// classification records per-run line counts that replay consumes by
/// global run index, so the runs must line up walk-to-walk.
pub trait WindowSource {
    /// Walk the trace from the start, invoking `f` on each bounded
    /// window in order.
    fn for_each_window(&mut self, f: &mut dyn FnMut(&CompressedTrace));
}

/// Borrowed in-RAM access list chunked into bounded windows, each
/// delta-compressed on the fly.  The migration adapter: lets every
/// in-RAM caller stream through the same windowed code path, and the
/// property suite compare windowed against monolithic execution at
/// arbitrary window sizes.
pub struct ChunkedWindows<'a> {
    accesses: &'a [Access],
    window: usize,
}

impl<'a> ChunkedWindows<'a> {
    /// Window granularity `window` accesses (> 0).
    pub fn new(accesses: &'a [Access], window: usize) -> Self {
        assert!(window > 0, "window must be positive");
        ChunkedWindows { accesses, window }
    }
}

impl WindowSource for ChunkedWindows<'_> {
    fn for_each_window(&mut self, f: &mut dyn FnMut(&CompressedTrace)) {
        for chunk in self.accesses.chunks(self.window) {
            f(&CompressedTrace::compress(chunk));
        }
    }
}

/// Re-blocks an inner source's windows to at least `min` accesses per
/// emitted window (except possibly the last).  The SoA classification
/// kernel (S28, [`super::grid::ClassifyKernel::Soa`]) consumes the
/// delta-word stream in fixed-size batches; a producer that streams
/// tiny windows would starve those inner loops, so callers can wrap it
/// here.  Deterministic — the same inner window sequence re-blocks to
/// the same output sequence on every walk, preserving the
/// [`WindowSource`] re-iteration contract — and order-preserving, so
/// every core's result is unchanged (window boundaries are
/// semantically invisible to the replay cores).
pub struct CoalescedWindows<'a> {
    inner: &'a mut dyn WindowSource,
    min: usize,
}

impl<'a> CoalescedWindows<'a> {
    /// Emit windows of at least `min` accesses (> 0).
    pub fn new(inner: &'a mut dyn WindowSource, min: usize) -> Self {
        assert!(min > 0, "min must be positive");
        CoalescedWindows { inner, min }
    }
}

impl WindowSource for CoalescedWindows<'_> {
    fn for_each_window(&mut self, f: &mut dyn FnMut(&CompressedTrace)) {
        let min = self.min;
        let mut buf: Vec<Access> = Vec::new();
        self.inner.for_each_window(&mut |w| {
            buf.extend(w.expand());
            if buf.len() >= min {
                f(&CompressedTrace::compress(&buf));
                buf.clear();
            }
        });
        if !buf.is_empty() {
            f(&CompressedTrace::compress(&buf));
        }
    }
}

/// A single already-compressed trace as a one-window source — the
/// adapter that makes the monolithic `classify`/`replay`/`extract`
/// entry points run through the windowed implementations.
pub struct OneWindow<'a>(pub &'a CompressedTrace);

impl WindowSource for OneWindow<'_> {
    fn for_each_window(&mut self, f: &mut dyn FnMut(&CompressedTrace)) {
        f(self.0);
    }
}

/// Event-core streaming replay: drive each window through the batched
/// kernels in order, continuing from `ctl.now()`.  Returns the
/// completion cycle.  Bit-identical to replaying the concatenated
/// trace in one call — `replay_events` threads the clock and
/// accumulates every statistics counter across calls.
pub fn replay_events_source(ctl: &mut MemoryController, src: &mut dyn WindowSource) -> u64 {
    let mut end = ctl.now();
    src.for_each_window(&mut |w| {
        end = ctl.replay_events(w);
    });
    end
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ControllerConfig;
    use crate::testkit::Rng;

    fn mixed_trace(seed: u64, n: usize) -> Vec<Access> {
        let mut rng = Rng::new(seed);
        let mut trace = Vec::with_capacity(n);
        for i in 0..n as u64 {
            match rng.below(6) {
                0 => trace.push(Access::Stream {
                    addr: i * 4096,
                    bytes: 1024 + rng.below(4096) as usize,
                }),
                1 => trace.push(Access::Element {
                    addr: (1 << 30) + rng.below(1 << 20) * 16,
                    bytes: 16,
                }),
                2 => trace.push(Access::CachedStore {
                    addr: (2 << 28) + rng.below(1 << 12) * 16,
                    bytes: 16,
                }),
                _ => trace.push(Access::Cached {
                    addr: (8 << 20) + rng.below(1 << 12) * 64,
                    bytes: 64,
                }),
            }
        }
        trace
    }

    #[test]
    fn chunked_windows_cover_the_trace_and_reiterate_identically() {
        let raw = mixed_trace(3, 1_000);
        let mut src = ChunkedWindows::new(&raw, 137);
        let mut first: Vec<Vec<Access>> = Vec::new();
        src.for_each_window(&mut |w| first.push(w.expand()));
        let flat: Vec<Access> = first.iter().flatten().copied().collect();
        assert_eq!(flat, raw, "windows must concatenate to the trace");
        let mut second: Vec<Vec<Access>> = Vec::new();
        src.for_each_window(&mut |w| second.push(w.expand()));
        assert_eq!(first, second, "re-iteration must be identical");
    }

    #[test]
    fn windowed_event_replay_is_bit_identical_to_monolithic() {
        let raw = mixed_trace(7, 2_000);
        let mono = CompressedTrace::compress(&raw);
        for window in [1usize, 3, 64, 999, 2_000, 100_000] {
            let mut a = MemoryController::new(ControllerConfig::default_for(16));
            let mut b = MemoryController::new(ControllerConfig::default_for(16));
            let ta = a.replay_events(&mono);
            let tb = replay_events_source(&mut b, &mut ChunkedWindows::new(&raw, window));
            assert_eq!(ta, tb, "window {window}");
            assert_eq!(a.stats(), b.stats(), "window {window}");
            assert_eq!(a.cache_stats(), b.cache_stats(), "window {window}");
            assert_eq!(a.dma_stats(), b.dma_stats(), "window {window}");
            assert_eq!(a.dram_stats(), b.dram_stats(), "window {window}");
        }
    }

    #[test]
    fn coalesced_windows_reblock_without_changing_the_trace() {
        let raw = mixed_trace(11, 1_000);
        let mono = CompressedTrace::compress(&raw);
        for min in [1usize, 10, 257, 5_000] {
            let mut inner = ChunkedWindows::new(&raw, 3);
            let mut src = CoalescedWindows::new(&mut inner, min);
            let mut windows: Vec<Vec<Access>> = Vec::new();
            src.for_each_window(&mut |w| windows.push(w.expand()));
            let flat: Vec<Access> = windows.iter().flatten().copied().collect();
            assert_eq!(flat, raw, "min {min}: windows must concatenate");
            for w in &windows[..windows.len().saturating_sub(1)] {
                assert!(w.len() >= min, "min {min}: emitted window too small");
            }
            let mut a = MemoryController::new(ControllerConfig::default_for(16));
            let mut b = MemoryController::new(ControllerConfig::default_for(16));
            let ta = a.replay_events(&mono);
            let mut inner2 = ChunkedWindows::new(&raw, 3);
            let mut co = CoalescedWindows::new(&mut inner2, min);
            let tb = replay_events_source(&mut b, &mut co);
            assert_eq!(ta, tb, "min {min}");
            assert_eq!(a.stats(), b.stats(), "min {min}");
        }
    }

    #[test]
    fn empty_source_replays_to_current_clock() {
        let raw: Vec<Access> = Vec::new();
        let mut ctl = MemoryController::new(ControllerConfig::default_for(16));
        let t = replay_events_source(&mut ctl, &mut ChunkedWindows::new(&raw, 16));
        assert_eq!(t, 0);
    }
}
