//! Delta-encoded compressed access traces — the shared trace
//! representation the event engine replays ([`crate::engine`], S19).
//!
//! A raw [`Access`] list spends 24 bytes per request and forces the
//! replay loop through an enum dispatch per access.  Real spMTTKRP
//! traces are extremely regular, though: tensor records stream in
//! fixed-size contiguous chunks, and factor-row loads are millions of
//! same-width cached reads whose addresses differ only in the row
//! index.  [`CompressedTrace`] exploits exactly that structure:
//!
//! * **Stream runs** — a maximal sequence of contiguous `Stream`
//!   requests collapses to `(base, chunk, count, tail)`: request `i`
//!   covers `chunk` bytes at `base + i*chunk`, the final request covers
//!   `tail` bytes.  One 24-byte run replaces `count` accesses.
//! * **Cached runs** — a maximal sequence of same-width `Cached` loads
//!   collapses to a base address plus one `u32` word per access
//!   (`addr = base + 4*word`, the delta from the run's lowest address
//!   in 4-byte units): 4 bytes per access instead of 24, so the replay
//!   loop streams 6x less trace data through the host cache.
//! * **Verbatim runs** — anything else (`Element`, `CachedStore`, and
//!   the rare run that does not fit the delta encoding, e.g. offsets
//!   beyond the 16 GiB window) is kept as raw accesses and replayed
//!   exactly as the lockstep engine would.
//!
//! The encoding is **lossless**: [`CompressedTrace::expand`] rebuilds
//! the original access list element for element, which is what the
//! differential test harness checks (`tests/differential.rs`), and the
//! event engine's replay of the compressed form is bit-identical in
//! cycles and statistics to lockstep replay of the raw form.

use crate::controller::Access;

/// One batched event: a run of homogeneous accesses.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Run {
    /// `count` contiguous `Stream` requests: request `i` covers `chunk`
    /// bytes at `base + i*chunk`; the last request covers `tail` bytes
    /// (`tail == chunk` when the run divides evenly).
    Stream {
        base: u64,
        chunk: u32,
        count: u32,
        tail: u32,
    },
    /// `count` `Cached` loads of `bytes` each at
    /// `base + 4*words[off + i]`.
    Cached {
        base: u64,
        bytes: u32,
        off: usize,
        count: usize,
    },
    /// `count` raw accesses at `raw[off..off + count]`, replayed
    /// verbatim.
    Verbatim { off: usize, count: usize },
}

/// A lossless, delta-encoded access trace (see module docs).
///
/// Build one with [`CompressedTrace::compress`]; replay it with
/// [`crate::controller::MemoryController::replay_events`].  The
/// compressed form is configuration-independent — addresses depend
/// only on tensor shape, rank, and layout — so one trace serves every
/// DSE candidate configuration.
#[derive(Debug, Clone, Default)]
pub struct CompressedTrace {
    runs: Vec<Run>,
    /// Packed 4-byte-unit address deltas for cached runs.
    words: Vec<u32>,
    /// Verbatim accesses (cold access classes and encoding fallbacks).
    raw: Vec<Access>,
    /// Total request count (= the raw trace's length).
    requests: u64,
    /// Total bytes across all requests.
    total_bytes: u64,
}

impl CompressedTrace {
    /// Delta-encode a raw access trace.  Lossless for any input;
    /// accesses that do not fit the run encodings fall back to
    /// verbatim storage.
    pub fn compress(trace: &[Access]) -> CompressedTrace {
        let mut out = CompressedTrace::default();
        for a in trace {
            out.requests += 1;
            out.total_bytes += a.bytes() as u64;
        }

        let mut i = 0usize;
        while i < trace.len() {
            match trace[i] {
                Access::Stream { .. } => {
                    let mut j = i;
                    while j < trace.len() && matches!(trace[j], Access::Stream { .. }) {
                        j += 1;
                    }
                    out.encode_streams(&trace[i..j]);
                    i = j;
                }
                Access::Cached { bytes, .. } => {
                    let mut j = i + 1;
                    while j < trace.len()
                        && matches!(trace[j], Access::Cached { bytes: b, .. } if b == bytes)
                    {
                        j += 1;
                    }
                    out.encode_cached(&trace[i..j]);
                    i = j;
                }
                _ => {
                    let mut j = i;
                    while j < trace.len()
                        && matches!(
                            trace[j],
                            Access::Element { .. } | Access::CachedStore { .. }
                        )
                    {
                        j += 1;
                    }
                    out.push_verbatim(&trace[i..j]);
                    i = j;
                }
            }
        }
        out
    }

    /// Encode a maximal `Stream`-only segment as contiguous runs.
    fn encode_streams(&mut self, seg: &[Access]) {
        let at = |k: usize| -> (u64, usize) {
            match seg[k] {
                Access::Stream { addr, bytes } => (addr, bytes),
                _ => unreachable!("stream segment"),
            }
        };
        let mut k = 0usize;
        while k < seg.len() {
            let (base, chunk) = at(k);
            if chunk > u32::MAX as usize {
                self.push_verbatim(&seg[k..k + 1]);
                k += 1;
                continue;
            }
            // Extend while each next request starts exactly where the
            // previous uniform chunk ends; a single short (or long)
            // tail request is absorbed and terminates the run.
            let mut count = 1u32;
            let mut tail = chunk as u32;
            while tail == chunk as u32 && k + (count as usize) < seg.len() {
                let (a, b) = at(k + count as usize);
                if a != base + count as u64 * chunk as u64 || b > u32::MAX as usize {
                    break;
                }
                tail = b as u32;
                count += 1;
            }
            self.runs.push(Run::Stream {
                base,
                chunk: chunk as u32,
                count,
                tail,
            });
            k += count as usize;
        }
    }

    /// Encode a maximal same-width `Cached` segment as one delta run,
    /// falling back to verbatim if the offsets do not fit the window.
    fn encode_cached(&mut self, seg: &[Access]) {
        let addr_of = |a: &Access| -> u64 {
            match *a {
                Access::Cached { addr, .. } => addr,
                _ => unreachable!("cached segment"),
            }
        };
        let bytes = seg[0].bytes();
        let base = seg.iter().map(addr_of).min().expect("non-empty segment");
        let fits = bytes <= u32::MAX as usize
            && seg.iter().all(|a| {
                let d = addr_of(a) - base;
                d % 4 == 0 && d / 4 <= u32::MAX as u64
            });
        if !fits {
            self.push_verbatim(seg);
            return;
        }
        let off = self.words.len();
        self.words
            .extend(seg.iter().map(|a| ((addr_of(a) - base) / 4) as u32));
        self.runs.push(Run::Cached {
            base,
            bytes: bytes as u32,
            off,
            count: seg.len(),
        });
    }

    fn push_verbatim(&mut self, seg: &[Access]) {
        if seg.is_empty() {
            return;
        }
        // Merge with a directly preceding verbatim run.
        if let Some(Run::Verbatim { off, count }) = self.runs.last_mut() {
            if *off + *count == self.raw.len() {
                *count += seg.len();
                self.raw.extend_from_slice(seg);
                return;
            }
        }
        self.runs.push(Run::Verbatim {
            off: self.raw.len(),
            count: seg.len(),
        });
        self.raw.extend_from_slice(seg);
    }

    /// Reconstruct the original raw access list (lossless inverse of
    /// [`CompressedTrace::compress`]).
    pub fn expand(&self) -> Vec<Access> {
        let mut out = Vec::with_capacity(self.requests as usize);
        for run in &self.runs {
            match *run {
                Run::Stream {
                    base,
                    chunk,
                    count,
                    tail,
                } => {
                    for i in 0..count as u64 {
                        let bytes = if i + 1 == count as u64 { tail } else { chunk };
                        out.push(Access::Stream {
                            addr: base + i * chunk as u64,
                            bytes: bytes as usize,
                        });
                    }
                }
                Run::Cached {
                    base,
                    bytes,
                    off,
                    count,
                } => {
                    for &w in &self.words[off..off + count] {
                        out.push(Access::Cached {
                            addr: base + 4 * w as u64,
                            bytes: bytes as usize,
                        });
                    }
                }
                Run::Verbatim { off, count } => {
                    out.extend_from_slice(&self.raw[off..off + count]);
                }
            }
        }
        out
    }

    /// Number of accesses (requests) the trace encodes.
    pub fn len(&self) -> usize {
        self.requests as usize
    }

    /// True when the trace encodes no accesses.
    pub fn is_empty(&self) -> bool {
        self.requests == 0
    }

    /// Total request count, for bulk controller-stat accounting.
    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Total bytes across all requests, for bulk accounting.
    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    /// Host bytes of the compressed representation.
    pub fn encoded_bytes(&self) -> usize {
        self.runs.len() * std::mem::size_of::<Run>()
            + self.words.len() * 4
            + self.raw.len() * std::mem::size_of::<Access>()
    }

    /// Host bytes the equivalent raw `Vec<Access>` occupies.
    pub fn raw_bytes(&self) -> usize {
        self.len() * std::mem::size_of::<Access>()
    }

    /// raw / encoded size ratio (higher = better compression).
    pub fn compression_ratio(&self) -> f64 {
        if self.encoded_bytes() == 0 {
            1.0
        } else {
            self.raw_bytes() as f64 / self.encoded_bytes() as f64
        }
    }

    pub(crate) fn runs(&self) -> &[Run] {
        &self.runs
    }

    pub(crate) fn words_at(&self, off: usize, count: usize) -> &[u32] {
        &self.words[off..off + count]
    }

    pub(crate) fn raw_at(&self, off: usize, count: usize) -> &[Access] {
        &self.raw[off..off + count]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Rng;

    fn roundtrip(trace: &[Access]) {
        let ct = CompressedTrace::compress(trace);
        assert_eq!(ct.len(), trace.len());
        assert_eq!(
            ct.total_bytes(),
            trace.iter().map(|a| a.bytes() as u64).sum::<u64>()
        );
        assert_eq!(ct.expand(), trace, "compress/expand must be lossless");
    }

    #[test]
    fn empty_trace() {
        let ct = CompressedTrace::compress(&[]);
        assert!(ct.is_empty());
        assert!(ct.expand().is_empty());
    }

    #[test]
    fn contiguous_stream_with_tail_is_one_run() {
        let trace: Vec<Access> = (0..5)
            .map(|i| Access::Stream {
                addr: 1_000 + i * 4096,
                bytes: if i == 4 { 100 } else { 4096 },
            })
            .collect();
        let ct = CompressedTrace::compress(&trace);
        assert_eq!(ct.runs().len(), 1);
        roundtrip(&trace);
    }

    #[test]
    fn cached_rows_pack_as_words() {
        let mut rng = Rng::new(1);
        let trace: Vec<Access> = (0..500)
            .map(|_| Access::Cached {
                addr: (8 << 20) + rng.below(10_000) * 64,
                bytes: 64,
            })
            .collect();
        let ct = CompressedTrace::compress(&trace);
        assert_eq!(ct.runs().len(), 1, "one delta run expected");
        assert!(
            ct.compression_ratio() > 4.0,
            "ratio {}",
            ct.compression_ratio()
        );
        roundtrip(&trace);
    }

    #[test]
    fn mixed_classes_roundtrip() {
        let mut rng = Rng::new(2);
        let mut trace = Vec::new();
        for i in 0..400u64 {
            match rng.below(5) {
                0 => trace.push(Access::Stream {
                    addr: i * 4096,
                    bytes: 4096,
                }),
                1 => trace.push(Access::Element {
                    addr: (1 << 30) + i * 16,
                    bytes: 16,
                }),
                2 => trace.push(Access::CachedStore {
                    addr: (2 << 30) + rng.below(1 << 20) * 16,
                    bytes: 16,
                }),
                3 => trace.push(Access::Cached {
                    addr: (3 << 30) + rng.below(1 << 16) * 64,
                    bytes: 64,
                }),
                _ => trace.push(Access::Cached {
                    // Different width: must split the cached run.
                    addr: (3 << 30) + rng.below(1 << 16) * 32,
                    bytes: 32,
                }),
            }
        }
        roundtrip(&trace);
    }

    #[test]
    fn far_apart_cached_addresses_fall_back_to_verbatim() {
        // A >16 GiB span cannot be expressed in u32 4-byte deltas.
        let trace = vec![
            Access::Cached { addr: 0, bytes: 64 },
            Access::Cached {
                addr: 1 << 40,
                bytes: 64,
            },
        ];
        let ct = CompressedTrace::compress(&trace);
        assert_eq!(ct.expand(), trace);
    }

    #[test]
    fn unaligned_cached_addresses_fall_back_to_verbatim() {
        let trace = vec![
            Access::Cached { addr: 3, bytes: 8 },
            Access::Cached { addr: 10, bytes: 8 },
        ];
        let ct = CompressedTrace::compress(&trace);
        assert_eq!(ct.expand(), trace);
    }

    #[test]
    fn gapped_streams_split_into_runs() {
        // Output-row stores with unused rows between them.
        let trace = vec![
            Access::Stream {
                addr: 0,
                bytes: 64,
            },
            Access::Stream {
                addr: 64,
                bytes: 64,
            },
            Access::Stream {
                addr: 256, // gap
                bytes: 64,
            },
        ];
        let ct = CompressedTrace::compress(&trace);
        assert_eq!(ct.runs().len(), 2);
        roundtrip(&trace);
    }

    #[test]
    fn shard_trace_compresses_well() {
        use crate::controller::MemLayout;
        use crate::shard::{partition_indices, shard_trace, ShardPlan};
        use crate::tensor::synth::{generate, Profile, SynthConfig};
        let t = generate(&SynthConfig {
            dims: vec![300, 200, 150],
            nnz: 5_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed: 4,
        });
        let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), 16);
        let plan = ShardPlan::balance(&t, 0, 2);
        let parts = partition_indices(&t, &plan);
        let trace = shard_trace(&t, 16, 0, &layout, &plan.shards[0], &parts[0], 0);
        let ct = CompressedTrace::compress(&trace);
        assert_eq!(ct.expand(), trace);
        assert!(
            ct.compression_ratio() > 3.0,
            "spMTTKRP shard traces are highly regular: {}",
            ct.compression_ratio()
        );
    }
}
