//! Wire protocol of the DSE service (S32): length-prefixed frames
//! ([`crate::util::write_frame`] / [`crate::util::read_frame`]) whose
//! bodies are hand-rolled little-endian records over
//! [`ByteWriter`] / [`ByteReader`] — the same zero-dependency codec
//! the warm cache and config files use.
//!
//! Every frame body opens with a 4-byte magic (`b"PTSV"`) and a
//! one-byte message tag, so a client that connects to the wrong port
//! (or a stream that desyncs) fails with a typed [`ErrorClass::Parse`]
//! error instead of misinterpreting bytes.  Tags are append-only;
//! unknown tags are parse errors, never panics.
//!
//! Requests: [`Request::Submit`] (one [`JobSpec`]), [`Request::Stats`],
//! [`Request::Shutdown`].  Responses: [`Response::Result`] (one
//! [`JobResult`]), [`Response::Error`], [`Response::Stats`],
//! [`Response::Bye`].  Submitted jobs are answered in submission order
//! per connection, matched by the client-chosen `id`.

use crate::dse::SearchStrategy;
use crate::engine::EngineKind;
use crate::error::{Error, ErrorClass};
use crate::tensor::synth::Profile;
use crate::util::{ByteReader, ByteWriter};

/// Magic prefix of every frame body: `b"PTSV"` as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"PTSV");

/// Upper bound on a frame body accepted by either side.  Generous for
/// real traffic (a 10k-point frontier is ~1 MiB) while refusing a
/// hostile or desynced length prefix before allocating.
pub const MAX_FRAME: usize = 64 << 20;

const REQ_SUBMIT: u8 = 1;
const REQ_STATS: u8 = 2;
const REQ_SHUTDOWN: u8 = 3;

const RESP_RESULT: u8 = 1;
const RESP_ERROR: u8 = 2;
const RESP_STATS: u8 = 3;
const RESP_BYE: u8 = 4;

/// A typed [`ErrorClass::Parse`] decode failure.
fn perr(msg: impl std::fmt::Display) -> Error {
    Error::msg(format!("serve protocol: {msg}")).classify(ErrorClass::Parse)
}

/// Which evaluator a job scores through.  The service deliberately
/// exposes only the analytic model and the single-controller cycle
/// simulator — the sharded evaluator's worker count is a server-side
/// resource decision, not a per-job knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalKind {
    /// Analytic Performance Model Simulator ([`crate::pms`]).
    Pms,
    /// Cycle-approximate simulation ([`crate::dse::Evaluator::CycleSim`]).
    Sim,
}

impl EvalKind {
    /// Stable wire tag (append-only).
    pub fn tag(self) -> u8 {
        match self {
            EvalKind::Pms => 0,
            EvalKind::Sim => 1,
        }
    }

    /// Inverse of [`EvalKind::tag`].
    pub fn from_tag(tag: u8) -> Option<EvalKind> {
        match tag {
            0 => Some(EvalKind::Pms),
            1 => Some(EvalKind::Sim),
            _ => None,
        }
    }

    /// The `--evaluator` label this kind corresponds to — the string
    /// the warm-cache [`crate::dse::KeyBuilder`] is keyed with, so a
    /// served job and a CLI `explore --warm-cache` run of the same
    /// workload land on the same memo context.
    pub fn label(self) -> &'static str {
        match self {
            EvalKind::Pms => "pms",
            EvalKind::Sim => "sim",
        }
    }
}

/// Which sweep grid a job explores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridPreset {
    /// [`crate::dse::Grids::default`] — the paper's full §5.2.1 grid.
    Default,
    /// [`crate::dse::Grids::smoke`] — the tiny CI/smoke grid.
    Smoke,
}

impl GridPreset {
    pub fn tag(self) -> u8 {
        match self {
            GridPreset::Default => 0,
            GridPreset::Smoke => 1,
        }
    }

    pub fn from_tag(tag: u8) -> Option<GridPreset> {
        match tag {
            0 => Some(GridPreset::Default),
            1 => Some(GridPreset::Smoke),
            _ => None,
        }
    }
}

/// One exploration job: a synthetic workload plus the search knobs of
/// `ptmc explore`.  The tensor is described, not shipped — the server
/// regenerates it from `(dims, nnz, profile, seed)`, which is exactly
/// the identity the cross-query memo keys on, so two clients
/// describing the same tensor share one in-memory instance *and* one
/// memo context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobSpec {
    /// Client-chosen correlation id, echoed in the response.
    pub id: u64,
    /// Tenant name this job bills against (see server `--tenant-budget`).
    pub tenant: String,
    /// Synthetic tensor mode lengths.
    pub dims: Vec<usize>,
    /// Synthetic tensor non-zero count.
    pub nnz: usize,
    /// Generator seed (also seeds the factor matrices).
    pub seed: u64,
    /// Coordinate distribution.
    pub profile: Profile,
    /// CP rank.
    pub rank: usize,
    pub evaluator: EvalKind,
    pub engine: EngineKind,
    pub strategy: SearchStrategy,
    /// How many best points the response's `top` could report (the
    /// search layer clamps to >= 1).
    pub top_k: usize,
    pub grid: GridPreset,
}

/// One explored point on the wire: the config in its canonical
/// [`crate::util::encode_config`] encoding (the same bytes the memo
/// and warm cache key on) plus the score and resource usage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WirePoint {
    pub cfg_enc: Vec<u8>,
    /// `f64::to_bits` of the cycle count — bit-exact across the wire.
    pub cycles_bits: u64,
    pub bram36: u64,
    pub uram: u64,
}

impl WirePoint {
    pub fn cycles(&self) -> f64 {
        f64::from_bits(self.cycles_bits)
    }
}

/// A completed job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobResult {
    /// The submitting [`JobSpec::id`].
    pub id: u64,
    pub best: WirePoint,
    /// Pareto frontier, ascending in cycles (see
    /// [`crate::dse::Exploration::pareto`]).
    pub pareto: Vec<WirePoint>,
    /// Feasible points visited.
    pub visited: u64,
    /// Candidates rejected as not fitting the device.
    pub rejected: u64,
    /// Cross-query memo hits charged to this job's view.
    pub memo_hits: u64,
    /// Cross-query memo misses charged to this job's view.
    pub memo_misses: u64,
}

/// Server-wide counters returned by [`Request::Stats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ServerStats {
    /// Jobs completed (successfully) since startup.
    pub jobs_done: u64,
    /// Jobs rejected with an error response.
    pub jobs_failed: u64,
    /// Entries resident in the cross-query memo.
    pub memo_entries: u64,
    /// Store-wide memo hits across every query.
    pub memo_hits: u64,
    /// Store-wide memo misses.
    pub memo_misses: u64,
    /// Worker threads in the job pool.
    pub workers: u64,
}

/// A client-to-server message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    Submit(JobSpec),
    Stats,
    /// Graceful shutdown: the server drains queued jobs, answers
    /// [`Response::Bye`], and exits its accept loop.
    Shutdown,
}

/// A server-to-client message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Response {
    Result(JobResult),
    /// A job (or frame) the server refused; `id` is 0 when the
    /// failure happened before a job id could be parsed.
    Error {
        id: u64,
        class: ErrorClass,
        msg: String,
    },
    Stats(ServerStats),
    Bye,
}

fn put_str(w: &mut ByteWriter, s: &str) {
    w.usize(s.len());
    w.bytes(s.as_bytes());
}

fn get_str(r: &mut ByteReader<'_>, what: &str) -> Result<String, Error> {
    let len = r.usize().ok_or_else(|| perr(format!("{what}: truncated length")))?;
    let raw = r
        .take(len)
        .ok_or_else(|| perr(format!("{what}: truncated body ({len} bytes)")))?;
    String::from_utf8(raw.to_vec()).map_err(|_| perr(format!("{what}: invalid utf-8")))
}

fn put_blob(w: &mut ByteWriter, b: &[u8]) {
    w.usize(b.len());
    w.bytes(b);
}

fn get_blob(r: &mut ByteReader<'_>, what: &str) -> Result<Vec<u8>, Error> {
    let len = r.usize().ok_or_else(|| perr(format!("{what}: truncated length")))?;
    let raw = r
        .take(len)
        .ok_or_else(|| perr(format!("{what}: truncated body ({len} bytes)")))?;
    Ok(raw.to_vec())
}

fn put_profile(w: &mut ByteWriter, p: Profile) {
    match p {
        Profile::Uniform => w.u8(0),
        Profile::Zipf { alpha_milli } => {
            w.u8(1);
            w.u32(alpha_milli);
        }
        Profile::Clustered { block, blocks } => {
            w.u8(2);
            w.usize(block);
            w.usize(blocks);
        }
    }
}

fn get_profile(r: &mut ByteReader<'_>) -> Result<Profile, Error> {
    match r.u8().ok_or_else(|| perr("profile: truncated tag"))? {
        0 => Ok(Profile::Uniform),
        1 => Ok(Profile::Zipf {
            alpha_milli: r.u32().ok_or_else(|| perr("profile: truncated alpha"))?,
        }),
        2 => Ok(Profile::Clustered {
            block: r.usize().ok_or_else(|| perr("profile: truncated block"))?,
            blocks: r.usize().ok_or_else(|| perr("profile: truncated blocks"))?,
        }),
        t => Err(perr(format!("profile: unknown tag {t}"))),
    }
}

fn put_strategy(w: &mut ByteWriter, s: SearchStrategy) {
    match s {
        SearchStrategy::Coordinate => {
            w.u8(0);
            w.u32(0);
        }
        SearchStrategy::Joint => {
            w.u8(1);
            w.u32(0);
        }
        SearchStrategy::Beam { width } => {
            w.u8(2);
            w.u32(width.min(u32::MAX as usize) as u32);
        }
    }
}

fn get_strategy(r: &mut ByteReader<'_>) -> Result<SearchStrategy, Error> {
    let tag = r.u8().ok_or_else(|| perr("strategy: truncated tag"))?;
    let width = r.u32().ok_or_else(|| perr("strategy: truncated width"))? as usize;
    match tag {
        0 => Ok(SearchStrategy::Coordinate),
        1 => Ok(SearchStrategy::Joint),
        2 => Ok(SearchStrategy::Beam {
            width: width.max(1),
        }),
        t => Err(perr(format!("strategy: unknown tag {t}"))),
    }
}

fn class_tag(c: ErrorClass) -> u8 {
    c.exit_code()
}

fn class_from_tag(tag: u8) -> Option<ErrorClass> {
    match tag {
        1 => Some(ErrorClass::Internal),
        2 => Some(ErrorClass::Usage),
        3 => Some(ErrorClass::Parse),
        4 => Some(ErrorClass::Io),
        5 => Some(ErrorClass::Budget),
        6 => Some(ErrorClass::Worker),
        _ => None,
    }
}

fn put_point(w: &mut ByteWriter, p: &WirePoint) {
    put_blob(w, &p.cfg_enc);
    w.u64(p.cycles_bits);
    w.u64(p.bram36);
    w.u64(p.uram);
}

fn get_point(r: &mut ByteReader<'_>) -> Result<WirePoint, Error> {
    Ok(WirePoint {
        cfg_enc: get_blob(r, "point config")?,
        cycles_bits: r.u64().ok_or_else(|| perr("point: truncated cycles"))?,
        bram36: r.u64().ok_or_else(|| perr("point: truncated bram36"))?,
        uram: r.u64().ok_or_else(|| perr("point: truncated uram"))?,
    })
}

fn put_spec(w: &mut ByteWriter, s: &JobSpec) {
    w.u64(s.id);
    put_str(w, &s.tenant);
    w.usize(s.dims.len());
    for &d in &s.dims {
        w.usize(d);
    }
    w.usize(s.nnz);
    w.u64(s.seed);
    put_profile(w, s.profile);
    w.usize(s.rank);
    w.u8(s.evaluator.tag());
    w.u8(s.engine.tag());
    put_strategy(w, s.strategy);
    w.usize(s.top_k);
    w.u8(s.grid.tag());
}

fn get_spec(r: &mut ByteReader<'_>) -> Result<JobSpec, Error> {
    let id = r.u64().ok_or_else(|| perr("job: truncated id"))?;
    let tenant = get_str(r, "job tenant")?;
    let n_dims = r.usize().ok_or_else(|| perr("job: truncated dim count"))?;
    // A desynced stream could claim billions of dims; real tensors
    // have a handful of modes, so bound before allocating.
    if n_dims == 0 || n_dims > 16 {
        return Err(perr(format!("job: implausible mode count {n_dims}")));
    }
    let mut dims = Vec::with_capacity(n_dims);
    for _ in 0..n_dims {
        dims.push(r.usize().ok_or_else(|| perr("job: truncated dim"))?);
    }
    let nnz = r.usize().ok_or_else(|| perr("job: truncated nnz"))?;
    let seed = r.u64().ok_or_else(|| perr("job: truncated seed"))?;
    let profile = get_profile(r)?;
    let rank = r.usize().ok_or_else(|| perr("job: truncated rank"))?;
    let evaluator = r
        .u8()
        .and_then(EvalKind::from_tag)
        .ok_or_else(|| perr("job: bad evaluator tag"))?;
    let engine = r
        .u8()
        .and_then(EngineKind::from_tag)
        .ok_or_else(|| perr("job: bad engine tag"))?;
    let strategy = get_strategy(r)?;
    let top_k = r.usize().ok_or_else(|| perr("job: truncated top_k"))?;
    let grid = r
        .u8()
        .and_then(GridPreset::from_tag)
        .ok_or_else(|| perr("job: bad grid tag"))?;
    Ok(JobSpec {
        id,
        tenant,
        dims,
        nnz,
        seed,
        profile,
        rank,
        evaluator,
        engine,
        strategy,
        top_k,
        grid,
    })
}

fn put_result(w: &mut ByteWriter, res: &JobResult) {
    w.u64(res.id);
    put_point(w, &res.best);
    w.usize(res.pareto.len());
    for p in &res.pareto {
        put_point(w, p);
    }
    w.u64(res.visited);
    w.u64(res.rejected);
    w.u64(res.memo_hits);
    w.u64(res.memo_misses);
}

fn get_result(r: &mut ByteReader<'_>) -> Result<JobResult, Error> {
    let id = r.u64().ok_or_else(|| perr("result: truncated id"))?;
    let best = get_point(r)?;
    let n = r
        .usize()
        .ok_or_else(|| perr("result: truncated frontier length"))?;
    // Each point is >= 28 bytes on the wire; refuse a length claim the
    // remaining bytes cannot possibly satisfy before allocating.
    if n > r.remaining() / 28 + 1 {
        return Err(perr(format!("result: implausible frontier length {n}")));
    }
    let mut pareto = Vec::with_capacity(n);
    for _ in 0..n {
        pareto.push(get_point(r)?);
    }
    Ok(JobResult {
        id,
        best,
        pareto,
        visited: r.u64().ok_or_else(|| perr("result: truncated visited"))?,
        rejected: r.u64().ok_or_else(|| perr("result: truncated rejected"))?,
        memo_hits: r.u64().ok_or_else(|| perr("result: truncated hits"))?,
        memo_misses: r.u64().ok_or_else(|| perr("result: truncated misses"))?,
    })
}

fn put_stats(w: &mut ByteWriter, st: &ServerStats) {
    w.u64(st.jobs_done);
    w.u64(st.jobs_failed);
    w.u64(st.memo_entries);
    w.u64(st.memo_hits);
    w.u64(st.memo_misses);
    w.u64(st.workers);
}

fn get_stats(r: &mut ByteReader<'_>) -> Result<ServerStats, Error> {
    Ok(ServerStats {
        jobs_done: r.u64().ok_or_else(|| perr("stats: truncated jobs_done"))?,
        jobs_failed: r.u64().ok_or_else(|| perr("stats: truncated jobs_failed"))?,
        memo_entries: r.u64().ok_or_else(|| perr("stats: truncated entries"))?,
        memo_hits: r.u64().ok_or_else(|| perr("stats: truncated hits"))?,
        memo_misses: r.u64().ok_or_else(|| perr("stats: truncated misses"))?,
        workers: r.u64().ok_or_else(|| perr("stats: truncated workers"))?,
    })
}

/// The common frame-body prelude: magic + message tag.
fn open_body(body: &[u8]) -> Result<(u8, ByteReader<'_>), Error> {
    let mut r = ByteReader::new(body);
    let magic = r.u32().ok_or_else(|| perr("frame shorter than magic"))?;
    if magic != MAGIC {
        return Err(perr(format!(
            "bad magic {magic:#010x} (expected {MAGIC:#010x})"
        )));
    }
    let tag = r.u8().ok_or_else(|| perr("frame missing message tag"))?;
    Ok((tag, r))
}

/// Reject bytes left over after a complete decode — a trailing-junk
/// frame means the stream is desynced and nothing after it can be
/// trusted.
fn close_body(r: &ByteReader<'_>, what: &str) -> Result<(), Error> {
    if r.is_empty() {
        Ok(())
    } else {
        Err(perr(format!(
            "{what}: {} trailing bytes after message",
            r.remaining()
        )))
    }
}

impl Request {
    /// The frame body for this request.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(MAGIC);
        match self {
            Request::Submit(spec) => {
                w.u8(REQ_SUBMIT);
                put_spec(&mut w, spec);
            }
            Request::Stats => w.u8(REQ_STATS),
            Request::Shutdown => w.u8(REQ_SHUTDOWN),
        }
        w.into_bytes()
    }

    /// Decode one frame body; failures are [`ErrorClass::Parse`].
    pub fn decode(body: &[u8]) -> Result<Request, Error> {
        let (tag, mut r) = open_body(body)?;
        let req = match tag {
            REQ_SUBMIT => Request::Submit(get_spec(&mut r)?),
            REQ_STATS => Request::Stats,
            REQ_SHUTDOWN => Request::Shutdown,
            t => return Err(perr(format!("unknown request tag {t}"))),
        };
        close_body(&r, "request")?;
        Ok(req)
    }
}

impl Response {
    /// The frame body for this response.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(MAGIC);
        match self {
            Response::Result(res) => {
                w.u8(RESP_RESULT);
                put_result(&mut w, res);
            }
            Response::Error { id, class, msg } => {
                w.u8(RESP_ERROR);
                w.u64(*id);
                w.u8(class_tag(*class));
                put_str(&mut w, msg);
            }
            Response::Stats(st) => {
                w.u8(RESP_STATS);
                put_stats(&mut w, st);
            }
            Response::Bye => w.u8(RESP_BYE),
        }
        w.into_bytes()
    }

    /// Decode one frame body; failures are [`ErrorClass::Parse`].
    pub fn decode(body: &[u8]) -> Result<Response, Error> {
        let (tag, mut r) = open_body(body)?;
        let resp = match tag {
            RESP_RESULT => Response::Result(get_result(&mut r)?),
            RESP_ERROR => {
                let id = r.u64().ok_or_else(|| perr("error: truncated id"))?;
                let class = r
                    .u8()
                    .and_then(class_from_tag)
                    .ok_or_else(|| perr("error: bad class tag"))?;
                let msg = get_str(&mut r, "error message")?;
                Response::Error { id, class, msg }
            }
            RESP_STATS => Response::Stats(get_stats(&mut r)?),
            RESP_BYE => Response::Bye,
            t => return Err(perr(format!("unknown response tag {t}"))),
        };
        close_body(&r, "response")?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            id: 7,
            tenant: "team-a".to_string(),
            dims: vec![200, 150, 100],
            nnz: 5_000,
            seed: 42,
            profile: Profile::Zipf { alpha_milli: 1200 },
            rank: 8,
            evaluator: EvalKind::Pms,
            engine: EngineKind::Event,
            strategy: SearchStrategy::Beam { width: 3 },
            top_k: 2,
            grid: GridPreset::Smoke,
        }
    }

    #[test]
    fn requests_round_trip() {
        for req in [
            Request::Submit(spec()),
            Request::Stats,
            Request::Shutdown,
        ] {
            let body = req.encode();
            assert_eq!(Request::decode(&body).unwrap(), req);
        }
    }

    #[test]
    fn responses_round_trip() {
        let point = WirePoint {
            cfg_enc: vec![1, 2, 3, 4],
            cycles_bits: 1.5e9f64.to_bits(),
            bram36: 100,
            uram: 8,
        };
        for resp in [
            Response::Result(JobResult {
                id: 7,
                best: point.clone(),
                pareto: vec![point.clone(), point.clone()],
                visited: 40,
                rejected: 3,
                memo_hits: 12,
                memo_misses: 28,
            }),
            Response::Error {
                id: 9,
                class: ErrorClass::Budget,
                msg: "tenant budget exhausted".to_string(),
            },
            Response::Stats(ServerStats {
                jobs_done: 5,
                jobs_failed: 1,
                memo_entries: 123,
                memo_hits: 40,
                memo_misses: 83,
                workers: 4,
            }),
            Response::Bye,
        ] {
            let body = resp.encode();
            assert_eq!(Response::decode(&body).unwrap(), resp);
        }
    }

    #[test]
    fn malformed_bodies_are_typed_parse_errors() {
        let cases: Vec<Vec<u8>> = vec![
            vec![],                           // empty
            vec![0xde, 0xad],                 // shorter than magic
            {
                let mut b = 0xdeadbeefu32.to_le_bytes().to_vec();
                b.push(REQ_STATS);
                b
            }, // wrong magic
            {
                let mut b = MAGIC.to_le_bytes().to_vec();
                b.push(0xff);
                b
            }, // unknown tag
            {
                let mut b = Request::Submit(spec()).encode();
                b.truncate(b.len() - 3);
                b
            }, // truncated spec
            {
                let mut b = Request::Stats.encode();
                b.push(0);
                b
            }, // trailing junk
        ];
        for body in cases {
            let err = Request::decode(&body).unwrap_err();
            assert_eq!(err.class(), ErrorClass::Parse, "body {body:?}");
        }
    }

    #[test]
    fn implausible_mode_count_is_rejected_before_allocating() {
        let mut w = ByteWriter::new();
        w.u32(MAGIC);
        w.u8(REQ_SUBMIT);
        w.u64(1); // id
        w.usize(1); // tenant length
        w.bytes(b"t");
        w.usize(usize::MAX); // dim count
        let err = Request::decode(w.as_slice()).unwrap_err();
        assert_eq!(err.class(), ErrorClass::Parse);
    }
}
