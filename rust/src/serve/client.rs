//! Batch client of the DSE service: pipeline a batch of jobs over one
//! connection, collect the responses, and aggregate the memo
//! economics (CLI `ptmc batch`).
//!
//! The client writes every Submit frame up front, then reads exactly
//! one response per job.  Responses arrive in *completion* order and
//! are matched by [`JobSpec::id`]; pipelining keeps the server's whole
//! worker pool busy from a single connection.

use std::io::{self, Write};
use std::net::TcpStream;

use crate::error::{Error, ErrorClass};
use crate::util::{read_frame, write_frame};

use super::proto::{self, JobResult, JobSpec, Request, Response, ServerStats};

/// One failed job from a batch.
#[derive(Debug, Clone)]
pub struct BatchError {
    /// The submitting [`JobSpec::id`] (0 for connection-level errors).
    pub id: u64,
    pub class: ErrorClass,
    pub msg: String,
}

/// Everything a batch produced, results and errors each sorted by
/// job id.
#[derive(Debug, Clone, Default)]
pub struct BatchReport {
    pub results: Vec<JobResult>,
    pub errors: Vec<BatchError>,
}

impl BatchReport {
    /// Cross-query memo hits summed over the batch's results.
    pub fn memo_hits(&self) -> u64 {
        self.results.iter().map(|r| r.memo_hits).sum()
    }

    /// Cross-query memo misses summed over the batch's results.
    pub fn memo_misses(&self) -> u64 {
        self.results.iter().map(|r| r.memo_misses).sum()
    }

    /// The class of the first (lowest-id) error, if any — what a CLI
    /// frontend should exit with, so e.g. a tenant-budget rejection
    /// surfaces as exit code 5.
    pub fn first_error_class(&self) -> Option<ErrorClass> {
        self.errors.first().map(|e| e.class)
    }
}

fn ioerr(what: &str, e: &io::Error) -> Error {
    Error::msg(format!("{what}: {e}")).classify(ErrorClass::Io)
}

fn connect(addr: &str) -> Result<TcpStream, Error> {
    TcpStream::connect(addr).map_err(|e| ioerr(&format!("connect {addr}"), &e))
}

/// Read one response frame; a clean EOF is an IO error here (the
/// caller always expects a response).
fn read_response(stream: &mut TcpStream) -> Result<Response, Error> {
    match read_frame(stream, proto::MAX_FRAME) {
        Ok(Some(body)) => Response::decode(&body),
        Ok(None) => Err(Error::msg("server closed the connection mid-conversation")
            .classify(ErrorClass::Io)),
        Err(e) => Err(ioerr("read response", &e)),
    }
}

fn write_request(stream: &mut TcpStream, req: &Request) -> Result<(), Error> {
    write_frame(stream, &req.encode()).map_err(|e| ioerr("write request", &e))?;
    stream.flush().map_err(|e| ioerr("flush request", &e))
}

/// Submit `jobs` over one pipelined connection and collect one
/// response per job.  Connection-level failures (transport errors, a
/// server that closes early) are `Err`; per-job rejections land in
/// [`BatchReport::errors`].
pub fn submit_batch(addr: &str, jobs: &[JobSpec]) -> Result<BatchReport, Error> {
    let mut stream = connect(addr)?;
    for job in jobs {
        write_request(&mut stream, &Request::Submit(job.clone()))?;
    }
    let mut report = BatchReport::default();
    for _ in 0..jobs.len() {
        match read_response(&mut stream)? {
            Response::Result(res) => report.results.push(res),
            Response::Error { id, class, msg } => {
                report.errors.push(BatchError { id, class, msg })
            }
            other => {
                return Err(Error::msg(format!(
                    "unexpected response to a job submission: {other:?}"
                ))
                .classify(ErrorClass::Parse))
            }
        }
    }
    report.results.sort_by_key(|r| r.id);
    report.errors.sort_by_key(|e| e.id);
    Ok(report)
}

/// Fetch the server's lifetime counters.
pub fn stats(addr: &str) -> Result<ServerStats, Error> {
    let mut stream = connect(addr)?;
    write_request(&mut stream, &Request::Stats)?;
    match read_response(&mut stream)? {
        Response::Stats(st) => Ok(st),
        other => Err(Error::msg(format!("unexpected response to Stats: {other:?}"))
            .classify(ErrorClass::Parse)),
    }
}

/// Ask the server to drain and exit; returns once it acknowledges.
pub fn shutdown(addr: &str) -> Result<(), Error> {
    let mut stream = connect(addr)?;
    write_request(&mut stream, &Request::Shutdown)?;
    match read_response(&mut stream)? {
        Response::Bye => Ok(()),
        other => Err(Error::msg(format!(
            "unexpected response to Shutdown: {other:?}"
        ))
        .classify(ErrorClass::Parse)),
    }
}
