//! DSE-as-a-service (S32): a persistent, multi-tenant exploration
//! server and its batch client.
//!
//! The server ([`Server`]) listens on a TCP socket speaking the
//! length-prefixed frame protocol of [`proto`] (zero dependencies —
//! `std::net` plus the crate's own codec).  Each connection gets a
//! lightweight reader thread; the actual explorations run on a fixed
//! worker pool ([`crate::util::Pool`]), so a slow client cannot starve
//! other tenants and the host's cores bound the simulation load.
//!
//! The headline optimization is the **cross-query memo**
//! ([`crate::dse::MemoStore`]): every job's evaluator is wrapped in a
//! [`crate::dse::MemoView`] keyed by the full scoring context (tensor
//! fingerprint, evaluator, engine, rank, device, factors — the same
//! [`crate::dse::KeyBuilder`] identity the CLI warm cache uses), so N
//! concurrent or consecutive explorations of the same tensor share
//! classification verdicts and simulation scores.  A repeat submission
//! of an identical job performs **zero** new simulations — every
//! candidate is a memo hit — and returns a Pareto frontier
//! byte-identical to the cold run's.  Same-tensor jobs additionally
//! share one in-memory tensor instance and one [`crate::dse::SimMemo`]
//! (trace prep + remap-pass simulation), the intra-query sharing PR 5
//! introduced, now lifted across queries.
//!
//! Tenancy: each job names a tenant; `--tenant-budget N` bounds the
//! jobs any single tenant may submit over the server's lifetime.  A
//! tenant over budget gets a typed [`ErrorClass::Budget`] response
//! (exit code 5 at the batch client) and the job is never queued.
//!
//! Fault handling (S29): the accept loop and the per-connection frame
//! reader sit behind the `serve.accept` / `serve.frame` failpoints; an
//! injected fault (or a real dropped connection) closes that one
//! connection without poisoning the job queue or the memo — in-flight
//! jobs complete and their verdicts stay shared.  Memo spills run
//! behind `memo.flush` and degrade to in-memory on persistent failure.

use std::collections::HashMap;
use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::controller::ControllerConfig;
use crate::cpd::linalg::Mat;
use crate::dse::{
    explore_with, tensor_fingerprint, Exploration, Grids, KeyBuilder, MemoStore, Point,
    ScoreCache, SearchOptions, SimMemo,
};
use crate::error::{Error, ErrorClass};
use crate::fpga::{self, Device};
use crate::pms::TensorProfile;
use crate::tensor::synth::{generate, SynthConfig};
use crate::tensor::SparseTensor;
use crate::util::{
    effective_parallelism, fault, read_frame, set_parallelism_cap, write_frame, ByteWriter, Pool,
};

pub mod client;
pub mod proto;

use proto::{EvalKind, GridPreset, JobResult, JobSpec, Request, Response, ServerStats, WirePoint};

/// Hard sanity bound on a served synthetic tensor — a usage error, not
/// a crash, for a client asking the server to materialize billions of
/// non-zeros.
const MAX_NNZ: usize = 10_000_000;

/// Server-side configuration (CLI `ptmc serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads in the job pool (>= 1).
    pub workers: usize,
    /// Max jobs one tenant may submit over the server's lifetime
    /// (`None` = unmetered).
    pub tenant_budget: Option<u64>,
    /// Memo spill directory — the warm-cache on-disk format, so a
    /// served context survives restarts and interoperates with CLI
    /// `explore --warm-cache` runs.  `None` keeps the memo in memory.
    pub spill: Option<PathBuf>,
    /// Device every job is explored against.
    pub device: Device,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            tenant_budget: None,
            spill: None,
            device: Device::alveo_u250(),
        }
    }
}

/// One workload resident in the server: the regenerated tensor, its
/// factor matrices, the measured PMS profile, its fingerprint, and the
/// shared per-tensor [`SimMemo`] (trace prep + remap-pass memo) that
/// concurrent same-tensor jobs score through.
struct TensorEntry {
    tensor: SparseTensor,
    factors: Vec<Mat>,
    profile: TensorProfile,
    fp: u64,
    sim: Arc<SimMemo>,
}

/// Shared server state: the memo store, the job pool, the tensor
/// registry, and tenant accounting.
struct ServerState {
    cfg: ServeConfig,
    addr: SocketAddr,
    store: Arc<MemoStore>,
    pool: Pool,
    tensors: Mutex<HashMap<Vec<u8>, Arc<TensorEntry>>>,
    /// Jobs accepted per tenant (budget accounting).
    tenants: Mutex<HashMap<String, u64>>,
    jobs_done: AtomicU64,
    jobs_failed: AtomicU64,
    shutdown: AtomicBool,
}

/// The persistent DSE server.  `bind` then `run`; `run` returns after
/// a client sends [`Request::Shutdown`] and the queue has drained.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
}

/// The identity of a served workload *before* generation: everything
/// [`TensorEntry`] is derived from.  (The memo context additionally
/// hashes the generated tensor's fingerprint, evaluator, engine, and
/// device through [`KeyBuilder`].)
fn tensor_key(spec: &JobSpec) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.usize(spec.dims.len());
    for &d in &spec.dims {
        w.usize(d);
    }
    w.usize(spec.nnz);
    w.u64(spec.seed);
    match spec.profile {
        crate::tensor::synth::Profile::Uniform => w.u8(0),
        crate::tensor::synth::Profile::Zipf { alpha_milli } => {
            w.u8(1);
            w.u32(alpha_milli);
        }
        crate::tensor::synth::Profile::Clustered { block, blocks } => {
            w.u8(2);
            w.usize(block);
            w.usize(blocks);
        }
    }
    w.usize(spec.rank);
    w.into_bytes()
}

/// Write one response frame under the connection's write lock (frames
/// from concurrently completing jobs must not interleave).
fn send(writer: &Mutex<TcpStream>, resp: &Response) -> io::Result<()> {
    let body = resp.encode();
    let mut s = writer.lock().unwrap();
    write_frame(&mut *s, &body)?;
    s.flush()
}

fn uerr(msg: impl std::fmt::Display) -> Error {
    Error::msg(msg).classify(ErrorClass::Usage)
}

impl Server {
    /// Bind the service.  `addr` is a `host:port` string; port 0 picks
    /// a free port (read it back via [`Server::local_addr`]).
    ///
    /// Binding also installs the process-wide parallelism cap
    /// ([`set_parallelism_cap`]): each of the pool's `workers` jobs
    /// fans its candidate batches out over at most
    /// `host_threads / workers` threads, so a full pool saturates the
    /// host without oversubscribing it.
    pub fn bind(addr: &str, cfg: ServeConfig) -> io::Result<Server> {
        let workers = cfg.workers.max(1);
        let host = std::thread::available_parallelism().map_or(1, |n| n.get());
        set_parallelism_cap(Some((host / workers).max(1)));
        let store = match &cfg.spill {
            Some(dir) => MemoStore::with_spill(dir.clone()),
            None => MemoStore::new(),
        };
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let state = Arc::new(ServerState {
            pool: Pool::new(workers),
            cfg: ServeConfig { workers, ..cfg },
            addr: local,
            store,
            tensors: Mutex::new(HashMap::new()),
            tenants: Mutex::new(HashMap::new()),
            jobs_done: AtomicU64::new(0),
            jobs_failed: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        });
        Ok(Server { listener, state })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.addr
    }

    /// Accept connections until shutdown, then drain the job queue.
    ///
    /// An injected `serve.accept` fault (or a transient accept error)
    /// is logged and the loop continues — a flaky peer must not take
    /// the service down.
    pub fn run(self) -> io::Result<()> {
        println!(
            "serve: listening on {} ({} workers, {} sim threads each{})",
            self.state.addr,
            self.state.cfg.workers,
            effective_parallelism(),
            match self.state.cfg.tenant_budget {
                Some(b) => format!(", tenant budget {b} jobs"),
                None => String::new(),
            }
        );
        loop {
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            if let Err(e) = fault::check_io(fault::SERVE_ACCEPT) {
                eprintln!("warning: serve: accept failed: {e}");
                continue;
            }
            let stream = match self.listener.accept() {
                Ok((s, _peer)) => s,
                Err(e) => {
                    eprintln!("warning: serve: accept failed: {e}");
                    continue;
                }
            };
            if self.state.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let state = Arc::clone(&self.state);
            let spawned = std::thread::Builder::new()
                .name("ptmc-serve-conn".to_string())
                .spawn(move || handle_conn(stream, state));
            if let Err(e) = spawned {
                eprintln!("warning: serve: could not spawn connection handler: {e}");
            }
        }
        // Drain: every queued job completes (and its verdicts land in
        // the memo/spill) before the process exits.
        self.state.pool.wait_idle();
        println!(
            "serve: shut down ({} jobs done, {} failed, memo {} entries, hits={} misses={})",
            self.state.jobs_done.load(Ordering::Relaxed),
            self.state.jobs_failed.load(Ordering::Relaxed),
            self.state.store.entries(),
            self.state.store.hits(),
            self.state.store.misses(),
        );
        Ok(())
    }
}

/// Map a framing failure to the protocol's typed error taxonomy:
/// desynced or oversized frames are parse errors, genuine transport
/// failures are IO.
fn frame_error_class(e: &io::Error) -> ErrorClass {
    match e.kind() {
        io::ErrorKind::InvalidData | io::ErrorKind::UnexpectedEof => ErrorClass::Parse,
        _ => ErrorClass::Io,
    }
}

/// One connection: read frames, answer Stats/Shutdown inline, queue
/// Submits on the pool.  Responses to queued jobs are written by the
/// pool workers through the shared write half, in completion order —
/// clients match on [`JobSpec::id`].
fn handle_conn(stream: TcpStream, state: Arc<ServerState>) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            eprintln!("warning: serve: connection setup failed: {e}");
            return;
        }
    };
    let mut reader = io::BufReader::new(stream);
    loop {
        // An injected frame fault models the peer dropping mid-stream:
        // close this connection only.  Jobs already queued keep
        // running and their verdicts stay in the shared memo.
        if let Err(e) = fault::check_io(fault::SERVE_FRAME) {
            eprintln!("warning: serve: connection dropped: {e}");
            return;
        }
        let body = match read_frame(&mut reader, proto::MAX_FRAME) {
            Ok(Some(b)) => b,
            Ok(None) => return, // clean EOF
            Err(e) => {
                // The stream is desynced or dead; best-effort typed
                // error, then close.
                let _ = send(
                    &writer,
                    &Response::Error {
                        id: 0,
                        class: frame_error_class(&e),
                        msg: format!("frame error: {e}"),
                    },
                );
                return;
            }
        };
        let req = match Request::decode(&body) {
            Ok(r) => r,
            Err(e) => {
                let _ = send(
                    &writer,
                    &Response::Error {
                        id: 0,
                        class: e.class(),
                        msg: e.to_string(),
                    },
                );
                return;
            }
        };
        match req {
            Request::Stats => {
                let st = ServerStats {
                    jobs_done: state.jobs_done.load(Ordering::Relaxed),
                    jobs_failed: state.jobs_failed.load(Ordering::Relaxed),
                    memo_entries: state.store.entries() as u64,
                    memo_hits: state.store.hits(),
                    memo_misses: state.store.misses(),
                    workers: state.cfg.workers as u64,
                };
                if send(&writer, &Response::Stats(st)).is_err() {
                    return;
                }
            }
            Request::Shutdown => {
                state.shutdown.store(true, Ordering::SeqCst);
                // Finish everything in flight before acknowledging, so
                // Bye means "quiesced".
                state.pool.wait_idle();
                let _ = send(&writer, &Response::Bye);
                // Wake the accept loop so it observes the flag.
                let _ = TcpStream::connect(state.addr);
                return;
            }
            Request::Submit(spec) => {
                if let Err(e) = admit(&state, &spec) {
                    state.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    let resp = Response::Error {
                        id: spec.id,
                        class: e.class(),
                        msg: e.to_string(),
                    };
                    if send(&writer, &resp).is_err() {
                        return;
                    }
                    continue;
                }
                let st = Arc::clone(&state);
                let wr = Arc::clone(&writer);
                if !state.pool.spawn(move || run_job(st, wr, spec)) {
                    let _ = send(
                        &writer,
                        &Response::Error {
                            id: 0,
                            class: ErrorClass::Io,
                            msg: "server is shutting down".to_string(),
                        },
                    );
                    return;
                }
            }
        }
    }
}

/// Admission control: spec sanity plus the tenant budget.  Runs on the
/// connection thread so rejections answer immediately and never
/// consume a pool slot.
fn admit(state: &ServerState, spec: &JobSpec) -> Result<(), Error> {
    if spec.tenant.is_empty() {
        return Err(uerr("job names no tenant"));
    }
    if spec.dims.iter().any(|&d| d < 2) {
        return Err(uerr(format!("implausible mode lengths {:?}", spec.dims)));
    }
    if spec.nnz == 0 || spec.nnz > MAX_NNZ {
        return Err(uerr(format!("nnz {} out of range 1..={MAX_NNZ}", spec.nnz)));
    }
    // The generator de-duplicates draws; a target above half the cell
    // count would thrash (or never terminate at == cell count).
    let cells = spec.dims.iter().try_fold(1usize, |a, &d| a.checked_mul(d));
    if let Some(cells) = cells {
        if spec.nnz > cells / 2 {
            return Err(uerr(format!(
                "nnz {} exceeds half the {} cells of {:?}",
                spec.nnz, cells, spec.dims
            )));
        }
    }
    if spec.rank == 0 || spec.rank > 512 {
        return Err(uerr(format!("rank {} out of range 1..=512", spec.rank)));
    }
    if let Some(budget) = state.cfg.tenant_budget {
        let mut tenants = state.tenants.lock().unwrap();
        let used = tenants.entry(spec.tenant.clone()).or_insert(0);
        if *used >= budget {
            return Err(Error::msg(format!(
                "tenant {:?} exhausted its budget of {budget} jobs",
                spec.tenant
            ))
            .classify(ErrorClass::Budget));
        }
        *used += 1;
    }
    Ok(())
}

/// Get-or-build the resident workload for `spec`.  Built outside the
/// registry lock so a large cold tensor doesn't stall other tenants;
/// on a concurrent first-submission race the first insert wins and the
/// duplicate build is dropped.
fn tensor_entry(state: &ServerState, spec: &JobSpec) -> Arc<TensorEntry> {
    let key = tensor_key(spec);
    if let Some(e) = state.tensors.lock().unwrap().get(&key) {
        return Arc::clone(e);
    }
    let cfg = SynthConfig {
        dims: spec.dims.clone(),
        nnz: spec.nnz,
        profile: spec.profile,
        seed: spec.seed,
    };
    let tensor = generate(&cfg);
    let factors: Vec<Mat> = tensor
        .dims()
        .iter()
        .map(|&d| Mat::randn(d, spec.rank, 3))
        .collect();
    let entry = Arc::new(TensorEntry {
        fp: tensor_fingerprint(&tensor),
        profile: TensorProfile::measure(&tensor),
        factors,
        tensor,
        sim: Arc::new(SimMemo::default()),
    });
    let mut reg = state.tensors.lock().unwrap();
    Arc::clone(reg.entry(key).or_insert(entry))
}

fn wire_point(p: &Point) -> WirePoint {
    WirePoint {
        cfg_enc: crate::util::encode_config(&p.cfg),
        cycles_bits: p.cycles.to_bits(),
        bram36: p.bram36 as u64,
        uram: p.uram as u64,
    }
}

/// Execute one admitted job to an [`Exploration`], scoring through a
/// fresh [`crate::dse::MemoView`] of the job's context.
fn execute(state: &ServerState, spec: &JobSpec) -> Result<(Exploration, u64, u64), Error> {
    let entry = tensor_entry(state, spec);
    let dev = state.cfg.device;
    // The same identity the CLI warm cache uses (workers = 0: the
    // service's pool width is a resource decision, not part of the
    // scoring context), so a served job and an `explore --warm-cache`
    // run of the same workload share one spill file.
    let ctx = KeyBuilder::new(entry.fp)
        .evaluator(spec.evaluator.label())
        .engine(spec.engine)
        .rank(spec.rank)
        .workers(0)
        .device(&dev)
        .factors(&entry.factors)
        .finish();
    let view = state.store.view(ctx);
    let base = ControllerConfig::default_for(entry.tensor.record_bytes());
    let est = fpga::estimate(&base, &dev);
    if !est.fits || !dev.supports(&base.mem) {
        return Err(uerr(format!(
            "base configuration does not fit {} ({} BRAM36 + {} URAM)",
            dev.name, est.bram36_used, est.uram_used
        )));
    }
    let builder = crate::dse::EvaluatorBuilder::new()
        .engine(spec.engine)
        .rank(spec.rank)
        .score_cache(Some(Arc::clone(&view) as Arc<dyn ScoreCache>))
        .sim_memo(Some(Arc::clone(&entry.sim)));
    let eval = match spec.evaluator {
        EvalKind::Pms => builder.pms(&entry.profile),
        EvalKind::Sim => builder.cycle_sim(&entry.tensor, &entry.factors),
    };
    let grids = match spec.grid {
        GridPreset::Default => Grids::default(),
        GridPreset::Smoke => Grids::smoke(),
    };
    let opts = SearchOptions {
        strategy: spec.strategy,
        top_k: spec.top_k.max(1),
        // Never resume: every response must be byte-identical to a
        // solo cold run — the memo accelerates, it must not steer.
        resume: false,
        checkpoint_every: 0,
    };
    let ex = explore_with(&base, &grids, &dev, &eval, &opts);
    Ok((ex, view.hits(), view.misses()))
}

/// Pool-side job body: run the exploration, then write the response
/// through the connection's shared write half.  A panic inside the
/// search becomes a typed Internal error response — never a dead
/// worker or a lost reply.
fn run_job(state: Arc<ServerState>, writer: Arc<Mutex<TcpStream>>, spec: JobSpec) {
    let outcome = catch_unwind(AssertUnwindSafe(|| execute(&state, &spec)));
    let resp = match outcome {
        Ok(Ok((ex, hits, misses))) => {
            state.jobs_done.fetch_add(1, Ordering::Relaxed);
            Response::Result(JobResult {
                id: spec.id,
                best: wire_point(&ex.best),
                pareto: ex.pareto.iter().map(wire_point).collect(),
                visited: ex.visited.len() as u64,
                rejected: ex.rejected as u64,
                memo_hits: hits,
                memo_misses: misses,
            })
        }
        Ok(Err(e)) => {
            state.jobs_failed.fetch_add(1, Ordering::Relaxed);
            Response::Error {
                id: spec.id,
                class: e.class(),
                msg: e.to_string(),
            }
        }
        Err(panic) => {
            state.jobs_failed.fetch_add(1, Ordering::Relaxed);
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "job panicked".to_string());
            Response::Error {
                id: spec.id,
                class: ErrorClass::Internal,
                msg: format!("job panicked: {msg}"),
            }
        }
    };
    // A dead connection is the client's problem; the verdicts this job
    // computed are already in the memo for the next query.
    let _ = send(&writer, &resp);
}
