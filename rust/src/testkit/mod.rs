//! Test substrate (S15): deterministic PRNG and a minimal property-based
//! testing harness.
//!
//! The offline build environment has no `rand`/`proptest`, so this module
//! supplies the pieces the rest of the crate and its tests need: a
//! splitmix/xoshiro-style generator with the distributions we use
//! (uniform ints, floats, Zipf) and a `forall`-style check runner with
//! seed reporting and simple shrinking of integer cases.

/// xoshiro256** PRNG seeded via splitmix64.  Deterministic, fast, and
/// good enough statistical quality for workload generation and tests.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (splitmix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Lemire's multiply-shift rejection method.
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "Rng::range empty ({lo}..{hi})");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `alpha` via
    /// inverse-CDF on a cached harmonic table is overkill here; we use
    /// rejection-free approximate inversion (Devroye) — adequate for
    /// generating the skewed fiber-length distributions of real tensors.
    pub fn zipf(&mut self, n: u64, alpha: f64) -> u64 {
        debug_assert!(n > 0 && alpha > 0.0);
        if (alpha - 1.0).abs() < 1e-9 {
            // alpha == 1: inverse CDF of 1/x on [1, n+1).
            let u = self.f64();
            let x = ((n as f64 + 1.0).ln() * u).exp();
            return (x as u64).min(n).saturating_sub(1);
        }
        let u = self.f64();
        let one_m = 1.0 - alpha;
        let x = ((((n as f64 + 1.0).powf(one_m) - 1.0) * u) + 1.0).powf(1.0 / one_m);
        (x as u64).clamp(1, n) - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct PropFailure {
    /// Seed that produced the failing case (re-run with this to reproduce).
    pub seed: u64,
    /// Case index within the run.
    pub case: usize,
    /// Panic / assertion message.
    pub message: String,
}

/// Minimal `forall` runner: executes `cases` random cases of `prop`,
/// each receiving a fresh deterministic [`Rng`].  On failure, reports the
/// first failing seed so the case is reproducible.  Panics (like a test
/// assertion) with the failure report.
///
/// ```no_run
/// // (no_run: doctest binaries lack the xla rpath in this image)
/// ptmc::testkit::forall("sum_commutes", 64, |rng| {
///     let a = rng.below(1000) as i64;
///     let b = rng.below(1000) as i64;
///     assert_eq!(a + b, b + a);
/// });
/// ```
pub fn forall(name: &str, cases: usize, prop: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
    if let Some(fail) = check(name, cases, &prop) {
        panic!(
            "property `{name}` failed at case {} (seed {:#x}): {}",
            fail.case, fail.seed, fail.message
        );
    }
}

/// Non-panicking core of [`forall`]; returns the first failure if any.
pub fn check(
    name: &str,
    cases: usize,
    prop: &(impl Fn(&mut Rng) + std::panic::RefUnwindSafe),
) -> Option<PropFailure> {
    // Derive per-case seeds from the property name so adding properties
    // doesn't reshuffle unrelated cases.
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
        });
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(err) = result {
            let message = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            return Some(PropFailure {
                seed,
                case,
                message,
            });
        }
    }
    None
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "allclose failed at [{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut rng = Rng::new(11);
        let n = 1000;
        let mut counts = vec![0u32; n as usize];
        for _ in 0..20_000 {
            counts[rng.zipf(n, 1.2) as usize] += 1;
        }
        // Head must dominate the tail for a skewed distribution.
        let head: u32 = counts[..10].iter().sum();
        let tail: u32 = counts[500..510].iter().sum();
        assert!(head > 20 * tail.max(1), "head {head} tail {tail}");
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut rng = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 32, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn check_reports_failure_with_seed() {
        let fail = check("always_fails", 4, &|_rng: &mut Rng| {
            panic!("boom");
        });
        let fail = fail.expect("must fail");
        assert_eq!(fail.case, 0);
        assert!(fail.message.contains("boom"));
    }

    #[test]
    fn allclose_accepts_and_rejects() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[2.0], 1e-5, 1e-6);
        });
        assert!(r.is_err());
    }
}
