//! Coordinator metrics: per-phase wall-clock accounting for the PJRT
//! dispatch path (gather / host->device / execute / accumulate), plus
//! block-throughput summaries for the serving-style logs.

use std::time::Duration;

/// Accumulated timings of one coordinator run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub blocks: u64,
    pub nnz: u64,
    pub padded_lanes: u64,
    pub gather: Duration,
    pub execute: Duration,
    pub accumulate: Duration,
    /// Remap passes performed between modes.
    pub remaps: u64,
    pub remap: Duration,
}

impl Metrics {
    pub fn total(&self) -> Duration {
        self.gather + self.execute + self.accumulate + self.remap
    }

    /// Non-zeros processed per second of end-to-end time.
    pub fn nnz_per_sec(&self) -> f64 {
        let s = self.total().as_secs_f64();
        if s == 0.0 {
            0.0
        } else {
            self.nnz as f64 / s
        }
    }

    /// Fraction of kernel lanes wasted on padding.
    pub fn padding_ratio(&self) -> f64 {
        let lanes = self.nnz + self.padded_lanes;
        if lanes == 0 {
            0.0
        } else {
            self.padded_lanes as f64 / lanes as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "blocks={} nnz={} pad={:.1}% gather={:?} exec={:?} accum={:?} remap={:?} ({:.0} nnz/s)",
            self.blocks,
            self.nnz,
            100.0 * self.padding_ratio(),
            self.gather,
            self.execute,
            self.accumulate,
            self.remap,
            self.nnz_per_sec(),
        )
    }

    /// Merge another run's metrics into this one.
    pub fn merge(&mut self, other: &Metrics) {
        self.blocks += other.blocks;
        self.nnz += other.nnz;
        self.padded_lanes += other.padded_lanes;
        self.gather += other.gather;
        self.execute += other.execute;
        self.accumulate += other.accumulate;
        self.remaps += other.remaps;
        self.remap += other.remap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_ratio_and_throughput() {
        let m = Metrics {
            blocks: 4,
            nnz: 900,
            padded_lanes: 100,
            execute: Duration::from_millis(100),
            ..Default::default()
        };
        assert!((m.padding_ratio() - 0.1).abs() < 1e-12);
        assert!((m.nnz_per_sec() - 9000.0).abs() < 1.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics {
            blocks: 1,
            nnz: 10,
            ..Default::default()
        };
        let b = Metrics {
            blocks: 2,
            nnz: 20,
            remaps: 1,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.blocks, 3);
        assert_eq!(a.nnz, 30);
        assert_eq!(a.remaps, 1);
    }

    #[test]
    fn zero_division_is_safe() {
        let m = Metrics::default();
        assert_eq!(m.nnz_per_sec(), 0.0);
        assert_eq!(m.padding_ratio(), 0.0);
    }
}
