//! Block packing: convert a mode-sorted COO tensor into the fixed-shape
//! blocks the AOT MTTKRP artifacts consume.
//!
//! This is the coordinator-side mirror of the paper's remap guarantee:
//! because all non-zeros with the same output coordinate are consecutive,
//! a greedy scan packs up to `blk` non-zeros covering up to `s` distinct
//! output coordinates per block, assigns block-local output *slots*, and
//! pads the tail block to the artifact's fixed shape (padded lanes carry
//! `val = 0`, so they contribute nothing).

use crate::tensor::{Coord, SortOrder, SparseTensor};

/// One fixed-shape MTTKRP block.
#[derive(Debug, Clone)]
pub struct Block {
    /// Half-open nnz range [start, start+len) of real elements.
    pub start: usize,
    pub len: usize,
    /// Block-local output slot of each lane (padded lanes -> slot 0).
    pub seg_ids: Vec<i32>,
    /// Output coordinate of each used slot (len <= s).
    pub slots: Vec<Coord>,
}

/// Packing parameters, matched to an artifact's (blk, s).
#[derive(Debug, Clone, Copy)]
pub struct PackConfig {
    pub blk: usize,
    pub s: usize,
}

/// Pack a tensor sorted by `mode` into blocks.
pub fn pack(t: &SparseTensor, mode: usize, cfg: PackConfig) -> Vec<Block> {
    assert_eq!(
        t.order(),
        SortOrder::ByMode(mode),
        "pack requires the tensor sorted by the output mode"
    );
    assert!(cfg.blk >= 1 && cfg.s >= 1);
    let col = t.mode_col(mode);
    let nnz = col.len();
    let mut blocks = Vec::new();
    let mut start = 0usize;
    while start < nnz {
        let mut end = start;
        let mut slots: Vec<Coord> = Vec::with_capacity(cfg.s);
        let mut seg_ids: Vec<i32> = Vec::with_capacity(cfg.blk);
        while end < nnz && end - start < cfg.blk {
            let c = col[end];
            match slots.last() {
                Some(&last) if last == c => {}
                _ => {
                    if slots.len() == cfg.s {
                        break;
                    }
                    slots.push(c);
                }
            }
            seg_ids.push(slots.len() as i32 - 1);
            end += 1;
        }
        let len = end - start;
        seg_ids.resize(cfg.blk, 0); // padded lanes
        blocks.push(Block {
            start,
            len,
            seg_ids,
            slots,
        });
        start = end;
    }
    blocks
}

/// Gather the per-block dense operands for the artifacts: padded `vals`
/// and one flat row-major `[blk, r]` buffer per input mode.
pub struct GatheredBlock {
    pub vals: Vec<f32>,
    /// One `[blk * r]` buffer per non-output mode, in mode order.
    pub rows: Vec<Vec<f32>>,
}

/// Gather operands for `block` against the current factor matrices.
pub fn gather(
    t: &SparseTensor,
    factors: &[crate::cpd::linalg::Mat],
    mode: usize,
    block: &Block,
    blk: usize,
) -> GatheredBlock {
    let r = factors[0].cols();
    let mut g = GatheredBlock {
        vals: vec![0.0f32; blk],
        rows: vec![vec![0.0f32; blk * r]; t.n_modes() - 1],
    };
    gather_into(t, factors, mode, block, blk, &mut g);
    g
}

/// [`gather`] into preallocated buffers (the §Perf hot-loop variant: no
/// per-block allocation).  `out` must be shaped for (blk, r, n_modes-1).
pub fn gather_into(
    t: &SparseTensor,
    factors: &[crate::cpd::linalg::Mat],
    mode: usize,
    block: &Block,
    blk: usize,
    out: &mut GatheredBlock,
) {
    let r = factors[0].cols();
    debug_assert_eq!(out.vals.len(), blk);
    out.vals[..block.len].copy_from_slice(&t.values()[block.start..block.start + block.len]);
    out.vals[block.len..].fill(0.0);

    let mut ri = 0usize;
    for m in 0..t.n_modes() {
        if m == mode {
            continue;
        }
        let col = t.mode_col(m);
        let buf = &mut out.rows[ri];
        debug_assert_eq!(buf.len(), blk * r);
        for k in 0..block.len {
            let row = factors[m].row(col[block.start + k] as usize);
            buf[k * r..(k + 1) * r].copy_from_slice(row);
        }
        // Padded lanes carry val=0, so stale row data is harmless; zero
        // anyway to keep the operand deterministic.
        buf[block.len * r..].fill(0.0);
        ri += 1;
    }
}

/// Build the row-major `[s, blk]` one-hot scatter matrix for a block.
pub fn onehot(block: &Block, blk: usize, s: usize) -> Vec<f32> {
    let mut m = vec![0.0f32; s * blk];
    onehot_into(block, blk, s, &mut m);
    m
}

/// [`onehot`] into a preallocated `[s * blk]` buffer (cleared first).
pub fn onehot_into(block: &Block, blk: usize, s: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), s * blk);
    out.fill(0.0);
    // Padded lanes have val=0; point them at slot 0 harmlessly (matches
    // seg_ids). Only real lanes need their slot bit set for correctness,
    // but setting all keeps the matrix consistent with seg_ids.
    for (lane, &slot) in block.seg_ids.iter().enumerate() {
        out[slot as usize * blk + lane] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpd::linalg::Mat;
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::testkit::forall;

    fn sorted_tensor(seed: u64, nnz: usize) -> SparseTensor {
        let mut t = generate(&SynthConfig {
            dims: vec![50, 40, 30],
            nnz,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed,
        });
        t.sort_by_mode(0);
        t
    }

    #[test]
    fn blocks_cover_all_nnz_in_order() {
        let t = sorted_tensor(61, 1_000);
        let blocks = pack(&t, 0, PackConfig { blk: 128, s: 32 });
        let mut cursor = 0;
        for b in &blocks {
            assert_eq!(b.start, cursor);
            assert!(b.len >= 1 && b.len <= 128);
            assert!(b.slots.len() <= 32);
            cursor += b.len;
        }
        assert_eq!(cursor, 1_000);
    }

    #[test]
    fn seg_ids_map_lanes_to_correct_coords() {
        forall("pack_segids_consistent", 16, |rng| {
            let t = sorted_tensor(rng.next_u64(), rng.range(1, 800));
            let cfg = PackConfig {
                blk: 1 << rng.range(4, 9),
                s: 1 << rng.range(2, 7),
            };
            let col = t.mode_col(0);
            for b in pack(&t, 0, cfg) {
                for k in 0..b.len {
                    let slot = b.seg_ids[k] as usize;
                    assert_eq!(
                        b.slots[slot], col[b.start + k],
                        "lane {k} of block at {} maps to wrong coord",
                        b.start
                    );
                }
                // Padded lanes are slot 0.
                for k in b.len..cfg.blk {
                    assert_eq!(b.seg_ids[k], 0);
                }
            }
        });
    }

    #[test]
    fn a_fiber_longer_than_blk_spans_blocks() {
        // All nnz share output coord 0 -> blocks split a single fiber.
        let entries: Vec<(Vec<Coord>, f32)> = (0..300)
            .map(|i| (vec![0, (i % 40) as Coord, (i % 30) as Coord], 1.0))
            .collect();
        // Dedup may drop duplicates; build unique second coords instead.
        let entries: Vec<(Vec<Coord>, f32)> = entries
            .into_iter()
            .enumerate()
            .map(|(i, (mut c, v))| {
                c[1] = (i % 40) as Coord;
                c[2] = (i / 40) as Coord;
                (c, v)
            })
            .collect();
        let mut t = SparseTensor::new(vec![4, 40, 30], &entries);
        t.sort_by_mode(0);
        let blocks = pack(&t, 0, PackConfig { blk: 128, s: 16 });
        assert_eq!(blocks.len(), 3); // 300 = 128 + 128 + 44
        for b in &blocks {
            assert_eq!(b.slots, vec![0]);
        }
    }

    #[test]
    fn slot_limit_splits_blocks_before_blk() {
        // Every nnz has a distinct output coord -> s limits block size.
        let entries: Vec<(Vec<Coord>, f32)> =
            (0..100).map(|i| (vec![i as Coord, 0, 0], 1.0)).collect();
        let mut t = SparseTensor::new(vec![100, 1, 1], &entries);
        t.sort_by_mode(0);
        let blocks = pack(&t, 0, PackConfig { blk: 128, s: 8 });
        assert_eq!(blocks.len(), 13); // ceil(100/8)
        assert!(blocks.iter().all(|b| b.len <= 8));
    }

    #[test]
    fn gather_and_onehot_shapes() {
        let t = sorted_tensor(62, 500);
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 8, 3)).collect();
        let cfg = PackConfig { blk: 128, s: 32 };
        let blocks = pack(&t, 0, cfg);
        let g = gather(&t, &factors, 0, &blocks[0], cfg.blk);
        assert_eq!(g.vals.len(), 128);
        assert_eq!(g.rows.len(), 2);
        assert_eq!(g.rows[0].len(), 128 * 8);
        let oh = onehot(&blocks[0], cfg.blk, cfg.s);
        assert_eq!(oh.len(), 32 * 128);
        // Each lane has exactly one hot slot.
        for lane in 0..cfg.blk {
            let hot: f32 = (0..cfg.s).map(|s| oh[s * cfg.blk + lane]).sum();
            assert_eq!(hot, 1.0);
        }
    }

    #[test]
    fn padded_vals_are_zero() {
        let t = sorted_tensor(63, 100);
        let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, 4, 3)).collect();
        let cfg = PackConfig { blk: 256, s: 64 };
        let blocks = pack(&t, 0, cfg);
        let last = blocks.last().unwrap();
        let g = gather(&t, &factors, 0, last, cfg.blk);
        for k in last.len..cfg.blk {
            assert_eq!(g.vals[k], 0.0);
        }
    }
}
