//! L3 coordinator (S13): the leader that drives CP-ALS with the MTTKRP
//! hot path offloaded to the AOT-compiled PJRT executables.
//!
//! This is the runtime mirror of the paper's division of labour: the
//! *memory controller* (here: remap + block packing + row gather) feeds
//! dense, fixed-shape operands to a *dumb, fast compute unit* (here: the
//! Pallas-derived MTTKRP block kernel on PJRT instead of FPGA MAC
//! pipelines).  Python is never touched: artifacts are loaded from disk.

pub mod block;
pub mod metrics;

use std::time::Instant;

use crate::cpd::linalg::Mat;
use crate::cpd::MttkrpBackend;
use crate::err;
use crate::error::Result;
use crate::runtime::Runtime;
use crate::tensor::{remap, SortOrder, SparseTensor};

pub use block::{gather, gather_into, onehot, onehot_into, pack, Block, GatheredBlock, PackConfig};
pub use metrics::Metrics;

/// Segment encoding variant to use (DESIGN.md D2 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegMode {
    /// One-hot scatter matrix built host-side; kernel is a pure matmul.
    Onehot,
    /// One-hot matmul form lowered without Pallas (pure jnp): isolates
    /// interpret-mode overhead on CPU backends (§Perf L1).
    OnehotJnp,
    /// int32 segment ids; the one-hot materializes inside the graph.
    SegIds,
    /// int32 segment ids through the jnp segment-sum reference graph.
    RefSeg,
}

impl SegMode {
    fn manifest_key(self) -> &'static str {
        match self {
            SegMode::Onehot => "onehot",
            SegMode::OnehotJnp => "onehot_jnp",
            SegMode::SegIds => "segids",
            SegMode::RefSeg => "refseg",
        }
    }
}

/// The PJRT-offloading coordinator.  Implements [`MttkrpBackend`] so
/// [`crate::cpd::cp_als`] can run unchanged on top of it.
pub struct PjrtCoordinator {
    rt: Runtime,
    seg_mode: SegMode,
    metrics: Metrics,
}

impl PjrtCoordinator {
    pub fn new(rt: Runtime, seg_mode: SegMode) -> Self {
        PjrtCoordinator {
            rt,
            seg_mode,
            metrics: Metrics::default(),
        }
    }

    /// Open the default artifacts directory with the one-hot kernel.
    pub fn open_default() -> Result<Self> {
        Ok(Self::new(Runtime::open_default()?, SegMode::Onehot))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn reset_metrics(&mut self) {
        self.metrics = Metrics::default();
    }

    /// Compute one mode's MTTKRP via blocked PJRT dispatch.  The tensor
    /// is remapped into the mode's direction if needed (paper Alg. 5).
    pub fn mttkrp_pjrt(
        &mut self,
        t: &mut SparseTensor,
        factors: &[Mat],
        mode: usize,
    ) -> Result<Mat> {
        let n_modes = t.n_modes();
        let r = factors[0].cols();
        let seg = self.seg_mode;

        // Remap into output direction (the coordinator plays the Tensor
        // Remapper's role on the host data structure).
        if t.order() != SortOrder::ByMode(mode) {
            let t0 = Instant::now();
            remap::remap(t, mode, usize::MAX);
            self.metrics.remap += t0.elapsed();
            self.metrics.remaps += 1;
        }

        let meta = self
            .rt
            .find_mttkrp(n_modes, r, seg.manifest_key())
            .ok_or_else(|| {
                err!(
                    "no mttkrp artifact for modes={n_modes} r={r} seg={} — \
                     add the variant to python/compile/aot.py and re-run `make artifacts`",
                    seg.manifest_key()
                )
            })?;
        let name = meta.name.clone();
        let (blk, s) = (
            meta.int("blk").ok_or_else(|| err!("blk missing"))?,
            meta.int("s").ok_or_else(|| err!("s missing"))?,
        );

        let blocks = pack(t, mode, PackConfig { blk, s });
        let mut out = Mat::zeros(t.dims()[mode], r);

        // §Perf: scratch buffers reused across blocks (no per-block
        // allocation in the hot loop).
        let mut g = block::GatheredBlock {
            vals: vec![0.0f32; blk],
            rows: vec![vec![0.0f32; blk * r]; n_modes - 1],
        };
        let mut oh = vec![0.0f32; s * blk];

        for b in &blocks {
            let t0 = Instant::now();
            block::gather_into(t, factors, mode, b, blk, &mut g);
            let row_refs: Vec<&[f32]> = g.rows.iter().map(|v| v.as_slice()).collect();
            self.metrics.gather += t0.elapsed();

            let t1 = Instant::now();
            let partial = match seg {
                SegMode::Onehot | SegMode::OnehotJnp => {
                    block::onehot_into(b, blk, s, &mut oh);
                    self.rt
                        .mttkrp_block_onehot(&name, &oh, &g.vals, &row_refs)?
                }
                SegMode::SegIds | SegMode::RefSeg => {
                    self.rt
                        .mttkrp_block_segids(&name, &b.seg_ids, &g.vals, &row_refs)?
                }
            };
            self.metrics.execute += t1.elapsed();

            let t2 = Instant::now();
            // Accumulate used slots into the output rows (a fiber can
            // span blocks, so += not =).
            for (slot, &coord) in b.slots.iter().enumerate() {
                let dst = out.row_mut(coord as usize);
                let src = &partial[slot * r..(slot + 1) * r];
                for (d, &v) in dst.iter_mut().zip(src) {
                    *d += v;
                }
            }
            self.metrics.accumulate += t2.elapsed();

            self.metrics.blocks += 1;
            self.metrics.nnz += b.len as u64;
            self.metrics.padded_lanes += (blk - b.len) as u64;
        }
        Ok(out)
    }
}

impl MttkrpBackend for PjrtCoordinator {
    fn mttkrp(&mut self, t: &mut SparseTensor, factors: &[Mat], mode: usize) -> Mat {
        self.mttkrp_pjrt(t, factors, mode)
            .expect("PJRT MTTKRP failed")
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mttkrp::oracle;
    use crate::tensor::synth::{generate, Profile, SynthConfig};
    use crate::testkit::assert_allclose;
    use std::path::Path;

    fn artifacts_available() -> bool {
        Path::new("artifacts/manifest.txt").exists()
    }

    fn setup(seed: u64, r: usize) -> (SparseTensor, Vec<Mat>) {
        let t = generate(&SynthConfig {
            dims: vec![80, 60, 40],
            nnz: 3_000,
            profile: Profile::Zipf { alpha_milli: 1200 },
            seed,
        });
        let factors = t
            .dims()
            .iter()
            .enumerate()
            .map(|(m, &d)| Mat::randn(d, r, seed + m as u64))
            .collect();
        (t, factors)
    }

    #[test]
    fn pjrt_mttkrp_matches_oracle() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
        let (mut t, factors) = setup(71, 16);
        let mut c = PjrtCoordinator::open_default().unwrap();
        for mode in 0..3 {
            let want = oracle::mttkrp(&t, &factors, mode);
            let got = c.mttkrp_pjrt(&mut t, &factors, mode).unwrap();
            assert_allclose(got.data(), want.data(), 1e-4, 1e-4);
        }
        assert!(c.metrics().blocks > 0);
        assert!(c.metrics().remaps >= 2);
    }

    #[test]
    fn segids_variant_matches_oracle() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
        let (mut t, factors) = setup(72, 16);
        let rt = Runtime::open_default().unwrap();
        let mut c = PjrtCoordinator::new(rt, SegMode::SegIds);
        let want = oracle::mttkrp(&t, &factors, 0);
        let got = c.mttkrp_pjrt(&mut t, &factors, 0).unwrap();
        assert_allclose(got.data(), want.data(), 1e-4, 1e-4);
    }

    #[test]
    fn missing_variant_is_a_clean_error() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
        let (mut t, factors) = setup(73, 7); // r=7 has no artifact
        let mut c = PjrtCoordinator::open_default().unwrap();
        let err = c.mttkrp_pjrt(&mut t, &factors, 0).unwrap_err();
        assert!(err.to_string().contains("no mttkrp artifact"), "{err}");
    }

    #[test]
    fn cp_als_runs_on_pjrt_backend() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts` first");
            return;
        }
        use crate::cpd::{cp_als, AlsConfig};
        let (mut t, _) = setup(74, 16);
        let mut c = PjrtCoordinator::open_default().unwrap();
        let cfg = AlsConfig {
            rank: 16,
            max_iters: 3,
            tol: 0.0,
            ..Default::default()
        };
        let model = cp_als(&mut t, &cfg, &mut c);
        assert_eq!(model.fit_history.len(), 3);
        assert!(model.final_fit().is_finite());
    }
}
