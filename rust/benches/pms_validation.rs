//! E7 — validate the analytic PMS against the cycle-level simulator
//! across a configuration grid: per-config relative error and, more
//! importantly for the DSE use-case, *rank agreement* (does the PMS
//! order configurations the same way the simulator does?).

use ptmc::bench::{sized, smoke, Table};
use ptmc::controller::{CacheConfig, ControllerConfig};
use ptmc::cpd::linalg::Mat;
use ptmc::dse::{Evaluator, EvaluatorBuilder};
use ptmc::engine::EngineKind;
use ptmc::fpga::Device;
use ptmc::pms::TensorProfile;
use ptmc::tensor::synth::{generate, Profile, SynthConfig};

fn main() {
    let rank = 16usize;
    let t = generate(&SynthConfig {
        dims: vec![sized(5_000, 500), sized(3_000, 300), sized(2_000, 200)],
        nnz: sized(80_000, 6_000),
        profile: Profile::Zipf { alpha_milli: 1250 },
        seed: 23,
    });
    let factors: Vec<Mat> = t
        .dims()
        .iter()
        .enumerate()
        .map(|(m, &d)| Mat::randn(d, rank, m as u64))
        .collect();
    let profile = TensorProfile::measure(&t);
    let dev = Device::alveo_u250();
    let pms_eval = Evaluator::Pms {
        profile: &profile,
        rank,
    };
    let sim_eval = EvaluatorBuilder::new()
        .engine(EngineKind::Event)
        .cycle_sim(&t, &factors);

    // Grid: cache geometry x pointer budget (the params with the largest
    // time impact).
    let mut tbl = Table::new(&["cache", "pointers", "sim cycles", "pms cycles", "rel err"]);
    let mut pairs: Vec<(f64, f64)> = Vec::new();
    for &num_lines in &[256usize, 1024, 4096, 16384] {
        for &max_pointers in &[1usize << 10, 1 << 14, 1 << 20] {
            let mut cfg = ControllerConfig::default_for(t.record_bytes());
            cfg.cache = CacheConfig {
                line_bytes: 64,
                num_lines,
                assoc: 4,
                hit_latency: 2,
            };
            cfg.remapper.max_pointers = max_pointers;
            let sim = sim_eval.score(&cfg, &dev).expect("fits");
            let pms = pms_eval.score(&cfg, &dev).expect("fits");
            let rel = (pms - sim).abs() / sim;
            pairs.push((sim, pms));
            tbl.row(&[
                format!("{num_lines}x64B"),
                max_pointers.to_string(),
                format!("{sim:.3e}"),
                format!("{pms:.3e}"),
                format!("{:.1}%", 100.0 * rel),
            ]);
        }
    }
    tbl.emit(
        "E7 — PMS estimate vs cycle simulation",
        Some(std::path::Path::new("bench_results/pms_validation.csv")),
    );

    // Aggregate error.
    let rels: Vec<f64> = pairs
        .iter()
        .map(|(s, p)| (p - s).abs() / s)
        .collect();
    let mean = rels.iter().sum::<f64>() / rels.len() as f64;
    let max = rels.iter().cloned().fold(0.0, f64::max);

    // Spearman rank correlation between sim and pms orderings.
    let rank_of = |xs: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
        let mut r = vec![0usize; xs.len()];
        for (pos, &i) in idx.iter().enumerate() {
            r[i] = pos;
        }
        r
    };
    let sims: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let pmss: Vec<f64> = pairs.iter().map(|p| p.1).collect();
    let (ra, rb) = (rank_of(&sims), rank_of(&pmss));
    let n = ra.len() as f64;
    let d2: f64 = ra
        .iter()
        .zip(&rb)
        .map(|(&a, &b)| ((a as f64) - (b as f64)).powi(2))
        .sum();
    let spearman = 1.0 - 6.0 * d2 / (n * (n * n - 1.0));

    println!("mean rel error {:.1}%, max {:.1}%", 100.0 * mean, 100.0 * max);
    println!("Spearman rank correlation (DSE fidelity): {spearman:.3}");
    // Targets: analytic models drift in absolute terms, but the DSE only
    // needs ordering — demand strong rank agreement and sane magnitude.
    if !smoke() {
        assert!(mean < 0.40, "mean error too large: {mean}");
        assert!(spearman > 0.8, "PMS must rank configs like the simulator");
    }
    println!("E7 OK");
}
