//! PR-8 raw-speed bench: the branch-light SoA classification kernel
//! (S28, `ClassifyKernel::Soa`) against its scalar oracle, in
//! classified accesses/second over the default DSE cache grid, on both
//! random cache-class traces and real MTTKRP shard traces; plus the
//! warm-start layer's headline claim — a repeat `explore` query over
//! the same tensor/context replays every verdict from the on-disk
//! cache and must beat the cold search by >= 3x.
//!
//! Emits a `classify_kernel` section into the repo-root
//! `BENCH_dse.json` (preserving the sections the other bench binaries
//! own).  Shortfalls warn by default and only fail under
//! `PTMC_BENCH_ENFORCE=1`, set for acceptance runs on a quiet host.
//! `PTMC_BENCH_SMOKE` shrinks the workloads to CI scale.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use ptmc::bench::{self, sized, smoke, upsert_json_file};
use ptmc::controller::{Access, CacheConfig, ControllerConfig, MemLayout};
use ptmc::cpd::linalg::Mat;
use ptmc::dram::RowPolicy;
use ptmc::dse::{
    explore_with, tensor_fingerprint, EvaluatorBuilder, Grids, KeyBuilder, SearchOptions,
    SearchStrategy, WarmCache,
};
use ptmc::engine::{ClassifyKernel, CompressedTrace, EngineKind, GridClassification};
use ptmc::fpga::Device;
use ptmc::mem::MemTech;
use ptmc::shard::{partition_indices, shard_trace, ShardPlan};
use ptmc::tensor::frostt::TnsBlockReader;
use ptmc::tensor::synth::{generate, Profile, SynthConfig};
use ptmc::testkit::Rng;
use ptmc::util::fault;

/// Every valid cache candidate of the default DSE grid (the same
/// power-of-two-sets filter `dse::explore` applies).
fn default_grid_configs() -> Vec<CacheConfig> {
    let g = Grids::default();
    let mut configs = Vec::new();
    for &line_bytes in &g.cache_line_bytes {
        for &num_lines in &g.cache_num_lines {
            for &assoc in &g.cache_assoc {
                if num_lines % assoc != 0 || !(num_lines / assoc).is_power_of_two() {
                    continue;
                }
                configs.push(CacheConfig {
                    line_bytes,
                    num_lines,
                    assoc,
                    hit_latency: 2,
                });
            }
        }
    }
    configs
}

/// The random cache-class mix the property suite classifies: hot zipf
/// rows, cold unaligned addresses, small/medium working sets, mixed
/// widths with line-straddling accesses, ~25% stores.
fn random_cache_trace(n: usize, seed: u64) -> Vec<Access> {
    let mut rng = Rng::new(seed);
    let mut trace = Vec::with_capacity(n);
    for _ in 0..n {
        let addr = match rng.below(4) {
            0 => rng.zipf(4096, 1.2) * 64,
            1 => rng.below(1 << 22),
            2 => (8 << 20) + rng.below(1 << 10) * 256,
            _ => rng.below(1 << 16) * 64,
        };
        let bytes = match rng.below(4) {
            0 => 16,
            1 => 64,
            2 => 1 + rng.below(300) as usize,
            _ => 4,
        };
        if rng.below(4) == 0 {
            trace.push(Access::CachedStore { addr, bytes });
        } else {
            trace.push(Access::Cached { addr, bytes });
        }
    }
    trace
}

/// A compact search space so the cold/warm explore comparison measures
/// cache replay, not grid size.
fn explore_grids() -> Grids {
    Grids {
        cache_line_bytes: vec![32, 64],
        cache_num_lines: vec![256, 1024],
        cache_assoc: vec![2, 4],
        dma_num: vec![1, 2],
        dma_buffers: vec![2],
        dma_buffer_bytes: vec![4096],
        mem_techs: vec![MemTech::Ddr4],
        dram_channels: vec![1, 2],
        dram_banks: vec![16],
        dram_row_policy: vec![RowPolicy::Open],
        remap_max_pointers: vec![1 << 10, 1 << 18],
    }
}

/// Walk up from the current directory to the repo root (the directory
/// holding ROADMAP.md) so BENCH_dse.json lands in one canonical place
/// regardless of where cargo runs the bench binary.
fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            return std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        }
    }
}

/// Warn by default; fail hard when `PTMC_BENCH_ENFORCE=1` is set.
fn warn_or_enforce(msg: &str) {
    assert!(std::env::var_os("PTMC_BENCH_ENFORCE").is_none(), "{msg}");
    eprintln!("warning: {msg}");
}

fn main() {
    let iters = if smoke() { 2u32 } else { 5 };
    let configs = default_grid_configs();
    let n_cfg = configs.len();

    // 1. Random cache-class trace, scalar vs SoA kernel.
    let n = sized(400_000, 20_000);
    let trace = random_cache_trace(n, 0xC1A551F1);
    let ct = CompressedTrace::compress(&trace);
    let scalar = bench::time(1, iters, || {
        GridClassification::classify_with(&ct, &configs, ClassifyKernel::Scalar)
    });
    let soa = bench::time(1, iters, || {
        GridClassification::classify_with(&ct, &configs, ClassifyKernel::Soa)
    });
    let kernel_accs = (n * n_cfg) as f64;
    let scalar_rate = kernel_accs / scalar.mean.as_secs_f64();
    let soa_rate = kernel_accs / soa.mean.as_secs_f64();
    let soa_speedup = scalar.mean.as_secs_f64() / soa.mean.as_secs_f64();
    println!("random trace: {n} accesses x {n_cfg} configs");
    println!("  scalar {scalar_rate:.3e} acc/s, soa {soa_rate:.3e} acc/s");
    println!("  soa speedup: {soa_speedup:.2}x");

    // 2. Real MTTKRP shard traces (streams + factor-row cache traffic).
    let rank = 16usize;
    let t = generate(&SynthConfig {
        dims: vec![512, 384, 256],
        nnz: sized(150_000, 10_000),
        profile: Profile::Zipf { alpha_milli: 1200 },
        seed: 42,
    });
    let layout = MemLayout::plan(t.dims(), t.nnz(), t.record_bytes(), rank);
    let plan = ShardPlan::balance(&t, 0, 4);
    let parts = partition_indices(&t, &plan);
    let mut shard_cts = Vec::new();
    let mut shard_accs = 0usize;
    let mut offset = 0usize;
    for (spec, zs) in plan.shards.iter().zip(&parts) {
        let tr = shard_trace(&t, rank, 0, &layout, spec, zs, offset);
        offset += spec.nnz;
        shard_accs += tr.len();
        shard_cts.push(CompressedTrace::compress(&tr));
    }
    let shard_scalar = bench::time(1, iters, || {
        let mut total = 0u64;
        for sct in &shard_cts {
            let cls = GridClassification::classify_with(sct, &configs, ClassifyKernel::Scalar);
            total += cls.hits(0);
        }
        total
    });
    let shard_soa = bench::time(1, iters, || {
        let mut total = 0u64;
        for sct in &shard_cts {
            let cls = GridClassification::classify_with(sct, &configs, ClassifyKernel::Soa);
            total += cls.hits(0);
        }
        total
    });
    let shard_work = (shard_accs * n_cfg) as f64;
    let shard_scalar_rate = shard_work / shard_scalar.mean.as_secs_f64();
    let shard_soa_rate = shard_work / shard_soa.mean.as_secs_f64();
    let shard_speedup = shard_scalar.mean.as_secs_f64() / shard_soa.mean.as_secs_f64();
    println!("shard traces: {shard_accs} accesses x {n_cfg} configs");
    println!("  scalar {shard_scalar_rate:.3e} acc/s, soa {shard_soa_rate:.3e} acc/s");
    println!("  soa speedup: {shard_speedup:.2}x");

    // 3. Cold vs warm repeat explore over the same tensor and context.
    let base = ControllerConfig::default_for(t.record_bytes());
    let dev = Device::alveo_u250();
    let factors: Vec<Mat> = t.dims().iter().map(|&d| Mat::randn(d, rank, 3)).collect();
    let grids = explore_grids();
    let opts = SearchOptions {
        strategy: SearchStrategy::Coordinate,
        top_k: 3,
        resume: false,
        checkpoint_every: 0,
    };
    let cold_eval = EvaluatorBuilder::new().rank(rank).cycle_sim(&t, &factors);
    let t0 = Instant::now();
    let cold = explore_with(&base, &grids, &dev, &cold_eval, &opts);
    let cold_s = t0.elapsed().as_secs_f64();

    let dir = repo_root().join("bench_results").join("warm_cache");
    let key = KeyBuilder::new(tensor_fingerprint(&t))
        .evaluator("cycle")
        .engine(EngineKind::Grid)
        .rank(rank)
        .device(&dev)
        .factors(&factors)
        .finish();
    let _ = std::fs::remove_dir_all(&dir);
    let cache = Arc::new(WarmCache::open(&dir, key));
    let warm = Some(Arc::clone(&cache));
    let eval = EvaluatorBuilder::new().rank(rank).warm_cache(warm).cycle_sim(&t, &factors);
    let first = explore_with(&base, &grids, &dev, &eval, &opts);
    assert_eq!(cold.best.cfg, first.best.cfg);

    let cache2 = Arc::new(WarmCache::open(&dir, key));
    let warm2 = Some(Arc::clone(&cache2));
    let eval2 = EvaluatorBuilder::new().rank(rank).warm_cache(warm2).cycle_sim(&t, &factors);
    let t1 = Instant::now();
    let warm_ex = explore_with(&base, &grids, &dev, &eval2, &opts);
    let warm_s = t1.elapsed().as_secs_f64();
    assert_eq!(cold.best.cfg, warm_ex.best.cfg);
    assert_eq!(cold.best.cycles.to_bits(), warm_ex.best.cycles.to_bits());
    let warm_speedup = cold_s / warm_s;
    let warm_hits = cache2.hits();
    println!("explore: cold {cold_s:.2}s, warm repeat {warm_s:.2}s");
    println!("  warm speedup: {warm_speedup:.2}x ({warm_hits} cache hits)");

    // 4. Disarmed failpoint overhead (the PR 9 robustness claim): one
    //    relaxed atomic load per check, amortized over the block parse
    //    it actually guards — must stay under 1% of the guarded work.
    let checks = sized(20_000_000, 1_000_000) as u32;
    let check_t = bench::time(1, iters, || {
        let mut ok = 0u32;
        for _ in 0..checks {
            if fault::check_io(fault::FROSTT_READ_BLOCK).is_ok() {
                ok += 1;
            }
        }
        bench::black_box(ok)
    });
    let disarmed_check_ns = check_t.mean.as_secs_f64() * 1e9 / f64::from(checks);

    let block_nnz = sized(1 << 18, 1 << 13);
    let mut tns_text = String::new();
    {
        use std::fmt::Write as _;
        let mut rng = Rng::new(0xFA017);
        for _ in 0..block_nnz {
            let _ = writeln!(
                tns_text,
                "{} {} {} 1.0",
                1 + rng.below(512),
                1 + rng.below(384),
                1 + rng.below(256)
            );
        }
    }
    let parse_t = bench::time(1, iters, || {
        let mut r = TnsBlockReader::new(std::io::Cursor::new(tns_text.as_bytes()), block_nnz);
        let mut parsed = 0usize;
        while let Ok(Some(b)) = r.next_block() {
            parsed += b.nnz();
        }
        bench::black_box(parsed)
    });
    // One check guards one block read, so the per-block parse time is
    // the denominator.
    let block_parse_ns = parse_t.mean.as_secs_f64() * 1e9;
    let overhead_pct = disarmed_check_ns / block_parse_ns * 100.0;
    println!("fault check (disarmed): {disarmed_check_ns:.2} ns/check");
    println!(
        "  guarded block parse ({block_nnz} nnz): {:.3e} ns -> overhead {overhead_pct:.6}%",
        block_parse_ns
    );

    let section = format!(
        "{{\n    \"pr\": 8,\n    \"smoke\": {},\n    \
         \"kernel_accesses\": {n},\n    \"grid_configs\": {n_cfg},\n    \
         \"scalar_acc_per_s\": {scalar_rate:.3e},\n    \
         \"soa_acc_per_s\": {soa_rate:.3e},\n    \"soa_speedup\": {soa_speedup:.3},\n    \
         \"shard_accesses\": {shard_accs},\n    \
         \"shard_scalar_acc_per_s\": {shard_scalar_rate:.3e},\n    \
         \"shard_soa_acc_per_s\": {shard_soa_rate:.3e},\n    \
         \"shard_soa_speedup\": {shard_speedup:.3},\n    \
         \"cold_explore_s\": {cold_s:.3},\n    \"warm_explore_s\": {warm_s:.3},\n    \
         \"warm_speedup\": {warm_speedup:.2},\n    \"warm_hits\": {warm_hits}\n  }}",
        smoke(),
    );
    let fault_section = format!(
        "{{\n    \"pr\": 9,\n    \"smoke\": {},\n    \"checks\": {checks},\n    \
         \"disarmed_check_ns\": {disarmed_check_ns:.3},\n    \
         \"block_nnz\": {block_nnz},\n    \"block_parse_ns\": {block_parse_ns:.3e},\n    \
         \"overhead_pct\": {overhead_pct:.6},\n    \"target_pct\": 1.0\n  }}",
        smoke(),
    );
    let bench_path = repo_root().join("BENCH_dse.json");
    match upsert_json_file(&bench_path, "classify_kernel", &section)
        .and_then(|()| upsert_json_file(&bench_path, "fault_overhead", &fault_section))
    {
        Err(e) => eprintln!("warning: failed to update {}: {e}", bench_path.display()),
        Ok(()) => println!("[bench sections written to {}]", bench_path.display()),
    }

    // The fault check must cost under 1% of the work it guards,
    // regardless of smoke mode (the ratio is size-independent).
    if overhead_pct > 1.0 {
        let msg = format!("disarmed fault check above 1% of a block parse: {overhead_pct:.4}%");
        warn_or_enforce(&msg);
    }

    if !smoke() {
        // The PR 8 acceptance claims.  Wall-clock ratios are host noise
        // on loaded machines, so shortfalls warn by default and only
        // fail under PTMC_BENCH_ENFORCE=1.
        if soa_speedup < 1.0 {
            let msg = format!("SoA kernel slower than scalar: {soa_speedup:.2}x");
            warn_or_enforce(&msg);
        }
        if warm_speedup < 3.0 {
            let msg = format!("warm repeat explore below 3x: {warm_speedup:.2}x");
            warn_or_enforce(&msg);
        }
    }
}
